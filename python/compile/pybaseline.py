"""Pure-Python per-cell CA baseline — the CellPyLib cost model, measured.

CellPyLib (Antunes 2021), the paper's Fig. 3 comparator, evaluates a Python
rule function per cell per step. The Rust `automata::*` baselines are far
faster than that (compiled scalar loops), which makes the Rust-reported
speedups conservative. This script measures the *actual* pure-Python
per-cell dispatch cost on this machine — a faithful CellPyLib-role number —
and records it in ``artifacts/py_baseline.json`` for `cax-tables fig3` /
`cargo bench` to report against.

Run by ``make artifacts`` (build time only; never on the request path):

    python -m compile.pybaseline --out-dir ../artifacts
"""

import argparse
import json
import time


def eca_rule30_step(row: list[int]) -> list[int]:
    """One ECA step, CellPyLib-style: a Python function call per cell."""
    w = len(row)

    def rule(left: int, center: int, right: int) -> int:
        # Rule 30 lookup, as a per-cell Python callable (the cost model).
        idx = (left << 2) | (center << 1) | right
        return (30 >> idx) & 1

    return [rule(row[(x - 1) % w], row[x], row[(x + 1) % w])
            for x in range(w)]


def life_step(grid: list[list[int]]) -> list[list[int]]:
    """One Game-of-Life step with a per-cell Python rule call."""
    h, w = len(grid), len(grid[0])

    def rule(alive: int, neighbors: int) -> int:
        return 1 if neighbors == 3 or (alive and neighbors == 2) else 0

    out = [[0] * w for _ in range(h)]
    for y in range(h):
        ym, yp = (y - 1) % h, (y + 1) % h
        for x in range(w):
            xm, xp = (x - 1) % w, (x + 1) % w
            n = (grid[ym][xm] + grid[ym][x] + grid[ym][xp]
                 + grid[y][xm] + grid[y][xp]
                 + grid[yp][xm] + grid[yp][x] + grid[yp][xp])
            out[y][x] = rule(grid[y][x], n)
    return out


def measure_eca(width: int, steps: int) -> float:
    """Cell updates per second of the pure-Python ECA."""
    import random
    random.seed(0)
    row = [random.randint(0, 1) for _ in range(width)]
    t0 = time.perf_counter()
    for _ in range(steps):
        row = eca_rule30_step(row)
    dt = time.perf_counter() - t0
    return width * steps / dt


def measure_life(size: int, steps: int) -> float:
    import random
    random.seed(0)
    grid = [[random.randint(0, 1) for _ in range(size)] for _ in range(size)]
    t0 = time.perf_counter()
    for _ in range(steps):
        grid = life_step(grid)
    dt = time.perf_counter() - t0
    return size * size * steps / dt


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--eca-width", type=int, default=4096)
    ap.add_argument("--eca-steps", type=int, default=40)
    ap.add_argument("--life-size", type=int, default=192)
    ap.add_argument("--life-steps", type=int, default=4)
    args = ap.parse_args()

    eca_ups = measure_eca(args.eca_width, args.eca_steps)
    life_ups = measure_life(args.life_size, args.life_steps)
    report = {
        "description": "pure-Python per-cell baseline (CellPyLib cost "
                       "model), cell updates per second",
        "eca_updates_per_s": eca_ups,
        "life_updates_per_s": life_ups,
        "eca_width": args.eca_width,
        "life_size": args.life_size,
    }
    import os
    path = os.path.join(args.out_dir, "py_baseline.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"eca  {eca_ups:.3e} cell-updates/s (pure Python)")
    print(f"life {life_ups:.3e} cell-updates/s (pure Python)")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
