"""AOT compiler: lower every Layer-2 artifact to HLO text + manifest.

This is the ONLY Python entry point of the build (`make artifacts`). It
lowers each artifact function with ``jax.jit(...).lower(...)``, converts the
StableHLO module to an XlaComputation, and writes **HLO text** — NOT
``.serialize()``: jax >= 0.5 emits protos with 64-bit instruction ids which
the runtime's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs in ``artifacts/``:
- ``<name>.hlo.txt``   — one per artifact
- ``<name>.bin``       — initial parameter / constant blobs (little-endian f32)
- ``manifest.json``    — machine-readable signatures the Rust runtime loads

Usage: ``python -m compile.aot --out-dir ../artifacts [--preset test|paper]
[--only name1,name2]``
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import configs
from compile.models import (arc, autoenc3d, classic, conditional, diffusing,
                            growing, mnist_classify, vae)

_DTYPES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32",
           jnp.uint32.dtype: "u32"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    ``as_hlo_text(True)`` = print_large_constants: the default printer
    ELIDES literals over ~10 elements as ``constant({...})``, which the
    runtime's text parser silently re-parses as ZEROS — wiping perception
    kernels and masks. Guarded here and by tests/test_aot.py.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(True)
    if "constant({...})" in text or "{ ... }" in text:
        raise RuntimeError("HLO text contains elided constants — they would "
                           "silently become zeros at parse time")
    return text


def dtype_name(dt) -> str:
    if dt not in _DTYPES:
        raise ValueError(f"unsupported artifact dtype {dt}")
    return _DTYPES[dt]


def collect_artifacts(preset: str, seed: int = 0) -> list[dict]:
    """All artifact descriptors across every model family."""
    cfgs = configs.get_preset(preset)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 8)
    arts = []
    arts += classic.artifacts(cfgs["classic"])
    arts += growing.artifacts(cfgs["growing"], keys[0])
    arts += conditional.artifacts(cfgs["conditional"], keys[1])
    arts += vae.artifacts(cfgs["vae"], keys[2])
    arts += mnist_classify.artifacts(cfgs["mnist"], keys[3])
    arts += diffusing.artifacts(cfgs["diffusing"], keys[4])
    arts += autoenc3d.artifacts(cfgs["autoenc3d"], keys[5])
    arts += arc.artifacts(cfgs["arc"], keys[6])
    names = [a["name"] for a in arts]
    if len(names) != len(set(names)):
        raise RuntimeError(f"duplicate artifact names: {sorted(names)}")
    return arts


def lower_artifact(art: dict, out_dir: str) -> dict:
    """Lower one artifact; returns its manifest entry."""
    name, fn = art["name"], art["fn"]
    arg_specs = [s for (_, s) in art["args"]]
    t0 = time.time()
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    out_shapes = jax.eval_shape(fn, *arg_specs)
    outputs = [
        {"dtype": dtype_name(o.dtype), "shape": list(o.shape)}
        for o in jax.tree_util.tree_leaves(out_shapes)
    ]
    entry = {
        "name": name,
        "file": fname,
        "inputs": [
            {"name": arg_name, "dtype": dtype_name(s.dtype),
             "shape": list(s.shape)}
            for (arg_name, s) in art["args"]
        ],
        "outputs": outputs,
        "meta": art.get("meta", {}),
    }
    print(f"  {name}: {len(text)} chars, {len(outputs)} outputs, "
          f"{time.time() - t0:.1f}s")
    return entry


def write_blobs(arts: list[dict], out_dir: str) -> list[dict]:
    entries = []
    for art in arts:
        for bname, arr in art.get("blobs", {}).items():
            arr = np.asarray(arr, dtype=np.float32)
            fname = f"{bname}.bin"
            arr.astype("<f4").tofile(os.path.join(out_dir, fname))
            entries.append({"name": bname, "file": fname, "dtype": "f32",
                            "shape": list(arr.shape)})
            print(f"  blob {bname}: shape {list(arr.shape)}")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="test", choices=["test", "paper"])
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names to (re)lower")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    print(f"collecting artifacts (preset={args.preset}) ...")
    arts = collect_artifacts(args.preset, args.seed)
    if args.only:
        keep = set(args.only.split(","))
        missing = keep - {a["name"] for a in arts}
        if missing:
            raise SystemExit(f"unknown artifact(s): {sorted(missing)}")
        arts = [a for a in arts if a["name"] in keep]

    print(f"lowering {len(arts)} artifacts ...")
    entries = [lower_artifact(a, args.out_dir) for a in arts]
    blob_entries = write_blobs(arts, args.out_dir)

    if args.only:
        # Partial rebuild: merge into the existing manifest (replace the
        # re-lowered names, keep everything else).
        man_path = os.path.join(args.out_dir, "manifest.json")
        if os.path.exists(man_path):
            with open(man_path) as f:
                old = json.load(f)
            new_names = {e["name"] for e in entries}
            entries = [e for e in old.get("artifacts", [])
                       if e["name"] not in new_names] + entries
            new_blobs = {e["name"] for e in blob_entries}
            blob_entries = [e for e in old.get("blobs", [])
                            if e["name"] not in new_blobs] + blob_entries

    manifest = {
        "preset": args.preset,
        "seed": args.seed,
        "jax_version": jax.__version__,
        "artifacts": entries,
        "blobs": blob_entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} artifacts + {len(blob_entries)} blobs + "
          f"manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
