"""Pallas kernel: Lenia neighbourhood convolution + growth update.

Layer-1 hot-spot for the continuous CA (paper Table 1 row 3). Lenia's local
rule is ``A' = clip(A + dt * G(K * A), 0, 1)`` where K is a smooth ring
kernel of radius R and G a Gaussian-bump growth mapping (Chan 2019).

The Pallas kernel implements the *direct* convolution (tap-accumulate over
the (2R+1)^2 stencil) — the form a TPU would tile through VMEM. The L2 model
(``models/lenia.py``) uses the mathematically identical FFT path for large
grids; both are validated against ``ref.lenia_step_ref`` and against each
other in pytest.

``interpret=True``: see eca.py.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _lenia_kernel(state_ref, kernel_ref, out_ref, *, mu: float, sigma: float,
                  dt: float, radius: int):
    """Program body: one board. state_ref: f32[1, H, W]."""
    board = state_ref[0, :, :]
    kern = kernel_ref[...]
    u = jnp.zeros_like(board)
    ksz = 2 * radius + 1
    for ky in range(ksz):
        for kx in range(ksz):
            u = u + kern[ky, kx] * jnp.roll(
                board, (radius - ky, radius - kx), axis=(0, 1)
            )
    growth = 2.0 * jnp.exp(-0.5 * ((u - mu) / sigma) ** 2) - 1.0
    out_ref[0, :, :] = jnp.clip(board + dt * growth, 0.0, 1.0)


@functools.partial(jax.jit, static_argnames=("mu", "sigma", "dt", "radius"))
def lenia_step(state: jnp.ndarray, kernel: jnp.ndarray, *, mu: float,
               sigma: float, dt: float, radius: int) -> jnp.ndarray:
    """One Lenia step via the Pallas direct-convolution kernel.

    Args:
        state: f32[B, H, W] in [0, 1].
        kernel: f32[2R+1, 2R+1] ring kernel, normalized to sum 1.
        mu, sigma: growth-bump centre/width.
        dt: integration step.
        radius: R (static; must match kernel shape).

    Returns:
        f32[B, H, W] next state.
    """
    b, h, w = state.shape
    ksz = 2 * radius + 1
    if kernel.shape != (ksz, ksz):
        raise ValueError(f"kernel shape {kernel.shape} != ({ksz}, {ksz})")
    return pl.pallas_call(
        functools.partial(_lenia_kernel, mu=mu, sigma=sigma, dt=dt,
                          radius=radius),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((ksz, ksz), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), state.dtype),
        interpret=True,
    )(state, kernel)


def ring_kernel(radius: int) -> np.ndarray:
    """The standard Lenia ring kernel: exp bump over normalized radius.

    K(r) = exp(4 - 1 / (r * (1 - r)))   for 0 < r < 1, else 0,
    normalized to sum 1. (Chan 2019, "Lenia — Biology of Artificial Life".)

    Returns:
        f32[2*radius+1, 2*radius+1], sum == 1.
    """
    y, x = np.mgrid[-radius : radius + 1, -radius : radius + 1]
    r = np.sqrt(x * x + y * y) / radius
    with np.errstate(divide="ignore", over="ignore", invalid="ignore"):
        k = np.where(
            (r > 0) & (r < 1), np.exp(4.0 - 1.0 / np.maximum(r * (1 - r), 1e-9)), 0.0
        )
    k = k / k.sum()
    return k.astype(np.float32)
