"""Pallas kernel: one Conway's Game of Life step (Moore neighbourhood, wrap).

Layer-1 hot-spot for the 2D discrete CA (paper Table 1 row 2, Fig. 3 left).
Gridded over the batch: each program owns one full H x W board. At the paper's
benchmark scale (128 x 128) a board is 64 KiB f32 — comfortably inside a TPU
core's ~16 MiB VMEM with room for the 8 shifted copies; larger boards would
tile rows with a 1-row halo exchanged via two extra block rows (DESIGN.md §5).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _life_kernel(state_ref, out_ref):
    """Program body: one board. state_ref: f32[1, H, W]."""
    board = state_ref[0, :, :]
    n = jnp.zeros_like(board)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == 0 and dx == 0:
                continue
            n = n + jnp.roll(board, (dy, dx), axis=(0, 1))
    birth = (board == 0.0) & (n == 3.0)
    survive = (board == 1.0) & ((n == 2.0) | (n == 3.0))
    out_ref[0, :, :] = jnp.where(birth | survive, 1.0, 0.0)


@functools.partial(jax.jit, static_argnames=())
def life_step(state: jnp.ndarray) -> jnp.ndarray:
    """One Game of Life step via the Pallas kernel.

    Args:
        state: f32[B, H, W] of {0., 1.}.

    Returns:
        f32[B, H, W] next state.
    """
    b, h, w = state.shape
    return pl.pallas_call(
        _life_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), state.dtype),
        interpret=True,
    )(state)
