"""Pallas kernel: depthwise 3x3 perception convolution — THE NCA hot-spot.

This is the CAX ``DepthwiseConvPerceive`` module (paper §3.1.1): every neural
CA in the paper perceives its neighbourhood by convolving each state channel
with K fixed or learned 3x3 kernels (identity + Sobel-x + Sobel-y [+
Laplacian]) and feeding the concatenated K*C features to the update MLP.

The kernel is gridded over row-tiles: each program owns ``block_h`` rows of
the (periodically padded) grid plus a one-row halo on each side, all channels.
VMEM per program ~= (block_h + 2) * W * C * 4 bytes in + block_h * W * C * K
out; at paper scale (72 x 72 x 16, K=4) a 8-row tile is ~82 KiB — deep inside
VMEM, leaving the MXU free to chew on the update MLP that consumes this
output (DESIGN.md §5).

``interpret=True``: see eca.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dwconv_kernel(padded_ref, kernels_ref, out_ref, *, block_h: int):
    """Program body: one row-tile.

    padded_ref: f32[1, block_h + 2, W + 2, C] — input tile with halo.
    kernels_ref: f32[3, 3, K].
    out_ref: f32[1, block_h, W, C*K].
    """
    tile = padded_ref[0, ...]
    kern = kernels_ref[...]
    _, wp, c = tile.shape
    k = kern.shape[-1]
    w = wp - 2
    acc = jnp.zeros((block_h, w, c, k), dtype=tile.dtype)
    for ky in range(3):
        for kx in range(3):
            win = tile[ky : ky + block_h, kx : kx + w, :]
            acc = acc + win[..., None] * kern[ky, kx][None, None, None, :]
    out_ref[0, ...] = acc.reshape(block_h, w, c * k)


def _pick_block_h(h: int) -> int:
    """Largest divisor of h that is <= 8 (keeps tiles VMEM-sized)."""
    for cand in (8, 6, 4, 3, 2, 1):
        if h % cand == 0:
            return cand
    return 1


@jax.custom_vjp
def dwconv(state: jnp.ndarray, kernels: jnp.ndarray) -> jnp.ndarray:
    """Depthwise 3x3 perception via the Pallas kernel (periodic padding).

    Differentiable: interpret-mode ``pallas_call`` has no reverse-mode rule,
    so ``dwconv`` carries a ``custom_vjp`` whose backward pass is *also* the
    Pallas kernel — d/dstate of a periodic depthwise convolution is the same
    convolution with spatially flipped kernels, summed over K; d/dkernels is
    a small correlation reduction done in jnp.

    Args:
        state: f32[H, W, C].
        kernels: f32[3, 3, K].

    Returns:
        f32[H, W, C*K]; output channel ``c*K + k`` = kernel k on channel c.
    """
    return _dwconv_impl(state, kernels)


def _dwconv_fwd(state, kernels):
    return _dwconv_impl(state, kernels), (state, kernels)


def _dwconv_bwd(res, g):
    state, kernels = res
    h, w, c = state.shape
    k = kernels.shape[-1]
    g4 = g.reshape(h, w, c, k)
    flipped = kernels[::-1, ::-1, :]  # f32[3, 3, K]
    # dstate[., ., c] = sum_k conv(g[., ., c, k], flip(kern_k)) — one Pallas
    # dwconv per perception kernel with K=1.
    dstate = jnp.zeros_like(state)
    for kk in range(k):
        dstate = dstate + _dwconv_impl(g4[..., kk], flipped[..., kk : kk + 1])
    # dkern[ky, kx, k] = sum_{y,x,c} state[y+ky-1, x+kx-1, c] * g4[y, x, c, k]
    dkern = jnp.zeros_like(kernels)
    for ky in range(3):
        for kx in range(3):
            shifted = jnp.roll(state, (1 - ky, 1 - kx), axis=(0, 1))
            dkern = dkern.at[ky, kx].set(
                jnp.einsum("yxc,yxck->k", shifted, g4)
            )
    return dstate, dkern


def _dwconv_impl(state: jnp.ndarray, kernels: jnp.ndarray) -> jnp.ndarray:
    """Forward implementation (see ``dwconv``)."""
    h, w, c = state.shape
    k = kernels.shape[-1]
    block_h = _pick_block_h(h)

    # Periodic halo. Rows need halo across tiles, so we pad by 1 everywhere
    # and hand each program an overlapping (block_h + 2)-row window. Overlap
    # is expressed by element-indexed maps (Pallas blocks are element-strided
    # through index_map * block_shape, so we use a stride-block_h map over a
    # (block_h + 2)-row block via explicit dynamic slicing of a padded array).
    padded = jnp.pad(state, ((1, 1), (1, 1), (0, 0)), mode="wrap")

    # Pallas block starts are block-shape-strided, which cannot express the
    # 2-row overlap directly; instead we pre-gather the overlapping windows
    # into a [num_tiles, block_h + 2, W + 2, C] array and grid over tiles.
    grid = (h // block_h,)
    num_tiles = h // block_h
    starts = jnp.arange(num_tiles) * block_h
    windows = jax.vmap(
        lambda s: jax.lax.dynamic_slice(
            padded, (s, 0, 0), (block_h + 2, w + 2, c)
        )
    )(starts)

    out = pl.pallas_call(
        functools.partial(_dwconv_kernel, block_h=block_h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_h + 2, w + 2, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, k), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_h, w, c * k), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles, block_h, w, c * k), state.dtype),
        interpret=True,
    )(windows, kernels)
    return out.reshape(h, w, c * k)


def perception_kernels(num_kernels: int) -> jnp.ndarray:
    """The canonical NCA perception stack: identity, Sobel-x, Sobel-y, Laplacian.

    Args:
        num_kernels: 1..4 — how many of the stack to take.

    Returns:
        f32[3, 3, num_kernels].
    """
    ident = jnp.array([[0, 0, 0], [0, 1, 0], [0, 0, 0]], dtype=jnp.float32)
    sobel_x = jnp.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], dtype=jnp.float32) / 8.0
    sobel_y = sobel_x.T
    lap = jnp.array([[1, 2, 1], [2, -12, 2], [1, 2, 1]], dtype=jnp.float32) / 16.0
    stack = jnp.stack([ident, sobel_x, sobel_y, lap], axis=-1)
    if not 1 <= num_kernels <= 4:
        raise ValueError(f"num_kernels must be in [1, 4], got {num_kernels}")
    return stack[..., :num_kernels]


dwconv.defvjp(_dwconv_fwd, _dwconv_bwd)
