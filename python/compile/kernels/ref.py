"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal of Layer 1: each Pallas kernel in
``dwconv.py`` / ``eca.py`` / ``life.py`` / ``lenia.py`` must agree with the
corresponding function here (exactly for the discrete CAs, to float tolerance
for the continuous ones). pytest + hypothesis sweep shapes, rules and random
states against these references.

All references use **periodic (wrap) boundary conditions**, matching both the
paper's implementations and the Rust naive simulators.
"""

import jax.numpy as jnp


def eca_step_ref(state: jnp.ndarray, rule: jnp.ndarray) -> jnp.ndarray:
    """One elementary-CA step.

    Args:
        state: f32[B, W] of {0., 1.}.
        rule: f32[8] — Wolfram rule table; ``rule[i]`` is the output for the
            neighbourhood pattern with value ``i = 4*left + 2*center + right``.

    Returns:
        f32[B, W] next state.
    """
    left = jnp.roll(state, 1, axis=-1)
    right = jnp.roll(state, -1, axis=-1)
    idx = (4.0 * left + 2.0 * state + right).astype(jnp.int32)
    return jnp.take(rule, idx)


def life_step_ref(state: jnp.ndarray) -> jnp.ndarray:
    """One Conway's Game of Life step (Moore neighbourhood, wrap).

    Args:
        state: f32[B, H, W] of {0., 1.}.

    Returns:
        f32[B, H, W] next state.
    """
    n = jnp.zeros_like(state)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == 0 and dx == 0:
                continue
            n = n + jnp.roll(state, (dy, dx), axis=(-2, -1))
    birth = (state == 0.0) & (n == 3.0)
    survive = (state == 1.0) & ((n == 2.0) | (n == 3.0))
    return jnp.where(birth | survive, 1.0, 0.0)


def dwconv_ref(state: jnp.ndarray, kernels: jnp.ndarray) -> jnp.ndarray:
    """Depthwise 3x3 perception convolution (NCA perceive module).

    Applies each of the K 3x3 kernels to every channel independently
    (periodic padding), concatenating along the channel axis — exactly the
    CAX ``DepthwiseConvPerceive`` with ``num_kernels = K``.

    Args:
        state: f32[H, W, C].
        kernels: f32[3, 3, K].

    Returns:
        f32[H, W, C*K] perception; output channel ``c*K + k`` is kernel k
        applied to input channel c.
    """
    h, w, c = state.shape
    k = kernels.shape[-1]
    out = jnp.zeros((h, w, c * k), dtype=state.dtype)
    for ky in range(3):
        for kx in range(3):
            # shifted[y, x, c] == state[y + ky - 1, x + kx - 1, c] (wrapped)
            shifted = jnp.roll(state, (1 - ky, 1 - kx), axis=(0, 1))
            contrib = shifted[:, :, :, None] * kernels[ky, kx][None, None, None, :]
            out = out + contrib.reshape(h, w, c * k)
    return out


def lenia_conv_ref(state: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """Direct (non-FFT) periodic convolution with a (2R+1)^2 kernel.

    Args:
        state: f32[H, W].
        kernel: f32[2R+1, 2R+1], already normalized to sum 1.

    Returns:
        f32[H, W] neighbourhood potential U.
    """
    ksz = kernel.shape[0]
    r = ksz // 2
    out = jnp.zeros_like(state)
    for ky in range(ksz):
        for kx in range(ksz):
            out = out + kernel[ky, kx] * jnp.roll(
                state, (r - ky, r - kx), axis=(0, 1)
            )
    return out


def lenia_growth_ref(u: jnp.ndarray, mu: float, sigma: float) -> jnp.ndarray:
    """Lenia exponential growth mapping G(u) = 2*exp(-((u-mu)/sigma)^2/2) - 1."""
    return 2.0 * jnp.exp(-0.5 * ((u - mu) / sigma) ** 2) - 1.0


def lenia_step_ref(state, kernel, mu, sigma, dt):
    """One Lenia step: clip(A + dt * G(K*A), 0, 1)."""
    u = lenia_conv_ref(state, kernel)
    return jnp.clip(state + dt * lenia_growth_ref(u, mu, sigma), 0.0, 1.0)


def lenia_fft_conv_ref(state: jnp.ndarray, kernel_fft: jnp.ndarray) -> jnp.ndarray:
    """FFT-based periodic convolution (the L2 fast path for Lenia).

    Args:
        state: f32[H, W].
        kernel_fft: c64[H, W] — FFT of the kernel already centred at (0, 0)
            (i.e. ``jnp.fft.fft2(jnp.fft.ifftshift(padded_kernel))``).
    """
    return jnp.real(jnp.fft.ifft2(jnp.fft.fft2(state) * kernel_fft))


def nca_update_mlp_ref(perception, w1, b1, w2, b2):
    """The NCA update MLP applied per cell: relu(p @ w1 + b1) @ w2 + b2.

    Args:
        perception: f32[..., P].
        w1: f32[P, H]; b1: f32[H]; w2: f32[H, C]; b2: f32[C].

    Returns:
        f32[..., C] residual update (before stochastic cell dropout).
    """
    h = jnp.maximum(perception @ w1 + b1, 0.0)
    return h @ w2 + b2
