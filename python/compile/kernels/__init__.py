"""Layer 1 — Pallas kernels for the CA compute hot-spots.

Each kernel ships with a pure-jnp oracle in ``ref.py``; pytest + hypothesis
enforce agreement. All kernels run ``interpret=True`` (CPU-PJRT constraint,
see DESIGN.md §5).
"""

from compile.kernels.dwconv import dwconv, perception_kernels
from compile.kernels.eca import eca_step, rule_to_table
from compile.kernels.life import life_step
from compile.kernels.lenia import lenia_step, ring_kernel

__all__ = [
    "dwconv", "perception_kernels", "eca_step", "rule_to_table",
    "life_step", "lenia_step", "ring_kernel",
]
