"""Pallas kernel: one elementary-cellular-automaton step.

Layer-1 hot-spot for the 1D discrete CAs (paper Table 1 row 1, Fig. 3 left).
The kernel is gridded over the batch dimension: each program instance owns one
full row of cells (rows are small enough to fit VMEM comfortably — W*4 bytes;
at the paper's benchmark scale W=1024 that is 4 KiB in, 4 KiB out, plus the
32 B rule table).

``interpret=True`` is mandatory: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO ops that travel through
the HLO-text AOT bridge unchanged.

On a real TPU the natural adaptation keeps the same BlockSpec (one row per
program) but pads W up to lane multiples (128); the rule gather becomes an
8-way select to stay on the VPU. See DESIGN.md §5.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _eca_kernel(state_ref, rule_ref, out_ref):
    """Program body: one batch row. state_ref: f32[1, W]; rule_ref: f32[8]."""
    row = state_ref[0, :]
    left = jnp.roll(row, 1)
    right = jnp.roll(row, -1)
    idx = (4.0 * left + 2.0 * row + right).astype(jnp.int32)
    # Rule gather as an 8-way masked sum: VPU-friendly (no dynamic gather),
    # and exact because idx is one-hot over 0..7.
    out = jnp.zeros_like(row)
    for pattern in range(8):
        out = out + jnp.where(idx == pattern, rule_ref[pattern], 0.0)
    out_ref[0, :] = out


@functools.partial(jax.jit, static_argnames=())
def eca_step(state: jnp.ndarray, rule: jnp.ndarray) -> jnp.ndarray:
    """One ECA step via the Pallas kernel.

    Args:
        state: f32[B, W] of {0., 1.}.
        rule: f32[8] Wolfram rule table (index = 4*left + 2*center + right).

    Returns:
        f32[B, W] next state.
    """
    b, w = state.shape
    return pl.pallas_call(
        _eca_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i: (i, 0)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, w), state.dtype),
        interpret=True,
    )(state, rule)


def rule_to_table(rule_number: int) -> jnp.ndarray:
    """Wolfram rule number -> f32[8] table (bit i of the number = table[i])."""
    if not 0 <= rule_number <= 255:
        raise ValueError(f"rule number must be in [0, 255], got {rule_number}")
    return jnp.array(
        [(rule_number >> i) & 1 for i in range(8)], dtype=jnp.float32
    )
