"""Growing Conditional NCA (Sudhakaran et al. 2022) — Table 1 row 5.

The controllable-CA instantiation (paper §2.2): a goal one-hot vector is
broadcast to every cell as an external input at every step, and a single rule
grows a *different* target sprite per goal from the same seed.

Artifacts: ``conditional_train_step``, ``conditional_grow`` (final state for
a given goal).
"""

import jax
import jax.numpy as jnp

from compile.models import common, nca
from compile.models.growing import seed_state


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def init_params(key, cfg):
    kernels = nca.default_kernels_2d(3)
    ng = cfg.extra["num_goals"]
    perc = cfg.channels * kernels.shape[-1] + ng  # + goal input per cell
    return {"update": nca.init_update_params(key, perc, cfg.hidden,
                                             cfg.channels)}


def _step(params, state, key, goals1h, cfg):
    b, h, w, _ = state.shape
    ng = goals1h.shape[-1]
    ext = jnp.broadcast_to(goals1h[:, None, None, :], (b, h, w, ng))
    return nca.nca_step_2d(
        params["update"], state, key, kernels=nca.default_kernels_2d(3),
        dropout=cfg.dropout, alive_masking=True, ext_input=ext,
    )


def artifacts(cfg, key) -> list[dict]:
    h, w, c, b, t = cfg.height, cfg.width, cfg.channels, cfg.batch, cfg.steps
    ng = cfg.extra["num_goals"]
    params = init_params(key, cfg)
    params_flat, unravel = common.flatten_params(params)
    n = params_flat.shape[0]

    def loss_fn(p, targets, goals1h, key):
        # targets: [K, H, W, 4]; goals1h: [B, K] — sample b grows target
        # argmax(goals1h[b]).
        state = jnp.broadcast_to(seed_state(h, w, c)[None],
                                 (b, h, w, c))

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), goals1h, cfg)
            return st, None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        per_goal_target = goals1h @ targets.reshape(ng, -1)
        per_goal_target = per_goal_target.reshape(b, h, w, 4)
        loss = jnp.mean(jnp.square(fin[..., :4] - per_goal_target))
        return loss, ()

    train_step = common.make_train_step(loss_fn, unravel, cfg)

    def grow(pf, goal1h, seed):
        p = unravel(pf)
        key = jax.random.PRNGKey(seed)
        state = seed_state(h, w, c)[None]

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), goal1h[None],
                       cfg)
            return st, None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        return (fin[0],)

    meta = {"kind": "nca", "ca": "conditional", "height": h, "width": w,
            "channels": c, "batch": b, "steps": t, "hidden": cfg.hidden,
            "num_goals": ng, "param_count": int(n)}
    return [
        dict(name="conditional_train_step", fn=train_step,
             args=[("params", spec(n)), ("m", spec(n)), ("v", spec(n)),
                   ("step", spec(dtype=jnp.int32)),
                   ("targets", spec(ng, h, w, 4)),
                   ("goals1h", spec(b, ng)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta, blobs={"conditional_params": params_flat}),
        dict(name="conditional_grow", fn=grow,
             args=[("params", spec(n)), ("goal1h", spec(ng)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta),
    ]
