"""Growing Unsupervised NCA — Variational Neural Cellular Automata
(Palm et al. 2021) — Table 1 row 6.

A dense VAE encoder maps a digit image to a latent code; the code is planted
in the hidden channels of the centre seed cell; the NCA decodes by *growing*
the reconstruction in channel 0. ELBO = reconstruction BCE + KL. This is the
paper's §3.2.2 "variational autoencoder implementation" utility exercised
end-to-end.

Artifacts: ``vae_train_step``, ``vae_reconstruct``.
"""

import jax
import jax.numpy as jnp

from compile.models import common, nca


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def init_params(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    kernels = nca.default_kernels_2d(3)
    perc = cfg.channels * kernels.shape[-1]
    hw = cfg.height * cfg.width
    latent = cfg.extra["latent"]
    enc_h = cfg.extra["enc_hidden"]
    return {
        "enc1": common.dense_init(k1, hw, enc_h),
        "enc_mu": common.dense_init(k2, enc_h, latent, scale=0.01),
        "enc_logvar": common.dense_init(k3, enc_h, latent, scale=0.01),
        "update": nca.init_update_params(k4, perc, cfg.hidden, cfg.channels),
    }


def encode(params, digits):
    """digits [B, H, W] -> (mu, logvar) [B, L]."""
    b = digits.shape[0]
    hidden = jnp.tanh(common.dense(params["enc1"], digits.reshape(b, -1)))
    return (common.dense(params["enc_mu"], hidden),
            common.dense(params["enc_logvar"], hidden))


def seed_from_latent(z, h, w, c):
    """Latent planted in the centre cell's trailing channels; alpha-ish
    channel 1 set to 1 so the update has signal to propagate."""
    b, latent = z.shape
    state = jnp.zeros((b, h, w, c), dtype=jnp.float32)
    state = state.at[:, h // 2, w // 2, 1].set(1.0)
    state = state.at[:, h // 2, w // 2, c - latent:].set(z)
    return state


def _step(params, state, key, cfg):
    return nca.nca_step_2d(
        params["update"], state, key, kernels=nca.default_kernels_2d(3),
        dropout=cfg.dropout, alive_masking=False,
    )


def artifacts(cfg, key) -> list[dict]:
    h, w, c, b, t = cfg.height, cfg.width, cfg.channels, cfg.batch, cfg.steps
    latent = cfg.extra["latent"]
    klw = cfg.extra["kl_weight"]
    params = init_params(key, cfg)
    params_flat, unravel = common.flatten_params(params)
    n = params_flat.shape[0]

    def decode_rollout(p, z, key):
        state = seed_from_latent(z, h, w, c)

        def body(carry, i):
            return _step(p, carry, jax.random.fold_in(key, i), cfg), None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        return fin

    def loss_fn(p, digits, key):
        zkey, rkey = jax.random.split(key)
        mu, logvar = encode(p, digits)
        eps = jax.random.normal(zkey, mu.shape)
        z = mu + jnp.exp(0.5 * logvar) * eps
        fin = decode_rollout(p, z, rkey)
        recon = jax.nn.sigmoid(fin[..., 0])
        bce = -jnp.mean(
            digits * jnp.log(recon + 1e-7)
            + (1.0 - digits) * jnp.log(1.0 - recon + 1e-7)
        )
        kl = -0.5 * jnp.mean(1.0 + logvar - mu**2 - jnp.exp(logvar))
        return bce + klw * kl, (bce, kl)

    train_step = common.make_train_step(loss_fn, unravel, cfg)

    def reconstruct(pf, digits, seed):
        p = unravel(pf)
        key = jax.random.PRNGKey(seed)
        mu, _ = encode(p, digits)
        fin = decode_rollout(p, mu, key)
        return (jax.nn.sigmoid(fin[..., 0]),)

    meta = {"kind": "nca", "ca": "vae", "height": h, "width": w,
            "channels": c, "batch": b, "steps": t, "hidden": cfg.hidden,
            "latent": latent, "param_count": int(n)}
    return [
        dict(name="vae_train_step", fn=train_step,
             args=[("params", spec(n)), ("m", spec(n)), ("v", spec(n)),
                   ("step", spec(dtype=jnp.int32)),
                   ("digits", spec(b, h, w)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta, blobs={"vae_params": params_flat}),
        dict(name="vae_reconstruct", fn=reconstruct,
             args=[("params", spec(n)), ("digits", spec(b, h, w)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta),
    ]
