"""Self-autoencoding MNIST digits in 3D (paper §5.2, Fig. 6 & 7).

A 3D NCA with the digit written (frozen) on the z=0 face, a wall of
non-updatable cells at z=D/2 with a single-cell hole in its centre, and a
reconstruction objective on the z=D-1 face. The identical per-cell rule must
learn to *encode* the digit, squeeze the code through the one-cell channel,
and *decode* it on the far side.

Artifacts: ``autoenc3d_train_step``, ``autoenc3d_eval`` (reconstructed face).
"""

import jax
import jax.numpy as jnp

from compile.models import common, nca


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def init_params(key, cfg):
    perc = cfg.channels * 4  # identity + 3 axis gradients (perceive3d)
    return {"update": nca.init_update_params(key, perc, cfg.hidden,
                                             cfg.channels)}


def wall_mask(d: int, h: int, w: int) -> jnp.ndarray:
    """f32[D, H, W, 1]: 1 where cells may update, 0 on the wall (z = D/2)
    except a single-cell hole at the face centre."""
    mask = jnp.ones((d, h, w), dtype=jnp.float32)
    mask = mask.at[d // 2].set(0.0)
    mask = mask.at[d // 2, h // 2, w // 2].set(1.0)
    return mask[..., None]


def input_freeze(digits, d, c):
    """Frozen mask + initial state: digit intensity on face z=0, channel 0."""
    b, h, w = digits.shape
    state = jnp.zeros((b, d, h, w, c), dtype=jnp.float32)
    state = state.at[:, 0, :, :, 0].set(digits)
    frozen = jnp.zeros((b, d, h, w, c), dtype=jnp.float32)
    frozen = frozen.at[:, 0, :, :, 0].set(1.0)
    return state, frozen


def _step(params, state, key, frozen, mask, cfg):
    return nca.nca_step_3d(
        params["update"], state, key, dropout=cfg.dropout,
        frozen=frozen, update_mask=mask,
    )


def artifacts(cfg, key) -> list[dict]:
    d, h, w = cfg.depth, cfg.height, cfg.width
    c, b, t = cfg.channels, cfg.batch, cfg.steps
    params = init_params(key, cfg)
    params_flat, unravel = common.flatten_params(params)
    n = params_flat.shape[0]
    mask = wall_mask(d, h, w)

    def loss_fn(p, digits, key):
        state, frozen = input_freeze(digits, d, c)

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), frozen, mask,
                       cfg)
            return st, None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        recon = fin[:, d - 1, :, :, 0]
        loss = jnp.mean(jnp.square(recon - digits))
        return loss, ()

    train_step = common.make_train_step(loss_fn, unravel, cfg)

    def eval_fn(pf, digits, seed):
        p = unravel(pf)
        key = jax.random.PRNGKey(seed)
        state, frozen = input_freeze(digits, d, c)

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), frozen, mask,
                       cfg)
            return st, None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        return (fin[:, d - 1, :, :, 0],)

    meta = {"kind": "nca", "ca": "autoenc3d", "depth": d, "height": h,
            "width": w, "channels": c, "batch": b, "steps": t,
            "hidden": cfg.hidden, "param_count": int(n)}
    return [
        dict(name="autoenc3d_train_step", fn=train_step,
             args=[("params", spec(n)), ("m", spec(n)), ("v", spec(n)),
                   ("step", spec(dtype=jnp.int32)),
                   ("digits", spec(b, h, w)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta, blobs={"autoenc3d_params": params_flat}),
        dict(name="autoenc3d_eval", fn=eval_fn,
             args=[("params", spec(n)), ("digits", spec(b, h, w)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta),
    ]
