"""Diffusing Neural Cellular Automata (paper §5.1, Fig. 4 & 5).

Instead of growing from a seed with a sample pool, the NCA learns to *denoise*:
the RGBA part of the state is initialized to a convex mixture of the target
and pure noise (per-sample noise level ~ U[lo, hi], hi = 1 covering the
pure-noise start of Fig. 4), then rolled out for a fixed number of steps and
trained with MSE to the target. No pool, no alive-masking — the paper's
point is that this objective builds a wide attractor basin around the target
(hence the emergent regeneration of Fig. 5, which the Rust ``damage``
protocol probes by cutting a region and re-rolling out).

Artifacts: ``diffusing_train_step``, ``diffusing_rollout`` (trajectory).
"""

import jax
import jax.numpy as jnp

from compile.models import common, nca


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def init_params(key, cfg):
    kernels = nca.default_kernels_2d(3)
    perc = cfg.channels * kernels.shape[-1]
    return {"update": nca.init_update_params(key, perc, cfg.hidden,
                                             cfg.channels)}


def _step(params, state, key, cfg):
    return nca.nca_step_2d(
        params["update"], state, key, kernels=nca.default_kernels_2d(3),
        dropout=cfg.dropout, alive_masking=False,
    )


def noisy_init(key, target, b, h, w, c, lo, hi):
    """Per-sample noise level in [lo, hi]; RGBA = mix(target, noise)."""
    lkey, nkey = jax.random.split(key)
    levels = jax.random.uniform(lkey, (b, 1, 1, 1), minval=lo, maxval=hi)
    noise = jax.random.uniform(nkey, (b, h, w, 4))
    rgba = (1.0 - levels) * target[None] + levels * noise
    state = jnp.zeros((b, h, w, c), dtype=jnp.float32)
    return state.at[..., :4].set(rgba)


def artifacts(cfg, key) -> list[dict]:
    h, w, c, b, t = cfg.height, cfg.width, cfg.channels, cfg.batch, cfg.steps
    lo, hi = cfg.extra["noise_lo"], cfg.extra["noise_hi"]
    params = init_params(key, cfg)
    params_flat, unravel = common.flatten_params(params)
    n = params_flat.shape[0]

    def loss_fn(p, target, key):
        ikey, rkey = jax.random.split(key)
        state = noisy_init(ikey, target, b, h, w, c, lo, hi)

        def body(carry, i):
            return _step(p, carry, jax.random.fold_in(rkey, i), cfg), None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        loss = jnp.mean(jnp.square(fin[..., :4] - target[None]))
        return loss, ()

    train_step = common.make_train_step(loss_fn, unravel, cfg)

    def rollout(pf, state, seed):
        p = unravel(pf)
        key = jax.random.PRNGKey(seed)

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), cfg)
            return st, st

        final, traj = jax.lax.scan(body, state[None], jnp.arange(t))
        return final[0], traj[:, 0]

    meta = {"kind": "nca", "ca": "diffusing", "height": h, "width": w,
            "channels": c, "batch": b, "steps": t, "hidden": cfg.hidden,
            "noise_lo": lo, "noise_hi": hi, "param_count": int(n)}
    return [
        dict(name="diffusing_train_step", fn=train_step,
             args=[("params", spec(n)), ("m", spec(n)), ("v", spec(n)),
                   ("step", spec(dtype=jnp.int32)),
                   ("target", spec(h, w, 4)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta, blobs={"diffusing_params": params_flat}),
        dict(name="diffusing_rollout", fn=rollout,
             args=[("params", spec(n)), ("state", spec(h, w, c)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta),
    ]
