"""The CAX modular NCA core: perceive -> update (paper §3.1).

Mirrors the paper's two-component local rule:

- **perceive**: depthwise convolution of every channel with K fixed kernels
  (identity + gradients [+ Laplacian]). The 2D path calls the Layer-1 Pallas
  kernel (``kernels.dwconv``) so it lowers into the same HLO as the rest of
  the graph; the 1D and 3D paths are jnp roll-based (same math, dimensions
  the Pallas kernel doesn't cover — see DESIGN.md §4.1).
- **update**: a per-cell MLP producing a residual update, gated by stochastic
  per-cell dropout, optionally with alive-masking (growing models) and an
  external per-cell input (controllable CA, paper §2.2).

All state layouts are channel-last: [B, W, C] (1D), [B, H, W, C] (2D),
[B, D, H, W, C] (3D).
"""

import jax
import jax.numpy as jnp

from compile.kernels import dwconv, perception_kernels
from compile.models import common


# --------------------------------------------------------------------------
# Perceive
# --------------------------------------------------------------------------

def perceive2d(state: jnp.ndarray, kernels: jnp.ndarray) -> jnp.ndarray:
    """Batched 2D perception via the Pallas dwconv kernel.

    Args:
        state: f32[B, H, W, C]; kernels: f32[3, 3, K].

    Returns:
        f32[B, H, W, C*K].
    """
    return jax.vmap(lambda s: dwconv(s, kernels))(state)


def perception_kernels_1d(num_kernels: int = 2) -> jnp.ndarray:
    """1D stack: identity, central gradient[, second difference]. f32[3, K]."""
    ident = jnp.array([0.0, 1.0, 0.0])
    grad = jnp.array([-0.5, 0.0, 0.5])
    lap = jnp.array([1.0, -2.0, 1.0])
    stack = jnp.stack([ident, grad, lap], axis=-1).astype(jnp.float32)
    return stack[:, :num_kernels]


def perceive1d(state: jnp.ndarray, kernels: jnp.ndarray) -> jnp.ndarray:
    """Batched 1D perception (periodic). state f32[B, W, C], kernels f32[3, K].

    Returns f32[B, W, C*K]; channel c*K + k = kernel k on channel c.
    """
    b, w, c = state.shape
    k = kernels.shape[-1]
    out = jnp.zeros((b, w, c, k), dtype=state.dtype)
    for tap in range(3):
        shifted = jnp.roll(state, 1 - tap, axis=1)
        out = out + shifted[..., None] * kernels[tap][None, None, None, :]
    return out.reshape(b, w, c * k)


def perceive3d(state: jnp.ndarray) -> jnp.ndarray:
    """Batched 3D perception: identity + central gradient along each axis.

    state f32[B, D, H, W, C] -> f32[B, D, H, W, C*4] (identity, dz, dy, dx).
    This is ``grad_kernel(ndim=3)`` + identity of the CAX notebook.
    """
    grads = [state]
    for axis in (1, 2, 3):
        fwd = jnp.roll(state, -1, axis=axis)
        bwd = jnp.roll(state, 1, axis=axis)
        grads.append(0.5 * (fwd - bwd))
    b, d, h, w, c = state.shape
    return jnp.stack(grads, axis=-1).reshape(b, d, h, w, c * 4)


# --------------------------------------------------------------------------
# Update
# --------------------------------------------------------------------------

def init_update_params(key, perception_size: int, hidden: int, channels: int):
    """The NCA update MLP: perception -> hidden (relu) -> residual update.

    Output layer zero-init so training starts from the identity dynamics.
    """
    k1, _ = jax.random.split(key)
    return {
        "fc1": common.dense_init(k1, perception_size, hidden),
        "fc2": common.dense_zeros(hidden, channels),
    }


def update_mlp(params, perception: jnp.ndarray) -> jnp.ndarray:
    """Per-cell residual update from perception features (trailing axis)."""
    h = jnp.maximum(common.dense(params["fc1"], perception), 0.0)
    return common.dense(params["fc2"], h)


def cell_dropout(key, update: jnp.ndarray, rate: float) -> jnp.ndarray:
    """Per-cell stochastic update mask ("per-cell dropout", Mordvintsev 2020).

    Masks whole cells (all channels together); no rescaling — the NCA is a
    dynamical system, not an expectation model.
    """
    if rate <= 0.0:
        return update
    keep = jax.random.bernoulli(key, 1.0 - rate, update.shape[:-1])
    return update * keep[..., None].astype(update.dtype)


def alive_mask_2d(state: jnp.ndarray, alpha_channel: int = 3,
                  threshold: float = 0.1) -> jnp.ndarray:
    """Growing-NCA alive masking: a cell is alive if any neighbour (3x3) has
    alpha > threshold. state f32[B, H, W, C] -> f32[B, H, W, 1]."""
    alpha = state[..., alpha_channel]
    neigh_max = alpha
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy == 0 and dx == 0:
                continue
            neigh_max = jnp.maximum(
                neigh_max, jnp.roll(alpha, (dy, dx), axis=(-2, -1))
            )
    return (neigh_max > threshold).astype(state.dtype)[..., None]


# --------------------------------------------------------------------------
# Step (the paper's CA.step: state -> perceive -> update -> state')
# --------------------------------------------------------------------------

def nca_step_2d(params, state, key, *, kernels, dropout: float,
                alive_masking: bool = False, frozen: jnp.ndarray | None = None,
                ext_input: jnp.ndarray | None = None,
                update_mask: jnp.ndarray | None = None):
    """One 2D NCA step.

    Args:
        params: update-MLP params.
        state: f32[B, H, W, C].
        key: dropout PRNG key.
        kernels: perception kernels f32[3, 3, K].
        dropout: per-cell dropout rate.
        alive_masking: apply growing-NCA alive gating on channel 3.
        frozen: optional f32[B, H, W, C] {0,1} mask of channels/cells that
            must NOT change (e.g. the MNIST input channel).
        ext_input: optional f32[B, H, W, E] controllable input, concatenated
            to the perception features (paper §2.2).
        update_mask: optional f32 broadcastable to [B, H, W, 1] — cells where
            updates are disabled entirely (autoencoding wall).

    Returns:
        f32[B, H, W, C] next state.
    """
    if alive_masking:
        pre_alive = alive_mask_2d(state)
    perception = perceive2d(state, kernels)
    if ext_input is not None:
        perception = jnp.concatenate([perception, ext_input], axis=-1)
    upd = update_mlp(params, perception)
    upd = cell_dropout(key, upd, dropout)
    if update_mask is not None:
        upd = upd * update_mask
    new_state = state + upd
    if alive_masking:
        post_alive = alive_mask_2d(new_state)
        new_state = new_state * (pre_alive * post_alive)
    if frozen is not None:
        new_state = jnp.where(frozen > 0.5, state, new_state)
    return new_state


def nca_step_1d(params, state, key, *, kernels, dropout: float,
                frozen: jnp.ndarray | None = None):
    """One 1D NCA step. state f32[B, W, C]; kernels f32[3, K]."""
    perception = perceive1d(state, kernels)
    upd = update_mlp(params, perception)
    upd = cell_dropout(key, upd, dropout)
    new_state = state + upd
    if frozen is not None:
        new_state = jnp.where(frozen > 0.5, state, new_state)
    return new_state


def nca_step_3d(params, state, key, *, dropout: float,
                frozen: jnp.ndarray | None = None,
                update_mask: jnp.ndarray | None = None):
    """One 3D NCA step. state f32[B, D, H, W, C]."""
    perception = perceive3d(state)
    upd = update_mlp(params, perception)
    upd = cell_dropout(key, upd, dropout)
    if update_mask is not None:
        upd = upd * update_mask
    new_state = state + upd
    if frozen is not None:
        new_state = jnp.where(frozen > 0.5, state, new_state)
    return new_state


def rollout(step_fn, state, key, num_steps: int, with_traj: bool = False):
    """Scan ``step_fn(state, key) -> state`` for ``num_steps`` (paper §3.2.1).

    Returns final state, or (final, traj[T, ...]) when ``with_traj``.
    """

    def body(carry, i):
        st = step_fn(carry, jax.random.fold_in(key, i))
        return st, (st if with_traj else None)

    final, traj = jax.lax.scan(body, state, jnp.arange(num_steps))
    return (final, traj) if with_traj else final


def default_kernels_2d(num_kernels: int = 3) -> jnp.ndarray:
    """Identity + Sobel-x + Sobel-y (+ Laplacian) from the L1 kernel stack."""
    return perception_kernels(num_kernels)
