"""Growing Neural Cellular Automata (Mordvintsev et al. 2020) — Table 1 row 4.

State: f32[H, W, C]; channels 0-3 are RGBA, the rest hidden. A single seed
cell grows into a target RGBA sprite; alive-masking on the alpha channel
keeps dead regions inert; training samples a pool of intermediate states and
replaces the worst batch element with a fresh seed (App. B notebook).

Artifacts:
- ``growing_train_step`` — full in-graph step: worst-of-batch reseeding,
  per-sample random rollout length in [T/2, T], MSE-to-target, BPTT, Adam.
  The Rust pool writes back the returned post-rollout states.
- ``growing_rollout``    — T-step trajectory of one state (viz, Fig. 5
  damage protocol: Rust mutates the state, then calls this).
- ``growing_seed``       — the canonical single-seed initial state.
"""

import jax
import jax.numpy as jnp

from compile.models import common, nca


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def seed_state(h: int, w: int, c: int) -> jnp.ndarray:
    """Single live cell in the centre, alpha+hidden = 1 (App. B notebook)."""
    state = jnp.zeros((h, w, c), dtype=jnp.float32)
    return state.at[h // 2, w // 2, 3:].set(1.0)


def init_params(key, cfg):
    kernels = nca.default_kernels_2d(3)
    perc = cfg.channels * kernels.shape[-1]
    return {"update": nca.init_update_params(key, perc, cfg.hidden,
                                             cfg.channels)}


def _step(params, state, key, cfg):
    return nca.nca_step_2d(
        params["update"], state, key, kernels=nca.default_kernels_2d(3),
        dropout=cfg.dropout, alive_masking=True,
    )


def rgba_loss(state, target):
    """Per-sample MSE between state RGBA and target. state [B,H,W,C]."""
    return jnp.mean(
        jnp.square(state[..., :4] - target[None]), axis=(1, 2, 3)
    )


def artifacts(cfg, key) -> list[dict]:
    h, w, c, b, t = cfg.height, cfg.width, cfg.channels, cfg.batch, cfg.steps
    params = init_params(key, cfg)
    params_flat, unravel = common.flatten_params(params)
    n = params_flat.shape[0]

    def loss_fn(p, states, target, key):
        # Replace the worst batch element (highest loss) with a fresh seed —
        # the App. B pool strategy, done in-graph.
        pre = rgba_loss(states, target)
        worst = jnp.argmax(pre)
        states = states.at[worst].set(seed_state(h, w, c))

        # Per-sample random rollout length in [T/2, T): run T steps, keep
        # each sample's state at its own sampled index.
        tkey, rkey = jax.random.split(key)
        lengths = jax.random.randint(rkey, (b,), t // 2, t)

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(tkey, i), cfg)
            return st, st

        _, traj = jax.lax.scan(body, states, jnp.arange(t))
        picked = traj[lengths, jnp.arange(b)]  # [B, H, W, C]
        loss = jnp.mean(rgba_loss(picked, target))
        return loss, picked

    train_step = common.make_train_step(loss_fn, unravel, cfg)

    def rollout(pf, state, seed):
        p = unravel(pf)
        key = jax.random.PRNGKey(seed)

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), cfg)
            return st, st

        final, traj = jax.lax.scan(body, state[None], jnp.arange(t))
        return final[0], traj[:, 0]

    def seed_art():
        return (seed_state(h, w, c),)

    meta = {"kind": "nca", "ca": "growing", "height": h, "width": w,
            "channels": c, "batch": b, "steps": t, "hidden": cfg.hidden,
            "param_count": int(n)}
    return [
        dict(name="growing_train_step", fn=train_step,
             args=[("params", spec(n)), ("m", spec(n)), ("v", spec(n)),
                   ("step", spec(dtype=jnp.int32)),
                   ("states", spec(b, h, w, c)), ("target", spec(h, w, 4)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta, blobs={"growing_params": params_flat}),
        dict(name="growing_rollout", fn=rollout,
             args=[("params", spec(n)), ("state", spec(h, w, c)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta),
        dict(name="growing_seed", fn=seed_art, args=[], meta=meta),
    ]
