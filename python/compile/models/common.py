"""Shared Layer-2 machinery: parameter init, flat-vector interchange, Adam.

The Rust coordinator owns parameters and optimizer state as opaque flat f32
vectors; every train-step artifact takes ``(params, m, v, step, ...)`` and
returns the updated triple. Adam (with global-norm clipping and the paper's
linear learning-rate schedule) runs **in-graph**, so the request path never
needs Python.

No flax/optax in this environment — everything here is pure JAX.
"""

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def dense_init(key, fan_in: int, fan_out: int, scale: float | None = None):
    """He-normal dense layer init. Returns dict(w=[in,out], b=[out])."""
    if scale is None:
        scale = (2.0 / fan_in) ** 0.5
    w = scale * jax.random.normal(key, (fan_in, fan_out), dtype=jnp.float32)
    return {"w": w, "b": jnp.zeros((fan_out,), dtype=jnp.float32)}


def dense_zeros(fan_in: int, fan_out: int):
    """Zero-init dense layer — the NCA output layer starts as the identity
    residual (Mordvintsev et al. 2020)."""
    return {
        "w": jnp.zeros((fan_in, fan_out), dtype=jnp.float32),
        "b": jnp.zeros((fan_out,), dtype=jnp.float32),
    }


def dense(params, x):
    """Apply a dense layer to the trailing axis."""
    return x @ params["w"] + params["b"]


def flatten_params(params):
    """Pytree -> (flat f32 vector, unravel closure)."""
    flat, unravel = ravel_pytree(params)
    return flat.astype(jnp.float32), unravel


def global_norm_clip(grads_flat: jnp.ndarray, max_norm: float = 1.0):
    """Clip a flat gradient vector by global norm (optax-equivalent)."""
    norm = jnp.sqrt(jnp.sum(grads_flat * grads_flat) + 1e-12)
    scale = jnp.minimum(1.0, max_norm / norm)
    return grads_flat * scale


def linear_lr(step, init_lr: float, end_lr: float, transition_steps: int):
    """optax.linear_schedule equivalent (step may be traced)."""
    frac = jnp.clip(step.astype(jnp.float32) / float(transition_steps), 0.0, 1.0)
    return init_lr + (end_lr - init_lr) * frac


def adam_update(params, m, v, grads, step, lr, *, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step on flat vectors; ``step`` is the 0-based step index.

    Returns (params', m', v'). Bias correction uses step+1.
    """
    t = step.astype(jnp.float32) + 1.0
    m = b1 * m + (1.0 - b1) * grads
    v = b2 * v + (1.0 - b2) * grads * grads
    m_hat = m / (1.0 - b1**t)
    v_hat = v / (1.0 - b2**t)
    params = params - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return params, m, v


def make_train_step(loss_fn, unravel, cfg):
    """Build the canonical in-graph train step around a loss function.

    Args:
        loss_fn: ``(params_pytree, *batch, key) -> (loss, aux)``.
        unravel: flat-vector -> pytree closure from :func:`flatten_params`.
        cfg: NcaCfg with lr / lr_end_frac / lr_steps.

    Returns:
        ``step_fn(params, m, v, step, *batch, seed) ->
        (params', m', v', loss, *aux)`` operating on flat f32 vectors.
    """

    def step_fn(params_flat, m, v, step, *batch_and_seed):
        *batch, seed = batch_and_seed
        key = jax.random.PRNGKey(seed)
        key = jax.random.fold_in(key, step)

        def flat_loss(pf):
            loss, aux = loss_fn(unravel(pf), *batch, key)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(flat_loss, has_aux=True)(
            params_flat
        )
        grads = global_norm_clip(grads, 1.0)
        lr = linear_lr(step, cfg.lr, cfg.lr * cfg.lr_end_frac, cfg.lr_steps)
        params_flat, m, v = adam_update(params_flat, m, v, grads, step, lr)
        if isinstance(aux, (tuple, list)):
            return (params_flat, m, v, loss, *aux)
        return params_flat, m, v, loss, aux

    return step_fn
