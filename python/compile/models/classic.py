"""Layer-2 models for the classic CAs: Elementary CA, Game of Life, Lenia.

Paper Table 1 rows 1-3 and the Figure-3-left benchmark subjects. Each model
comes in three artifact flavours:

- ``*_step``     — a single global-rule application. This is the *stepwise
  dispatch* baseline of E1/E2: the Rust harness calls it T times with a host
  round-trip per step, reproducing the cost structure the paper attributes
  to CellPyLib-style per-step execution.
- ``*_rollout``  — T steps fused in one ``lax.scan`` program, returning only
  the final state. This is the CAX fast path (paper §3.2.1).
- ``*_traj``     — fused rollout that also returns the whole trajectory, for
  space-time rendering and cross-layer equivalence tests.

The scan body calls the Layer-1 Pallas kernels, so the fused artifacts carry
the Pallas compute through the HLO-text bridge.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import eca_step, life_step, ring_kernel
from compile.kernels.ref import lenia_growth_ref


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _scan_steps(step_fn, state, num_steps, with_traj):
    def body(carry, _):
        nxt = step_fn(carry)
        return nxt, (nxt if with_traj else None)

    final, traj = jax.lax.scan(body, state, None, length=num_steps)
    return (final, traj) if with_traj else final


def lenia_fft_kernel(size: int, radius: int) -> np.ndarray:
    """Precompute the FFT of the ring kernel on a size x size torus.

    Returned as interleaved (real, imag) f32[2, H, W] so it stays f32 across
    the artifact boundary (the manifest interchange is all-f32).
    """
    k = ring_kernel(radius)
    padded = np.zeros((size, size), dtype=np.float32)
    ksz = 2 * radius + 1
    padded[:ksz, :ksz] = k
    padded = np.roll(padded, (-radius, -radius), axis=(0, 1))
    kf = np.fft.fft2(padded)
    return np.stack([kf.real, kf.imag]).astype(np.float32)


def lenia_step_fft(state, kfft_ri, mu, sigma, dt):
    """One Lenia step via FFT convolution. state f32[B, H, W]."""
    kfft = kfft_ri[0] + 1j * kfft_ri[1]
    u = jnp.real(jnp.fft.ifft2(jnp.fft.fft2(state) * kfft[None]))
    return jnp.clip(state + dt * lenia_growth_ref(u, mu, sigma), 0.0, 1.0)


def artifacts(cfg) -> list[dict]:
    """Build all classic-CA artifact descriptors for ``aot.py``.

    Args:
        cfg: a ``configs.ClassicCfg``.
    """
    arts = []

    # ---------------- Elementary CA ----------------
    b, w, t = cfg.eca_batch, cfg.eca_width, cfg.eca_steps

    def eca_one(state, rule):
        return (eca_step(state, rule),)

    def eca_rollout(state, rule):
        return (_scan_steps(lambda s: eca_step(s, rule), state, t, False),)

    tw, tt = cfg.eca_traj_width, cfg.eca_traj_steps

    def eca_traj(state, rule):
        final, traj = _scan_steps(
            lambda s: eca_step(s, rule), state, tt, True
        )
        return final, traj

    arts += [
        dict(name="eca_step", fn=eca_one,
             args=[("state", spec(b, w)), ("rule", spec(8))],
             meta={"kind": "classic", "ca": "eca", "batch": b, "width": w}),
        dict(name="eca_rollout", fn=eca_rollout,
             args=[("state", spec(b, w)), ("rule", spec(8))],
             meta={"kind": "classic", "ca": "eca", "batch": b, "width": w,
                   "steps": t}),
        dict(name="eca_traj", fn=eca_traj,
             args=[("state", spec(b, tw)), ("rule", spec(8))],
             meta={"kind": "classic", "ca": "eca", "batch": b, "width": tw,
                   "steps": tt}),
    ]

    # ---------------- Game of Life ----------------
    lb, lh, lw, lt = cfg.life_batch, cfg.life_height, cfg.life_width, cfg.life_steps

    def life_one(state):
        return (life_step(state),)

    def life_rollout(state):
        return (_scan_steps(life_step, state, lt, False),)

    ltt = cfg.life_traj_steps

    def life_traj(state):
        final, traj = _scan_steps(life_step, state, ltt, True)
        return final, traj

    arts += [
        dict(name="life_step", fn=life_one,
             args=[("state", spec(lb, lh, lw))],
             meta={"kind": "classic", "ca": "life", "batch": lb,
                   "height": lh, "width": lw}),
        dict(name="life_rollout", fn=life_rollout,
             args=[("state", spec(lb, lh, lw))],
             meta={"kind": "classic", "ca": "life", "batch": lb,
                   "height": lh, "width": lw, "steps": lt}),
        dict(name="life_traj", fn=life_traj,
             args=[("state", spec(lb, lh, lw))],
             meta={"kind": "classic", "ca": "life", "batch": lb,
                   "height": lh, "width": lw, "steps": ltt}),
    ]

    # ---------------- Lenia ----------------
    nb, n, nt = cfg.lenia_batch, cfg.lenia_size, cfg.lenia_steps
    mu, sigma, dt = cfg.lenia_mu, cfg.lenia_sigma, cfg.lenia_dt

    def lenia_one(state, kfft):
        return (lenia_step_fft(state, kfft, mu, sigma, dt),)

    def lenia_rollout(state, kfft):
        return (
            _scan_steps(
                lambda s: lenia_step_fft(s, kfft, mu, sigma, dt), state, nt,
                False,
            ),
        )

    def lenia_traj(state, kfft):
        final, traj = _scan_steps(
            lambda s: lenia_step_fft(s, kfft, mu, sigma, dt), state, nt, True
        )
        return final, traj

    # ---------------- bench-scale variants (Fig. 3) ----------------
    # Same rules at sizes where vectorization wins; used only by the bench
    # harness (fig3_classic / cax-tables fig3), never by the test suite.
    bb, bw, bt = cfg.bench_eca_batch, cfg.bench_eca_width, cfg.bench_eca_steps

    def eca_step_bench(state, rule):
        return (eca_step(state, rule),)

    def eca_rollout_bench(state, rule):
        return (_scan_steps(lambda s: eca_step(s, rule), state, bt, False),)

    glb, gls, glt = (cfg.bench_life_batch, cfg.bench_life_size,
                     cfg.bench_life_steps)

    def life_step_bench(state):
        return (life_step(state),)

    def life_rollout_bench(state):
        return (_scan_steps(life_step, state, glt, False),)

    arts += [
        dict(name="eca_step_bench", fn=eca_step_bench,
             args=[("state", spec(bb, bw)), ("rule", spec(8))],
             meta={"kind": "classic", "ca": "eca", "batch": bb, "width": bw}),
        dict(name="eca_rollout_bench", fn=eca_rollout_bench,
             args=[("state", spec(bb, bw)), ("rule", spec(8))],
             meta={"kind": "classic", "ca": "eca", "batch": bb, "width": bw,
                   "steps": bt}),
        dict(name="life_step_bench", fn=life_step_bench,
             args=[("state", spec(glb, gls, gls))],
             meta={"kind": "classic", "ca": "life", "batch": glb,
                   "height": gls, "width": gls}),
        dict(name="life_rollout_bench", fn=life_rollout_bench,
             args=[("state", spec(glb, gls, gls))],
             meta={"kind": "classic", "ca": "life", "batch": glb,
                   "height": gls, "width": gls, "steps": glt}),
    ]

    kf_blob = lenia_fft_kernel(n, cfg.lenia_radius)
    lmeta = {"kind": "classic", "ca": "lenia", "batch": nb, "height": n,
             "width": n, "steps": nt, "radius": cfg.lenia_radius,
             "mu": mu, "sigma": sigma, "dt": dt}
    arts += [
        dict(name="lenia_step", fn=lenia_one,
             args=[("state", spec(nb, n, n)), ("kfft", spec(2, n, n))],
             meta=lmeta, blobs={"lenia_kfft": kf_blob}),
        dict(name="lenia_rollout", fn=lenia_rollout,
             args=[("state", spec(nb, n, n)), ("kfft", spec(2, n, n))],
             meta=lmeta),
        dict(name="lenia_traj", fn=lenia_traj,
             args=[("state", spec(nb, n, n)), ("kfft", spec(2, n, n))],
             meta=lmeta),
    ]
    return arts
