"""Layer 2 — JAX compute graphs for every CA in the paper's Table 1."""
