"""Self-classifying MNIST digits (Randazzo et al. 2020) — Table 1 row 7,
and the Figure-3-right benchmark subject.

Each alive cell (a digit pixel) must locally agree on the digit's class:
channel 0 carries the (frozen) pixel intensity, the last 10 channels are
per-cell class logits. Cross-entropy is averaged over alive cells at the
final and half-way steps (consensus must form *and persist*).

Artifacts:
- ``mnist_train_step`` — fused whole-rollout BPTT train step (the CAX path).
- ``mnist_eval``       — deterministic rollout returning per-cell logits.
- ``mnist_step_fwd``   — ONE forward step (stepwise-dispatch baseline, E3).
- ``mnist_step_vjp``   — VJP of one step given the upstream cotangent; the
  Rust harness chains T of these to do host-driven BPTT, reproducing the
  per-step-dispatch cost structure of the TensorFlow reference (Fig. 3
  right) on identical hardware.
- ``mnist_final_grad`` — loss + d(loss)/d(state) at the readout, seeding the
  host-driven BPTT.
"""

import jax
import jax.numpy as jnp

from compile.models import common, nca


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def init_params(key, cfg):
    kernels = nca.default_kernels_2d(3)
    perc = cfg.channels * kernels.shape[-1]
    return {"update": nca.init_update_params(key, perc, cfg.hidden,
                                             cfg.channels)}


def init_state(digits, c):
    """Digit intensity in channel 0, everything else zero. digits [B,H,W]."""
    b, h, w = digits.shape
    state = jnp.zeros((b, h, w, c), dtype=jnp.float32)
    return state.at[..., 0].set(digits)


def _frozen_mask(digits, c):
    """Channel 0 is frozen input. [B, H, W, C] {0,1}."""
    b, h, w = digits.shape
    frozen = jnp.zeros((b, h, w, c), dtype=jnp.float32)
    return frozen.at[..., 0].set(1.0)


def _step(params, state, key, digits, cfg):
    # Updates only happen where there is ink (alive = digit pixel).
    alive = (digits > 0.1).astype(jnp.float32)[..., None]
    return nca.nca_step_2d(
        params["update"], state, key, kernels=nca.default_kernels_2d(3),
        dropout=cfg.dropout, frozen=_frozen_mask(digits, cfg.channels),
        update_mask=alive,
    )


def _cell_ce(state, digits, labels1h, nc):
    """Mean cross-entropy of per-cell logits over alive cells."""
    logits = state[..., -nc:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.sum(logp * labels1h[:, None, None, :], axis=-1)  # [B,H,W]
    alive = (digits > 0.1).astype(jnp.float32)
    return jnp.sum(ce * alive) / jnp.maximum(jnp.sum(alive), 1.0)


def artifacts(cfg, key) -> list[dict]:
    h, w, c, b, t = cfg.height, cfg.width, cfg.channels, cfg.batch, cfg.steps
    nc = cfg.extra["num_classes"]
    params = init_params(key, cfg)
    params_flat, unravel = common.flatten_params(params)
    n = params_flat.shape[0]

    def loss_fn(p, digits, labels1h, key):
        state = init_state(digits, c)

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), digits, cfg)
            return st, None

        mid, _ = jax.lax.scan(body, state, jnp.arange(t // 2))
        fin, _ = jax.lax.scan(
            body, mid, jnp.arange(t // 2, t)
        )
        loss = 0.5 * (_cell_ce(mid, digits, labels1h, nc)
                      + _cell_ce(fin, digits, labels1h, nc))
        return loss, ()

    train_step = common.make_train_step(loss_fn, unravel, cfg)

    def eval_fn(pf, digits, seed):
        p = unravel(pf)
        key = jax.random.PRNGKey(seed)
        state = init_state(digits, c)

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), digits, cfg)
            return st, None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        return (fin[..., -nc:],)

    def step_fwd(pf, state, digits, seed):
        p = unravel(pf)
        key = jax.random.PRNGKey(seed)
        return (_step(p, state, key, digits, cfg),)

    def step_vjp(pf, state, digits, seed, ct):
        # Recomputes the step (same seed => same dropout mask) and pulls the
        # cotangent back through it.
        key = jax.random.PRNGKey(seed)

        def f(pf_, st_):
            p = unravel(pf_)
            return _step(p, st_, key, digits, cfg)

        _, vjp = jax.vjp(f, pf, state)
        dpf, dstate = vjp(ct)
        return dpf, dstate

    def final_grad(state, digits, labels1h):
        def f(st):
            return _cell_ce(st, digits, labels1h, nc)

        loss, grad = jax.value_and_grad(f)(state)
        return loss, grad

    meta = {"kind": "nca", "ca": "mnist", "height": h, "width": w,
            "channels": c, "batch": b, "steps": t, "hidden": cfg.hidden,
            "num_classes": nc, "param_count": int(n)}
    st_spec = spec(b, h, w, c)
    return [
        dict(name="mnist_train_step", fn=train_step,
             args=[("params", spec(n)), ("m", spec(n)), ("v", spec(n)),
                   ("step", spec(dtype=jnp.int32)),
                   ("digits", spec(b, h, w)), ("labels1h", spec(b, nc)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta, blobs={"mnist_params": params_flat}),
        dict(name="mnist_eval", fn=eval_fn,
             args=[("params", spec(n)), ("digits", spec(b, h, w)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta),
        dict(name="mnist_step_fwd", fn=step_fwd,
             args=[("params", spec(n)), ("state", st_spec),
                   ("digits", spec(b, h, w)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta),
        dict(name="mnist_step_vjp", fn=step_vjp,
             args=[("params", spec(n)), ("state", st_spec),
                   ("digits", spec(b, h, w)),
                   ("seed", spec(dtype=jnp.uint32)), ("ct", st_spec)],
             meta=meta),
        dict(name="mnist_final_grad", fn=final_grad,
             args=[("state", st_spec), ("digits", spec(b, h, w)),
                   ("labels1h", spec(b, nc))],
             meta=meta),
    ]
