"""1D-ARC Neural Cellular Automata (paper §5.3, Table 2, Fig. 8).

A one-dimensional NCA is trained per task to transform an input row of
colored pixels into the target row after a fixed number of steps. Colors are
one-hot over 10 channels (ARC palette); the remaining channels are hidden.

Artifacts:
- ``arc_train_step`` — batch of (input, target) one-hot rows; CE at the
  final step; fused BPTT + Adam.
- ``arc_eval``       — deterministic rollout; final color logits [B, W, 10]
  (the Rust evaluator argmaxes and scores exact-match, Table 2).
- ``arc_traj``       — one sample's color-argmax trajectory for the Fig. 8
  space-time diagrams.
"""

import jax
import jax.numpy as jnp

from compile.models import common, nca


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def init_params(key, cfg):
    kernels = nca.perception_kernels_1d(3)
    perc = cfg.channels * kernels.shape[-1]
    return {"update": nca.init_update_params(key, perc, cfg.hidden,
                                             cfg.channels)}


def init_state(inputs1h, c):
    """inputs1h f32[B, W, 10] -> state [B, W, C] with colors in ch 0-9."""
    b, w, ncol = inputs1h.shape
    state = jnp.zeros((b, w, c), dtype=jnp.float32)
    return state.at[..., :ncol].set(inputs1h)


def _step(params, state, key, cfg, dropout=None):
    return nca.nca_step_1d(
        params["update"], state, key,
        kernels=nca.perception_kernels_1d(3),
        dropout=cfg.dropout if dropout is None else dropout,
    )


def artifacts(cfg, key) -> list[dict]:
    w, c, b, t = cfg.width, cfg.channels, cfg.batch, cfg.steps
    ncol = cfg.extra["num_colors"]
    params = init_params(key, cfg)
    params_flat, unravel = common.flatten_params(params)
    n = params_flat.shape[0]

    def ce(state, targets1h):
        logp = jax.nn.log_softmax(state[..., :ncol], axis=-1)
        return -jnp.mean(jnp.sum(logp * targets1h, axis=-1))

    def loss_fn(p, inputs1h, targets1h, key):
        state = init_state(inputs1h, c)

        def body(carry, i):
            return _step(p, carry, jax.random.fold_in(key, i), cfg), None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        return ce(fin, targets1h), ()

    train_step = common.make_train_step(loss_fn, unravel, cfg)

    def eval_fn(pf, inputs1h):
        p = unravel(pf)
        key = jax.random.PRNGKey(0)
        state = init_state(inputs1h, c)

        def body(carry, i):
            # Keep the cell dropout at evaluation (fixed key -> repeatable):
            # the learned dynamics are update-rate-dependent, so running
            # dropout-free doubles each cell's effective step count and
            # overshoots (e.g. Move-1 shifts too far).
            return _step(p, carry, jax.random.fold_in(key, i), cfg), None

        fin, _ = jax.lax.scan(body, state, jnp.arange(t))
        return (fin[..., :ncol],)

    def traj_fn(pf, input1h):
        p = unravel(pf)
        key = jax.random.PRNGKey(0)
        state = init_state(input1h[None], c)

        def body(carry, i):
            st = _step(p, carry, jax.random.fold_in(key, i), cfg)
            return st, st[..., :ncol]

        _, traj = jax.lax.scan(body, state, jnp.arange(t))
        return (traj[:, 0],)  # [T, W, 10]

    meta = {"kind": "nca", "ca": "arc", "width": w, "channels": c,
            "batch": b, "steps": t, "hidden": cfg.hidden,
            "num_colors": ncol, "param_count": int(n)}
    return [
        dict(name="arc_train_step", fn=train_step,
             args=[("params", spec(n)), ("m", spec(n)), ("v", spec(n)),
                   ("step", spec(dtype=jnp.int32)),
                   ("inputs", spec(b, w, ncol)),
                   ("targets", spec(b, w, ncol)),
                   ("seed", spec(dtype=jnp.uint32))],
             meta=meta, blobs={"arc_params": params_flat}),
        dict(name="arc_eval", fn=eval_fn,
             args=[("params", spec(n)), ("inputs", spec(b, w, ncol))],
             meta=meta),
        dict(name="arc_traj", fn=traj_fn,
             args=[("params", spec(n)), ("input", spec(w, ncol))],
             meta=meta),
    ]
