"""Scale presets for every artifact CAX-RS lowers.

Two presets:

- ``test``  — small shapes so the full stack (pytest, cargo test, benches,
  examples) runs in minutes on the CPU PJRT backend. This is the default.
- ``paper`` — the hyperparameters of the paper's Appendix A (Tables 3-5) and
  the classic-CA benchmark sizes of Figure 3. Lowering produces the same HLO
  structure with bigger shapes; running them on CPU is expensive, so they are
  emitted for completeness and used by the paper-scale bench rows only.

Every entry is consumed by ``aot.py`` (lowering) and mirrored into
``artifacts/manifest.json`` so the Rust coordinator can introspect shapes.
"""

from dataclasses import dataclass, field, asdict


@dataclass
class ClassicCfg:
    """Classic discrete/continuous CA rollout shapes (Fig. 3 left)."""

    eca_batch: int = 4
    eca_width: int = 256
    eca_steps: int = 256
    eca_traj_width: int = 128
    eca_traj_steps: int = 128

    life_batch: int = 4
    life_height: int = 64
    life_width: int = 64
    life_steps: int = 256
    life_traj_steps: int = 64

    lenia_batch: int = 1
    lenia_size: int = 64
    lenia_steps: int = 64
    lenia_radius: int = 10
    lenia_dt: float = 0.1
    lenia_mu: float = 0.15
    lenia_sigma: float = 0.017

    # Bench-scale shapes (Fig. 3 at sizes where vectorization matters; the
    # tiny `test` shapes above keep the *correctness* suite fast instead).
    bench_eca_batch: int = 8
    bench_eca_width: int = 4096
    bench_eca_steps: int = 512
    bench_life_batch: int = 4
    bench_life_size: int = 192
    bench_life_steps: int = 256


@dataclass
class NcaCfg:
    """One neural-CA experiment's shapes/hyperparameters."""

    height: int = 32
    width: int = 32
    depth: int = 0            # >0 => 3D
    channels: int = 12
    hidden: int = 64
    batch: int = 4
    steps: int = 24
    dropout: float = 0.5
    lr: float = 1e-3
    lr_end_frac: float = 0.1  # linear schedule end = lr * frac
    lr_steps: int = 2000
    extra: dict = field(default_factory=dict)


def test_preset() -> dict:
    """Small shapes; everything runnable on CPU in minutes."""
    return {
        "classic": ClassicCfg(),
        "growing": NcaCfg(height=32, width=32, channels=12, hidden=64,
                          batch=4, steps=24, lr=2e-3),
        "conditional": NcaCfg(height=24, width=24, channels=12, hidden=64,
                              batch=6, steps=16, extra={"num_goals": 3}),
        "vae": NcaCfg(height=16, width=16, channels=12, hidden=64, batch=4,
                      steps=16, extra={"latent": 8, "enc_hidden": 64,
                                       "kl_weight": 1e-3}),
        "mnist": NcaCfg(height=16, width=16, channels=16, hidden=64, batch=4,
                        steps=16, extra={"num_classes": 10}),
        # noise_lo = 0: the NCA must also learn that the clean target is a
        # FIXED POINT — without level-0 samples the attractor basin of
        # Fig. 5 has a hole at its centre and light damage diverges.
        # The diffusing NCA is deliberately the largest test-preset model
        # (16ch / hidden 128 / 32 steps): hole-filling regeneration (Fig. 5)
        # needs capacity + horizon, mirroring the paper where it is the
        # biggest configuration (App. A Table 3: 64ch / 256 / 128 steps).
        "diffusing": NcaCfg(height=24, width=24, channels=16, hidden=128,
                            batch=4, steps=32, extra={"noise_lo": 0.0,
                                                      "noise_hi": 1.0}),
        "autoenc3d": NcaCfg(height=12, width=12, depth=8, channels=12,
                            hidden=48, batch=4, steps=24),
        # steps == width, matching the paper's Table-5 geometry (128/128):
        # information must be able to cross the whole row (pattern copy,
        # move-towards) within the rollout's light cone.
        "arc": NcaCfg(height=1, width=32, channels=16, hidden=64, batch=8,
                      steps=32, extra={"num_colors": 10}),
    }


def paper_preset() -> dict:
    """Appendix A hyperparameters (Tables 3-5) + App. B notebook values."""
    return {
        "classic": ClassicCfg(eca_batch=8, eca_width=1024, eca_steps=1024,
                              life_batch=8, life_height=128, life_width=128,
                              life_steps=1024, lenia_size=128,
                              lenia_radius=13, lenia_steps=256),
        # App. B notebook: 40px target + 16 padding => 72x72, 16 channels.
        "growing": NcaCfg(height=72, width=72, channels=16, hidden=128,
                          batch=8, steps=128, lr=2e-3),
        "conditional": NcaCfg(height=72, width=72, channels=16, hidden=128,
                              batch=8, steps=64, extra={"num_goals": 3}),
        "vae": NcaCfg(height=28, width=28, channels=16, hidden=128, batch=8,
                      steps=64, extra={"latent": 16, "enc_hidden": 256,
                                       "kl_weight": 1e-3}),
        "mnist": NcaCfg(height=28, width=28, channels=20, hidden=128, batch=8,
                        steps=20, extra={"num_classes": 10}),
        # Table 3: 72x72, 64 ch, hidden 256, batch 8, 128 steps, lr 1e-3.
        "diffusing": NcaCfg(height=72, width=72, channels=64, hidden=256,
                            batch=8, steps=128, lr=1e-3,
                            extra={"noise_lo": 0.0, "noise_hi": 1.0}),
        # Table 4: (16, 16, 32) spatial, hidden 256, batch 8, 96 steps.
        "autoenc3d": NcaCfg(height=16, width=16, depth=32, channels=16,
                            hidden=256, batch=8, steps=96, lr=1e-3),
        # Table 5: width 128, 32 ch, hidden 256, batch 8, 128 steps, lr 1e-3.
        "arc": NcaCfg(height=1, width=128, channels=32, hidden=256, batch=8,
                      steps=128, lr=1e-3, extra={"num_colors": 10}),
    }


PRESETS = {"test": test_preset, "paper": paper_preset}


def get_preset(name: str) -> dict:
    if name not in PRESETS:
        raise ValueError(f"unknown preset {name!r}; options: {list(PRESETS)}")
    return PRESETS[name]()


def preset_as_dict(name: str) -> dict:
    return {k: asdict(v) for k, v in get_preset(name).items()}
