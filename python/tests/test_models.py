"""Layer-2 correctness: model semantics, shapes, and trainability.

Each NCA family gets (a) shape/structure checks and (b) a short *real*
training run through its train-step function asserting the loss decreases —
the same function that is lowered to the HLO artifact the Rust trainer runs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs
from compile.models import (arc, autoenc3d, common, conditional, diffusing,
                            growing, mnist_classify, nca, vae)


@pytest.fixture(scope="module")
def cfgs():
    return configs.get_preset("test")


def tiny(cfg, **kw):
    """Shrink a config for fast in-test training."""
    import dataclasses
    return dataclasses.replace(cfg, **kw)


def rand_digits(seed, b, h, w):
    rng = np.random.default_rng(seed)
    d = np.zeros((b, h, w), dtype=np.float32)
    # blobby "digits": a few random rectangles of ink
    for i in range(b):
        for _ in range(3):
            y0, x0 = rng.integers(0, h - 2), rng.integers(0, w - 2)
            d[i, y0:y0 + rng.integers(2, 4), x0:x0 + rng.integers(2, 4)] = 1.0
    return jnp.array(d)


# ------------------------------------------------------------- core NCA

def test_perceive1d_matches_manual():
    state = jnp.array(np.random.default_rng(0).random((2, 8, 3)),
                      dtype=jnp.float32)
    kernels = nca.perception_kernels_1d(2)
    out = nca.perceive1d(state, kernels)
    assert out.shape == (2, 8, 6)
    # identity kernel -> channel c*2 reproduces channel c
    np.testing.assert_allclose(np.array(out[..., 0::2]), np.array(state),
                               atol=1e-6)


def test_perceive3d_identity_and_gradient():
    state = jnp.array(np.random.default_rng(1).random((1, 4, 5, 6, 2)),
                      dtype=jnp.float32)
    out = nca.perceive3d(state)
    assert out.shape == (1, 4, 5, 6, 8)
    np.testing.assert_allclose(np.array(out[..., 0::4]), np.array(state),
                               atol=1e-6)
    # gradient of a constant field is zero
    const = jnp.ones((1, 3, 3, 3, 1))
    g = nca.perceive3d(const)
    np.testing.assert_allclose(np.array(g[..., 1:]), 0.0, atol=1e-6)


def test_alive_mask_spreads_one_cell():
    state = jnp.zeros((1, 7, 7, 5))
    state = state.at[0, 3, 3, 3].set(1.0)
    mask = np.array(nca.alive_mask_2d(state))[0, :, :, 0]
    assert mask.sum() == 9  # 3x3 neighbourhood of the live cell
    assert mask[3, 3] == 1 and mask[0, 0] == 0


def test_cell_dropout_masks_whole_cells():
    upd = jnp.ones((2, 6, 6, 4))
    out = np.array(nca.cell_dropout(jax.random.PRNGKey(0), upd, 0.5))
    per_cell = out.sum(axis=-1)
    assert set(np.unique(per_cell)).issubset({0.0, 4.0})
    assert 0.0 < (per_cell == 4.0).mean() < 1.0


def test_update_mlp_zero_init_is_identity_dynamics():
    params = nca.init_update_params(jax.random.PRNGKey(0), 12, 16, 4)
    perc = jnp.array(np.random.default_rng(2).random((3, 3, 12)),
                     dtype=jnp.float32)
    np.testing.assert_allclose(np.array(nca.update_mlp(params, perc)), 0.0)


def test_adam_reduces_quadratic():
    x = jnp.array([5.0, -3.0])
    m = jnp.zeros(2)
    v = jnp.zeros(2)
    for step in range(200):
        g = 2.0 * x
        x, m, v = common.adam_update(x, m, v, g, jnp.int32(step), 0.1)
    assert float(jnp.abs(x).max()) < 0.5


def test_global_norm_clip():
    g = jnp.array([3.0, 4.0])  # norm 5
    clipped = common.global_norm_clip(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped)) - 1.0) < 1e-5
    small = jnp.array([0.3, 0.4])
    np.testing.assert_allclose(np.array(common.global_norm_clip(small, 1.0)),
                               np.array(small), atol=1e-6)


def test_linear_lr_schedule():
    lr0 = common.linear_lr(jnp.int32(0), 1e-3, 1e-4, 100)
    lr_mid = common.linear_lr(jnp.int32(50), 1e-3, 1e-4, 100)
    lr_end = common.linear_lr(jnp.int32(1000), 1e-3, 1e-4, 100)
    assert abs(float(lr0) - 1e-3) < 1e-9
    assert abs(float(lr_mid) - 5.5e-4) < 1e-6
    assert abs(float(lr_end) - 1e-4) < 1e-9


# ------------------------------------------------------------- training

def run_train(art_list, name, inputs, steps=30):
    """Drive a train-step artifact function directly (pre-lowering)."""
    art = next(a for a in art_list if a["name"] == name)
    fn = jax.jit(art["fn"])
    n = art["args"][0][1].shape[0]
    blob_name = next(iter(art["blobs"]))
    params = jnp.array(art["blobs"][blob_name])
    m = jnp.zeros(n)
    v = jnp.zeros(n)
    losses = []
    extra_state = None
    for i in range(steps):
        out = fn(params, m, v, jnp.int32(i), *inputs(i, extra_state),
                 jnp.uint32(1234))
        params, m, v, loss = out[0], out[1], out[2], out[3]
        if len(out) > 4:
            extra_state = out[4:]
        losses.append(float(loss))
    return losses


def test_growing_trains(cfgs):
    cfg = tiny(cfgs["growing"], height=16, width=16, channels=8, hidden=32,
               batch=4, steps=12)
    arts = growing.artifacts(cfg, jax.random.PRNGKey(0))
    target = jnp.zeros((16, 16, 4)).at[5:11, 5:11, :].set(0.8)
    states = jnp.broadcast_to(
        growing.seed_state(16, 16, 8)[None], (4, 16, 16, 8)
    )
    holder = {"states": states}

    def inputs(i, extra):
        if extra is not None:
            holder["states"] = extra[0]  # pool write-back
        return holder["states"], target

    # Pool write-back + per-sample random rollout lengths make the loss
    # noisy; compare window means rather than endpoints.
    losses = run_train(arts, "growing_train_step", inputs, steps=48)
    first, last = np.mean(losses[:8]), np.mean(losses[-8:])
    assert last < first * 0.9, (first, last, losses[::12])


def test_mnist_trains(cfgs):
    cfg = tiny(cfgs["mnist"], height=12, width=12, channels=14, hidden=32,
               batch=4, steps=8)
    arts = mnist_classify.artifacts(cfg, jax.random.PRNGKey(1))
    digits = rand_digits(0, 4, 12, 12)
    labels = jax.nn.one_hot(jnp.array([0, 1, 2, 3]), 10)
    losses = run_train(arts, "mnist_train_step",
                       lambda i, e: (digits, labels), steps=40)
    assert losses[-1] < losses[0] * 0.9, losses[::10]


def test_arc_trains_move1(cfgs):
    cfg = tiny(cfgs["arc"], width=16, channels=12, hidden=32, batch=8,
               steps=8)
    arts = arc.artifacts(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)

    def make_batch():
        x = np.zeros((8, 16), dtype=np.int64)
        for i in range(8):
            start = rng.integers(0, 10)
            x[i, start:start + 3] = rng.integers(1, 10)
        y = np.roll(x, 1, axis=1)  # Move-1 task
        return (jax.nn.one_hot(jnp.array(x), 10),
                jax.nn.one_hot(jnp.array(y), 10))

    batches = [make_batch() for _ in range(8)]
    losses = run_train(arts, "arc_train_step",
                       lambda i, e: batches[i % 8], steps=48)
    assert losses[-1] < losses[0] * 0.7, losses[::12]


def test_diffusing_trains(cfgs):
    cfg = tiny(cfgs["diffusing"], height=12, width=12, channels=8,
               hidden=32, batch=4, steps=8)
    arts = diffusing.artifacts(cfg, jax.random.PRNGKey(3))
    target = jnp.zeros((12, 12, 4)).at[3:9, 3:9, :].set(0.7)
    # Each step draws a fresh noise level, so per-step loss is noisy;
    # compare first/last window means over a longer run instead.
    losses = run_train(arts, "diffusing_train_step",
                       lambda i, e: (target,), steps=120)
    first = sum(losses[:12]) / 12
    last = sum(losses[-12:]) / 12
    assert last < first * 0.8, (first, last, losses[::16])


def test_autoenc3d_trains(cfgs):
    cfg = tiny(cfgs["autoenc3d"], depth=6, height=8, width=8, channels=8,
               hidden=24, batch=4, steps=12)
    arts = autoenc3d.artifacts(cfg, jax.random.PRNGKey(4))
    digits = rand_digits(5, 4, 8, 8)
    losses = run_train(arts, "autoenc3d_train_step",
                       lambda i, e: (digits,), steps=40)
    assert losses[-1] < losses[0], losses[::10]


def test_conditional_trains(cfgs):
    cfg = tiny(cfgs["conditional"], height=12, width=12, channels=8,
               hidden=32, batch=6, steps=8)
    arts = conditional.artifacts(cfg, jax.random.PRNGKey(5))
    targets = jnp.stack([
        jnp.zeros((12, 12, 4)).at[3:9, 3:9, :].set(v)
        for v in (0.3, 0.6, 0.9)
    ])
    goals = jax.nn.one_hot(jnp.array([0, 1, 2, 0, 1, 2]), 3)
    losses = run_train(arts, "conditional_train_step",
                       lambda i, e: (targets, goals), steps=40)
    assert losses[-1] < losses[0] * 0.9, losses[::10]


def test_vae_trains(cfgs):
    cfg = tiny(cfgs["vae"], height=10, width=10, channels=10, hidden=32,
               batch=4, steps=8,
               extra={"latent": 4, "enc_hidden": 32, "kl_weight": 1e-3})
    arts = vae.artifacts(cfg, jax.random.PRNGKey(6))
    digits = rand_digits(7, 4, 10, 10)
    losses = run_train(arts, "vae_train_step",
                       lambda i, e: (digits,), steps=40)
    assert losses[-1] < losses[0], losses[::10]


# ------------------------------------------------------------- structure

def test_growing_seed_state():
    s = np.array(growing.seed_state(9, 9, 6))
    assert s[4, 4, 3:].tolist() == [1.0, 1.0, 1.0]
    assert s.sum() == 3.0


def test_autoenc3d_wall_mask():
    m = np.array(autoenc3d.wall_mask(8, 6, 6))[..., 0]
    assert m[4].sum() == 1.0          # wall layer: only the hole
    assert m[4, 3, 3] == 1.0          # the hole
    assert m[0].sum() == 36.0         # other layers fully updatable


def test_mnist_frozen_channel_stays():
    cfg = tiny(configs.get_preset("test")["mnist"], height=8, width=8,
               channels=12, hidden=16, batch=2, steps=4)
    params = mnist_classify.init_params(jax.random.PRNGKey(0), cfg)
    digits = rand_digits(8, 2, 8, 8)
    state = mnist_classify.init_state(digits, 12)
    out = mnist_classify._step(params, state, jax.random.PRNGKey(1), digits,
                               cfg)
    np.testing.assert_allclose(np.array(out[..., 0]), np.array(digits))


def test_vae_encode_shapes():
    cfg = tiny(configs.get_preset("test")["vae"], height=10, width=10,
               channels=10, hidden=16, batch=3, steps=4,
               extra={"latent": 4, "enc_hidden": 16, "kl_weight": 1e-3})
    params = vae.init_params(jax.random.PRNGKey(0), cfg)
    mu, logvar = vae.encode(params, rand_digits(9, 3, 10, 10))
    assert mu.shape == (3, 4) and logvar.shape == (3, 4)
