"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes, rules, and random states; discrete CAs must match
EXACTLY, continuous ones to float tolerance. This is the core correctness
signal of the kernel layer (see DESIGN.md §6).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (dwconv, eca_step, life_step, lenia_step,
                             perception_kernels, ring_kernel, rule_to_table)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand_state(seed, shape, binary=False):
    rng = np.random.default_rng(seed)
    x = rng.random(shape).astype(np.float32)
    if binary:
        return (x < 0.5).astype(np.float32)
    return x


# ---------------------------------------------------------------- ECA

@settings(**SETTINGS)
@given(st.integers(0, 255), st.integers(1, 6), st.integers(3, 96),
       st.integers(0, 2**31 - 1))
def test_eca_matches_ref_exactly(rule_num, b, w, seed):
    state = jnp.array(rand_state(seed, (b, w), binary=True))
    rule = rule_to_table(rule_num)
    out = eca_step(state, rule)
    expect = ref.eca_step_ref(state, rule)
    assert out.shape == (b, w)
    np.testing.assert_array_equal(np.array(out), np.array(expect))


def test_eca_rule_table_bits():
    # Rule 110 = 0b01101110: patterns 111->0, 110->1, 101->1, 100->0,
    # 011->1, 010->1, 001->1, 000->0 (table index = pattern value).
    table = np.array(rule_to_table(110))
    assert table.tolist() == [0, 1, 1, 1, 0, 1, 1, 0]


def test_eca_rule_number_bounds():
    with pytest.raises(ValueError):
        rule_to_table(256)
    with pytest.raises(ValueError):
        rule_to_table(-1)


def test_eca_rule0_kills_everything():
    state = jnp.ones((2, 16), dtype=jnp.float32)
    out = eca_step(state, rule_to_table(0))
    assert float(jnp.sum(out)) == 0.0


def test_eca_rule204_is_identity():
    # Rule 204's table is exactly "copy the centre cell".
    state = jnp.array(rand_state(7, (3, 33), binary=True))
    out = eca_step(state, rule_to_table(204))
    np.testing.assert_array_equal(np.array(out), np.array(state))


# ---------------------------------------------------------------- Life

@settings(**SETTINGS)
@given(st.integers(1, 4), st.integers(3, 24), st.integers(3, 24),
       st.integers(0, 2**31 - 1))
def test_life_matches_ref_exactly(b, h, w, seed):
    state = jnp.array(rand_state(seed, (b, h, w), binary=True))
    out = life_step(state)
    expect = ref.life_step_ref(state)
    np.testing.assert_array_equal(np.array(out), np.array(expect))


def test_life_block_is_still():
    """A 2x2 block is a still life."""
    state = np.zeros((1, 8, 8), dtype=np.float32)
    state[0, 3:5, 3:5] = 1.0
    out = life_step(jnp.array(state))
    np.testing.assert_array_equal(np.array(out), state)


def test_life_blinker_oscillates():
    """A period-2 blinker returns to itself after two steps."""
    state = np.zeros((1, 8, 8), dtype=np.float32)
    state[0, 4, 3:6] = 1.0
    s1 = life_step(jnp.array(state))
    s2 = life_step(s1)
    assert not np.array_equal(np.array(s1), state)
    np.testing.assert_array_equal(np.array(s2), state)


def test_life_glider_translates():
    """The glider returns to itself shifted by (1, 1) after 4 steps (wrap)."""
    state = np.zeros((1, 16, 16), dtype=np.float32)
    glider = np.array([[0, 1, 0], [0, 0, 1], [1, 1, 1]], dtype=np.float32)
    state[0, 2:5, 2:5] = glider
    s = jnp.array(state)
    for _ in range(4):
        s = life_step(s)
    np.testing.assert_array_equal(
        np.array(s), np.roll(state, (1, 1), axis=(1, 2))
    )


# ---------------------------------------------------------------- dwconv

@settings(**SETTINGS)
@given(st.integers(2, 24), st.integers(2, 24), st.integers(1, 8),
       st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_dwconv_matches_ref(h, w, c, k, seed):
    state = jnp.array(rand_state(seed, (h, w, c)))
    kernels = jnp.array(rand_state(seed + 1, (3, 3, k)) - 0.5)
    out = dwconv(state, kernels)
    expect = ref.dwconv_ref(state, kernels)
    assert out.shape == (h, w, c * k)
    np.testing.assert_allclose(np.array(out), np.array(expect), atol=1e-5)


def test_dwconv_identity_kernel_is_identity():
    state = jnp.array(rand_state(3, (10, 12, 5)))
    out = dwconv(state, perception_kernels(1))
    np.testing.assert_allclose(np.array(out), np.array(state), atol=1e-6)


def test_dwconv_sobel_zero_on_constant():
    """Gradient kernels must vanish on a constant field (periodic)."""
    state = jnp.ones((8, 8, 3), dtype=jnp.float32) * 0.7
    out = np.array(dwconv(state, perception_kernels(4)))
    out4 = out.reshape(8, 8, 3, 4)
    np.testing.assert_allclose(out4[..., 1:], 0.0, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 12), st.integers(2, 12), st.integers(1, 5),
       st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_dwconv_grad_matches_ref(h, w, c, k, seed):
    state = jnp.array(rand_state(seed, (h, w, c)))
    kernels = jnp.array(rand_state(seed + 1, (3, 3, k)) - 0.5)

    def f(s, kk):
        return jnp.sum(jnp.tanh(dwconv(s, kk)))

    def f_ref(s, kk):
        return jnp.sum(jnp.tanh(ref.dwconv_ref(s, kk)))

    g = jax.grad(f, argnums=(0, 1))(state, kernels)
    gr = jax.grad(f_ref, argnums=(0, 1))(state, kernels)
    # dkern accumulates over H*W*C f32 products: scale tolerance with the
    # magnitude of the reference (pure-atol fails for large reductions).
    np.testing.assert_allclose(np.array(g[0]), np.array(gr[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(g[1]), np.array(gr[1]),
                               rtol=1e-4, atol=1e-4)


def test_perception_kernels_bounds():
    with pytest.raises(ValueError):
        perception_kernels(0)
    with pytest.raises(ValueError):
        perception_kernels(5)


# ---------------------------------------------------------------- Lenia

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.integers(12, 32), st.integers(2, 5),
       st.integers(0, 2**31 - 1))
def test_lenia_matches_ref(b, size, radius, seed):
    state = jnp.array(rand_state(seed, (b, size, size)))
    kern = jnp.array(ring_kernel(radius))
    out = lenia_step(state, kern, mu=0.15, sigma=0.017, dt=0.1,
                     radius=radius)
    expect = jax.vmap(
        lambda s: ref.lenia_step_ref(s, kern, 0.15, 0.017, 0.1)
    )(state)
    np.testing.assert_allclose(np.array(out), np.array(expect), atol=1e-5)


def test_ring_kernel_normalized():
    for r in (3, 5, 10, 13):
        k = ring_kernel(r)
        assert abs(k.sum() - 1.0) < 1e-5
        assert k.min() >= 0.0
        # Centre of the ring kernel is 0 (r=0 excluded).
        assert k[r, r] == 0.0


def test_lenia_fft_equals_direct():
    """The L2 FFT path and the L1 Pallas direct path must agree."""
    from compile.models.classic import lenia_fft_kernel, lenia_step_fft

    size, radius = 32, 5
    state = jnp.array(rand_state(11, (2, size, size)))
    kfft = jnp.array(lenia_fft_kernel(size, radius))
    out_fft = lenia_step_fft(state, kfft, 0.15, 0.017, 0.1)
    kern = jnp.array(ring_kernel(radius))
    out_direct = lenia_step(state, kern, mu=0.15, sigma=0.017, dt=0.1,
                            radius=radius)
    np.testing.assert_allclose(np.array(out_fft), np.array(out_direct),
                               atol=1e-4)


def test_lenia_state_stays_in_unit_interval():
    state = jnp.array(rand_state(5, (1, 24, 24)))
    kern = jnp.array(ring_kernel(4))
    for _ in range(5):
        state = lenia_step(state, kern, mu=0.15, sigma=0.017, dt=0.1,
                           radius=4)
    arr = np.array(state)
    assert arr.min() >= 0.0 and arr.max() <= 1.0
