"""AOT pipeline integrity: artifact collection, lowering, manifest schema."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs


def test_presets_exist():
    for name in ("test", "paper"):
        preset = configs.get_preset(name)
        assert set(preset) == {"classic", "growing", "conditional", "vae",
                               "mnist", "diffusing", "autoenc3d", "arc"}


def test_unknown_preset_raises():
    with pytest.raises(ValueError):
        configs.get_preset("huge")


def test_paper_preset_matches_appendix_a():
    p = configs.get_preset("paper")
    # Table 3 (diffusing): 72x72, 64ch, hidden 256, batch 8, 128 steps.
    d = p["diffusing"]
    assert (d.height, d.width, d.channels, d.hidden, d.batch, d.steps) == \
        (72, 72, 64, 256, 8, 128)
    assert d.lr == 1e-3 and d.dropout == 0.5
    # Table 4 (autoenc3d): (16, 16, 32) spatial, hidden 256, 96 steps.
    z = p["autoenc3d"]
    assert (z.height, z.width, z.depth, z.hidden, z.steps) == \
        (16, 16, 32, 256, 96)
    # Table 5 (arc): width 128, 32 ch, hidden 256, batch 8, 128 steps.
    a = p["arc"]
    assert (a.width, a.channels, a.hidden, a.batch, a.steps) == \
        (128, 32, 256, 8, 128)


def test_collect_artifacts_unique_and_complete():
    arts = aot.collect_artifacts("test")
    names = {a["name"] for a in arts}
    # Table 1 coverage: every CA family present.
    for family in ("eca", "life", "lenia", "growing", "conditional", "vae",
                   "mnist", "arc", "diffusing", "autoenc3d"):
        assert any(family in n for n in names), f"missing family {family}"
    assert len(names) == len(arts)
    for a in arts:
        for (arg_name, s) in a["args"]:
            assert isinstance(arg_name, str)
            aot.dtype_name(s.dtype)  # must not raise


def test_dtype_name_rejects_unknown():
    with pytest.raises(ValueError):
        aot.dtype_name(jnp.float64.dtype)


def test_lower_artifact_roundtrip(tmp_path):
    """Lower one small artifact and validate manifest entry + HLO header."""
    arts = aot.collect_artifacts("test")
    art = next(a for a in arts if a["name"] == "eca_step")
    entry = aot.lower_artifact(art, str(tmp_path))
    assert entry["name"] == "eca_step"
    assert entry["inputs"][0] == {"name": "state", "dtype": "f32",
                                  "shape": [4, 256]}
    assert entry["outputs"][0]["shape"] == [4, 256]
    text = (tmp_path / "eca_step.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_existing_manifest_consistent():
    """If `make artifacts` has run, the manifest must describe real files."""
    mpath = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                         "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet")
    with open(mpath) as f:
        manifest = json.load(f)
    base = os.path.dirname(mpath)
    assert manifest["preset"] in ("test", "paper")
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(base, a["file"])), a["name"]
        assert a["inputs"] is not None and a["outputs"]
    for b in manifest["blobs"]:
        path = os.path.join(base, b["file"])
        assert os.path.exists(path)
        expected = 4
        for dim in b["shape"]:
            expected *= dim
        assert os.path.getsize(path) == expected


def test_blob_params_finite():
    arts = aot.collect_artifacts("test")
    import numpy as np
    for a in arts:
        for name, blob in a.get("blobs", {}).items():
            arr = np.asarray(blob)
            assert np.isfinite(arr).all(), name


def test_no_elided_constants_in_artifacts():
    """The HLO printer must include large literals: elided ``{...}``
    constants re-parse as zeros in the runtime (silently breaking
    perception kernels and masks)."""
    import glob
    import os
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..",
                           "artifacts")
    files = glob.glob(os.path.join(art_dir, "*.hlo.txt"))
    if not files:
        import pytest
        pytest.skip("artifacts not built")
    for f in files:
        text = open(f).read()
        assert "constant({...})" not in text and "{ ... }" not in text, \
            f"{os.path.basename(f)} contains elided constants"
