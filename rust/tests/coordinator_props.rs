//! Property-based tests over coordinator invariants (routing of examples
//! into batches, pool state management, dataset generator semantics) using
//! the in-tree `util::check` shrinking property harness.

use cax::datasets::arc1d::{argmax_colors, one_hot_batch, Task};
use cax::datasets::mnist::{self, MnistConfig};
use cax::pool::SamplePool;
use cax::prop_assert;
use cax::tensor::Tensor;
use cax::util::check::{check, Gen};
use cax::util::rng::Rng;

// ----------------------------------------------------------------- arc1d

#[test]
fn prop_arc_examples_well_formed() {
    // Every generated example, for every task: input/target same width,
    // colors < 10, and input differs from target only when the task demands
    // a transformation (never empty rows).
    check(0x1DA, 150, |g: &mut Gen| {
        let width = g.usize_in(16, 64);
        let task = Task::ALL[g.usize_in(0, Task::ALL.len())];
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let e = task.generate(width, &mut rng);
        prop_assert!(e.input.len() == width, "input width");
        prop_assert!(e.target.len() == width, "target width");
        prop_assert!(e.input.iter().all(|&c| c < 10), "input colors");
        prop_assert!(e.target.iter().all(|&c| c < 10), "target colors");
        prop_assert!(e.input.iter().any(|&c| c != 0), "input non-empty");
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_move_tasks_shift_exactly() {
    // The Move-k family: target is the input circularly shifted k cells
    // right (k = 1, 2, 3) — checked against the generator's own output.
    check(0x11E, 100, |g: &mut Gen| {
        let width = g.usize_in(16, 48);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        for (task, k) in
            [(Task::Move1, 1usize), (Task::Move2, 2), (Task::Move3, 3)]
        {
            let e = task.generate(width, &mut rng);
            let mut shifted = vec![0u8; width];
            for (i, &c) in e.input.iter().enumerate() {
                if c != 0 {
                    shifted[i + k] = c;
                }
            }
            prop_assert!(shifted == e.target, "move-{k} mismatch");
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_denoise_target_is_the_clean_block() {
    check(0xDE01, 100, |g: &mut Gen| {
        let width = g.usize_in(16, 48);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let e = Task::Denoise.generate(width, &mut rng);
        // Target: one contiguous block of a single color.
        let nz: Vec<usize> =
            (0..width).filter(|&i| e.target[i] != 0).collect();
        prop_assert!(!nz.is_empty(), "empty denoise target");
        let color = e.target[nz[0]];
        prop_assert!(nz.windows(2).all(|w| w[1] == w[0] + 1),
                     "target not contiguous");
        prop_assert!(nz.iter().all(|&i| e.target[i] == color),
                     "target not single-colored");
        // Input contains the block plus noise pixels.
        prop_assert!(nz.iter().all(|&i| e.input[i] == color),
                     "block must survive in input");
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_dataset_split_deterministic_and_disjoint_streams() {
    check(0x5EED, 40, |g: &mut Gen| {
        let width = g.usize_in(16, 40);
        let seed = g.usize_in(0, 1 << 20) as u64;
        let task = Task::ALL[g.usize_in(0, Task::ALL.len())];
        let (tr1, te1) = task.dataset(width, 8, 8, seed);
        let (tr2, te2) = task.dataset(width, 8, 8, seed);
        prop_assert!(tr1 == tr2 && te1 == te2, "dataset not deterministic");
        // Train and test streams must differ somewhere (disjoint RNG).
        prop_assert!(tr1 != te1, "train/test streams identical");
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_one_hot_argmax_roundtrip() {
    check(0xA007, 100, |g: &mut Gen| {
        let width = g.usize_in(4, 40);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let row: Vec<u8> =
            (0..width).map(|_| rng.range(0, 10) as u8).collect();
        let batch = one_hot_batch(&[&row], width);
        prop_assert!(batch.shape() == [1, width, 10], "one-hot shape");
        // Exactly one 1 per cell.
        for x in 0..width {
            let s: f32 = (0..10).map(|c| batch.at(&[0, x, c])).sum();
            prop_assert!((s - 1.0).abs() < 1e-6, "not one-hot at {x}");
        }
        let back = argmax_colors(&batch);
        prop_assert!(back[0] == row, "argmax(one_hot(row)) != row");
        Ok(())
    })
    .unwrap();
}

// ------------------------------------------------------------------ pool

#[test]
fn prop_pool_sample_writeback_cycle_preserves_untouched_slots() {
    check(0x9001, 80, |g: &mut Gen| {
        let cap = g.usize_in(2, 10);
        let shape = [g.usize_in(1, 4), g.usize_in(1, 4)];
        let seed_state = Tensor::full(&shape, 0.5);
        let mut pool = SamplePool::new(cap, &seed_state);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let rounds = g.usize_in(1, 6);
        let mut last_written: Vec<Option<f32>> = vec![None; cap];
        for round in 0..rounds {
            let b = g.usize_in(1, cap + 1).min(cap);
            let (idx, mut batch) = pool.sample(b, &mut rng);
            let stamp = (round + 1) as f32;
            batch.data_mut().iter_mut().for_each(|v| *v = stamp);
            pool.write_back(&idx, &batch);
            for &i in &idx {
                last_written[i] = Some(stamp);
            }
            for i in 0..cap {
                let expect = last_written[i].unwrap_or(0.5);
                prop_assert!(
                    pool.entry(i).at(&[0, 0]) == expect,
                    "slot {i} expected {expect}"
                );
            }
        }
        Ok(())
    })
    .unwrap();
}

// ----------------------------------------------------------------- mnist

#[test]
fn prop_digit_corpus_labeled_and_normalized() {
    check(0xD161, 60, |g: &mut Gen| {
        let h = g.usize_in(12, 20);
        let w = g.usize_in(12, 20);
        let cfg = MnistConfig::for_grid(h, w);
        let seed = g.usize_in(0, 1 << 20) as u64;
        let digits = mnist::dataset(10, &cfg, seed);
        prop_assert!(digits.len() == 10, "corpus size");
        for d in &digits {
            prop_assert!(d.label < 10, "label range");
            prop_assert!(d.image.shape() == [h, w], "image shape");
            let (mut lo, mut hi, mut ink) = (f32::MAX, f32::MIN, 0);
            for &v in d.image.data() {
                lo = lo.min(v);
                hi = hi.max(v);
                if v > 0.1 {
                    ink += 1;
                }
            }
            prop_assert!(lo >= 0.0 && hi <= 1.0, "pixel range");
            prop_assert!(ink > 5, "digit has almost no ink");
            prop_assert!(ink < h * w / 2, "digit floods the grid");
        }
        // All ten classes appear (dataset cycles labels).
        let mut seen = [false; 10];
        for d in &digits {
            seen[d.label as usize] = true;
        }
        prop_assert!(seen.iter().all(|&s| s), "not all classes present");
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_batching_helpers_agree_with_sources() {
    check(0xBA7C, 60, |g: &mut Gen| {
        let cfg = MnistConfig::for_grid(12, 12);
        let digits = mnist::dataset(6, &cfg, g.usize_in(0, 1 << 20) as u64);
        let refs: Vec<&mnist::Digit> = digits.iter().collect();
        let images = mnist::batch_images(&refs);
        let labels = mnist::batch_labels(&refs);
        prop_assert!(images.shape() == [6, 12, 12], "image batch shape");
        prop_assert!(labels.shape() == [6, 10], "label batch shape");
        for (i, d) in digits.iter().enumerate() {
            prop_assert!(images.index_axis0(i).bit_eq(&d.image),
                         "image {i} corrupted by batching");
            let onehot_sum: f32 =
                (0..10).map(|c| labels.at(&[i, c])).sum();
            prop_assert!((onehot_sum - 1.0).abs() < 1e-6, "label one-hot");
            prop_assert!(labels.at(&[i, d.label as usize]) == 1.0,
                         "label position");
        }
        Ok(())
    })
    .unwrap();
}

// ------------------------------------------------------------------- rng

#[test]
fn prop_rng_streams_fold_in_independent() {
    check(0xF01D, 60, |g: &mut Gen| {
        let seed = g.usize_in(0, 1 << 30) as u64;
        let mut a = Rng::new(seed).fold_in(1);
        let mut b = Rng::new(seed).fold_in(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        prop_assert!(xs != ys, "fold_in streams collide");
        // Determinism.
        let mut a2 = Rng::new(seed).fold_in(1);
        let xs2: Vec<u64> = (0..8).map(|_| a2.next_u64()).collect();
        prop_assert!(xs == xs2, "stream not reproducible");
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_sample_indices_distinct_in_range() {
    check(0x5A3B, 100, |g: &mut Gen| {
        let n = g.usize_in(1, 50);
        let k = g.usize_in(0, n + 1).min(n);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let idx = rng.sample_indices(n, k);
        prop_assert!(idx.len() == k, "wrong count");
        prop_assert!(idx.iter().all(|&i| i < n), "out of range");
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert!(sorted.len() == k, "duplicates");
        Ok(())
    })
    .unwrap();
}

// ---------------------------------------------------------------- tensor

#[test]
fn prop_tensor_stack_index_roundtrip() {
    check(0x7E50, 80, |g: &mut Gen| {
        let n = g.usize_in(1, 6);
        let shape = [g.usize_in(1, 5), g.usize_in(1, 5)];
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let parts: Vec<Tensor> = (0..n)
            .map(|_| {
                Tensor::new(shape.to_vec(),
                            rng.vec_f32(shape.iter().product()))
                    .unwrap()
            })
            .collect();
        let stacked = Tensor::stack(&parts).unwrap();
        for (i, p) in parts.iter().enumerate() {
            prop_assert!(stacked.index_axis0(i).bit_eq(p),
                         "roundtrip failed at {i}");
        }
        Ok(())
    })
    .unwrap();
}
