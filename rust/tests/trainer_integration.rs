//! Training integration.
//!
//! Default features: the native train path — growing NCA actually
//! learns (hand-rolled BPTT + Adam, sample pool, no artifacts), the
//! 1D-ARC NCA learns a Table-2 task to nonzero exact-match, and
//! checkpoints round-trip through `TrainState`.
//!
//! With `--features pjrt` (+ artifacts): each neural CA's fused train
//! step learns, checkpoints round-trip, and the stepwise BPTT baseline
//! computes the same math as the fused artifact.

use cax::backend::native::opt::LrSchedule;
use cax::backend::native::train::{
    ArcTrainSpec, NativeTrainBackend, NcaTrainSpec,
};
use cax::backend::ProgramBackend;
use cax::coordinator::trainer::{train_loop, TrainCfg, TrainState};
use cax::coordinator::{evaluator, experiments};
use cax::datasets::arc1d::Task;
use cax::datasets::mnist::{self, MnistConfig};
use cax::runtime::Value;

#[cfg(feature = "pjrt")]
mod common;

fn quick_cfg(steps: usize) -> TrainCfg {
    TrainCfg { steps, seed: 1, log_every: 0, out_dir: None }
}

/// Test-sized native training backend: small grids keep the ≤200-step
/// runs fast in debug builds while exercising every code path (pool
/// sampling, worst-of-batch reseed, BPTT, clip, Adam, write-back).
fn native_backend() -> NativeTrainBackend {
    let growing = NcaTrainSpec {
        height: 8,
        width: 8,
        channels: 6,
        hidden: 16,
        batch: 3,
        rollout_min: 5,
        rollout_max: 7,
        lr: LrSchedule::constant(3e-3),
        ..NcaTrainSpec::growing()
    };
    let mnist = NcaTrainSpec {
        height: 10,
        width: 10,
        channels: 12,
        hidden: 12,
        batch: 2,
        rollout_min: 4,
        rollout_max: 6,
        lr: LrSchedule::constant(3e-3),
        ..NcaTrainSpec::mnist()
    };
    NativeTrainBackend::with_specs(growing, mnist, 4)
}

#[test]
fn native_growing_nca_loss_halves() {
    let backend = native_backend();
    let cfg = quick_cfg(200);
    let (run, pool) =
        experiments::train_growing(&backend, &cfg, 16).unwrap();
    let initial = run.history.values()[0];
    let (_, last) = run.history.window_means(10);
    assert!(last <= 0.5 * initial,
            "growing (native): loss {initial:.5} -> {last:.5}, \
             wanted <= {:.5}", 0.5 * initial);
    assert_eq!(pool.writes(), 200, "one pool write-back per step");
    assert!(pool.mean_age() < 16.0);
}

/// The 200-step native 1D-ARC acceptance run: the §5.3 pipeline —
/// generate a split, train with `arc_train_step` through the shared
/// experiments driver, score the paper's exact-match criterion — must
/// halve the loss and solve held-out examples, hermetically. The
/// prototype-validated margins are wide (loss ratio ~0.02-0.05 and
/// exact-match ~1.0 on Move-1 across seeds for this geometry).
#[test]
fn native_arc_nca_learns_move1_to_nonzero_exact_match() {
    let spec = ArcTrainSpec {
        width: 16,
        extra: 2,
        hidden: 24,
        batch: 4,
        rollout_min: 8,
        rollout_max: 12,
        eval_steps: 10,
        ..ArcTrainSpec::default()
    };
    let backend = NativeTrainBackend::with_arc_spec(spec, 4);
    let task = Task::Move1;
    let (train_set, test_set) =
        experiments::arc_split(&backend, task, 64, 16, 11).unwrap();
    assert_eq!(train_set[0].input.len(), 16, "split at the spec width");

    let run = experiments::train_arc(&backend, &quick_cfg(200), task,
                                     &train_set)
        .unwrap();
    let initial = run.history.values()[0];
    let (_, last) = run.history.window_means(10);
    assert!(last <= 0.5 * initial,
            "arc (native): loss {initial:.5} -> {last:.5}, wanted <= {:.5}",
            0.5 * initial);

    let acc = evaluator::arc_accuracy(&backend, &run.state.params,
                                      &test_set)
        .unwrap();
    assert!(acc > 0.0,
            "Move-1 must solve at least one held-out example exactly \
             (got {acc})");
}

#[test]
fn native_checkpoint_roundtrip_through_train_state() {
    let backend = native_backend();
    let (run, _) =
        experiments::train_growing(&backend, &quick_cfg(6), 8).unwrap();
    let dir = std::env::temp_dir()
        .join(format!("cax_native_ckpt_{}", std::process::id()));
    let path = dir.join("growing.params.bin");
    run.state.save(&path).unwrap();
    let loaded = TrainState::load(&path).unwrap();
    assert!(loaded.params.bit_eq(&run.state.params));
    assert_eq!(loaded.step, 0, "Adam state resets on load");
    std::fs::remove_dir_all(&dir).ok();

    // The reloaded checkpoint drives further native train steps.
    let mut state = loaded;
    let spec = backend.growing_spec().clone();
    let target = experiments::growing_target(&backend).unwrap();
    let seed_state = experiments::growing_seed(&backend).unwrap();
    let states =
        cax::Tensor::stack(&vec![seed_state; spec.batch]).unwrap();
    let history = train_loop(
        &backend,
        "growing_train_step",
        &mut state,
        &quick_cfg(2),
        |_| Ok(vec![Value::F32(states.clone()),
                    Value::F32(target.clone())]),
        |_| Ok(()),
    )
    .unwrap();
    assert_eq!(history.len(), 2);
    assert!(state.params.max_abs_diff(&run.state.params).unwrap() > 0.0,
            "resumed training must keep moving the params");
}

#[test]
fn native_mnist_train_smoke() {
    // Short self-classifying-MNIST run through the same experiments
    // driver the CLI uses: losses finite, parameters move.
    let backend = native_backend();
    let initial = backend.load_params("mnist_params").unwrap();
    let run = experiments::train_mnist(&backend, &quick_cfg(20)).unwrap();
    assert_eq!(run.history.len(), 20);
    assert!(run.history.values().iter().all(|l| l.is_finite()));
    assert!(run.state.params.max_abs_diff(&initial).unwrap() > 0.0);
    assert_eq!(run.state.step, 20);
}

#[test]
fn native_train_loop_rejects_unknown_artifacts() {
    let backend = native_backend();
    let mut state =
        TrainState::from_blob(&backend, "growing_params").unwrap();
    let err = train_loop(
        &backend,
        "not_a_program",
        &mut state,
        &quick_cfg(1),
        |_| Ok(vec![]),
        |_| Ok(()),
    )
    .expect_err("unknown program must be rejected");
    assert!(format!("{err:#}").contains("not in manifest"));
}

/// MnistConfig is exercised on the native geometry too (the pjrt suite
/// below covers the artifact grids).
#[test]
fn native_mnist_batches_fit_the_manifest_spec() {
    let backend = native_backend();
    let info = backend.manifest().artifact("mnist_train_step").unwrap();
    let spec = &info.inputs[4];
    let (b, h, w) = (spec.shape[0], spec.shape[1], spec.shape[2]);
    let digits = mnist::dataset(b, &MnistConfig::for_grid(h, w), 5);
    let refs: Vec<&mnist::Digit> = digits.iter().collect();
    assert_eq!(mnist::batch_images(&refs).shape(), &[b, h, w]);
    assert_eq!(mnist::batch_labels(&refs).shape(), &[b, 10]);
}

#[cfg(feature = "pjrt")]
mod pjrt_path {
    use cax::coordinator::trainer::{TrainCfg, TrainState};
    use cax::coordinator::{experiments, stepwise};
    use cax::datasets::arc1d::Task;
    use cax::datasets::mnist::{self, MnistConfig};
    use cax::runtime::Value;

    use crate::common::engine;

    fn quick_cfg(steps: usize) -> TrainCfg {
        TrainCfg { steps, seed: 3, log_every: 0, out_dir: None }
    }

    #[test]
    fn growing_nca_loss_decreases_with_pool() {
        let engine = engine();
        let (run, pool) =
            experiments::train_growing(&engine, &quick_cfg(40), 32)
                .unwrap();
        let (first, last) = run.history.window_means(8);
        assert!(last < first, "growing loss {first:.5} -> {last:.5}");
        assert_eq!(pool.writes(), 40, "one pool write-back per step");
        assert!(pool.mean_age() < 32.0);
    }

    #[test]
    fn diffusing_nca_loss_decreases_without_pool() {
        let engine = engine();
        let run =
            experiments::train_diffusing(&engine, &quick_cfg(40)).unwrap();
        let (first, last) = run.history.window_means(8);
        assert!(last < first, "diffusing loss {first:.5} -> {last:.5}");
    }

    #[test]
    fn conditional_nca_loss_decreases() {
        let engine = engine();
        let run = experiments::train_conditional(&engine, &quick_cfg(40))
            .unwrap();
        let (first, last) = run.history.window_means(8);
        assert!(last < first, "conditional loss {first:.5} -> {last:.5}");
    }

    #[test]
    fn vae_nca_loss_decreases() {
        let engine = engine();
        let run = experiments::train_vae(&engine, &quick_cfg(40)).unwrap();
        let (first, last) = run.history.window_means(8);
        assert!(last < first, "vae loss {first:.5} -> {last:.5}");
    }

    #[test]
    fn mnist_nca_loss_decreases() {
        let engine = engine();
        let run = experiments::train_mnist(&engine, &quick_cfg(40)).unwrap();
        let (first, last) = run.history.window_means(8);
        assert!(last < first, "mnist loss {first:.5} -> {last:.5}");
    }

    #[test]
    fn autoenc3d_loss_decreases() {
        // The 3D bottleneck task learns slowly on a rotating corpus;
        // overfit a single fixed batch instead — same fused BPTT path,
        // reliable signal.
        let engine = engine();
        let info =
            engine.manifest().artifact("autoenc3d_train_step").unwrap();
        let spec = &info.inputs[4];
        let (b, h, w) = (spec.shape[0], spec.shape[1], spec.shape[2]);
        let digits = mnist::dataset(b, &MnistConfig::for_grid(h, w), 5);
        let refs: Vec<&mnist::Digit> = digits.iter().collect();
        let batch = mnist::batch_images(&refs);
        let mut state =
            TrainState::from_blob(&engine, "autoenc3d_params").unwrap();
        let history = cax::coordinator::train_loop(
            &engine,
            "autoenc3d_train_step",
            &mut state,
            &quick_cfg(80),
            |_| Ok(vec![cax::runtime::Value::F32(batch.clone())]),
            |_| Ok(()),
        )
        .unwrap();
        let (first, last) = {
            let v = history.values();
            (v[..10].iter().sum::<f64>() / 10.0,
             v[v.len() - 10..].iter().sum::<f64>() / 10.0)
        };
        assert!(last < first, "autoenc3d loss {first:.5} -> {last:.5}");
    }

    #[test]
    fn arc_nca_learns_an_easy_task() {
        let engine = engine();
        let task = Task::Move1;
        let (train_set, test_set) =
            experiments::arc_split(&engine, task, 96, 16, 7).unwrap();
        let run = experiments::train_arc(&engine, &quick_cfg(120), task,
                                         &train_set)
            .unwrap();
        let (first, last) = run.history.window_means(10);
        assert!(last < first, "arc loss {first:.5} -> {last:.5}");
        let acc = cax::coordinator::evaluator::arc_pixel_accuracy(
            &engine, &run.state.params, &test_set,
        )
        .unwrap();
        // Move1 is near-trivial for the NCA (paper: 100% exact match);
        // after a short run per-pixel accuracy must already beat the
        // 0.1 color prior.
        assert!(acc > 0.5, "Move1 per-pixel accuracy only {acc:.3}");
    }

    #[test]
    fn checkpoint_roundtrip_preserves_params() {
        let engine = engine();
        let run =
            experiments::train_diffusing(&engine, &quick_cfg(6)).unwrap();
        let dir = std::env::temp_dir()
            .join(format!("cax_ckpt_{}", std::process::id()));
        let path = dir.join("diffusing.params.bin");
        run.state.save(&path).unwrap();
        let loaded = TrainState::load(&path).unwrap();
        assert!(loaded.params.bit_eq(&run.state.params));
        assert_eq!(loaded.step, 0, "Adam state resets on load");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_loop_rejects_non_train_artifacts() {
        let engine = engine();
        let mut state =
            TrainState::from_blob(&engine, "growing_params").unwrap();
        let err = cax::coordinator::train_loop(
            &engine,
            "eca_step", // not a train step
            &mut state,
            &quick_cfg(1),
            |_| Ok(vec![]),
            |_| Ok(()),
        )
        .expect_err("eca_step must be rejected");
        assert!(format!("{err:#}").contains("train step"));
    }

    /// The fused mnist train step and the host-driven stepwise BPTT
    /// baseline implement the same math: starting from identical
    /// (params, m, v) and the same batch + seed, both must produce
    /// finite, comparable losses and move the parameters.
    /// (Bit-identity is not required: the fused path reduces gradients
    /// in a different order.)
    #[test]
    fn stepwise_and_fused_mnist_losses_agree_at_step_zero() {
        let engine = engine();
        let info = engine.manifest().artifact("mnist_train_step").unwrap();
        let spec = &info.inputs[4];
        let (b, h, w) = (spec.shape[0], spec.shape[1], spec.shape[2]);
        let digits = mnist::dataset(b, &MnistConfig::for_grid(h, w), 99);
        let refs: Vec<&mnist::Digit> = digits.iter().collect();
        let images = mnist::batch_images(&refs);
        let labels = mnist::batch_labels(&refs);

        // Fused.
        let st = TrainState::from_blob(&engine, "mnist_params").unwrap();
        let out = engine
            .execute(
                "mnist_train_step",
                &[
                    Value::F32(st.params.clone()),
                    Value::F32(st.m.clone()),
                    Value::F32(st.v.clone()),
                    Value::I32(0),
                    Value::F32(images.clone()),
                    Value::F32(labels.clone()),
                    Value::U32(5),
                ],
            )
            .unwrap();
        let fused_loss = out[3].data()[0] as f64;
        let fused_params = &out[0];

        // Stepwise (same seed -> same in-graph dropout masks per step).
        let mut st2 = TrainState::from_blob(&engine, "mnist_params")
            .unwrap();
        let stepwise_loss = stepwise::mnist_stepwise_train_step(
            &engine, &mut st2.params, &mut st2.m, &mut st2.v, 0, &images,
            &labels, 1e-3, 5,
        )
        .unwrap();

        assert!(fused_loss.is_finite() && stepwise_loss.is_finite());
        let rel = (fused_loss - stepwise_loss).abs()
            / fused_loss.abs().max(1e-9);
        assert!(rel < 0.05,
                "losses diverge: fused {fused_loss:.6} vs stepwise \
                 {stepwise_loss:.6}");
        // Both must actually move the parameters.
        assert!(fused_params.max_abs_diff(&st.params).unwrap() > 0.0);
        assert!(st2.params.max_abs_diff(&st.params).unwrap() > 0.0);
    }

    #[test]
    fn damage_protocol_reports_sane_mse_ordering() {
        // Protocol sanity independent of training quality: inject the
        // target RGBA as the "developed" state (develop_rounds = 0),
        // amputate, and check the MSE ordering + curve bookkeeping.
        // (Whether a briefly-trained NCA heals is a *result*, not an
        // invariant — cax-tables fig5 reports that.)
        let engine = engine();
        let cfg = quick_cfg(20);
        let run = experiments::train_diffusing(&engine, &cfg).unwrap();
        let info =
            engine.manifest().artifact("diffusing_rollout").unwrap();
        let shape = info.inputs[1].shape.clone();
        let target = cax::datasets::targets::Sprite::Lizard
            .render(shape[0], shape[1]);
        // Developed state = target painted into the RGBA channels.
        let mut developed = cax::Tensor::zeros(&shape);
        for y in 0..shape[0] {
            for x in 0..shape[1] {
                for c in 0..4 {
                    developed.set(&[y, x, c], target.at(&[y, x, c]));
                }
            }
        }
        let report = cax::coordinator::damage::run_damage_trial(
            &engine, "diffusing_rollout", &run.state.params, developed,
            &target, 0, 1, true,
            cax::coordinator::damage::DamageMode::Noise, 9,
        )
        .unwrap();
        assert!(report.pre_damage_mse < 1e-9,
                "target-injected state: {report:?}");
        assert!(report.post_damage_mse > report.pre_damage_mse,
                "damage must hurt: {report:?}");
        let steps = engine
            .manifest()
            .artifact("diffusing_rollout")
            .unwrap()
            .meta_usize("steps")
            .unwrap();
        assert_eq!(report.curve.len(), steps,
                   "one curve point per traj frame");
        assert!(report.recovery_fraction() >= 0.0
                && report.recovery_fraction() <= 1.0);
    }
}
