//! Property and contract tests for `cax::obs` — the observability
//! layer's promises, checked from outside the crate:
//!
//! - histogram percentiles track exact sorted-sample percentiles within
//!   the documented log-bucket relative error;
//! - `merge_from` is associative and commutative (snapshot-equal), so
//!   per-thread histograms can be combined in any order;
//! - spans record into the global registry when recording is on, are
//!   no-ops when it is off, and cost little either way;
//! - a trace capture round-trips through the Chrome Trace Event JSON
//!   writer and parses back with `util::json`.
//!
//! Tests that touch process-global state (recording flag, trace
//! capture, global registry, log level) serialize on one mutex so the
//! default multi-threaded test runner cannot interleave them.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use cax::obs::{
    self, log, trace, Gauge, Histogram, HistogramSnapshot, MetricSnapshot,
};
use cax::util::json::Json;
use cax::util::timer::percentile;

/// Serializes tests that flip process-global obs state.
static GLOBAL_STATE: Mutex<()> = Mutex::new(());

fn global_lock() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_STATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Deterministic pseudo-random u64 stream (splitmix64) — no external
/// rand crate, same values on every run.
fn splitmix(seed: &mut u64) -> u64 {
    *seed = seed.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *seed;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[test]
fn histogram_percentiles_track_exact_percentiles() {
    // Values spanning six decades — the regime latencies live in.
    let mut seed = 7u64;
    let mut values: Vec<u64> = (0..4000)
        .map(|_| {
            let magnitude = 1u64 << (10 + (splitmix(&mut seed) % 20));
            magnitude + splitmix(&mut seed) % magnitude
        })
        .collect();
    let h = Histogram::new();
    for &v in &values {
        h.record(v);
    }
    values.sort_unstable();
    let exact: Vec<f64> = values.iter().map(|&v| v as f64).collect();

    for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
        let approx = h.quantile(q);
        let truth = percentile(&exact, q);
        // Log-linear buckets with SUB_BITS=5 bound relative error by
        // 2^-5 ≈ 3.1%; allow 5% for rank-interpolation differences.
        let tol = truth * 0.05 + 1.0;
        assert!(
            (approx - truth).abs() <= tol,
            "q={q}: histogram {approx} vs exact {truth} (tol {tol})"
        );
    }
    assert_eq!(h.count(), 4000);
}

#[test]
fn histogram_merge_is_associative_and_commutative() {
    let make = |lo: u64, n: u64| {
        let h = Histogram::new();
        for i in 0..n {
            h.record(lo + i * 17);
        }
        h
    };
    let (a, b, c) = (make(1, 100), make(1_000, 50), make(1 << 20, 200));

    // (a ∪ b) ∪ c
    let left = Histogram::new();
    left.merge_from(&a);
    left.merge_from(&b);
    left.merge_from(&c);
    // a ∪ (b ∪ c)
    let bc = Histogram::new();
    bc.merge_from(&b);
    bc.merge_from(&c);
    let right = Histogram::new();
    right.merge_from(&a);
    right.merge_from(&bc);
    // c ∪ b ∪ a
    let rev = Histogram::new();
    rev.merge_from(&c);
    rev.merge_from(&b);
    rev.merge_from(&a);

    assert_eq!(left.snapshot(), right.snapshot(), "associativity");
    assert_eq!(left.snapshot(), rev.snapshot(), "commutativity");
    assert_eq!(left.count(), 350);
    let snap = left.snapshot();
    assert_eq!(snap.min, 1);
    assert_eq!(snap.max, (1 << 20) + 199 * 17);
}

#[test]
fn empty_histogram_is_well_defined() {
    let h = Histogram::new();
    assert!(h.is_empty());
    let snap = h.snapshot();
    assert_eq!(snap.quantile(0.5), 0.0);
    assert_eq!(snap.mean(), 0.0);
    assert_eq!(snap.cumulative_le(u64::MAX), 0);
}

#[test]
fn gauge_tracks_value_and_high_water() {
    let g = Gauge::default();
    g.set(3);
    g.set(12);
    g.set(5);
    assert_eq!(g.get(), 5);
    assert_eq!(g.high_water(), 12);
}

#[test]
fn registry_interns_metrics_by_name() {
    let reg = obs::Registry::new();
    let a = reg.histogram("x_seconds");
    let b = reg.histogram("x_seconds");
    a.record(10);
    assert_eq!(b.count(), 1, "same name must return the same histogram");
    let c1 = reg.counter("hits_total");
    reg.counter("hits_total").add(4);
    assert_eq!(c1.get(), 4);
    assert_eq!(reg.len(), 2);
}

#[test]
fn span_records_into_the_global_registry() {
    let _guard = global_lock();
    obs::set_recording(true);
    let hist = obs::Registry::global()
        .histogram("obs_props_probe_seconds");
    let before = hist.count();
    {
        let _span = obs::span("obs_props_probe");
        std::hint::black_box(());
    }
    assert_eq!(hist.count(), before + 1);
}

#[test]
fn span_is_a_noop_with_recording_off() {
    let _guard = global_lock();
    obs::set_recording(false);
    let hist = obs::Registry::global()
        .histogram("obs_props_noop_seconds");
    let before = hist.count();
    {
        let _span = obs::span("obs_props_noop");
    }
    assert_eq!(hist.count(), before, "disabled spans must not record");
    obs::set_recording(true);
}

#[test]
fn span_overhead_smoke() {
    let _guard = global_lock();
    obs::set_recording(true);
    let n = 10_000u32;
    let t = Instant::now();
    for _ in 0..n {
        let _span = obs::span("obs_props_overhead");
    }
    let per_span = t.elapsed().as_secs_f64() / n as f64;
    // Two Instant reads + one histogram record; generous bound so slow
    // CI machines never flake (the real budget is the serve_load bench).
    assert!(
        per_span < 50e-6,
        "span create/drop took {per_span:.2e}s each"
    );
}

#[test]
fn trace_capture_roundtrips_through_json() {
    let _guard = global_lock();
    trace::start_with_capacity(64);
    assert!(trace::active());
    let t0 = Instant::now();
    trace::record_complete("obs_props_launch", t0,
                           Duration::from_micros(250));
    trace::counter("obs_props_depth", 3.0);
    {
        // An armed span must land in the capture too.
        let _span = obs::span("obs_props_spanned");
    }

    let dir = std::env::temp_dir()
        .join(format!("cax_obs_props_{}", std::process::id()));
    let path = dir.join("trace.json");
    let written = trace::write(&path).expect("trace write");
    assert!(!trace::active(), "write must disarm the capture");
    assert_eq!(written, 3);

    let text = std::fs::read_to_string(&path).unwrap();
    let doc = Json::parse(&text).expect("trace JSON must parse");
    assert_eq!(
        doc.get("displayTimeUnit").and_then(Json::as_str),
        Some("ms")
    );
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert_eq!(events.len(), 3);
    let names: Vec<&str> = events
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    assert!(names.contains(&"obs_props_launch"));
    assert!(names.contains(&"obs_props_depth"));
    assert!(names.contains(&"obs_props_spanned"));
    let counter_ev = events
        .iter()
        .find(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
        .expect("counter event");
    assert_eq!(
        counter_ev
            .get("args")
            .and_then(|a| a.get("value"))
            .and_then(Json::as_f64),
        Some(3.0)
    );
    let span_ev = events
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str)
                  == Some("obs_props_launch"))
        .unwrap();
    assert_eq!(span_ev.get("ph").and_then(Json::as_str), Some("X"));
    let dur = span_ev.get("dur").and_then(Json::as_f64).unwrap();
    assert!((dur - 250.0).abs() < 1.0, "dur is microseconds (got {dur})");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_buffer_bounds_drops_instead_of_growing() {
    let _guard = global_lock();
    trace::start_with_capacity(4);
    let t0 = Instant::now();
    for _ in 0..10 {
        trace::record_complete("obs_props_flood", t0, Duration::ZERO);
    }
    let held = trace::stop();
    assert_eq!(held, 4, "buffer must cap at its capacity");
    assert!(!trace::active());
}

#[test]
fn metric_snapshots_roundtrip_json_bit_identically() {
    // Histogram: a wide spread of samples, round-tripped through the
    // `/metrics.json` wire format, must come back `PartialEq`-equal —
    // and a live histogram rebuilt from the parsed snapshot must
    // merge identically to merging the original directly.
    let mut seed = 11u64;
    let h = Histogram::new();
    for _ in 0..2500 {
        let magnitude = 1u64 << (4 + (splitmix(&mut seed) % 30));
        h.record(magnitude + splitmix(&mut seed) % magnitude);
    }
    let snap = h.snapshot();
    let wire = snap.to_json().to_string_compact();
    let back = HistogramSnapshot::from_json(&Json::parse(&wire).unwrap())
        .expect("histogram from_json");
    assert_eq!(snap, back, "snapshot -> JSON -> snapshot must be exact");

    let rebuilt = Histogram::from_snapshot(&back);
    let via_rebuilt = Histogram::new();
    via_rebuilt.merge_from(&rebuilt);
    let direct = Histogram::new();
    direct.merge_from(&h);
    assert_eq!(
        via_rebuilt.snapshot(),
        direct.snapshot(),
        "merging a JSON-round-tripped histogram must be bit-identical \
         to merging the original"
    );

    // Counter and gauge snapshots ride the same tagged encoding.
    let scalars = [
        MetricSnapshot::Counter(12_345),
        MetricSnapshot::Gauge { value: 7, high_water: 40 },
    ];
    for m in &scalars {
        let wire = m.to_json().to_string_compact();
        let back =
            MetricSnapshot::from_json(&Json::parse(&wire).unwrap())
                .expect("metric from_json");
        assert_eq!(*m, back);
    }

    // Empty histograms survive the trip: the internal min/max
    // sentinels are not JSON-representable and must be restored.
    let empty = Histogram::new().snapshot();
    let wire = empty.to_json().to_string_compact();
    assert_eq!(
        empty,
        HistogramSnapshot::from_json(&Json::parse(&wire).unwrap())
            .unwrap()
    );

    // And the whole named-metric map round-trips in order.
    let named = vec![
        ("a_total".to_string(), MetricSnapshot::Counter(3)),
        ("b_seconds".to_string(), MetricSnapshot::Histogram(snap)),
    ];
    let wire = obs::metrics_to_json(&named).to_string_compact();
    let back = obs::metrics_from_json(&Json::parse(&wire).unwrap())
        .expect("metrics_from_json");
    assert_eq!(named, back);
}

#[test]
fn fleet_merge_of_scraped_snapshots_is_exact() {
    // Three "shards" record disjoint latency populations. Merging
    // their JSON-round-tripped snapshots (exactly what the shard
    // router does with scraped `/metrics.json` documents) must equal
    // one histogram that saw every sample directly — so a fleet
    // quantile is the quantile of the union of the shards' samples,
    // never an average of per-shard percentiles.
    let mut seed = 23u64;
    let union = Histogram::new();
    let mut merged: Option<HistogramSnapshot> = None;
    for shard in 0..3u64 {
        let h = Histogram::new();
        for _ in 0..1000 {
            let v = (1u64 << (6 + 4 * shard))
                + splitmix(&mut seed) % 100_000;
            h.record(v);
            union.record(v);
        }
        let wire = h.snapshot().to_json().to_string_compact();
        let snap =
            HistogramSnapshot::from_json(&Json::parse(&wire).unwrap())
                .unwrap();
        match &mut merged {
            None => merged = Some(snap),
            Some(m) => m.merge_from(&snap),
        }
    }
    let merged = merged.unwrap();
    let union_snap = union.snapshot();
    assert_eq!(merged, union_snap, "bucket-exact fleet merge");
    for q in [0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
        assert_eq!(
            merged.quantile(q),
            union_snap.quantile(q),
            "fleet q={q} must equal the union's quantile exactly"
        );
    }

    // The typed wrapper merges with the same semantics, and gauges
    // aggregate as sum-of-now / max-of-high-water.
    let mut a = MetricSnapshot::Histogram(merged.clone());
    let b = MetricSnapshot::Histogram(union_snap.clone());
    a.merge_from(&b);
    match a {
        MetricSnapshot::Histogram(h) => {
            assert_eq!(h.count, 2 * union_snap.count)
        }
        _ => unreachable!(),
    }
    let mut g = MetricSnapshot::Gauge { value: 4, high_water: 9 };
    g.merge_from(&MetricSnapshot::Gauge { value: 3, high_water: 7 });
    assert_eq!(g, MetricSnapshot::Gauge { value: 7, high_water: 9 });
}

#[test]
fn log_levels_parse_and_gate() {
    let _guard = global_lock();
    assert_eq!(log::Level::parse("debug"), Some(log::Level::Debug));
    assert_eq!(log::Level::parse("WARN"), Some(log::Level::Warn));
    assert_eq!(log::Level::parse("warning"), Some(log::Level::Warn));
    assert_eq!(log::Level::parse("nope"), None);

    let prev = log::level();
    log::set_level(log::Level::Error);
    assert!(log::enabled(log::Level::Error));
    assert!(!log::enabled(log::Level::Info));
    log::set_level(log::Level::Debug);
    assert!(log::enabled(log::Level::Info));
    log::set_level(prev);
}
