//! Smoke tests: every artifact in the manifest loads, compiles and executes
//! with manifest-shaped inputs, and returns manifest-shaped outputs.
//!
//! This is the L3 half of the build contract — aot.py promises signatures
//! in manifest.json; these tests hold the runtime to them.
//!
//! Needs the PJRT engine + artifacts: `cargo test --features pjrt`.
#![cfg(feature = "pjrt")]

use cax::runtime::{Engine, Value};
use cax::tensor::Tensor;
use cax::util::rng::Rng;

mod common;
use common::engine;

/// Build plausible inputs for an artifact straight from its manifest spec.
fn synth_inputs(engine: &Engine, name: &str, rng: &mut Rng) -> Vec<Value> {
    let info = engine.manifest().artifact(name).unwrap();
    info.inputs
        .iter()
        .map(|spec| match spec.dtype {
            cax::runtime::Dtype::F32 => {
                // Parameters come from their blob when one exists (random
                // parameters can NaN out some train steps); states/batches
                // are random in [0, 1).
                if spec.name == "params" {
                    for e in cax::coordinator::registry::table1() {
                        if e.artifacts.contains(&name) {
                            if let Some(blob) = e.params_blob {
                                return Value::F32(
                                    engine.load_params(blob).unwrap(),
                                );
                            }
                        }
                    }
                }
                Value::F32(
                    Tensor::new(spec.shape.clone(), rng.vec_f32(spec.numel()))
                        .unwrap(),
                )
            }
            cax::runtime::Dtype::I32 => Value::I32(0),
            cax::runtime::Dtype::U32 => Value::U32(7),
        })
        .collect()
}

#[test]
fn every_artifact_executes_with_manifest_shapes() {
    let engine = engine();
    let names: Vec<String> =
        engine.manifest().artifacts.keys().cloned().collect();
    assert!(names.len() >= 25, "expected >=25 artifacts, got {}",
            names.len());
    let mut rng = Rng::new(0xA57);
    for name in &names {
        let inputs = synth_inputs(&engine, name, &mut rng);
        let outputs = engine
            .execute(name, &inputs)
            .unwrap_or_else(|e| panic!("executing {name}: {e:#}"));
        let info = engine.manifest().artifact(name).unwrap();
        assert_eq!(outputs.len(), info.outputs.len(), "{name}: output arity");
        for (o, spec) in outputs.iter().zip(&info.outputs) {
            assert_eq!(o.shape(), &spec.shape[..], "{name}: output shape");
            assert!(
                o.data().iter().all(|v| v.is_finite()),
                "{name}: non-finite output"
            );
        }
    }
}

#[test]
fn wrong_shape_is_rejected_before_ffi() {
    let engine = engine();
    let bad = Tensor::zeros(&[3, 3]);
    let err = engine
        .execute("life_step", &[Value::F32(bad)])
        .expect_err("shape mismatch must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("shape"), "unhelpful error: {msg}");
}

#[test]
fn wrong_arity_is_rejected() {
    let engine = engine();
    let err = engine.execute("life_step", &[]).expect_err("arity");
    assert!(format!("{err:#}").contains("inputs"));
}

#[test]
fn wrong_dtype_is_rejected() {
    let engine = engine();
    let info = engine.manifest().artifact("life_step").unwrap();
    let spec = &info.inputs[0];
    let _shape = spec.shape.clone();
    let err = engine
        .execute("life_step", &[Value::I32(1)])
        .expect_err("dtype mismatch must fail");
    assert!(format!("{err:#}").contains("dtype"));
}

#[test]
fn unknown_artifact_errors_cleanly() {
    let engine = engine();
    assert!(engine.execute("no_such_artifact", &[]).is_err());
}

#[test]
fn params_blobs_match_param_counts() {
    let engine = engine();
    for e in cax::coordinator::registry::table1() {
        let Some(blob) = e.params_blob else { continue };
        let params = engine.load_params(blob).unwrap();
        // Every artifact of the family taking `params` must agree.
        for &art in e.artifacts {
            let info = engine.manifest().artifact(art).unwrap();
            if let Some(spec) =
                info.inputs.iter().find(|s| s.name == "params")
            {
                assert_eq!(spec.numel(), params.numel(),
                           "{art} disagrees with blob {blob}");
            }
            if let Some(n) = info.meta_usize("param_count") {
                assert_eq!(n, params.numel(), "{art} meta.param_count");
            }
        }
    }
}

#[test]
fn engine_stats_accumulate() {
    let engine = engine();
    let before = engine.stats();
    let info = engine.manifest().artifact("eca_step").unwrap();
    let state = Tensor::zeros(&info.inputs[0].shape.clone());
    let rule = Tensor::zeros(&[8]);
    engine
        .execute("eca_step", &[Value::F32(state), Value::F32(rule)])
        .unwrap();
    let after = engine.stats();
    assert_eq!(after.executions, before.executions + 1);
    assert!(after.bytes_in > before.bytes_in);
    assert!(after.execute_secs >= before.execute_secs);
}

#[test]
fn compile_cache_hits_on_second_call() {
    let engine = engine();
    engine.ensure_compiled("eca_step").unwrap();
    let compiles = engine.stats().compiles;
    engine.ensure_compiled("eca_step").unwrap();
    assert_eq!(engine.stats().compiles, compiles, "cache miss on re-compile");
}
