//! The spectral-Lenia verification battery: property tests for the
//! in-tree FFT primitive and the differential fuzz suite pitting the
//! spectral kernel against the naive `LeniaSim` oracle. Runs on default
//! features: no artifacts, no XLA, no network.
//!
//! # Why the long-horizon cases pin the smooth growth regime
//!
//! The spectral path computes the convolution in f64 (exact at f32
//! resolution, ~1e-6 from the oracle's sequential f32 tap sums per
//! step). But Lenia's growth is `2 exp(-z^2/2) - 1` with
//! `z = (u - mu)/sigma`: its slope reaches `~1.2/sigma`, so at the
//! paper's `sigma = 0.017` a state perturbation can grow by up to
//! `1 + dt * 71` per step — the dynamics are chaotic, and over 50 steps
//! *any* reordering of f32 arithmetic (not just ours) drifts past any
//! useful tolerance. The long-horizon battery therefore draws
//! parameters from the smooth regime (`sigma >= 0.09`), where the
//! measured 50-step drift sits at 2e-6..4e-5 — comfortably inside the
//! 1e-4 contract — while the paper-default narrow regime is covered at
//! 10-step horizons (measured drift <= 4e-6) and by single-step
//! convolution checks at 2e-5. Calibration numbers come from an
//! f32-faithful prototype of both paths; the seeds here are fixed, so
//! the suite is deterministic.

use cax::automata::lenia::{
    growth, ring_kernel, KernelSpec, LeniaParams, LeniaWorld,
};
use cax::automata::LeniaSim;
use cax::backend::native::fft::{Complex, Fft, Fft2};
use cax::backend::native::lenia::{select_path, LeniaFft, LeniaPath};
use cax::backend::{Backend, CaProgram, NativeBackend};
use cax::prop_assert;
use cax::tensor::Tensor;
use cax::util::check::{check, Gen};
use cax::util::rng::Rng;

/// Transform sizes exercising both kinds (40, 44, 96, 100, 250 run
/// Bluestein; the rest run the power-of-two path).
const FFT_SIZES: &[usize] = &[8, 40, 44, 64, 96, 100, 128, 250, 256];

fn random_signal(n: usize, rng: &mut Rng) -> Vec<Complex> {
    (0..n)
        .map(|_| Complex::new(rng.next_f64() - 0.5, rng.next_f64() - 0.5))
        .collect()
}

fn max_err(a: &[Complex], b: &[Complex]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x.re - y.re).abs().max((x.im - y.im).abs()))
        .fold(0.0, f64::max)
}

// -------------------------------------------------- FFT primitive props

#[test]
fn fft_roundtrip_within_tolerance() {
    let mut rng = Rng::new(0xF0F0);
    for &n in FFT_SIZES {
        let fft = Fft::new(n);
        assert_eq!(fft.is_bluestein(), !n.is_power_of_two(), "n={n}");
        let x = random_signal(n, &mut rng);
        let mut buf = x.clone();
        fft.forward(&mut buf);
        fft.inverse(&mut buf);
        let err = max_err(&buf, &x);
        assert!(err < 1e-5, "n={n}: roundtrip err {err:.3e}");
    }
}

#[test]
fn fft_impulse_response_is_the_twiddle_spiral() {
    // delta[0] -> flat spectrum of ones; delta[j] -> e^{-2 pi i jk/n}.
    for &n in &[16usize, 40, 96, 250] {
        let fft = Fft::new(n);
        let mut flat = vec![Complex::ZERO; n];
        flat[0] = Complex::ONE;
        fft.forward(&mut flat);
        for (k, v) in flat.iter().enumerate() {
            assert!(
                (v.re - 1.0).abs() < 1e-9 && v.im.abs() < 1e-9,
                "n={n} bin {k}: {v:?}"
            );
        }
        let j = 3.min(n - 1);
        let mut spiral = vec![Complex::ZERO; n];
        spiral[j] = Complex::ONE;
        fft.forward(&mut spiral);
        for (k, v) in spiral.iter().enumerate() {
            let theta = -2.0 * std::f64::consts::PI * ((j * k) % n) as f64
                / n as f64;
            let expect = Complex::cis(theta);
            assert!(
                (v.re - expect.re).abs() < 1e-9
                    && (v.im - expect.im).abs() < 1e-9,
                "n={n} j={j} bin {k}"
            );
        }
    }
}

#[test]
fn prop_fft_is_linear() {
    check(0x11EA, 40, |g: &mut Gen| {
        let n = FFT_SIZES[g.usize_in(0, FFT_SIZES.len())];
        let a = g.f32_in(-2.0, 2.0) as f64;
        let b = g.f32_in(-2.0, 2.0) as f64;
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let x = random_signal(n, &mut rng);
        let y = random_signal(n, &mut rng);
        let fft = Fft::new(n);
        let mut combo: Vec<Complex> = x
            .iter()
            .zip(&y)
            .map(|(&xv, &yv)| xv.scale(a) + yv.scale(b))
            .collect();
        fft.forward(&mut combo);
        let mut fx = x;
        fft.forward(&mut fx);
        let mut fy = y;
        fft.forward(&mut fy);
        let expect: Vec<Complex> = fx
            .iter()
            .zip(&fy)
            .map(|(&xv, &yv)| xv.scale(a) + yv.scale(b))
            .collect();
        let err = max_err(&combo, &expect);
        prop_assert!(err < 1e-8, "n={n} a={a} b={b}: linearity err {err:.3e}");
        Ok(())
    })
    .unwrap();
}

#[test]
fn fft_parseval_identity() {
    // sum |x|^2 == (1/n) sum |X|^2 — energy is preserved.
    let mut rng = Rng::new(0x9A125);
    for &n in FFT_SIZES {
        let x = random_signal(n, &mut rng);
        let time: f64 = x.iter().map(|v| v.norm_sq()).sum();
        let mut buf = x;
        let fft = Fft::new(n);
        fft.forward(&mut buf);
        let freq: f64 =
            buf.iter().map(|v| v.norm_sq()).sum::<f64>() / n as f64;
        let rel = (time - freq).abs() / time.max(1e-12);
        assert!(rel < 1e-10, "n={n}: Parseval rel err {rel:.3e}");
    }
}

#[test]
fn fft2_roundtrip_impulse_and_parseval() {
    let (h, w) = (40, 96); // both Bluestein axes
    let fft = Fft2::new(h, w);
    assert_eq!(fft.shape(), (h, w));
    let mut rng = Rng::new(0x2D2D);
    let grid = random_signal(h * w, &mut rng);

    let mut buf = grid.clone();
    fft.forward(&mut buf);
    let time: f64 = grid.iter().map(|v| v.norm_sq()).sum();
    let freq: f64 =
        buf.iter().map(|v| v.norm_sq()).sum::<f64>() / (h * w) as f64;
    assert!((time - freq).abs() / time < 1e-10, "2D Parseval");
    fft.inverse(&mut buf);
    let err = max_err(&buf, &grid);
    assert!(err < 1e-5, "2D roundtrip err {err:.3e}");

    let mut impulse = vec![Complex::ZERO; h * w];
    impulse[0] = Complex::ONE;
    fft.forward(&mut impulse);
    for (i, v) in impulse.iter().enumerate() {
        assert!(
            (v.re - 1.0).abs() < 1e-9 && v.im.abs() < 1e-9,
            "2D impulse bin {i}"
        );
    }
}

// ------------------------------------------------- differential battery

/// One differential case: spectral rollout vs the naive oracle from the
/// same seeded random patch, `max |a - b| <= 1e-4` over every step's
/// endpoint (asserted at the horizon, which the calibration showed is
/// where the drift peaks).
fn diff_case(radius: usize, size: usize, mu: f32, sigma: f32, dt: f32,
             steps: usize, seed: u64) {
    let params = LeniaParams { radius, mu, sigma, dt };
    let mut rng = Rng::new(seed);
    let mut sim = LeniaSim::random_patch(params, size, size / 2, &mut rng);
    let plan = LeniaFft::new(params, size, size).unwrap();
    let mut board = sim.state().data().to_vec();
    plan.rollout(&mut board, steps);
    sim.run(steps);
    let mut worst = 0.0f32;
    for (&a, &b) in board.iter().zip(sim.state().data()) {
        worst = worst.max((a - b).abs());
    }
    assert!(
        worst <= 1e-4,
        "r={radius} size={size} mu={mu} sigma={sigma} dt={dt} \
         steps={steps}: spectral drifted {worst:.3e} from the oracle"
    );
    if steps >= 50 {
        // Long-horizon cases must stay dynamically alive, or the
        // comparison degenerates to clamped constants.
        let mean = board.iter().sum::<f32>() / board.len() as f32;
        assert!(
            (0.01..0.99).contains(&mean),
            "r={radius}: degenerate field (mean {mean})"
        );
    }
}

#[test]
fn diff_fuzz_small_radii_50_steps() {
    // Smooth regime (sigma 0.12, dt 0.05): measured drift ~2e-6 over
    // 50 steps — 50x inside the contract. Sizes 40/44/48 are all
    // Bluestein; radius spans the sparse-tap regime so the FFT path is
    // checked exactly where the crossover would not pick it.
    diff_case(3, 40, 0.30, 0.12, 0.05, 50, 0xA11CE);
    diff_case(5, 48, 0.30, 0.12, 0.05, 50, 0xB0B);
    diff_case(8, 44, 0.30, 0.12, 0.05, 50, 0xCAFE);
}

#[test]
fn diff_fuzz_paper_default_params_short_horizon() {
    // The paper's narrow growth (sigma 0.017) at a 10-step horizon:
    // measured drift <= 3e-6 (the chaotic amplification needs longer
    // horizons to express itself; see module docs).
    diff_case(10, 64, 0.15, 0.017, 0.1, 10, 0xDEFA);
}

#[test]
fn prop_diff_fuzz_random_params_short_horizon() {
    // Seeded-random radii/sizes/params, 8-step horizons: measured
    // worst drift at 10 steps is <= 4e-6 even in the narrow regime, so
    // 1e-4 holds with margin for any draw here.
    check(0xF022, 8, |g: &mut Gen| {
        let radius = g.usize_in(3, 13);
        let size = g.usize_in(2 * radius + 2, 65).max(33);
        let mu = g.f32_in(0.2, 0.35);
        let sigma = g.f32_in(0.06, 0.15);
        let dt = g.f32_in(0.04, 0.1);
        let steps = g.usize_in(4, 9);
        let params = LeniaParams { radius, mu, sigma, dt };
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let mut sim =
            LeniaSim::random_patch(params, size, size / 2, &mut rng);
        let plan = LeniaFft::new(params, size, size)
            .map_err(|e| format!("plan: {e}"))?;
        let mut board = sim.state().data().to_vec();
        plan.rollout(&mut board, steps);
        sim.run(steps);
        let mut worst = 0.0f32;
        for (&a, &b) in board.iter().zip(sim.state().data()) {
            worst = worst.max((a - b).abs());
        }
        prop_assert!(
            worst <= 1e-4,
            "r={radius} size={size} mu={mu} sigma={sigma} dt={dt} \
             steps={steps}: drifted {worst:.3e}"
        );
        Ok(())
    })
    .unwrap();
}

#[test]
#[ignore = "50-step large-radius sweeps: run with --release (CI does)"]
fn diff_fuzz_release_battery() {
    // The full radius range of the issue contract (3..=64), sizes
    // including non-powers-of-two, 50-step horizons in the smooth
    // regime plus paper-default params at 10 steps. Release-mode only
    // because the *oracle* is quadratic in the kernel radius.
    let cases: &[(usize, usize, f32, f32, f32, usize, u64)] = &[
        (3, 40, 0.25, 0.09, 0.10, 50, 0x1000),
        (5, 48, 0.30, 0.10, 0.10, 50, 0x1001),
        (12, 64, 0.30, 0.10, 0.10, 50, 0x1002),
        (16, 96, 0.30, 0.12, 0.05, 50, 0x1003),
        (16, 96, 0.15, 0.017, 0.10, 10, 0x1004),
        (24, 100, 0.30, 0.12, 0.05, 50, 0x1005),
        (32, 128, 0.30, 0.12, 0.05, 50, 0x1006),
        (32, 250, 0.30, 0.12, 0.05, 50, 0x1007),
        (32, 96, 0.15, 0.017, 0.10, 10, 0x1008),
        (48, 128, 0.30, 0.12, 0.05, 50, 0x1009),
        (64, 144, 0.30, 0.12, 0.05, 50, 0x100A),
    ];
    for &(radius, size, mu, sigma, dt, steps, seed) in cases {
        diff_case(radius, size, mu, sigma, dt, steps, seed);
    }
}

#[test]
fn single_step_convolution_contract_across_radii() {
    // The raw neighborhood potential u (before growth) from the
    // spectral path vs direct f32 tap sums: <= 2e-5 at every radius
    // (measured <= 5e-6 at radius 64). This is the no-chaos check that
    // covers the narrow growth regime at full radius range.
    let mut rng = Rng::new(0x5EC7);
    for &(radius, size) in
        &[(3usize, 40usize), (8, 44), (16, 64), (32, 96)]
    {
        let params = LeniaParams { radius, ..Default::default() };
        let field: Vec<f32> = rng.vec_f32(size * size);
        let plan = LeniaFft::new(params, size, size).unwrap();
        let u_fft = plan.convolve(0, &field);
        let kernel = ring_kernel(radius);
        let ksz = 2 * radius + 1;
        let mut worst = 0.0f32;
        for y in 0..size {
            for x in 0..size {
                let mut u = 0.0f32;
                for ky in 0..ksz {
                    for kx in 0..ksz {
                        let sy = (y + size + radius - ky) % size;
                        let sx = (x + size + radius - kx) % size;
                        u += kernel.at(&[ky, kx]) * field[sy * size + sx];
                    }
                }
                worst = worst.max((u - u_fft[y * size + x]).abs());
            }
        }
        assert!(
            worst <= 2e-5,
            "r={radius} size={size}: convolution err {worst:.3e}"
        );
    }
}

// ------------------------------------------------ determinism / threads

#[test]
fn fft_path_is_bit_identical_across_thread_counts() {
    // radius 32 on 64x64 dispatches to the spectral kernel; every
    // board is processed by exactly one worker, so worker count can
    // never change a bit.
    let params = LeniaParams { radius: 32, ..Default::default() };
    assert_eq!(select_path(32, 64, 64), LeniaPath::Fft);
    let mut rng = Rng::new(0x7B17);
    let state =
        Tensor::new(vec![5, 64, 64], rng.vec_f32(5 * 64 * 64)).unwrap();
    let prog = CaProgram::Lenia { params };
    let seq = NativeBackend::with_threads(1)
        .rollout(&prog, &state, 3)
        .unwrap();
    let par = NativeBackend::with_threads(8)
        .rollout(&prog, &state, 3)
        .unwrap();
    assert!(seq.bit_eq(&par), "fft path changed under threading");

    // Same for a multi-kernel world.
    let world = LeniaWorld::demo(3, 16);
    let wstate = Tensor::new(
        vec![4, world.channels, 48, 48],
        rng.vec_f32(4 * world.channels * 48 * 48),
    )
    .unwrap();
    let wprog = CaProgram::LeniaMulti(world);
    let seq = NativeBackend::with_threads(1)
        .rollout(&wprog, &wstate, 2)
        .unwrap();
    let par = NativeBackend::with_threads(8)
        .rollout(&wprog, &wstate, 2)
        .unwrap();
    assert!(seq.bit_eq(&par), "world path changed under threading");
}

// --------------------------------------------------- multi-kernel tests

#[test]
fn multi_k1_reproduces_single_kernel_spectral_bitwise() {
    // A [B, H, W] single-kernel rollout above the crossover and the
    // same boards as a [B, 1, H, W] 1x1 world must agree bit for bit —
    // the multi-kernel engine *is* the single-kernel engine on the
    // LeniaWorld::single embedding.
    let params = LeniaParams { radius: 32, ..Default::default() };
    assert_eq!(select_path(32, 64, 64), LeniaPath::Fft);
    let backend = NativeBackend::with_threads(2);
    let mut rng = Rng::new(0x171);
    let state =
        Tensor::new(vec![2, 64, 64], rng.vec_f32(2 * 64 * 64)).unwrap();
    let single = backend
        .rollout(&CaProgram::Lenia { params }, &state, 3)
        .unwrap();
    let multi_state =
        state.clone().reshape(vec![2, 1, 64, 64]).unwrap();
    let multi = backend
        .rollout(
            &CaProgram::LeniaMulti(LeniaWorld::single(params)),
            &multi_state,
            3,
        )
        .unwrap();
    assert_eq!(multi.shape(), &[2, 1, 64, 64]);
    assert!(
        single
            .data()
            .iter()
            .zip(multi.data())
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "K=1 world diverged from the single-kernel path"
    );
}

#[test]
fn two_channel_two_kernel_step_matches_scalar_reference() {
    // A hand-checkable world: 2 channels, 2 kernels with distinct
    // radii, growths and mixing rows. The reference below recomputes
    // the step per cell from first principles (tap sums in oracle
    // order, shared growth, k-major mixing); the staged
    // LeniaWorld::step_naive must match it bit for bit, the spectral
    // step within 1e-5 (single step, no chaotic amplification).
    let (h, w) = (12, 10);
    let world = LeniaWorld {
        channels: 2,
        dt: 0.1,
        kernels: vec![
            KernelSpec {
                src: 0,
                radius: 2,
                mu: 0.30,
                sigma: 0.10,
                weights: vec![0.6, 0.4],
            },
            KernelSpec {
                src: 1,
                radius: 3,
                mu: 0.25,
                sigma: 0.12,
                weights: vec![0.2, 0.8],
            },
        ],
    };
    world.validate().unwrap();
    let hw = h * w;
    let mut state = vec![0.0f32; 2 * hw];
    for c in 0..2 {
        for y in 0..h {
            for x in 0..w {
                state[c * hw + y * w + x] =
                    ((c * 7 + y * 3 + x * 5) % 13) as f32 / 13.0;
            }
        }
    }

    // First-principles reference: u_k per kernel, then the mix.
    let mut expect = vec![0.0f32; 2 * hw];
    let kerns: Vec<Tensor> =
        world.kernels.iter().map(|s| ring_kernel(s.radius)).collect();
    for c in 0..2 {
        for y in 0..h {
            for x in 0..w {
                let mut acc = 0.0f32;
                for (k, spec) in world.kernels.iter().enumerate() {
                    let r = spec.radius;
                    let ksz = 2 * r + 1;
                    let src = &state[spec.src * hw..(spec.src + 1) * hw];
                    let mut u = 0.0f32;
                    for ky in 0..ksz {
                        for kx in 0..ksz {
                            let sy = (y + h + r - ky) % h;
                            let sx = (x + w + r - kx) % w;
                            u += kerns[k].at(&[ky, kx])
                                * src[sy * w + sx];
                        }
                    }
                    acc +=
                        spec.weights[c] * growth(u, spec.mu, spec.sigma);
                }
                expect[c * hw + y * w + x] = (state[c * hw + y * w + x]
                    + world.dt * acc)
                    .clamp(0.0, 1.0);
            }
        }
    }

    let mut staged = vec![0.0f32; 2 * hw];
    world.step_naive(&state, &mut staged, h, w);
    assert!(
        staged
            .iter()
            .zip(&expect)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "step_naive disagrees with the first-principles reference"
    );

    let plan = LeniaFft::for_world(world, h, w).unwrap();
    let mut spectral = vec![0.0f32; 2 * hw];
    plan.step(&state, &mut spectral);
    let mut worst = 0.0f32;
    for (&a, &b) in spectral.iter().zip(&expect) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst <= 1e-5, "spectral 2x2 step drifted {worst:.3e}");
}

// ------------------------------------------------------- golden vector

const GOLDEN: &str = include_str!("common/lenia_fft_golden.txt");

fn golden_params() -> (LeniaParams, usize, usize) {
    // 48x48 forces Bluestein on both axes; the smooth regime keeps the
    // trajectory's libm sensitivity at the measured ~2e-7 level.
    (LeniaParams { radius: 16, mu: 0.30, sigma: 0.12, dt: 0.05 }, 48, 10)
}

fn golden_state(size: usize) -> Vec<f32> {
    let patch = size / 2;
    let start = (size - patch) / 2;
    let mut state = vec![0.0f32; size * size];
    for y in start..start + patch {
        for x in start..start + patch {
            state[y * size + x] =
                ((y * 31 + x * 17) % 101) as f32 / 101.0;
        }
    }
    state
}

#[test]
fn golden_vector_regression() {
    let (params, size, steps) = golden_params();
    let expect: Vec<f32> = GOLDEN
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .map(|l| l.trim().parse::<f32>().expect("golden parse"))
        .collect();
    assert_eq!(expect.len(), size * size, "golden file length");
    let plan = LeniaFft::new(params, size, size).unwrap();
    assert!(plan.is_bluestein());
    let mut board = golden_state(size);
    plan.rollout(&mut board, steps);
    let mut worst = 0.0f32;
    for (&a, &b) in board.iter().zip(&expect) {
        worst = worst.max((a - b).abs());
    }
    // Not bitwise: libm exp/sin/cos may differ by an ulp per platform;
    // the measured amplification over this trajectory is ~2e-7, so
    // 5e-5 still flags any real regression (those land >= 1e-3).
    assert!(worst <= 5e-5, "golden drifted {worst:.3e}");
    // The trajectory must be non-trivial for the guard to mean much.
    let mean = board.iter().sum::<f32>() / board.len() as f32;
    assert!(mean > 0.05, "golden field died (mean {mean})");
}

#[test]
#[ignore = "rewrites tests/common/lenia_fft_golden.txt from this build"]
fn regen_golden_vector() {
    let (params, size, steps) = golden_params();
    let plan = LeniaFft::new(params, size, size).unwrap();
    let mut board = golden_state(size);
    plan.rollout(&mut board, steps);
    let mut text = String::from(
        "# Spectral-Lenia golden vector (regression guard for the FFT \
         path).\n# Regenerated by `cargo test --release --test \
         native_fft_props regen_golden -- --ignored`.\n# 48x48, radius \
         16, mu 0.30, sigma 0.12, dt 0.05, 10 steps; see \
         golden_state() for the deterministic initial patch.\n",
    );
    for v in &board {
        text.push_str(&format!("{v:.9e}\n"));
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/common/lenia_fft_golden.txt");
    std::fs::write(&path, text).unwrap();
    println!("wrote {}", path.display());
}
