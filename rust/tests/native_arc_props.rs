//! Properties of the native 1D-ARC training path (default features, no
//! artifacts): the 1D BPTT backward pass is checked against central
//! finite differences per parameter group, the full `arc_train_step` is
//! bit-identical for any worker-thread count, and the exact-match
//! evaluator is verified against hand-computed rollouts.

use cax::backend::native::nca::{Grid, NcaModel};
use cax::backend::native::nca_grad;
use cax::backend::native::train::{ArcTrainSpec, NativeTrainBackend};
use cax::backend::{ProgramBackend, Value};
use cax::coordinator::evaluator;
use cax::datasets::arc1d::{one_hot_batch, Example, NUM_COLORS};
use cax::tensor::Tensor;
use cax::util::rng::Rng;

/// A small cell built for finite differences — the same construction as
/// `tests/native_train_props.rs`: the ReLU makes the loss only
/// piecewise smooth, so the check model pushes every pre-activation
/// away from zero (large alternating biases, small `w1`) and boosts
/// `w2` so the gradients sit well above the f32 noise floor. None of
/// the code paths under test change.
fn check_model(channels: usize, hidden: usize, seed: u64) -> NcaModel {
    let mut model = NcaModel::random(channels, hidden, &mut Rng::new(seed));
    for w in model.w1.iter_mut() {
        *w *= 0.15;
    }
    for (j, b) in model.b1.iter_mut().enumerate() {
        *b = if j % 2 == 0 { 0.8 } else { -0.8 };
    }
    for w in model.w2.iter_mut() {
        *w *= 2.0;
    }
    model
}

/// Mean-squared full-state loss of a `steps`-long 1D rollout (f64 sum).
fn rollout_loss(model: &NcaModel, board: &[f32], target: &[f32], w: usize,
                steps: usize, frozen: usize) -> f64 {
    let tape = nca_grad::rollout_tape_on(model, board, Grid::D1 { w },
                                         steps, frozen);
    let fin = tape.last().unwrap();
    fin.iter()
        .zip(target)
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / fin.len() as f64
}

/// Central finite differences over one parameter group, where `group`
/// selects the vector to perturb on a clone of the model.
#[allow(clippy::too_many_arguments)]
fn fd_group(model: &NcaModel, board: &[f32], target: &[f32], w: usize,
            steps: usize, frozen: usize, len: usize,
            group: fn(&mut NcaModel) -> &mut Vec<f32>) -> Vec<f64> {
    let eps = 3e-3f32;
    (0..len)
        .map(|i| {
            let mut plus = model.clone();
            group(&mut plus)[i] += eps;
            let lp = rollout_loss(&plus, board, target, w, steps, frozen);
            let mut minus = model.clone();
            group(&mut minus)[i] -= eps;
            let lm = rollout_loss(&minus, board, target, w, steps, frozen);
            (lp - lm) / (2.0 * eps as f64)
        })
        .collect()
}

/// Group-norm relative error plus a per-parameter sanity bound.
fn assert_group_matches(name: &str, analytic: &[f32], fd: &[f64]) {
    assert_eq!(analytic.len(), fd.len());
    let mut diff2 = 0.0f64;
    let mut norm2 = 0.0f64;
    for (i, (&a, &f)) in analytic.iter().zip(fd).enumerate() {
        let a = a as f64;
        diff2 += (a - f) * (a - f);
        norm2 += f * f;
        let denom = a.abs().max(f.abs()).max(1e-3);
        let rel = (a - f).abs() / denom;
        assert!(rel < 1e-2,
                "{name}[{i}]: analytic {a:.6e} vs fd {f:.6e} (rel {rel:.2e})");
    }
    let rel = (diff2.sqrt()) / norm2.sqrt().max(1e-12);
    assert!(rel < 1e-3,
            "{name}: group-norm rel err {rel:.3e} (>= 1e-3), \
             ||fd|| = {:.3e}", norm2.sqrt());
    assert!(norm2 > 0.0, "{name}: degenerate all-zero fd gradient");
}

fn gradient_check(frozen: usize, seed: u64) {
    // Small ring, short unroll — the 1D analogue of the 2D check.
    let (w, c, hid, steps) = (12, 4, 8, 2);
    let model = check_model(c, hid, seed);
    let mut rng = Rng::new(seed ^ 0x1D);
    let board = rng.vec_f32(w * c);
    let target = rng.vec_f32(w * c);

    let grid = Grid::D1 { w };
    let tape = nca_grad::rollout_tape_on(&model, &board, grid, steps,
                                         frozen);
    let fin = tape.last().unwrap();
    let n = fin.len() as f32;
    let d_final: Vec<f32> = fin
        .iter()
        .zip(&target)
        .map(|(&a, &b)| 2.0 * (a - b) / n)
        .collect();
    let (grads, _) =
        nca_grad::backward_on(&model, &tape, grid, frozen, &d_final);

    let fd_w1 = fd_group(&model, &board, &target, w, steps, frozen,
                         grads.w1.len(), |m| &mut m.w1);
    assert_group_matches("w1", &grads.w1, &fd_w1);
    let fd_b1 = fd_group(&model, &board, &target, w, steps, frozen,
                         grads.b1.len(), |m| &mut m.b1);
    assert_group_matches("b1", &grads.b1, &fd_b1);
    let fd_w2 = fd_group(&model, &board, &target, w, steps, frozen,
                         grads.w2.len(), |m| &mut m.w2);
    assert_group_matches("w2", &grads.w2, &fd_w2);
}

#[test]
fn bptt_1d_gradients_match_finite_differences() {
    gradient_check(0, 31);
}

#[test]
fn bptt_1d_gradients_match_finite_differences_with_frozen_channels() {
    // The ARC layout in miniature: the first channels pinned, still
    // feeding perception.
    gradient_check(2, 47);
}

#[test]
fn input_gradient_matches_finite_differences_too() {
    // dL/d(state_0), the remaining backward output: perturb two board
    // cells directly.
    let (w, c, hid, steps) = (10, 4, 6, 3);
    let model = check_model(c, hid, 8);
    let mut rng = Rng::new(80);
    let board = rng.vec_f32(w * c);
    let target = rng.vec_f32(w * c);
    let grid = Grid::D1 { w };
    let tape = nca_grad::rollout_tape_on(&model, &board, grid, steps, 0);
    let fin = tape.last().unwrap();
    let n = fin.len() as f32;
    let d_final: Vec<f32> = fin
        .iter()
        .zip(&target)
        .map(|(&a, &b)| 2.0 * (a - b) / n)
        .collect();
    let (_, d0) = nca_grad::backward_on(&model, &tape, grid, 0, &d_final);

    let eps = 3e-3f32;
    for idx in [0usize, (w * c) / 2 + 1] {
        let mut plus = board.clone();
        plus[idx] += eps;
        let lp = rollout_loss(&model, &plus, &target, w, steps, 0);
        let mut minus = board.clone();
        minus[idx] -= eps;
        let lm = rollout_loss(&model, &minus, &target, w, steps, 0);
        let fd = (lp - lm) / (2.0 * eps as f64);
        let a = d0[idx] as f64;
        let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1e-3);
        assert!(rel < 1e-2,
                "d_state0[{idx}]: analytic {a:.6e} vs fd {fd:.6e}");
    }
}

fn tiny_spec() -> ArcTrainSpec {
    ArcTrainSpec {
        width: 16,
        extra: 2,
        hidden: 10,
        batch: 3,
        rollout_min: 3,
        rollout_max: 5,
        eval_steps: 4,
        ..ArcTrainSpec::default()
    }
}

fn train_inputs(backend: &NativeTrainBackend, seed: u64)
                -> Vec<Value> {
    let spec = backend.arc_spec().clone();
    let p = spec.param_count();
    let params = backend.load_params("arc_params").unwrap();
    assert_eq!(params.numel(), p);
    let mut rng = Rng::new(seed);
    let examples: Vec<_> = (0..spec.batch)
        .map(|_| cax::datasets::arc1d::Task::Move1
            .generate(spec.width, &mut rng))
        .collect();
    let ins: Vec<&[u8]> =
        examples.iter().map(|e| e.input.as_slice()).collect();
    let tgts: Vec<&[u8]> =
        examples.iter().map(|e| e.target.as_slice()).collect();
    vec![
        Value::F32(params),
        Value::F32(Tensor::zeros(&[p])),
        Value::F32(Tensor::zeros(&[p])),
        Value::I32(0),
        Value::F32(one_hot_batch(&ins, spec.width)),
        Value::F32(one_hot_batch(&tgts, spec.width)),
        Value::U32(5),
    ]
}

#[test]
fn arc_train_step_is_bit_identical_across_thread_counts() {
    let single = NativeTrainBackend::with_arc_spec(tiny_spec(), 1);
    let many = NativeTrainBackend::with_arc_spec(tiny_spec(), 8);
    let inputs = train_inputs(&single, 7);
    let a = single.execute("arc_train_step", &inputs).unwrap();
    let b = many.execute("arc_train_step", &inputs).unwrap();
    assert_eq!(a.len(), 4);
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(x.bit_eq(y), "output {i} differs between 1 and 8 workers");
    }
    // And the step is a pure function of its inputs.
    let c = single.execute("arc_train_step", &inputs).unwrap();
    for (x, y) in a.iter().zip(&c) {
        assert!(x.bit_eq(y));
    }
    let loss = a[3].data()[0];
    assert!(loss.is_finite() && loss > 0.0, "arc loss {loss}");
}

#[test]
fn arc_eval_is_bit_identical_across_thread_counts() {
    let single = NativeTrainBackend::with_arc_spec(tiny_spec(), 1);
    let many = NativeTrainBackend::with_arc_spec(tiny_spec(), 8);
    let params = single.load_params("arc_params").unwrap();
    let all = train_inputs(&single, 9);
    let args = vec![Value::F32(params), all[4].clone()]; // one-hot batch
    let a = single.execute("arc_eval", &args).unwrap();
    let b = many.execute("arc_eval", &args).unwrap();
    assert!(a[0].bit_eq(&b[0]),
            "eval logits differ between 1 and 8 workers");
}

/// A cell whose rollout is hand-computable: `w1 = 0`, one always-on
/// hidden unit (`b1[0] = 1`, ReLU passes 1.0 through), and `w2` wired
/// so that unit feeds only the logit channel of `color`. Every step
/// then adds exactly `dt * 1.0` to that logit at every cell, so after
/// any positive number of eval steps the argmax prediction is `color`
/// everywhere.
fn constant_color_params(spec: &ArcTrainSpec, color: usize) -> Tensor {
    let c = spec.channels();
    let mut model = NcaModel {
        channels: c,
        hidden: spec.hidden,
        w1: vec![0.0; 3 * c * spec.hidden],
        b1: vec![0.0; spec.hidden],
        w2: vec![0.0; spec.hidden * c],
        dt: spec.dt,
    };
    model.b1[0] = 1.0;
    model.w2[NUM_COLORS + color] = 1.0; // hidden unit 0 -> logit `color`
    let flat = model.flatten();
    let n = flat.len();
    Tensor::new(vec![n], flat).unwrap()
}

#[test]
fn evaluator_exact_match_agrees_with_hand_computed_rollouts() {
    let spec = tiny_spec();
    let backend = NativeTrainBackend::with_arc_spec(spec.clone(), 2);
    let w = spec.width;
    let params = constant_color_params(&spec, 4);

    // The constant-color cell predicts color 4 at every pixel: solved
    // exactly when the target row is all 4s. Three test cases on a
    // batch of 3 exercises scoring; five exercises the padded chunking
    // path too.
    let all4 = Example { input: vec![0u8; w], target: vec![4u8; w] };
    let mut near4 = all4.clone();
    near4.target[w / 2] = 7; // one wrong pixel: not an exact match
    let all0 = Example { input: vec![4u8; w], target: vec![0u8; w] };

    let test = vec![all4.clone(), near4.clone(), all0.clone()];
    let acc = evaluator::arc_accuracy(&backend, &params, &test).unwrap();
    assert!((acc - 1.0 / 3.0).abs() < 1e-9, "exact-match {acc}");
    let pix =
        evaluator::arc_pixel_accuracy(&backend, &params, &test).unwrap();
    // Hand count: w + (w-1) + 0 correct pixels of 3w.
    let want = (2 * w - 1) as f64 / (3 * w) as f64;
    assert!((pix - want).abs() < 1e-9, "per-pixel {pix} vs {want}");

    // Padded chunk (5 examples, batch 3): padding must not be scored.
    let test5 = vec![all4.clone(), all4.clone(), near4, all0, all4];
    let acc5 = evaluator::arc_accuracy(&backend, &params, &test5).unwrap();
    assert!((acc5 - 3.0 / 5.0).abs() < 1e-9, "padded exact-match {acc5}");
}

#[test]
fn zero_params_predict_background_everywhere() {
    // All-zero weights leave the logits at zero; argmax ties resolve to
    // channel 0 = background. The paper's criterion then solves exactly
    // the examples whose target is empty.
    let spec = tiny_spec();
    let backend = NativeTrainBackend::with_arc_spec(spec.clone(), 1);
    let p = spec.param_count();
    let params = Tensor::zeros(&[p]);
    let w = spec.width;
    let empty = Example { input: vec![3u8; w], target: vec![0u8; w] };
    let full = Example { input: vec![0u8; w], target: vec![3u8; w] };
    let acc = evaluator::arc_accuracy(&backend, &params,
                                      &[empty, full]).unwrap();
    assert!((acc - 0.5).abs() < 1e-9, "background prior accuracy {acc}");
}

#[test]
fn for_call_infers_arc_geometry_from_tensors() {
    // NativeBackend::train_step route: geometry from the call tensors.
    use cax::backend::{Backend, NativeBackend};
    let spec = ArcTrainSpec { width: 20, batch: 2,
                              ..ArcTrainSpec::default() };
    let donor = NativeTrainBackend::with_arc_spec(spec.clone(), 1);
    let p = spec.param_count();
    let params = donor.load_params("arc_params").unwrap();
    let mut rng = Rng::new(3);
    let examples: Vec<_> = (0..2)
        .map(|_| cax::datasets::arc1d::Task::Fill.generate(20, &mut rng))
        .collect();
    let ins: Vec<&[u8]> =
        examples.iter().map(|e| e.input.as_slice()).collect();
    let tgts: Vec<&[u8]> =
        examples.iter().map(|e| e.target.as_slice()).collect();
    let inputs = vec![
        Value::F32(params),
        Value::F32(Tensor::zeros(&[p])),
        Value::F32(Tensor::zeros(&[p])),
        Value::I32(0),
        Value::F32(one_hot_batch(&ins, 20)),
        Value::F32(one_hot_batch(&tgts, 20)),
        Value::U32(1),
    ];
    let out = NativeBackend::with_threads(2)
        .train_step("arc_train_step", &inputs)
        .unwrap();
    assert_eq!(out.len(), 4);
    assert!(out[3].data()[0].is_finite());
}
