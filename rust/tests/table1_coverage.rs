//! E4 — Table 1 coverage: every CA row of the paper's Table 1 is present,
//! complete (all artifacts + parameter blobs in the manifest), and
//! instantiable through the registry.

use cax::coordinator::registry;
#[cfg(feature = "pjrt")]
use cax::coordinator::registry::CaType;

#[cfg(feature = "pjrt")]
mod common;
#[cfg(feature = "pjrt")]
use common::engine;

#[cfg(feature = "pjrt")]
#[test]
fn registry_matches_manifest_completely() {
    let engine = engine();
    let missing = registry::missing_artifacts(engine.manifest());
    assert!(missing.is_empty(), "missing artifacts: {missing:?}");
}

#[test]
fn table1_has_paper_rows() {
    let rows = registry::table1();
    assert_eq!(rows.len(), 10, "paper Table 1 has 10 rows");
    let labels: Vec<&str> = rows.iter().map(|e| e.label).collect();
    for expected in [
        "Elementary Cellular Automata",
        "Conway's Game of Life",
        "Lenia",
        "Growing Neural Cellular Automata",
        "Growing Conditional Neural Cellular Automata",
        "Growing Unsupervised Neural Cellular Automata",
        "Self-classifying MNIST Digits",
        "Diffusing Neural Cellular Automata",
        "Self-autoencoding MNIST Digits",
        "1D-ARC Neural Cellular Automata",
    ] {
        assert!(labels.contains(&expected), "missing row {expected:?}");
    }
}

#[test]
fn dimensions_column_matches_paper() {
    for (key, dims) in [
        ("eca", "1D"),
        ("life", "2D"),
        ("lenia", "ND"),
        ("growing", "2D"),
        ("conditional", "2D"),
        ("vae", "2D"),
        ("mnist", "2D"),
        ("diffusing", "2D"),
        ("autoenc3d", "3D"),
        ("arc", "1D"),
    ] {
        assert_eq!(registry::find(key).unwrap().dimensions, dims, "{key}");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn all_registry_artifacts_compile() {
    let engine = engine();
    for entry in registry::table1() {
        for &art in entry.artifacts {
            engine
                .ensure_compiled(art)
                .unwrap_or_else(|e| panic!("{}: {art}: {e:#}", entry.key));
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn neural_rows_have_train_steps_with_adam_contract() {
    // Train-step artifacts all share the (params, m, v, step, ..., seed) ->
    // (params', m', v', loss, ...) contract the trainer depends on.
    let engine = engine();
    for entry in registry::table1() {
        if entry.ca_type != CaType::Neural {
            continue;
        }
        let train = entry
            .artifacts
            .iter()
            .find(|a| a.ends_with("_train_step"))
            .unwrap_or_else(|| panic!("{} has no train step", entry.key));
        let info = engine.manifest().artifact(train).unwrap();
        assert!(info.inputs.len() >= 5, "{train}: too few inputs");
        assert_eq!(info.inputs[0].name, "params", "{train}");
        assert_eq!(info.inputs[1].name, "m", "{train}");
        assert_eq!(info.inputs[2].name, "v", "{train}");
        assert_eq!(info.inputs[3].name, "step", "{train}");
        assert_eq!(info.inputs.last().unwrap().name, "seed", "{train}");
        assert!(info.outputs.len() >= 4, "{train}: too few outputs");
        // params/m/v round-trip shapes.
        for i in 0..3 {
            assert_eq!(info.outputs[i].shape, info.inputs[i].shape,
                       "{train}: output {i} shape");
        }
        // loss is a scalar.
        assert!(info.outputs[3].shape.is_empty(), "{train}: loss not scalar");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn meta_dimensions_consistent_with_input_shapes() {
    let engine = engine();
    for (name, info) in &engine.manifest().artifacts {
        if let (Some(h), Some(w)) =
            (info.meta_usize("height"), info.meta_usize("width"))
        {
            // Some f32 input or output must mention H and W in its shape
            // (generators like conditional_grow only carry it on outputs).
            let found = info
                .inputs
                .iter()
                .chain(&info.outputs)
                .any(|s| s.shape.windows(2).any(|win| win == [h, w]));
            assert!(found, "{name}: no input/output carries meta {h}x{w}");
        }
    }
}
