//! Serve-layer properties: coalesced-vs-solo bit-identity, the HTTP
//! front end end-to-end, and graceful shutdown.
//!
//! The load-bearing contract is **bit-identity**: a session stepped
//! inside a packed batch (resident state, one launch per shape class)
//! must produce bitwise the same trajectory as the same initial board
//! stepped alone through `Backend::rollout`. That holds because the
//! coalesced path runs the exact same kernels in the same per-board
//! order (batch elements are independent in every native kernel — the
//! same property behind the backends' thread-count determinism
//! guarantees), and the bit-packed/f32 resident representations
//! round-trip {0,1}/f32 states exactly.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cax::backend::{Backend, NativeBackend};
use cax::serve::{self, Coalescer, ProgramSpec, ServeConfig, StepRequest};
use cax::Tensor;

fn test_config() -> ServeConfig {
    ServeConfig {
        port: 0,
        threads: 2,
        max_sessions: 64,
        max_batch: 64,
        max_pending: 256,
        max_steps: 10_000,
        seed: 9,
        tick_window: Duration::ZERO,
        ..ServeConfig::default()
    }
}

/// A `test_config` with a fresh per-test checkpoint directory attached
/// (fleet mode). The caller removes the directory when done.
fn fleet_config(tag: &str, threads: usize)
                -> (std::path::PathBuf, ServeConfig) {
    let dir = std::env::temp_dir()
        .join(format!("cax-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = ServeConfig {
        threads,
        state_dir: Some(dir.clone()),
        ..test_config()
    };
    (dir, cfg)
}

/// Submit one step request per session and run ticks until all served.
fn step_all(c: &Coalescer, ids: &[u64], steps: usize) -> Vec<usize> {
    let (tx, rx) = channel();
    for &id in ids {
        c.submit(StepRequest::new(id, steps, tx.clone()))
            .expect("submit");
    }
    drop(tx);
    let mut served = 0;
    while served < ids.len() {
        served += c.tick();
    }
    (0..ids.len())
        .map(|_| rx.recv().expect("reply").expect("step ok").batch)
        .collect()
}

// ------------------------------------------- coalesced-vs-solo contract

/// Create `n` sessions of `spec`, step them coalesced for `ticks`
/// rounds of `steps`, and assert every session's board is bitwise the
/// solo-rollout trajectory of its own initial board after every round.
fn assert_coalesced_matches_solo(spec: ProgramSpec, n: usize, ticks: usize,
                                 steps: usize) {
    let c = Coalescer::new(&test_config());
    let ids: Vec<u64> = {
        let mut reg = c.registry().lock().unwrap();
        (0..n)
            .map(|_| reg.create(c.backend(), spec.clone(), None).unwrap())
            .collect()
    };
    // Independent solo reference: a *separate* backend instance stepping
    // plain tensors through the public rollout path.
    let solo_backend = NativeBackend::new();
    let prog = spec.program().unwrap();
    let mut solo: Vec<Tensor> = ids
        .iter()
        .map(|&id| {
            c.registry().lock().unwrap().read_board(c.backend(), id).unwrap()
        })
        .collect();

    for tick in 0..ticks {
        let batches = step_all(&c, &ids, steps);
        assert!(batches.iter().all(|&b| b == n),
                "all {n} sessions should ride one launch, got {batches:?}");
        for (i, board) in solo.iter_mut().enumerate() {
            let stacked = Tensor::stack(&[board.clone()]).unwrap();
            *board = solo_backend
                .rollout(&prog, &stacked, steps)
                .unwrap()
                .index_axis0(0);
            let served = c
                .registry()
                .lock()
                .unwrap()
                .read_board(c.backend(), ids[i])
                .unwrap();
            assert!(
                served.bit_eq(board),
                "{:?}: session {i} diverged from its solo trajectory at \
                 tick {tick}",
                spec
            );
        }
    }
}

#[test]
fn coalesced_eca_is_bit_identical_to_solo() {
    // Width 70: exercises the partial-last-word bit packing.
    assert_coalesced_matches_solo(
        ProgramSpec::Eca { rule: 110, width: 70 }, 3, 4, 3,
    );
}

#[test]
fn coalesced_life_is_bit_identical_to_solo() {
    assert_coalesced_matches_solo(
        ProgramSpec::Life { height: 24, width: 33 }, 4, 4, 2,
    );
}

#[test]
fn coalesced_lenia_sparse_is_bit_identical_to_solo() {
    // Radius 5 stays on the sparse-tap kernel path.
    assert_coalesced_matches_solo(
        ProgramSpec::Lenia { radius: 5, height: 32, width: 32 }, 3, 3, 2,
    );
}

#[test]
fn coalesced_lenia_fft_is_bit_identical_to_solo() {
    // Radius 32 on 64x64 crosses over to the spectral kernel; the
    // resident path must build the identical plan.
    assert_coalesced_matches_solo(
        ProgramSpec::Lenia { radius: 32, height: 64, width: 64 }, 2, 2, 2,
    );
}

#[test]
fn coalesced_lenia_world_is_bit_identical_to_solo() {
    assert_coalesced_matches_solo(
        ProgramSpec::LeniaMulti {
            kernels: 2,
            radius: 4,
            height: 24,
            width: 24,
        },
        2, 2, 2,
    );
}

#[test]
fn coalesced_nca_is_bit_identical_to_solo() {
    // The growing-NCA cell wired from the native manifest programs.
    assert_coalesced_matches_solo(ProgramSpec::NcaGrowing, 2, 2, 2);
}

#[test]
fn concurrent_clients_with_running_scheduler_stay_exact() {
    let cfg = ServeConfig {
        tick_window: Duration::from_micros(200),
        ..test_config()
    };
    let c = Arc::new(Coalescer::new(&cfg));
    let spec = ProgramSpec::Life { height: 16, width: 16 };
    let ids: Vec<u64> = {
        let mut reg = c.registry().lock().unwrap();
        (0..8)
            .map(|_| reg.create(c.backend(), spec.clone(), None).unwrap())
            .collect()
    };
    let initial: Vec<Tensor> = ids
        .iter()
        .map(|&id| {
            c.registry().lock().unwrap().read_board(c.backend(), id).unwrap()
        })
        .collect();
    let scheduler = Coalescer::spawn(&c);

    // One client thread per session, each stepping 10 x 1 step through
    // the live scheduler (so requests race and coalesce arbitrarily).
    std::thread::scope(|scope| {
        for &id in &ids {
            let c = Arc::clone(&c);
            scope.spawn(move || {
                for _ in 0..10 {
                    let (tx, rx) = channel();
                    c.submit(StepRequest::new(id, 1, tx)).unwrap();
                    let done = rx
                        .recv_timeout(Duration::from_secs(20))
                        .expect("scheduler reply")
                        .expect("step ok");
                    assert!(done.batch >= 1);
                }
            });
        }
    });
    c.shutdown();
    scheduler.join().unwrap();

    let solo_backend = NativeBackend::new();
    let prog = spec.program().unwrap();
    for (i, &id) in ids.iter().enumerate() {
        let expect = solo_backend
            .rollout(&prog,
                     &Tensor::stack(&[initial[i].clone()]).unwrap(), 10)
            .unwrap()
            .index_axis0(0);
        let got = c
            .registry()
            .lock()
            .unwrap()
            .read_board(c.backend(), id)
            .unwrap();
        assert!(got.bit_eq(&expect),
                "session {i}: racing coalesced steps diverged from solo");
        assert_eq!(c.registry().lock().unwrap().get(id).unwrap().steps_done,
                   10);
    }
}

// --------------------------------------------------------- HTTP client

/// One HTTP/1.1 request over a fresh connection; returns (status, body).
fn http_bytes(addr: SocketAddr, method: &str, path: &str, body: &str)
              -> (u16, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: cax\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).expect("write request");
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).expect("read response");
    let header_end = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("header/body separator");
    let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    (status, buf[header_end + 4..].to_vec())
}

fn http(addr: SocketAddr, method: &str, path: &str, body: &str)
        -> (u16, String) {
    let (status, bytes) = http_bytes(addr, method, path, body);
    (status, String::from_utf8_lossy(&bytes).to_string())
}

/// Pull a `"field": "value"` string out of a JSON response body.
fn json_str_field(body: &str, field: &str) -> String {
    let pat = format!("\"{field}\": \"");
    let start = body.find(&pat).unwrap_or_else(|| {
        panic!("no {field:?} in {body}")
    }) + pat.len();
    let end = body[start..].find('"').expect("closing quote") + start;
    body[start..end].to_string()
}

#[test]
fn http_end_to_end_roundtrip() {
    let cfg = ServeConfig {
        max_sessions: 3,
        tick_window: Duration::from_micros(100),
        ..test_config()
    };
    let server = serve::start(&cfg).expect("start server");
    let addr = server.addr();

    let (status, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\": true"), "{body}");

    // Create -> step -> status -> snapshot -> reset -> delete.
    let (status, body) = http(addr, "POST", "/sessions",
                              r#"{"program": "life", "size": 16}"#);
    assert_eq!(status, 201, "{body}");
    let id = json_str_field(&body, "id");
    assert_eq!(id.len(), 16);

    let (status, body) =
        http(addr, "POST", &format!("/sessions/{id}/step"),
             r#"{"steps": 3}"#);
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"steps_done\": 3"), "{body}");
    assert!(body.contains("\"batch\": 1"), "{body}");

    // Empty body steps once.
    let (status, body) =
        http(addr, "POST", &format!("/sessions/{id}/step"), "");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"steps_done\": 4"), "{body}");

    let (status, body) = http(addr, "GET", &format!("/sessions/{id}"), "");
    assert_eq!(status, 200);
    assert!(body.contains("\"steps_done\": 4"), "{body}");
    assert!(body.contains("\"program\": \"life\""), "{body}");

    let (status, ppm) =
        http_bytes(addr, "GET", &format!("/sessions/{id}/snapshot.ppm"), "");
    assert_eq!(status, 200);
    assert!(ppm.starts_with(b"P6\n16 16\n255\n"),
            "snapshot is not a 16x16 P6: {:?}", &ppm[..20.min(ppm.len())]);

    let (status, body) =
        http(addr, "POST", &format!("/sessions/{id}/reset"), "");
    assert_eq!(status, 200);
    assert!(body.contains("\"steps_done\": 0"), "{body}");

    // Admission control over HTTP: the registry holds max 3.
    let mut extra = vec![];
    for _ in 0..2 {
        let (status, body) = http(addr, "POST", "/sessions",
                                  r#"{"program": "eca", "width": 32}"#);
        assert_eq!(status, 201, "{body}");
        extra.push(json_str_field(&body, "id"));
    }
    let (status, body) = http(addr, "POST", "/sessions",
                              r#"{"program": "eca", "width": 32}"#);
    assert_eq!(status, 503, "limit should reject: {body}");
    assert!(body.contains("session limit"), "{body}");

    let (status, _) = http(addr, "DELETE", &format!("/sessions/{id}"), "");
    assert_eq!(status, 200);
    let (status, body) = http(addr, "GET", &format!("/sessions/{id}"), "");
    assert_eq!(status, 404, "{body}");
    let (status, body) =
        http(addr, "POST", &format!("/sessions/{id}/step"), "");
    assert_eq!(status, 404, "stepping a deleted session: {body}");

    // Bad inputs get 400s, unknown routes 404s.
    let (status, _) = http(addr, "POST", "/sessions",
                           r#"{"program": "warp"}"#);
    assert_eq!(status, 400);
    let (status, _) = http(addr, "POST", "/sessions", "not json");
    assert_eq!(status, 400);
    let (status, _) = http(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    let (status, _) = http(addr, "GET", "/sessions/zzzz", "");
    assert_eq!(status, 404);

    let (status, body) = http(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    assert!(body.contains("\"batches\""), "{body}");
    assert!(body.contains("\"steps_per_s\""), "{body}");

    // Graceful shutdown via the endpoint: join returns cleanly.
    let (status, body) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    assert!(body.contains("\"draining\": true"), "{body}");
    server.join().expect("clean shutdown");
}

#[test]
fn http_sessions_coalesce_across_connections() {
    // Steps submitted from many live connections inside one scheduler
    // window should pack into one batch (observable via "batch" > 1).
    let cfg = ServeConfig {
        max_sessions: 8,
        tick_window: Duration::from_millis(30),
        ..test_config()
    };
    let server = serve::start(&cfg).expect("start server");
    let addr = server.addr();
    let mut ids = vec![];
    for _ in 0..4 {
        let (status, body) = http(addr, "POST", "/sessions",
                                  r#"{"program": "life", "size": 24}"#);
        assert_eq!(status, 201);
        ids.push(json_str_field(&body, "id"));
    }
    let batches: Vec<usize> = std::thread::scope(|scope| {
        let handles: Vec<_> = ids
            .iter()
            .map(|id| {
                scope.spawn(move || {
                    let (status, body) = http(
                        addr,
                        "POST",
                        &format!("/sessions/{id}/step"),
                        r#"{"steps": 2}"#,
                    );
                    assert_eq!(status, 200, "{body}");
                    let pat = "\"batch\": ";
                    let start = body.find(pat).unwrap() + pat.len();
                    let end = body[start..]
                        .find(|c: char| !c.is_ascii_digit())
                        .unwrap()
                        + start;
                    body[start..end].parse::<usize>().unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // All four landed somewhere; with a 30ms window they overwhelmingly
    // share launches, but the hard assertion stays scheduling-safe.
    assert_eq!(batches.len(), 4);
    assert!(batches.iter().all(|&b| (1..=4).contains(&b)));
    server.stop();
    server.join().expect("clean shutdown");
}

// ------------------------------------------- /stats and /metrics shape

/// The observability surface: after known traffic, `/stats` must report
/// wait/step percentiles and per-family counts that match what we sent,
/// and `/metrics` must expose the same truth as Prometheus text.
#[test]
fn stats_and_metrics_expose_latency_shape() {
    use cax::util::json::Json;

    let cfg = ServeConfig {
        max_sessions: 4,
        tick_window: Duration::from_micros(100),
        ..test_config()
    };
    let server = serve::start(&cfg).expect("start server");
    let addr = server.addr();

    let mut ids = vec![];
    for _ in 0..2 {
        let (status, body) = http(addr, "POST", "/sessions",
                                  r#"{"program": "life", "size": 16}"#);
        assert_eq!(status, 201, "{body}");
        ids.push(json_str_field(&body, "id"));
    }
    // 3 sequential steps per session = 6 requests, 6 wait samples.
    for id in &ids {
        for _ in 0..3 {
            let (status, body) =
                http(addr, "POST", &format!("/sessions/{id}/step"),
                     r#"{"steps": 2}"#);
            assert_eq!(status, 200, "{body}");
        }
    }

    let (status, body) = http(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&body).expect("stats is JSON");
    let num = |path: &[&str]| -> f64 {
        let mut v = &doc;
        for key in path {
            v = v.get(key).unwrap_or_else(|| {
                panic!("missing {path:?} in {body}")
            });
        }
        v.as_f64().unwrap_or_else(|| panic!("{path:?} not a number"))
    };
    assert_eq!(num(&["requests"]), 6.0);
    assert_eq!(num(&["request_wait", "count"]), 6.0);
    let (p50, p95, p99) = (
        num(&["request_wait", "p50_ms"]),
        num(&["request_wait", "p95_ms"]),
        num(&["request_wait", "p99_ms"]),
    );
    assert!(p50 <= p95 && p95 <= p99,
            "percentiles must be monotone: {p50} {p95} {p99}");
    assert!(num(&["step_latency", "count"]) >= 1.0);
    assert!(num(&["step_latency", "p99_ms"]) > 0.0);
    assert!(num(&["tick", "count"]) >= 1.0);
    assert!(num(&["batch_size", "count"]) >= 1.0);
    assert!(num(&["batch_size", "max"]) >= 1.0);
    assert!(num(&["queue_depth", "high_water"]) >= 1.0);
    assert_eq!(num(&["queue_depth", "now"]), 0.0);
    assert_eq!(num(&["families", "life"]), 6.0);
    assert_eq!(num(&["families", "eca"]), 0.0);

    let (status, text) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert!(text.contains("# TYPE cax_serve_requests_total counter"),
            "{text}");
    assert!(text.contains("cax_serve_requests_total 6\n"), "{text}");
    assert!(text.contains("cax_serve_requests_life_total 6\n"), "{text}");
    assert!(text.contains("cax_serve_requests_eca_total 0\n"), "{text}");
    assert!(text.contains("cax_serve_wait_seconds_bucket{le=\"+Inf\"} 6\n"),
            "{text}");
    assert!(text.contains("cax_serve_wait_seconds_count 6\n"), "{text}");
    assert!(text.contains("cax_serve_queue_depth_high_water"), "{text}");
    // Kernel spans record into the process-global registry; stepping a
    // Life session above guarantees this histogram exists and is
    // exposed alongside the per-coalescer metrics.
    assert!(text.contains("cax_kernel_life_seconds_count"), "{text}");

    // `/metrics.json` is the scrape wire format: the raw snapshots the
    // shard router merges. Its counts must be the exact numbers the
    // Prometheus page rendered, and the document must round-trip
    // through the snapshot parser.
    let (status, body) = http(addr, "GET", "/metrics.json", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&body).expect("metrics.json parses");
    assert_eq!(doc.get("shard"), Some(&Json::Null),
               "unsharded worker must report a null shard: {body}");
    assert_eq!(doc.get("sessions").and_then(Json::as_usize), Some(2),
               "{body}");
    assert_eq!(doc.get("pending").and_then(Json::as_usize), Some(0),
               "{body}");
    let metrics = cax::obs::metrics_from_json(
        doc.get("metrics").expect("metrics map"),
    )
    .expect("metric snapshots parse");
    let find = |name: &str| -> cax::obs::MetricSnapshot {
        metrics
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, m)| m.clone())
            .unwrap_or_else(|| panic!("missing {name} in {body}"))
    };
    assert_eq!(find("serve_requests_total"),
               cax::obs::MetricSnapshot::Counter(6));
    match find("serve_wait_seconds") {
        cax::obs::MetricSnapshot::Histogram(h) => {
            assert_eq!(h.count, 6,
                       "raw wait buckets must carry all 6 samples");
            assert!(h.quantile(0.99) >= h.quantile(0.5));
        }
        other => panic!("serve_wait_seconds was {other:?}"),
    }
    match find("serve_queue_depth") {
        cax::obs::MetricSnapshot::Gauge { value, high_water } => {
            assert_eq!(value, 0);
            assert!(high_water >= 1);
        }
        other => panic!("serve_queue_depth was {other:?}"),
    }

    server.stop();
    server.join().expect("clean shutdown");
}

// ------------------------------------------- poisoned-lock recovery

/// A handler thread that panics while holding the registry mutex
/// poisons it. The serve layer must treat that as one failed request,
/// not a process-wide cascade: every subsequent request (reads, steps
/// through the scheduler, creates) must still succeed.
#[test]
fn poisoned_registry_does_not_cascade() {
    let cfg = ServeConfig {
        tick_window: Duration::from_micros(100),
        ..test_config()
    };
    let c = Arc::new(Coalescer::new(&cfg));
    let server = cax::serve::http::start_with(&cfg, Arc::clone(&c))
        .expect("start server");
    let addr = server.addr();

    let (status, body) = http(addr, "POST", "/sessions",
                              r#"{"program": "life", "size": 16}"#);
    assert_eq!(status, 201, "{body}");
    let id = json_str_field(&body, "id");

    // Poison the registry lock exactly the way a panicking handler
    // would: panic while holding the guard.
    let poisoner = std::panic::catch_unwind(
        std::panic::AssertUnwindSafe(|| {
            let _guard = c.registry().lock().unwrap();
            panic!("injected handler panic while holding the registry");
        }),
    );
    assert!(poisoner.is_err(), "the injected panic must unwind");
    assert!(c.registry().lock().is_err(), "registry must be poisoned");

    // Every endpoint class keeps working over the poisoned lock.
    let (status, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200, "healthz after poison: {body}");
    let (status, body) =
        http(addr, "POST", &format!("/sessions/{id}/step"),
             r#"{"steps": 2}"#);
    assert_eq!(status, 200, "step after poison: {body}");
    assert!(body.contains("\"steps_done\": 2"), "{body}");
    let (status, body) = http(addr, "GET", &format!("/sessions/{id}"), "");
    assert_eq!(status, 200, "status after poison: {body}");
    let (status, body) = http(addr, "POST", "/sessions",
                              r#"{"program": "eca", "width": 32}"#);
    assert_eq!(status, 201, "create after poison: {body}");
    let (status, body) = http(addr, "GET", "/stats", "");
    assert_eq!(status, 200, "stats after poison: {body}");

    server.stop();
    server.join().expect("clean shutdown despite poisoned lock");
}

/// Boards that would smuggle NaN into the resident substrate are
/// refused at admission with a 400 — see `session::ensure_finite`. The
/// stock programs always generate finite boards, so this exercises the
/// validation seam directly.
#[test]
fn admission_validates_finiteness() {
    use cax::serve::session::ensure_finite;
    let good = Tensor::new(vec![4], vec![0.0, 1.0, 0.25, 1.0e-40]).unwrap();
    assert!(ensure_finite(&good).is_ok());
    let bad = Tensor::new(vec![4], vec![0.0, f32::NAN, 0.25, 1.0]).unwrap();
    let msg = format!("{:#}", ensure_finite(&bad).unwrap_err());
    assert!(msg.contains("non-finite"), "{msg}");
    // The serve error mapping sends that message class to a 400.
    // (`error_status` defaults non-"no session"/"busy"/"queue full"
    // messages to 400 — asserted end to end in http_end_to_end_roundtrip
    // for the other create-failure classes.)
}

// ------------------------------------------- sparse resident stepping

/// A parked (idle, long-lived) Life session stepped through the
/// activity-tracked sparse path must stay bitwise on the dense solo
/// trajectory, and — once the soup has settled into still lifes and
/// oscillators — the skipped-tile counter must actually move. This is
/// the serve-layer contract behind the idle-fleet row in `serve_load`.
#[test]
fn parked_session_sparse_stepping_stays_exact_and_skips() {
    use cax::backend::native::activity;

    let c = Coalescer::new(&test_config());
    let spec = ProgramSpec::Life { height: 48, width: 48 };
    let id = c
        .registry()
        .lock()
        .unwrap()
        .create(c.backend(), spec.clone(), Some(7))
        .unwrap();
    let initial = c
        .registry()
        .lock()
        .unwrap()
        .read_board(c.backend(), id)
        .unwrap();

    // Burn the soup down (13 x 20 steps), then measure the final tick:
    // by step 240 a 48x48 soup has settled enough that whole quiet
    // rows are provably skippable.
    activity::set_override(Some(true));
    for _ in 0..13 {
        step_all(&c, &[id], 20);
    }
    let skipped_before = activity::tiles_skipped_total();
    step_all(&c, &[id], 20);
    let skipped_after = activity::tiles_skipped_total();
    let served = c
        .registry()
        .lock()
        .unwrap()
        .read_board(c.backend(), id)
        .unwrap();
    activity::set_override(Some(false));
    let expect = NativeBackend::new()
        .rollout(&spec.program().unwrap(),
                 &Tensor::stack(&[initial]).unwrap(), 280)
        .unwrap()
        .index_axis0(0);
    activity::set_override(None);

    assert!(served.bit_eq(&expect),
            "sparse-stepped parked session diverged from dense solo");
    assert!(skipped_after > skipped_before,
            "a settled session must skip tiles \
             ({skipped_before} -> {skipped_after})");
}

// ------------------------------------------- checkpoint/restore fleet

/// The tentpole contract: an evicted-and-rehydrated session is
/// bit-identical to a never-evicted one, for every program family and
/// under multi-threaded stepping. Two sessions share one explicit seed
/// (same initial board); one is checkpointed to disk mid-trajectory and
/// lazily rehydrated by the next coalesced tick — after equal step
/// counts their boards must be bitwise equal.
#[test]
fn evicted_sessions_rehydrate_bit_identically_across_families() {
    let families: Vec<(&str, ProgramSpec)> = vec![
        ("eca", ProgramSpec::Eca { rule: 110, width: 70 }),
        ("life", ProgramSpec::Life { height: 24, width: 33 }),
        ("lenia", ProgramSpec::Lenia { radius: 5, height: 32, width: 32 }),
        (
            "lenia-multi",
            ProgramSpec::LeniaMulti {
                kernels: 2,
                radius: 4,
                height: 24,
                width: 24,
            },
        ),
        ("nca", ProgramSpec::NcaGrowing),
    ];
    for threads in [2usize, 8] {
        for (name, spec) in &families {
            let (dir, cfg) =
                fleet_config(&format!("rt-{name}-{threads}"), threads);
            let c = Coalescer::try_new(&cfg).expect("state dir opens");
            let (a, b) = {
                let mut reg = c.registry().lock().unwrap();
                let a = reg
                    .create(c.backend(), spec.clone(), Some(0xC0FFEE))
                    .unwrap();
                let b = reg
                    .create(c.backend(), spec.clone(), Some(0xC0FFEE))
                    .unwrap();
                (a, b)
            };
            step_all(&c, &[a, b], 3);
            {
                let mut reg = c.registry().lock().unwrap();
                reg.evict(a).unwrap();
                assert!(!reg.in_ram(a), "{name}: evict left it in RAM");
                assert_eq!(reg.total_sessions(), 2);
            }
            // The next coalesced tick rehydrates `a` transparently.
            step_all(&c, &[a, b], 4);
            let board = |id: u64| {
                c.registry()
                    .lock()
                    .unwrap()
                    .read_board(c.backend(), id)
                    .unwrap()
            };
            assert!(
                board(a).bit_eq(&board(b)),
                "{name} with {threads} threads: evicted-and-rehydrated \
                 trajectory diverged from the never-evicted one"
            );
            {
                let reg = c.registry().lock().unwrap();
                assert_eq!(reg.get(a).unwrap().steps_done, 7);
                assert_eq!(reg.get(b).unwrap().steps_done, 7);
            }
            assert_eq!(c.stats().evictions().get(), 1);
            assert_eq!(c.stats().rehydrations().get(), 1);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

/// Checkpoints are durable across a whole server restart: a fresh
/// coalescer over the same state dir resumes the parked trajectory
/// bitwise, and new creates never collide with on-disk ids.
#[test]
fn checkpoints_survive_a_coalescer_restart() {
    let (dir, cfg) = fleet_config("restart", 2);
    let spec = ProgramSpec::Life { height: 24, width: 33 };
    let (id, initial) = {
        let c = Coalescer::try_new(&cfg).unwrap();
        let id = c
            .registry()
            .lock()
            .unwrap()
            .create(c.backend(), spec.clone(), Some(42))
            .unwrap();
        let initial = c
            .registry()
            .lock()
            .unwrap()
            .read_board(c.backend(), id)
            .unwrap();
        step_all(&c, &[id], 3);
        assert_eq!(c.checkpoint_all().unwrap(), 1);
        (id, initial)
    };

    let c = Coalescer::try_new(&cfg).unwrap();
    {
        let reg = c.registry().lock().unwrap();
        assert!(!reg.in_ram(id), "restart starts with an empty registry");
        assert_eq!(reg.total_sessions(), 1, "the checkpoint is visible");
    }
    let other = c
        .registry()
        .lock()
        .unwrap()
        .create(c.backend(), spec.clone(), None)
        .unwrap();
    assert_ne!(other, id, "minting must avoid on-disk ids");
    step_all(&c, &[id], 4);
    let got = c
        .registry()
        .lock()
        .unwrap()
        .read_board(c.backend(), id)
        .unwrap();
    let expect = NativeBackend::new()
        .rollout(&spec.program().unwrap(),
                 &Tensor::stack(&[initial]).unwrap(), 7)
        .unwrap()
        .index_axis0(0);
    assert!(got.bit_eq(&expect),
            "restart-resumed trajectory diverged from uninterrupted solo");
    assert_eq!(c.registry().lock().unwrap().get(id).unwrap().steps_done, 7);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Over HTTP, a full working set evicts LRU instead of 503ing, evicted
/// sessions stay fully reachable (status rehydrates), `/stats` exposes
/// the fleet counters, and destroy removes checkpoint files.
#[test]
fn http_working_set_cap_evicts_and_rehydrates() {
    use cax::util::json::Json;

    let (dir, fleet) = fleet_config("http-lru", 2);
    let cfg = ServeConfig {
        max_sessions: 2,
        tick_window: Duration::from_micros(100),
        ..fleet
    };
    let server = serve::start(&cfg).expect("start server");
    let addr = server.addr();

    // Three creates through a cap of two: the third evicts the LRU
    // instead of rejecting (the pre-state-dir behavior was a 503).
    let mut ids = vec![];
    for _ in 0..3 {
        let (status, body) = http(addr, "POST", "/sessions",
                                  r#"{"program": "life", "size": 16}"#);
        assert_eq!(status, 201, "create must evict, not reject: {body}");
        ids.push(json_str_field(&body, "id"));
    }

    let (status, body) = http(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    let doc = Json::parse(&body).expect("stats is JSON");
    let fleet_num = |key: &str| -> f64 {
        doc.get("fleet")
            .and_then(|f| f.get(key))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing fleet.{key} in {body}"))
    };
    assert!(fleet_num("evictions") >= 1.0);
    assert_eq!(fleet_num("total_sessions"), 3.0);
    assert_eq!(fleet_num("evicted"), 1.0);
    assert!(fleet_num("resident_bytes") > 0.0);

    // Every session answers, evicted or not; each GET may itself evict
    // another (the cap holds), so this loops the whole working set
    // through disk.
    for id in &ids {
        let (status, body) =
            http(addr, "GET", &format!("/sessions/{id}"), "");
        assert_eq!(status, 200, "evicted session unreachable: {body}");
        let (status, body) =
            http(addr, "POST", &format!("/sessions/{id}/step"),
                 r#"{"steps": 2}"#);
        assert_eq!(status, 200, "stepping after eviction: {body}");
        assert!(body.contains("\"steps_done\": 2"), "{body}");
    }

    // Destroy reaches disk too: no checkpoint files survive.
    for id in &ids {
        let (status, body) =
            http(addr, "DELETE", &format!("/sessions/{id}"), "");
        assert_eq!(status, 200, "{body}");
    }
    let leftovers = std::fs::read_dir(&dir)
        .map(|it| {
            it.filter_map(|e| e.ok())
                .filter(|e| {
                    e.path().extension().is_some_and(|x| x == "ckpt")
                })
                .count()
        })
        .unwrap_or(0);
    assert_eq!(leftovers, 0, "destroyed sessions left checkpoints");

    server.stop();
    server.join().expect("clean shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- SSE frame stream

/// `GET /sessions/:id/stream` speaks chunked `text/event-stream`: an
/// initial frame on subscribe, then one frame per coalesced launch,
/// with the delivery counted in `/stats`.
#[test]
fn sse_stream_pushes_frames_per_tick() {
    let cfg = ServeConfig {
        tick_window: Duration::from_micros(100),
        ..test_config()
    };
    let server = serve::start(&cfg).expect("start server");
    let addr = server.addr();
    let (status, body) = http(addr, "POST", "/sessions",
                              r#"{"program": "life", "size": 16}"#);
    assert_eq!(status, 201, "{body}");
    let id = json_str_field(&body, "id");

    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(20)))
        .unwrap();
    write!(stream,
           "GET /sessions/{id}/stream HTTP/1.1\r\nHost: cax\r\n\
            Connection: close\r\n\r\n")
        .expect("send stream request");

    // Read until a predicate holds (the response arrives as chunks).
    let mut buf: Vec<u8> = Vec::new();
    let mut read_until = |buf: &mut Vec<u8>, what: &str| {
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut chunk = [0u8; 4096];
        while !String::from_utf8_lossy(buf).contains(what) {
            assert!(Instant::now() < deadline,
                    "timed out waiting for {what:?} in {:?}",
                    String::from_utf8_lossy(buf));
            match stream.read(&mut chunk) {
                Ok(0) => panic!("stream closed before {what:?}"),
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e) => panic!("stream read failed: {e}"),
            }
        }
    };

    // Headers: chunked SSE, then the initial frame event.
    read_until(&mut buf, "\r\n\r\n");
    let head = String::from_utf8_lossy(&buf).to_string();
    assert!(head.contains("200 OK"), "{head}");
    assert!(head.contains("text/event-stream"), "{head}");
    assert!(head.to_ascii_lowercase().contains("chunked"), "{head}");
    read_until(&mut buf, "event: frame");
    read_until(&mut buf, "\"steps_done\":0");

    // A step from another connection publishes a frame into the stream.
    let (status, body) =
        http(addr, "POST", &format!("/sessions/{id}/step"),
             r#"{"steps": 3}"#);
    assert_eq!(status, 200, "{body}");
    read_until(&mut buf, "\"steps_done\":3");
    let text = String::from_utf8_lossy(&buf).to_string();
    assert!(text.contains("\"ppm_base64\":\""), "frame carries a board");
    assert!(text.contains("\"batch\":1"), "{text}");

    // The delivery shows up in /stats.
    let (status, stats) = http(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    assert!(stats.contains("\"stream\""), "{stats}");
    let frames_pat = "\"frames\": ";
    let start = stats.find(frames_pat).expect("stream.frames") +
        frames_pat.len();
    let end = stats[start..]
        .find(|c: char| !c.is_ascii_digit())
        .unwrap() + start;
    let frames: u64 = stats[start..end].parse().unwrap();
    // The initial frame is written by the handler directly; only
    // tick-published deliveries count here.
    assert!(frames >= 1, "per-tick frame deliveries, got {frames}");

    drop(stream);
    server.stop();
    server.join().expect("clean shutdown with a live stream");
}

// ------------------------------------------------- shard router (e2e)

/// `cax serve` must drain and exit 0 on SIGTERM (the ctrl-c/SIGINT path
/// shares the same handler and flag).
#[test]
fn sigterm_drains_and_exits_zero() {
    let exe = env!("CARGO_BIN_EXE_cax");
    let mut child = std::process::Command::new(exe)
        .args(["serve", "--port", "0", "--threads", "2", "--max-sessions",
               "8"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn cax serve");

    let stdout = child.stdout.take().expect("child stdout");
    let stderr = child.stderr.take().expect("child stderr");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).expect("read listening line");
    assert!(line.contains("listening on"), "first line: {line:?}");
    let addr: SocketAddr = line
        .split("listening on ")
        .nth(1)
        .unwrap()
        .split_whitespace()
        .next()
        .unwrap()
        .parse()
        .expect("parse listen address");

    // Real in-flight work before the signal.
    let (status, body) = http(addr, "POST", "/sessions",
                              r#"{"program": "life", "size": 32}"#);
    assert_eq!(status, 201, "{body}");
    let id = json_str_field(&body, "id");
    let (status, body) =
        http(addr, "POST", &format!("/sessions/{id}/step"),
             r#"{"steps": 4}"#);
    assert_eq!(status, 200, "{body}");

    // Signal through the C runtime directly (no dependency on a `kill`
    // binary being installed).
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGTERM: i32 = 15;
    let rc = unsafe { kill(child.id() as i32, SIGTERM) };
    assert_eq!(rc, 0, "kill(SIGTERM) failed");

    let deadline = Instant::now() + Duration::from_secs(15);
    let status = loop {
        if let Some(st) = child.try_wait().expect("try_wait") {
            break st;
        }
        assert!(Instant::now() < deadline,
                "cax serve did not exit within 15s of SIGTERM");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(status.success(),
            "graceful shutdown must exit 0, got {status:?}");

    // The drain announcements go through the leveled logger, which
    // writes to stderr (stdout stays machine-parseable).
    let mut err = String::new();
    BufReader::new(stderr).read_to_string(&mut err).expect("drain stderr");
    assert!(err.contains("draining"),
            "expected the drain announcement on stderr, got: {err:?}");
}

/// `--shards 2` end to end: the router forks two worker processes,
/// spreads creates across them, routes every `/sessions/:id/...` by id,
/// and bit-identity holds across the process boundary — a snapshot
/// served by a worker matches an in-process solo rollout byte for byte.
#[test]
fn shard_router_routes_sessions_across_worker_processes() {
    let exe = env!("CARGO_BIN_EXE_cax");
    let mut child = std::process::Command::new(exe)
        .args(["serve", "--port", "0", "--shards", "2", "--threads", "2",
               "--max-sessions", "8", "--tick-us", "100"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn cax serve --shards 2");
    let stdout = child.stdout.take().expect("child stdout");
    drop(child.stderr.take()); // workers chatter here; let it flow to null

    // Worker stdout is forwarded to the router's stderr, so the first
    // (and only) stdout line is the router's own listening line.
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).expect("read listening line");
    assert!(line.contains("router listening on"), "first line: {line:?}");
    assert!(line.contains("2 shards"), "first line: {line:?}");
    let addr: SocketAddr = line
        .split("listening on ")
        .nth(1)
        .unwrap()
        .split_whitespace()
        .next()
        .unwrap()
        .parse()
        .expect("parse router address");

    // Round-robin creates: two sessions land on the two shards, which
    // is visible in their minted ids (id % shards == shard index).
    let (status, body) = http(
        addr, "POST", "/sessions",
        r#"{"program": "life", "size": 24, "seed": 123}"#,
    );
    assert_eq!(status, 201, "{body}");
    let seeded = json_str_field(&body, "id");
    let (status, body) = http(addr, "POST", "/sessions",
                              r#"{"program": "life", "size": 24}"#);
    assert_eq!(status, 201, "{body}");
    let other = json_str_field(&body, "id");
    let parity = |hex: &str| {
        u64::from_str_radix(hex, 16).expect("hex session id") % 2
    };
    assert_ne!(parity(&seeded), parity(&other),
               "round-robin must spread sessions across both shards");

    // Step on whichever shard owns the seeded session, then compare its
    // snapshot bytes against an in-process rollout of the same seed:
    // bit-identity across the process boundary.
    let (status, body) =
        http(addr, "POST", &format!("/sessions/{seeded}/step"),
             r#"{"steps": 5}"#);
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"steps_done\": 5"), "{body}");
    let (status, got) = http_bytes(
        addr, "GET", &format!("/sessions/{seeded}/snapshot.ppm"), "",
    );
    assert_eq!(status, 200);
    let spec = ProgramSpec::Life { height: 24, width: 24 };
    let expected = NativeBackend::new()
        .rollout(
            &spec.program().unwrap(),
            &Tensor::stack(&[spec.initial_board(123).unwrap()]).unwrap(),
            5,
        )
        .unwrap()
        .index_axis0(0);
    let want = cax::viz::spacetime::render_field(&expected)
        .unwrap()
        .ppm_bytes()
        .unwrap();
    assert_eq!(got, want,
               "worker-served snapshot diverged from the solo rollout");

    // Fan-out routes see both shards.
    let (status, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"shards\": 2"), "{body}");
    let (status, body) = http(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    assert!(body.contains("\"router\": true"), "{body}");
    assert!(body.contains("\"shard\": 1"), "{body}");

    // The /stats roll-up sums sessions across shards exactly and
    // carries the router's own proxy counters alongside.
    use cax::util::json::Json;
    let stats_doc = Json::parse(&body).expect("router stats parses");
    let fleet = stats_doc.get("fleet").expect("fleet roll-up");
    assert_eq!(fleet.get("sessions").and_then(Json::as_usize), Some(2),
               "{body}");
    assert_eq!(fleet.get("scraped_ok").and_then(Json::as_usize), Some(2),
               "{body}");
    let proxy = stats_doc.get("proxy").expect("proxy stats");
    assert!(proxy.get("proxied").and_then(Json::as_f64).unwrap_or(0.0)
                >= 4.0,
            "creates + step + snapshot all proxied: {body}");
    assert_eq!(proxy.get("errors").and_then(Json::as_usize), Some(0),
               "{body}");

    // Router /metrics: one fleet-wide Prometheus page — merged
    // (unlabeled) totals plus per-shard `shard="i"` series, with a
    // single `# TYPE` line per family.
    let (status, text) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert!(text.contains("cax_router_shards 2\n"), "{text}");
    assert!(text.contains("cax_serve_requests_total{shard=\"0\"}"),
            "{text}");
    assert!(text.contains("cax_serve_requests_total{shard=\"1\"}"),
            "{text}");
    assert!(text.lines()
                .any(|l| l.starts_with("cax_serve_requests_total ")),
            "merged family line must sit beside the labeled series: \
             {text}");
    assert_eq!(
        text.lines()
            .filter(|l| *l == "# TYPE cax_serve_requests_total counter")
            .count(),
        1,
        "exactly one TYPE line per family: {text}"
    );
    assert!(text.contains("cax_serve_wait_seconds_bucket{le=\"+Inf\"}"),
            "merged raw wait buckets must be exposed: {text}");
    assert!(text.contains("cax_router_proxied_total"), "{text}");

    // Router /metrics.json: per-shard exact snapshots plus the merged
    // fleet view. The merged requests counter must be the exact sum of
    // the per-shard counters — aggregation, never averaging.
    let (status, body) = http(addr, "GET", "/metrics.json", "");
    assert_eq!(status, 200, "{body}");
    let doc = Json::parse(&body).expect("router metrics.json parses");
    assert_eq!(doc.get("router").and_then(Json::as_bool), Some(true),
               "{body}");
    let shards_arr =
        doc.get("shards").and_then(Json::as_arr).expect("shards array");
    assert_eq!(shards_arr.len(), 2, "{body}");
    let requests_of = |metrics_json: &Json| -> u64 {
        let metrics = cax::obs::metrics_from_json(metrics_json)
            .expect("metric snapshots parse");
        match metrics.iter().find(|(n, _)| n == "serve_requests_total") {
            Some((_, cax::obs::MetricSnapshot::Counter(v))) => *v,
            other => panic!("serve_requests_total was {other:?}"),
        }
    };
    let shard_sum: u64 = shards_arr
        .iter()
        .map(|s| requests_of(s.get("metrics").expect("shard metrics")))
        .sum();
    let merged = doc.get("merged").expect("merged fleet view");
    let merged_requests =
        requests_of(merged.get("metrics").expect("merged metrics"));
    assert_eq!(merged_requests, shard_sum,
               "merged counter must equal the per-shard sum exactly");
    assert!(merged_requests >= 1, "the step above was counted: {body}");

    // Drain: the router shuts its workers down and exits 0.
    let (status, body) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("draining"), "{body}");
    let deadline = Instant::now() + Duration::from_secs(30);
    let status = loop {
        if let Some(st) = child.try_wait().expect("try_wait") {
            break st;
        }
        assert!(Instant::now() < deadline,
                "shard router did not exit within 30s of /shutdown");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(status.success(), "router drain must exit 0, got {status:?}");
}
