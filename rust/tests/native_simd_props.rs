//! Differential battery for the AVX2 SIMD paths: every vectorized
//! kernel must be **bit-identical** to its always-compiled scalar
//! reference — across radii, widths not divisible by the lane count,
//! thread counts, and boards poisoned with NaN / infinity / denormals.
//!
//! On hosts where [`cax::backend::native::simd::active`] is false
//! (non-x86_64, no AVX2, or `CAX_SIMD=off`) the dispatching entry
//! points run the scalar code and these tests hold vacuously — the CI
//! matrix runs the suite in both modes.

use cax::automata::lenia::LeniaParams;
use cax::backend::native::lenia::{
    update_stage, update_stage_scalar, LeniaKernel,
};
use cax::backend::native::nca::NcaModel;
use cax::backend::native::simd;
use cax::backend::{Backend, CaProgram, NativeBackend};
use cax::tensor::Tensor;
use cax::util::rng::Rng;

/// Bitwise slice comparison with a per-cell diagnostic.
fn assert_bits_eq(a: &[f32], b: &[f32], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{label}: cell {i} diverged: {x} ({:#010x}) vs {y} ({:#010x})",
            x.to_bits(),
            y.to_bits()
        );
    }
}

/// Step a board `steps` times through `step` (dispatching) and
/// `step_scalar` side by side, asserting bit identity after every step
/// so a divergence is caught at its first occurrence.
fn lenia_differential(kernel: &LeniaKernel, board: &[f32], h: usize,
                      w: usize, steps: usize, label: &str) {
    let mut cur = board.to_vec();
    let mut cur_ref = board.to_vec();
    let mut next = vec![0.0f32; board.len()];
    let mut next_ref = vec![0.0f32; board.len()];
    for step in 0..steps {
        kernel.step(&cur, &mut next, h, w);
        kernel.step_scalar(&cur_ref, &mut next_ref, h, w);
        assert_bits_eq(&next, &next_ref, &format!("{label} step {step}"));
        cur.copy_from_slice(&next);
        cur_ref.copy_from_slice(&next_ref);
    }
}

#[test]
fn lenia_sparse_tap_bit_identical_across_radii() {
    // Radii spanning tiny stencils to the FFT-crossover regime; widths
    // are 2r + 13 so every board has a full 8-lane interior plus a
    // ragged (non-multiple-of-8) vector tail and scalar edge columns.
    for &radius in &[3usize, 4, 5, 7, 10, 13, 16, 24, 32] {
        let params = LeniaParams { radius, ..Default::default() };
        let kernel = LeniaKernel::new(params);
        // Boards must be at least radius tall/wide (the wrap rule's
        // contract); the width also guarantees a full 8-lane interior.
        let (h, w) = (radius + 7, 2 * radius + 13);
        let mut rng = Rng::new(0x51D0 + radius as u64);
        let board = rng.vec_f32(h * w);
        lenia_differential(&kernel, &board, h, w, 3,
                           &format!("lenia r={radius}"));
    }
}

#[test]
fn lenia_sparse_tap_bit_identical_across_widths() {
    // Widths straddling the dispatch threshold (w >= 2r + 8 = 16 for
    // r=4) and exercising every tail length mod 8.
    let params = LeniaParams { radius: 4, ..Default::default() };
    let kernel = LeniaKernel::new(params);
    for &w in &[9usize, 15, 16, 17, 19, 21, 26, 30, 33, 40] {
        let h = 9;
        let mut rng = Rng::new(0xA11 + w as u64);
        let board = rng.vec_f32(h * w);
        lenia_differential(&kernel, &board, h, w, 3,
                           &format!("lenia w={w}"));
    }
}

#[test]
fn lenia_sparse_tap_bit_identical_on_poisoned_boards() {
    // NaN payloads, infinities and denormals must flow through the
    // SIMD lanes exactly as through the scalar taps — same propagation,
    // same clamp semantics, bit for bit. One step only: the poison
    // spreads to the whole neighborhood immediately.
    let params = LeniaParams { radius: 5, ..Default::default() };
    let kernel = LeniaKernel::new(params);
    let (h, w) = (9, 27);
    let mut rng = Rng::new(0xBAD);
    let mut board = rng.vec_f32(h * w);
    board[3] = f32::NAN;
    board[40] = f32::from_bits(0x7FC0_1234); // NaN with a payload
    board[77] = f32::INFINITY;
    board[120] = f32::NEG_INFINITY;
    board[150] = 1.0e-40; // denormal
    board[151] = -1.0e-42;
    board[200] = -0.0;
    lenia_differential(&kernel, &board, h, w, 1, "lenia poisoned");
}

#[test]
fn lenia_update_stage_bit_identical_with_poison() {
    // The shared growth/update stage of the spectral path: hw = 67
    // (8 full vectors + a 3-cell scalar tail), three kernels mixing
    // into one channel, with NaN / inf / denormal growths and states.
    let hw = 67;
    let wk = [0.5f32, -0.25, 0.75];
    let dt = 0.1f32;
    let mut rng = Rng::new(0x57A6E);
    let mut state = rng.vec_f32(hw);
    let mut growths = rng.vec_f32(wk.len() * hw);
    state[5] = f32::NAN;
    state[13] = -0.0;
    state[64] = 1.0e-41;
    growths[9] = f32::NAN;
    growths[hw + 20] = f32::INFINITY;
    growths[2 * hw + 33] = f32::NEG_INFINITY;
    growths[2 * hw + 66] = -1.0e-40;
    let mut next = vec![0.0f32; hw];
    let mut next_ref = vec![0.0f32; hw];
    update_stage(&state, &growths, hw, &wk, dt, &mut next);
    update_stage_scalar(&state, &growths, hw, &wk, dt, &mut next_ref);
    assert_bits_eq(&next, &next_ref, "update_stage");
}

/// Step an NCA board through the dispatching and scalar kernels side by
/// side, asserting bit identity after every step.
fn nca_differential(model: &NcaModel, board: &[f32], h: usize, w: usize,
                    frozen: usize, steps: usize, label: &str) {
    let mut cur = board.to_vec();
    let mut cur_ref = board.to_vec();
    let mut next = vec![0.0f32; board.len()];
    let mut next_ref = vec![0.0f32; board.len()];
    for step in 0..steps {
        model.step_frozen(&cur, &mut next, h, w, frozen);
        model.step_frozen_scalar(&cur_ref, &mut next_ref, h, w, frozen);
        assert_bits_eq(&next, &next_ref, &format!("{label} step {step}"));
        cur.copy_from_slice(&next);
        cur_ref.copy_from_slice(&next_ref);
    }
}

#[test]
fn nca_forward_bit_identical_across_geometries() {
    // Channel counts around the growing/MNIST models, hidden sizes on
    // both sides of a vector, widths from the dispatch threshold
    // (w >= 10) up through ragged tails, frozen prefixes on and off.
    for &(c, hidden) in &[(3usize, 5usize), (4, 16), (8, 16)] {
        for &w in &[10usize, 13, 16, 23] {
            for &frozen in &[0usize, 2] {
                let mut rng = Rng::new((c * 100 + w * 10 + frozen) as u64);
                let model = NcaModel::random(c, hidden, &mut rng);
                let h = 7;
                let board = rng.vec_f32(h * w * c);
                nca_differential(
                    &model, &board, h, w, frozen, 2,
                    &format!("nca c={c} hid={hidden} w={w} fz={frozen}"));
            }
        }
    }
}

#[test]
fn nca_forward_bit_identical_on_poisoned_boards() {
    // NaN folds to 0.0 through the ReLU in both paths (max with the
    // accumulator as the first operand), infinities and denormals
    // propagate — all bit-identical to the scalar cell.
    let mut rng = Rng::new(0xDEAD_BEEF);
    let model = NcaModel::random(4, 8, &mut rng);
    let (h, w, c) = (6, 14, 4);
    let mut board = rng.vec_f32(h * w * c);
    board[7] = f32::NAN;
    board[50] = f32::from_bits(0x7FC0_00AB);
    board[100] = f32::INFINITY;
    board[161] = f32::NEG_INFINITY;
    board[200] = 1.0e-40;
    board[260] = -0.0;
    nca_differential(&model, &board, h, w, 0, 1, "nca poisoned");
    nca_differential(&model, &board, h, w, 2, 1, "nca poisoned frozen");
}

#[test]
fn backend_rollouts_thread_invariant_in_current_mode() {
    // Whatever mode this host dispatches to, the batched backend must
    // stay bit-deterministic across worker counts (lane = cell keeps
    // the per-cell accumulation order thread- and SIMD-independent).
    let solo = NativeBackend::with_threads(1);
    let pool = NativeBackend::with_threads(8);

    let params = LeniaParams { radius: 5, ..Default::default() };
    let mut rng = Rng::new(0x7EAD);
    let lenia_state =
        Tensor::new(vec![3, 12, 25], rng.binary_vec(3 * 12 * 25, 0.5))
            .unwrap();
    let prog = CaProgram::Lenia { params };
    let a = solo.rollout(&prog, &lenia_state, 4).unwrap();
    let b = pool.rollout(&prog, &lenia_state, 4).unwrap();
    assert!(a.bit_eq(&b), "lenia rollout varies with thread count");

    let model = NcaModel::random(4, 8, &mut rng);
    let (h, w, c) = (9, 14, 4);
    let nca_state =
        Tensor::new(vec![2, h, w, c], rng.vec_f32(2 * h * w * c)).unwrap();
    let prog = CaProgram::Nca(model);
    let a = solo.rollout(&prog, &nca_state, 3).unwrap();
    let b = pool.rollout(&prog, &nca_state, 3).unwrap();
    assert!(a.bit_eq(&b), "nca rollout varies with thread count");
}

#[test]
fn simd_status_is_reported_and_consistent() {
    let backend = NativeBackend::with_threads(1);
    let status = backend.simd_status();
    assert_eq!(status, simd::status());
    if simd::active() {
        assert_eq!(status, "avx2");
    } else {
        assert!(status.starts_with("scalar"), "got {status:?}");
    }
}
