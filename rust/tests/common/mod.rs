//! Shared test scaffolding: locate the artifacts directory and build an
//! [`Engine`] exactly as the CLI does.

use std::path::PathBuf;

use cax::runtime::Engine;

/// The artifacts directory: `CAX_ARTIFACTS` override, else `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CAX_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A fresh engine over the build's artifacts. Panics with a pointer to
/// `make artifacts` if they are missing.
pub fn engine() -> Engine {
    let dir = artifacts_dir();
    Engine::load(&dir).unwrap_or_else(|e| {
        panic!(
            "cannot load artifacts from {} — run `make artifacts` first\n{e:#}",
            dir.display()
        )
    })
}
