//! Equivalence tests across the three execution paths of Figure 3.
//!
//! The fused XLA rollout, the stepwise XLA path and the naive Rust baseline
//! implement the *same* mathematical CA. For discrete CAs (ECA, Life) all
//! three must agree bit-exactly; for Lenia (continuous, FFT vs direct
//! convolution) the XLA paths agree bit-exactly with each other and the
//! naive direct convolution agrees within float tolerance.
//!
//! Needs the PJRT engine + artifacts: `cargo test --features pjrt`.
//! The artifact-free native-vs-naive equivalences live in
//! `native_backend_props.rs` and run on default features.
#![cfg(feature = "pjrt")]

use cax::automata::WolframRule;
use cax::coordinator::{Path, Simulator};
use cax::util::rng::Rng;

mod common;
use common::engine;

#[test]
fn eca_three_paths_agree_bitwise() {
    let engine = engine();
    let sim = Simulator::new(&engine);
    let steps = engine
        .manifest()
        .artifact("eca_rollout")
        .unwrap()
        .meta_usize("steps")
        .unwrap();
    let mut rng = Rng::new(11);
    for rule_no in [30u8, 90, 110, 184] {
        let rule = WolframRule::new(rule_no);
        let state = sim.random_state("eca_rollout", &mut rng).unwrap();
        let fused = sim.run_eca(Path::Fused, &state, rule, steps).unwrap();
        let stepwise =
            sim.run_eca(Path::Stepwise, &state, rule, steps).unwrap();
        let naive = sim.run_eca(Path::Naive, &state, rule, steps).unwrap();
        assert!(fused.bit_eq(&stepwise), "rule {rule_no}: fused != stepwise");
        assert!(fused.bit_eq(&naive), "rule {rule_no}: fused != naive");
    }
}

#[test]
fn eca_rule_90_is_xor_of_neighbors() {
    // Independent oracle: rule 90 = left XOR right. Checks the whole stack
    // against a closed-form definition rather than a reimplementation.
    let engine = engine();
    let sim = Simulator::new(&engine);
    let mut rng = Rng::new(5);
    let state = sim.random_state("eca_step", &mut rng).unwrap();
    let rule = WolframRule::new(90);
    // Stepwise: exactly one application of the XLA step artifact (the
    // fused rollout bakes its step count in-graph).
    let out = sim.run_eca(Path::Stepwise, &state, rule, 1).unwrap();
    let (b, w) = (state.shape()[0], state.shape()[1]);
    for i in 0..b {
        for x in 0..w {
            let l = state.at(&[i, (x + w - 1) % w]) as u8;
            let r = state.at(&[i, (x + 1) % w]) as u8;
            assert_eq!(out.at(&[i, x]) as u8, l ^ r, "batch {i} cell {x}");
        }
    }
}

#[test]
fn life_three_paths_agree_bitwise() {
    let engine = engine();
    let sim = Simulator::new(&engine);
    let steps = engine
        .manifest()
        .artifact("life_rollout")
        .unwrap()
        .meta_usize("steps")
        .unwrap();
    let mut rng = Rng::new(23);
    let state = sim.random_state("life_rollout", &mut rng).unwrap();
    let fused = sim.run_life(Path::Fused, &state, steps).unwrap();
    let stepwise = sim.run_life(Path::Stepwise, &state, steps).unwrap();
    let naive = sim.run_life(Path::Naive, &state, steps).unwrap();
    assert!(fused.bit_eq(&stepwise), "fused != stepwise");
    assert!(fused.bit_eq(&naive), "fused != naive");
}

#[test]
fn life_glider_translates() {
    // A glider on a torus returns to a translated copy of itself every 4
    // steps — a classic closed-form invariant of the rule.
    let engine = engine();
    let sim = Simulator::new(&engine);
    let info = engine.manifest().artifact("life_step").unwrap();
    let shape = info.inputs[0].shape.clone();
    let (h, w) = (shape[1], shape[2]);
    let mut state = cax::Tensor::zeros(&shape);
    // Glider (southeast-moving) in every batch element.
    for b in 0..shape[0] {
        for (dy, dx) in [(0, 1), (1, 2), (2, 0), (2, 1), (2, 2)] {
            state.set(&[b, 4 + dy, 4 + dx], 1.0);
        }
    }
    let out = sim.run_life(Path::Stepwise, &state, 4).unwrap();
    for b in 0..shape[0] {
        for y in 0..h {
            for x in 0..w {
                let src = state.at(&[b, y, x]);
                let dst = out.at(&[b, (y + 1) % h, (x + 1) % w]);
                assert_eq!(src, dst, "glider broke at b={b} y={y} x={x}");
            }
        }
    }
}

#[test]
fn lenia_xla_paths_bit_equal_and_naive_close() {
    let engine = engine();
    let sim = Simulator::new(&engine);
    let steps = engine
        .manifest()
        .artifact("lenia_rollout")
        .unwrap()
        .meta_usize("steps")
        .unwrap();
    let mut rng = Rng::new(37);
    let state = sim.random_state("lenia_rollout", &mut rng).unwrap();
    let fused = sim.run_lenia(Path::Fused, &state, steps).unwrap();
    let stepwise = sim.run_lenia(Path::Stepwise, &state, steps).unwrap();
    assert!(
        fused.max_abs_diff(&stepwise).unwrap() < 1e-5,
        "fused vs stepwise drift {}",
        fused.max_abs_diff(&stepwise).unwrap()
    );
    // Direct convolution vs FFT accumulates rounding over steps; run a
    // short horizon for the naive comparison.
    let short = 4;
    let f_short = sim.run_lenia(Path::Stepwise, &state, short).unwrap();
    let n_short = sim.run_lenia(Path::Naive, &state, short).unwrap();
    let diff = f_short.max_abs_diff(&n_short).unwrap();
    assert!(diff < 5e-3, "naive Lenia drifted {diff} after {short} steps");
}

#[test]
fn lenia_state_stays_in_unit_interval() {
    let engine = engine();
    let sim = Simulator::new(&engine);
    let mut rng = Rng::new(41);
    let state = sim.random_state("lenia_rollout", &mut rng).unwrap();
    let out = sim.run_lenia(Path::Fused, &state, 8).unwrap();
    for &v in out.data() {
        assert!((0.0..=1.0).contains(&v), "Lenia left [0,1]: {v}");
    }
}

#[test]
fn traj_artifacts_match_rollout_finals() {
    // The *_traj artifacts must tell the same story as the plain step
    // artifacts: traj[t] == t+1 applications of the step.
    let engine = engine();
    let sim = Simulator::new(&engine);
    let mut rng = Rng::new(59);
    let state = sim.random_state("eca_traj", &mut rng).unwrap();
    let rule = WolframRule::new(110);
    let (final_state, traj) = sim.eca_traj(&state, rule).unwrap();
    let t = traj.shape()[0];
    // Last trajectory frame equals the returned final state.
    assert!(final_state.bit_eq(&traj.index_axis0(t - 1)));
    // Frame 0 equals one application (naive path: the traj artifact's
    // width differs from eca_step's, so the XLA step can't be reused).
    let one = sim.run_eca(Path::Naive, &state, rule, 1).unwrap();
    assert!(one.bit_eq(&traj.index_axis0(0)), "traj[0] != step(state)");
    // And the naive path reproduces an arbitrary middle frame.
    let k = t / 2;
    let mid = sim.run_eca(Path::Naive, &state, rule, k + 1).unwrap();
    assert!(mid.bit_eq(&traj.index_axis0(k)), "traj[{k}] != naive^{}", k + 1);
}

#[test]
fn pjrt_backend_adapter_matches_simulator_stepwise() {
    // The generic Backend adapter must tell the same story as the
    // Simulator's artifact-named stepwise path.
    use cax::backend::{Backend, CaProgram, PjrtBackend};
    let engine = engine();
    let backend = PjrtBackend::new(&engine);
    let sim = Simulator::new(&engine);
    let mut rng = Rng::new(71);

    let rule = WolframRule::new(110);
    let prog = CaProgram::Eca { rule };
    assert!(backend.supports(&prog));
    let state = sim.random_state("eca_step", &mut rng).unwrap();
    let via_adapter = backend.rollout(&prog, &state, 3).unwrap();
    let via_sim = sim.run_eca(Path::Stepwise, &state, rule, 3).unwrap();
    assert!(via_adapter.bit_eq(&via_sim), "eca adapter != stepwise");

    let life = sim.random_state("life_step", &mut rng).unwrap();
    let a = backend.rollout(&CaProgram::Life, &life, 2).unwrap();
    let b = sim.run_life(Path::Stepwise, &life, 2).unwrap();
    assert!(a.bit_eq(&b), "life adapter != stepwise");
}
