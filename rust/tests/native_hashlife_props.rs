//! Equivalence battery for the memoizing quadtree arm: `LifeHash` /
//! `EcaHash` must be **bit-identical** to the SWAR kernels on every
//! board and every horizon — including every non-power-of-two step
//! count in `1..=257` (each one exercises a different largest-pow2
//! decomposition), long-horizon structured patterns (the Gosper gun,
//! the rule-90 Sierpinski gasket), chaotic soups, and a deliberately
//! tiny interner cap that forces mid-flight GC rebuilds.
//!
//! These tests bypass the dispatcher entirely, so they hold on both
//! `CAX_SPARSE` CI legs.

use cax::automata::WolframRule;
use cax::backend::native::hashlife::{EcaHash, LifeHash, DEFAULT_NODE_CAP};
use cax::backend::native::life::LifeKernel;
use cax::backend::native::{bits, eca};
use cax::util::rng::Rng;

/// The Gosper glider gun (36 cells, period 30), as `(x, y)` offsets.
const GOSPER_GUN: [(usize, usize); 36] = [
    (0, 4), (0, 5), (1, 4), (1, 5), (10, 4), (10, 5), (10, 6), (11, 3),
    (11, 7), (12, 2), (12, 8), (13, 2), (13, 8), (14, 5), (15, 3),
    (15, 7), (16, 4), (16, 5), (16, 6), (17, 5), (20, 2), (20, 3),
    (20, 4), (21, 2), (21, 3), (21, 4), (22, 1), (22, 5), (24, 0),
    (24, 1), (24, 5), (24, 6), (34, 2), (34, 3), (35, 2), (35, 3),
];

/// Pack the gun into a `size`×`size` torus at offset `(ox, oy)`.
fn gun_grid(size: usize, ox: usize, oy: usize) -> Vec<u64> {
    let wpr = bits::words_for(size);
    let mut grid = vec![0u64; size * wpr];
    for &(x, y) in &GOSPER_GUN {
        let (gx, gy) = (ox + x, oy + y);
        assert!(gx < size && gy < size, "gun out of bounds");
        grid[gy * wpr + gx / 64] |= 1 << (gx % 64);
    }
    grid
}

fn random_square(size: usize, density: f32, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let wpr = bits::words_for(size);
    let mut grid = vec![0u64; size * wpr];
    let cells = rng.binary_vec(size * size, density);
    cax::backend::native::life::pack_board(&cells, size, size, &mut grid);
    grid
}

// ----------------------------------------------------------------- Life

#[test]
fn hashlife_matches_swar_on_the_gosper_gun() {
    // One engine across all horizons: later advances must reuse the
    // memo table built by earlier ones and still stay exact.
    let size = 64;
    let start = gun_grid(size, 4, 8);
    let mut hl = LifeHash::default();
    let mut horizons: Vec<usize> = (1..=17).collect();
    horizons.extend([30, 64, 100, 256, 300]);
    for steps in horizons {
        let mut dense = start.clone();
        let mut kern = LifeKernel::new(size, size);
        kern.rollout(&mut dense, steps);
        let mut quad = start.clone();
        hl.advance(&mut quad, size, steps);
        assert_eq!(dense, quad, "gosper gun diverged at {steps} steps");
    }
    assert!(hl.memo_hits() > 0,
            "repeated gun advances must hit the memo table");
}

#[test]
fn hashlife_matches_swar_for_every_step_count_up_to_257() {
    // 1..=257 covers every binary-decomposition shape through 2^8 + 1.
    // The dense side advances incrementally (one step per horizon);
    // the quadtree side restarts from t=0 each time.
    let size = 32;
    let start = random_square(size, 0.35, 0xD1CE);
    let mut dense = start.clone();
    let mut kern = LifeKernel::new(size, size);
    let mut hl = LifeHash::default();
    for steps in 1..=257usize {
        kern.rollout(&mut dense, 1);
        let mut quad = start.clone();
        hl.advance(&mut quad, size, steps);
        assert_eq!(dense, quad, "soup diverged at {steps} steps");
    }
}

#[test]
fn hashlife_soup_sweep_across_densities_and_sizes() {
    for &size in &[4usize, 8, 16, 128] {
        for &density in &[0.1f32, 0.5, 0.9] {
            let start = random_square(size, density,
                                      size as u64 ^ 0xF00D);
            let mut dense = start.clone();
            let mut kern = LifeKernel::new(size, size);
            kern.rollout(&mut dense, 70);
            let mut quad = start.clone();
            LifeHash::default().advance(&mut quad, size, 70);
            assert_eq!(dense, quad,
                       "{size}x{size} density {density} diverged");
        }
    }
}

#[test]
fn hashlife_interner_stays_bounded_and_exact_under_a_tiny_cap() {
    // A cap far below what a chaotic 64x64 soup wants forces the GC
    // (expand -> wipe -> rebuild) mid-advance; results must not change
    // and the arena must respect the bound at every observation point.
    let cap = 1 << 12;
    let size = 64;
    let start = random_square(size, 0.4, 0xCA9);
    let mut capped = LifeHash::new(cap);
    let mut dense = start.clone();
    let mut kern = LifeKernel::new(size, size);
    let mut total = 0usize;
    for round in 0..6 {
        let steps = 37 + round; // odd horizons: many GC-spanning chunks
        kern.rollout(&mut dense, steps);
        total += steps;
        // Recompute the whole horizon from t=0 through the capped
        // engine; GC rebuilds along the way must not change the answer.
        let mut quad = start.clone();
        capped.advance(&mut quad, size, total);
        assert_eq!(dense, quad,
                   "capped engine diverged after {total} total steps");
        assert!(capped.node_count() < cap,
                "arena exceeded its cap: {} >= {cap}",
                capped.node_count());
    }
}

// ------------------------------------------------------------------ ECA

#[test]
fn eca_hashlife_draws_the_rule_90_sierpinski_gasket() {
    // A single seed under rule 90 at power-of-two horizons: the
    // classic memoization best case — and the easiest place to catch
    // an off-by-one in the torus shift/unshift algebra.
    let w = 1024;
    let nw = bits::words_for(w);
    let rule = WolframRule::new(90);
    let mut start = vec![0u64; nw];
    start[(w / 2) / 64] |= 1 << ((w / 2) % 64);
    let mut hl = EcaHash::new(90, DEFAULT_NODE_CAP);
    for &steps in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        let mut dense = start.clone();
        eca::rollout_row(&rule, &mut dense, w, steps);
        let mut quad = start.clone();
        hl.advance(&mut quad, w, steps);
        assert_eq!(dense, quad, "rule 90 diverged at {steps} steps");
        // Power-of-two horizons of rule 90 from one seed are exactly
        // two cells: seed ± steps (XOR light cone).
        let alive: u32 = quad.iter().map(|v| v.count_ones()).sum();
        assert_eq!(alive, 2, "rule 90 gasket rows at 2^k have 2 cells");
    }
}

#[test]
fn eca_hashlife_matches_swar_on_soups() {
    let w = 128;
    let nw = bits::words_for(w);
    for &rule_no in &[30u8, 90, 110] {
        let rule = WolframRule::new(rule_no);
        let mut rng = Rng::new(rule_no as u64);
        let cells = rng.binary_vec(w, 0.5);
        let mut start = vec![0u64; nw];
        bits::pack_row(&cells, &mut start);
        let mut hl = EcaHash::new(rule_no, DEFAULT_NODE_CAP);
        let mut dense = start.clone();
        for steps in 1..=65usize {
            eca::rollout_row(&rule, &mut dense, w, 1);
            let mut quad = start.clone();
            hl.advance(&mut quad, w, steps);
            assert_eq!(dense, quad,
                       "rule {rule_no} diverged at {steps} steps");
        }
    }
}

#[test]
fn eca_hashlife_interner_stays_bounded_under_a_tiny_cap() {
    let cap = 1 << 10;
    let w = 256;
    let nw = bits::words_for(w);
    let rule = WolframRule::new(30); // chaotic: memoization cannot win
    let mut rng = Rng::new(3);
    let cells = rng.binary_vec(w, 0.5);
    let mut start = vec![0u64; nw];
    bits::pack_row(&cells, &mut start);
    let mut capped = EcaHash::new(30, cap);
    for &steps in &[5usize, 40, 129, 200] {
        let mut dense = start.clone();
        eca::rollout_row(&rule, &mut dense, w, steps);
        let mut quad = start.clone();
        capped.advance(&mut quad, w, steps);
        assert_eq!(dense, quad, "capped eca diverged at {steps} steps");
        assert!(capped.node_count() < cap,
                "arena exceeded its cap: {} >= {cap}",
                capped.node_count());
    }
}
