//! Properties of the native NCA training path (default features, no
//! artifacts): the BPTT backward pass is checked against central finite
//! differences per parameter group, and the full train step is
//! bit-identical for any worker-thread count.

use cax::backend::native::nca::NcaModel;
use cax::backend::native::nca_grad;
use cax::backend::native::train::{NativeTrainBackend, NcaTrainSpec};
use cax::backend::{ProgramBackend, Value};
use cax::tensor::Tensor;
use cax::util::rng::Rng;

/// A small cell built for finite differences. The ReLU makes the loss
/// only piecewise smooth, and with the default init the pre-activations
/// crowd zero densely enough that some kink always lands inside the
/// central-difference window, corrupting the comparison (empirically a
/// few-percent error, independent of eps). So the check model pushes
/// every pre-activation away from zero — large alternating biases
/// (half the units active, half inactive: both ReLU branches stay
/// covered), small `w1` so the data term cannot bridge the gap — and
/// boosts `w2` so the gradients sit well above the f32 noise floor.
/// None of the code paths under test change.
fn check_model(channels: usize, hidden: usize, seed: u64) -> NcaModel {
    let mut model = NcaModel::random(channels, hidden, &mut Rng::new(seed));
    for w in model.w1.iter_mut() {
        *w *= 0.15;
    }
    for (j, b) in model.b1.iter_mut().enumerate() {
        *b = if j % 2 == 0 { 0.8 } else { -0.8 };
    }
    for w in model.w2.iter_mut() {
        *w *= 2.0;
    }
    model
}

/// Mean-squared full-state loss of a `steps`-long rollout (f64 sum).
fn rollout_loss(model: &NcaModel, board: &[f32], target: &[f32], h: usize,
                w: usize, steps: usize, frozen: usize) -> f64 {
    let tape = nca_grad::rollout_tape(model, board, h, w, steps, frozen);
    let fin = tape.last().unwrap();
    fin.iter()
        .zip(target)
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / fin.len() as f64
}

/// Central finite differences over one parameter group, where `group`
/// selects the vector to perturb on a clone of the model.
#[allow(clippy::too_many_arguments)]
fn fd_group(model: &NcaModel, board: &[f32], target: &[f32], h: usize,
            w: usize, steps: usize, frozen: usize, len: usize,
            group: fn(&mut NcaModel) -> &mut Vec<f32>) -> Vec<f64> {
    let eps = 3e-3f32;
    (0..len)
        .map(|i| {
            let mut plus = model.clone();
            group(&mut plus)[i] += eps;
            let lp = rollout_loss(&plus, board, target, h, w, steps, frozen);
            let mut minus = model.clone();
            group(&mut minus)[i] -= eps;
            let lm =
                rollout_loss(&minus, board, target, h, w, steps, frozen);
            (lp - lm) / (2.0 * eps as f64)
        })
        .collect()
}

/// Group-norm relative error plus a per-parameter sanity bound.
fn assert_group_matches(name: &str, analytic: &[f32], fd: &[f64]) {
    assert_eq!(analytic.len(), fd.len());
    let mut diff2 = 0.0f64;
    let mut norm2 = 0.0f64;
    for (i, (&a, &f)) in analytic.iter().zip(fd).enumerate() {
        let a = a as f64;
        diff2 += (a - f) * (a - f);
        norm2 += f * f;
        let denom = a.abs().max(f.abs()).max(1e-3);
        let rel = (a - f).abs() / denom;
        assert!(rel < 1e-2,
                "{name}[{i}]: analytic {a:.6e} vs fd {f:.6e} (rel {rel:.2e})");
    }
    let rel = (diff2.sqrt()) / norm2.sqrt().max(1e-12);
    assert!(rel < 1e-3,
            "{name}: group-norm rel err {rel:.3e} (>= 1e-3), \
             ||fd|| = {:.3e}", norm2.sqrt());
    assert!(norm2 > 0.0, "{name}: degenerate all-zero fd gradient");
}

fn gradient_check(frozen: usize, seed: u64) {
    // Small board, 2-step unroll — the ISSUE 2 acceptance geometry.
    let (h, w, c, hid, steps) = (8, 8, 4, 8, 2);
    let model = check_model(c, hid, seed);
    let mut rng = Rng::new(seed ^ 0x51);
    let board = rng.vec_f32(h * w * c);
    let target = rng.vec_f32(h * w * c);

    let tape = nca_grad::rollout_tape(&model, &board, h, w, steps, frozen);
    let fin = tape.last().unwrap();
    let n = fin.len() as f32;
    let d_final: Vec<f32> = fin
        .iter()
        .zip(&target)
        .map(|(&a, &b)| 2.0 * (a - b) / n)
        .collect();
    let (grads, _) =
        nca_grad::backward(&model, &tape, h, w, frozen, &d_final);

    let fd_w1 = fd_group(&model, &board, &target, h, w, steps, frozen,
                         grads.w1.len(), |m| &mut m.w1);
    assert_group_matches("w1", &grads.w1, &fd_w1);
    let fd_b1 = fd_group(&model, &board, &target, h, w, steps, frozen,
                         grads.b1.len(), |m| &mut m.b1);
    assert_group_matches("b1", &grads.b1, &fd_b1);
    let fd_w2 = fd_group(&model, &board, &target, h, w, steps, frozen,
                         grads.w2.len(), |m| &mut m.w2);
    assert_group_matches("w2", &grads.w2, &fd_w2);
}

#[test]
fn bptt_gradients_match_finite_differences() {
    gradient_check(0, 9);
}

#[test]
fn bptt_gradients_match_finite_differences_with_frozen_channel() {
    // The MNIST cell: channel 0 pinned, still feeding perception.
    gradient_check(1, 23);
}

#[test]
fn input_gradient_matches_finite_differences_too() {
    // dL/d(state_0), the remaining backward output: perturb two board
    // cells directly.
    let (h, w, c, hid, steps) = (6, 6, 4, 6, 3);
    let model = check_model(c, hid, 4);
    let mut rng = Rng::new(40);
    let board = rng.vec_f32(h * w * c);
    let target = rng.vec_f32(h * w * c);
    let tape = nca_grad::rollout_tape(&model, &board, h, w, steps, 0);
    let fin = tape.last().unwrap();
    let n = fin.len() as f32;
    let d_final: Vec<f32> = fin
        .iter()
        .zip(&target)
        .map(|(&a, &b)| 2.0 * (a - b) / n)
        .collect();
    let (_, d0) = nca_grad::backward(&model, &tape, h, w, 0, &d_final);

    let eps = 3e-3f32;
    for idx in [0usize, (h * w * c) / 2 + 1] {
        let mut plus = board.clone();
        plus[idx] += eps;
        let lp = rollout_loss(&model, &plus, &target, h, w, steps, 0);
        let mut minus = board.clone();
        minus[idx] -= eps;
        let lm = rollout_loss(&model, &minus, &target, h, w, steps, 0);
        let fd = (lp - lm) / (2.0 * eps as f64);
        let a = d0[idx] as f64;
        let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1e-3);
        assert!(rel < 1e-2,
                "d_state0[{idx}]: analytic {a:.6e} vs fd {fd:.6e}");
    }
}

fn tiny_backend(threads: usize) -> NativeTrainBackend {
    let growing = NcaTrainSpec {
        height: 8,
        width: 8,
        channels: 6,
        hidden: 12,
        batch: 4,
        rollout_min: 3,
        rollout_max: 5,
        ..NcaTrainSpec::growing()
    };
    let mnist = NcaTrainSpec {
        height: 10,
        width: 10,
        channels: 12,
        hidden: 12,
        batch: 3,
        rollout_min: 3,
        rollout_max: 4,
        ..NcaTrainSpec::mnist()
    };
    NativeTrainBackend::with_specs(growing, mnist, threads)
}

fn growing_inputs(backend: &NativeTrainBackend) -> Vec<Value> {
    let spec = backend.growing_spec().clone();
    let p = spec.param_count();
    let params = backend.load_params("growing_params").unwrap();
    let mut rng = Rng::new(77);
    let states = Tensor::new(
        vec![spec.batch, spec.height, spec.width, spec.channels],
        rng.vec_f32(spec.batch * spec.height * spec.width * spec.channels),
    )
    .unwrap();
    let target = Tensor::new(
        vec![spec.height, spec.width, 4],
        rng.vec_f32(spec.height * spec.width * 4),
    )
    .unwrap();
    vec![
        Value::F32(params),
        Value::F32(Tensor::zeros(&[p])),
        Value::F32(Tensor::zeros(&[p])),
        Value::I32(0),
        Value::F32(states),
        Value::F32(target),
        Value::U32(5),
    ]
}

#[test]
fn train_step_is_bit_identical_across_thread_counts() {
    let single = tiny_backend(1);
    let many = tiny_backend(8);
    let inputs = growing_inputs(&single);
    let a = single.execute("growing_train_step", &inputs).unwrap();
    let b = many.execute("growing_train_step", &inputs).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(x.bit_eq(y), "output {i} differs between 1 and 8 workers");
    }
    // And the step is a pure function of its inputs.
    let c = single.execute("growing_train_step", &inputs).unwrap();
    for (x, y) in a.iter().zip(&c) {
        assert!(x.bit_eq(y));
    }
}

#[test]
fn mnist_train_step_is_bit_identical_across_thread_counts() {
    let single = tiny_backend(1);
    let many = tiny_backend(8);
    let spec = single.mnist_spec().clone();
    let p = spec.param_count();
    let params = single.load_params("mnist_params").unwrap();
    let digits = cax::datasets::mnist::dataset(
        spec.batch,
        &cax::datasets::mnist::MnistConfig::for_grid(spec.height,
                                                     spec.width),
        3,
    );
    let refs: Vec<&cax::datasets::mnist::Digit> = digits.iter().collect();
    let images = cax::datasets::mnist::batch_images(&refs);
    let labels = cax::datasets::mnist::batch_labels(&refs);
    let inputs = vec![
        Value::F32(params),
        Value::F32(Tensor::zeros(&[p])),
        Value::F32(Tensor::zeros(&[p])),
        Value::I32(0),
        Value::F32(images),
        Value::F32(labels),
        Value::U32(11),
    ];
    let a = single.execute("mnist_train_step", &inputs).unwrap();
    let b = many.execute("mnist_train_step", &inputs).unwrap();
    assert_eq!(a.len(), 4);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(x.bit_eq(y), "output {i} differs between 1 and 8 workers");
    }
    let loss = a[3].data()[0];
    assert!(loss.is_finite() && loss > 0.0, "mnist loss {loss}");
}
