//! Differential battery for activity-tracked sparse stepping: every
//! sparse kernel must be **bit-identical** to its dense counterpart —
//! random soups, gliders crossing word/tile boundaries and wrap edges,
//! fully-quiescent and fully-active boards, ragged widths
//! (`w % 64 != 0`, `w % 32 != 0`), and 1-vs-8-thread launches.
//!
//! The kernel-level tests drive the sparse steppers directly, so they
//! hold on both CI legs (`CAX_SPARSE` default and `off`) — the sparse
//! code paths are exercised regardless of what the dispatcher would
//! pick. The backend-level tests force both sides of the dispatch
//! in-process via [`activity::set_override`].

use std::sync::Mutex;

use cax::automata::lenia::LeniaParams;
use cax::automata::WolframRule;
use cax::backend::native::activity::{self, ActivityMap};
use cax::backend::native::lenia::LeniaKernel;
use cax::backend::native::life::{self, LifeKernel};
use cax::backend::native::nca::NcaModel;
use cax::backend::native::{bits, eca};
use cax::backend::{Backend, CaProgram, NativeBackend, Resident};
use cax::serve::{CheckpointStore, ProgramSpec, SessionRegistry};
use cax::tensor::Tensor;
use cax::util::rng::Rng;

/// The in-process dispatch override is global; tests that flip it
/// serialize here so the harness's parallel threads cannot interleave.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

fn assert_bits_eq(a: &[f32], b: &[f32], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{label}: cell {i} diverged: {x} ({:#010x}) vs {y} ({:#010x})",
            x.to_bits(),
            y.to_bits()
        );
    }
}

// ------------------------------------------------------------------ ECA

/// Dense vs sparse ECA, asserting after every step so a divergence is
/// caught at its first occurrence. Returns the summed tile counts.
fn eca_differential(rule_no: u8, w: usize, steps: usize, seed: u64,
                    density: f32) -> (u64, u64) {
    let rule = WolframRule::new(rule_no);
    let nw = bits::words_for(w);
    let mut rng = Rng::new(seed);
    let cells = rng.binary_vec(w, density);
    let mut dense = vec![0u64; nw];
    bits::pack_row(&cells, &mut dense);
    let mut sparse = dense.clone();
    let mut map = ActivityMap::new(0, 1, nw);
    let (mut rec, mut skp) = (0, 0);
    for step in 0..steps {
        eca::rollout_row(&rule, &mut dense, w, 1);
        let (r, s) = eca::rollout_row_sparse(&rule, &mut sparse, w, 1,
                                             &mut map);
        rec += r;
        skp += s;
        assert_eq!(dense, sparse,
                   "rule {rule_no} w={w} diverged at step {step}");
    }
    assert_eq!(rec + skp, (steps * nw) as u64,
               "tile accounting must cover every word every step");
    (rec, skp)
}

#[test]
fn eca_sparse_matches_dense_across_rules_and_widths() {
    for (i, &rule) in [30u8, 90, 110, 184].iter().enumerate() {
        for &w in &[63usize, 64, 65, 130, 256, 1024] {
            eca_differential(rule, w, 48, 7_000 + i as u64, 0.5);
        }
    }
}

#[test]
fn eca_sparse_skips_quiet_regions_of_a_single_seed() {
    // One live cell in 4096: rule 30's light cone grows ~1 cell/step,
    // so most of the row's 64 words stay quiescent and must be skipped
    // (the first step is the all-dirty fresh step).
    let w = 4096;
    let nw = bits::words_for(w);
    let rule = WolframRule::new(30);
    let mut dense = vec![0u64; nw];
    dense[nw / 2] = 1;
    let mut sparse = dense.clone();
    let mut map = ActivityMap::new(0, 1, nw);
    let (mut rec, mut skp) = (0, 0);
    for _ in 0..32 {
        eca::rollout_row(&rule, &mut dense, w, 1);
        let (r, s) =
            eca::rollout_row_sparse(&rule, &mut sparse, w, 1, &mut map);
        rec += r;
        skp += s;
    }
    assert_eq!(dense, sparse);
    assert!(skp > rec,
            "a single seed must skip most words (rec={rec} skp={skp})");
}

#[test]
fn eca_sparse_handles_quiescent_and_saturated_rows() {
    // All-dead and all-alive rows are fixed points or near-fixed under
    // many rules; both extremes of the activity map must stay exact.
    for &(rule, density) in &[(0u8, 0.0f32), (30, 0.0), (30, 1.0),
                              (204, 0.5), (255, 1.0)] {
        let (rec, _skp) = eca_differential(rule, 130, 24, 11, density);
        // Rule 204 is the identity: after the fresh first step nothing
        // changes, so nothing may be recomputed again.
        if rule == 204 {
            assert_eq!(rec, bits::words_for(130) as u64,
                       "identity rule recomputes only the fresh step");
        }
    }
}

// ----------------------------------------------------------------- Life

fn life_differential(h: usize, w: usize, steps: usize, grid: Vec<u64>)
    -> (u64, u64) {
    let wpr = bits::words_for(w);
    assert_eq!(grid.len(), h * wpr);
    let mut dense = grid.clone();
    let mut sparse = grid;
    let mut dk = LifeKernel::new(h, w);
    let mut sk = LifeKernel::new(h, w);
    let mut map = ActivityMap::new(0, h, wpr);
    let (mut rec, mut skp) = (0, 0);
    for step in 0..steps {
        dk.rollout(&mut dense, 1);
        let (r, s) = sk.rollout_sparse(&mut sparse, 1, &mut map);
        rec += r;
        skp += s;
        assert_eq!(dense, sparse, "{h}x{w} diverged at step {step}");
    }
    assert_eq!(rec + skp, (steps * h * wpr) as u64);
    (rec, skp)
}

fn random_grid(h: usize, w: usize, density: f32, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let cells = rng.binary_vec(h * w, density);
    let mut grid = vec![0u64; h * bits::words_for(w)];
    life::pack_board(&cells, h, w, &mut grid);
    grid
}

#[test]
fn life_sparse_matches_dense_on_random_soups() {
    for (i, &(h, w)) in [(8usize, 8usize), (5, 63), (7, 64), (6, 65),
                         (9, 100), (3, 130), (16, 128), (32, 192)]
        .iter()
        .enumerate()
    {
        for &density in &[0.1f32, 0.4] {
            let grid = random_grid(h, w, density, 5_000 + i as u64);
            life_differential(h, w, 24, grid);
        }
    }
}

#[test]
fn life_sparse_handles_quiescent_and_saturated_boards() {
    // Empty board: after the fresh step, every tile must be skipped.
    let (h, w) = (24, 130);
    let wpr = bits::words_for(w);
    let (rec, skp) = life_differential(h, w, 16, vec![0u64; h * wpr]);
    assert_eq!(rec, (h * wpr) as u64,
               "an empty board recomputes only the fresh first step");
    assert_eq!(skp, (15 * h * wpr) as u64);
    // Saturated board: everything dies in one step, then quiesces.
    life_differential(h, w, 8, random_grid(h, w, 1.0, 0));
}

#[test]
fn life_glider_crosses_word_boundaries_and_wrap_edges() {
    // A glider on a 48x192 torus: it crosses the x=64 and x=128 word
    // boundaries and wraps both edges over 800 steps (diagonal period
    // 4, one cell per period; 800 steps move it 200 cells).
    let (h, w) = (48usize, 192usize);
    let wpr = bits::words_for(w);
    let mut grid = vec![0u64; h * wpr];
    // Glider heading south-east, head near the first word boundary.
    for &(y, x) in &[(10usize, 60usize), (11, 61), (12, 59), (12, 60),
                     (12, 61)] {
        grid[y * wpr + x / 64] |= 1 << (x % 64);
    }
    let (rec, skp) = life_differential(h, w, 800, grid);
    // Word-granular tiles with a ±1-word halo keep ~5 of 48 rows hot,
    // so the skip ratio is bounded by geometry, not by luck.
    assert!(skp > 5 * rec,
            "a lone glider must skip the overwhelming majority of \
             tiles (rec={rec} skp={skp})");
}

// ------------------------------------------------------- f32 substrates

#[test]
fn lenia_sparse_matches_dense_from_patch_and_soup() {
    let params = LeniaParams { radius: 5, ..Default::default() };
    let kernel = LeniaKernel::new(params);
    // Ragged (non-multiple-of-32) boards; the patch case starts
    // localized in a corner so its influence crosses the wrap edges.
    for &(h, w) in &[(33usize, 47usize), (48, 64), (40, 33)] {
        let mut rng = Rng::new((h * w) as u64);
        for patch_only in [true, false] {
            let mut board = if patch_only {
                let mut b = vec![0.0f32; h * w];
                for y in 0..6 {
                    for v in &mut b[y * w..y * w + 6] {
                        *v = rng.next_f32();
                    }
                }
                b
            } else {
                rng.vec_f32(h * w)
            };
            let mut sparse = board.clone();
            let mut scratch = vec![0.0f32; h * w];
            let mut smap_scratch = vec![0.0f32; h * w];
            let (tr, tc) = LeniaKernel::tile_dims(h, w);
            let mut map = ActivityMap::new(0, tr, tc);
            for step in 0..10 {
                kernel.rollout(&mut board, &mut scratch, h, w, 1);
                kernel.rollout_sparse(&mut sparse, &mut smap_scratch, h, w,
                                      1, &mut map);
                assert_bits_eq(
                    &board,
                    &sparse,
                    &format!("lenia {h}x{w} patch={patch_only} \
                              step {step}"),
                );
            }
        }
    }
}

#[test]
fn lenia_sparse_skips_tiles_on_a_quiescent_board() {
    let params = LeniaParams { radius: 5, ..Default::default() };
    let kernel = LeniaKernel::new(params);
    let (h, w) = (64usize, 96usize);
    let mut board = vec![0.0f32; h * w];
    let mut scratch = vec![0.0f32; h * w];
    let (tr, tc) = LeniaKernel::tile_dims(h, w);
    let mut map = ActivityMap::new(0, tr, tc);
    // Fresh step is dense; the all-zero board is a Lenia fixed point
    // here only if growth(0) <= 0 — with paper-default mu it is.
    let (r0, _) = kernel.rollout_sparse(&mut board, &mut scratch, h, w, 1,
                                        &mut map);
    assert_eq!(r0, (tr * tc) as u64);
    let (r1, s1) = kernel.rollout_sparse(&mut board, &mut scratch, h, w, 4,
                                         &mut map);
    assert_eq!(r1, 0, "a fixed-point board must skip every tile");
    assert_eq!(s1, (4 * tr * tc) as u64);
    assert!(board.iter().all(|&v| v == 0.0));
}

fn random_nca(channels: usize, hidden: usize, seed: u64) -> NcaModel {
    let mut rng = Rng::new(seed);
    let n = NcaModel::param_count(channels, hidden);
    // Small weights keep the residual update stable over many steps.
    let flat: Vec<f32> =
        rng.vec_f32(n).into_iter().map(|v| 0.2 * (v - 0.5)).collect();
    NcaModel::from_flat(channels, hidden, 0.5, &flat)
}

#[test]
fn nca_sparse_matches_dense_on_soup_and_seed() {
    let model = random_nca(4, 8, 42);
    for &(h, w) in &[(20usize, 36usize), (33, 32)] {
        let c = 4;
        let mut rng = Rng::new((h + w) as u64);
        for seed_only in [true, false] {
            let mut board = if seed_only {
                let mut b = vec![0.0f32; h * w * c];
                let seed = ((h / 2) * w + w / 2) * c;
                b[seed..seed + c].fill(1.0);
                b
            } else {
                rng.vec_f32(h * w * c)
            };
            let mut sparse = board.clone();
            let mut scratch = vec![0.0f32; h * w * c];
            let mut sscratch = vec![0.0f32; h * w * c];
            let (tr, tc) = NcaModel::tile_dims(h, w);
            let mut map = ActivityMap::new(0, tr, tc);
            for step in 0..8 {
                model.rollout(&mut board, &mut scratch, h, w, 1);
                model.rollout_sparse(&mut sparse, &mut sscratch, h, w, 1,
                                     &mut map);
                assert_bits_eq(
                    &board,
                    &sparse,
                    &format!("nca {h}x{w} seed={seed_only} step {step}"),
                );
            }
        }
    }
}

// ------------------------------------------------- backend-level dispatch

/// Rollout under a forced dispatch setting, restoring the environment
/// default afterwards. Callers hold [`OVERRIDE_LOCK`].
fn rollout_forced(backend: &NativeBackend, prog: &CaProgram,
                  state: &Tensor, steps: usize, sparse: bool) -> Tensor {
    activity::set_override(Some(sparse));
    let out = backend.rollout(prog, state, steps).unwrap();
    activity::set_override(None);
    out
}

#[test]
fn backend_rollouts_are_bit_identical_sparse_vs_dense() {
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    let backend = NativeBackend::with_threads(2);
    let mut rng = Rng::new(0xACE);
    let cases: Vec<(CaProgram, Vec<usize>)> = vec![
        (CaProgram::Eca { rule: WolframRule::new(110) }, vec![3, 200]),
        (CaProgram::Life, vec![2, 36, 70]),
        (
            CaProgram::Lenia {
                params: LeniaParams { radius: 5, ..Default::default() },
            },
            vec![2, 40, 40],
        ),
    ];
    for (prog, shape) in cases {
        let numel: usize = shape.iter().product();
        let state =
            Tensor::new(shape, rng.binary_vec(numel, 0.4)).unwrap();
        let dense = rollout_forced(&backend, &prog, &state, 23, false);
        let sparse = rollout_forced(&backend, &prog, &state, 23, true);
        assert!(dense.bit_eq(&sparse),
                "{} rollout diverged sparse vs dense", prog.name());
    }
    // NCA through the backend too (random small model).
    let model = random_nca(4, 8, 9);
    let prog = CaProgram::Nca(model);
    let state = Tensor::new(vec![1, 16, 16, 4],
                            rng.vec_f32(16 * 16 * 4)).unwrap();
    let dense = rollout_forced(&backend, &prog, &state, 6, false);
    let sparse = rollout_forced(&backend, &prog, &state, 6, true);
    assert!(dense.bit_eq(&sparse), "nca rollout diverged");
}

#[test]
fn step_resident_sparse_is_deterministic_across_thread_counts() {
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    let prog = CaProgram::Life;
    let mut rng = Rng::new(0xBEEF);
    let boards: Vec<Tensor> = (0..5)
        .map(|_| {
            Tensor::new(vec![40, 70], rng.binary_vec(40 * 70, 0.35))
                .unwrap()
        })
        .collect();

    // Dense solo reference (forced off), single-threaded.
    activity::set_override(Some(false));
    let solo = NativeBackend::with_threads(1);
    let expect: Vec<Tensor> = boards
        .iter()
        .map(|b| {
            let batched = Tensor::stack(std::slice::from_ref(b)).unwrap();
            solo.rollout(&prog, &batched, 9).unwrap().index_axis0(0)
        })
        .collect();

    // Sparse resident stepping, 1 and 8 threads, ticked 3+3+3 so the
    // activity maps carry dirty state across launches.
    activity::set_override(Some(true));
    for threads in [1usize, 8] {
        let backend = NativeBackend::with_threads(threads);
        let mut residents: Vec<_> = boards
            .iter()
            .map(|b| backend.admit(&prog, b).unwrap())
            .collect();
        for _ in 0..3 {
            let mut batch: Vec<&mut _> = residents.iter_mut().collect();
            backend.step_resident(&prog, &mut batch, 3).unwrap();
        }
        for (r, want) in residents.iter().zip(&expect) {
            let got = backend.read_resident(&prog, r).unwrap();
            assert!(got.bit_eq(want),
                    "sparse resident stepping with {threads} thread(s) \
                     diverged from dense solo");
        }
    }
    activity::set_override(None);
}

#[test]
fn sparse_launches_report_skipped_tiles() {
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    let backend = NativeBackend::with_threads(1);
    let prog = CaProgram::Life;
    // A quiescent board: after the fresh first step every tile skips.
    let board = Tensor::zeros(&[64, 128]);
    activity::set_override(Some(true));
    let mut resident = backend.admit(&prog, &board).unwrap();
    let before = activity::tiles_skipped_total();
    backend
        .step_resident(&prog, &mut [&mut resident], 8)
        .unwrap();
    let after = activity::tiles_skipped_total();
    activity::set_override(None);
    assert!(after > before,
            "a quiescent resident must report skipped tiles \
             ({before} -> {after})");
}

/// A session's persistent activity map must die with the state it
/// described: both `reset` (the board rewinds, the map must not claim
/// anything is clean) and checkpoint rehydration (maps are never
/// serialized) hand back `activity: None`, and the next sparse steps
/// stay bit-identical to a dense solo rollout. Regression for a stale
/// map surviving reset and silently skipping tiles the rewound board
/// had re-dirtied.
#[test]
fn registry_reset_and_rehydration_invalidate_activity_maps() {
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    let backend = NativeBackend::with_threads(1);
    let spec = ProgramSpec::Life { height: 40, width: 70 };
    let seed = 0xD00D;

    let assert_fresh_map = |reg: &SessionRegistry, id: u64, label: &str| {
        match &reg.get(id).unwrap().resident {
            Resident::Bits { activity, .. }
            | Resident::Board { activity, .. } => {
                assert!(activity.is_none(),
                        "{label}: stale activity map survived");
            }
            Resident::Host(_) => panic!("{label}: unexpected host state"),
        }
    };
    // Dense solo reference rollouts from the session's initial board.
    let dense_after = |steps: usize| {
        activity::set_override(Some(false));
        let initial = spec.initial_board(seed).unwrap();
        let batched = Tensor::stack(std::slice::from_ref(&initial)).unwrap();
        let out = backend
            .rollout(&CaProgram::Life, &batched, steps)
            .unwrap()
            .index_axis0(0);
        activity::set_override(Some(true));
        out
    };
    let step_sparse = |reg: &mut SessionRegistry, id: u64, steps: usize| {
        let mut s = reg.take_for_step(id).unwrap();
        backend
            .step_resident(&CaProgram::Life, &mut [&mut s.resident], steps)
            .unwrap();
        s.steps_done += steps as u64;
        reg.restore(s);
    };

    activity::set_override(Some(true));
    let dir = std::env::temp_dir()
        .join(format!("cax-sparse-reset-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let obs = cax::obs::Registry::new();
    let mut reg = SessionRegistry::new(3, 4);
    reg.set_store(CheckpointStore::open(&dir).unwrap(), obs.counter("ev"),
                  obs.counter("re"));
    let id = reg.create(&backend, spec.clone(), Some(seed)).unwrap();

    // Accumulate a dirty-tile map, then park the session and bring it
    // back: the rehydrated resident starts with no map, and further
    // sparse steps match the uninterrupted dense trajectory.
    step_sparse(&mut reg, id, 6);
    reg.evict(id).unwrap();
    assert!(!reg.in_ram(id));
    assert!(reg.ensure_resident(id).unwrap());
    assert_fresh_map(&reg, id, "rehydrate");
    step_sparse(&mut reg, id, 5);
    assert!(reg
        .read_board(&backend, id)
        .unwrap()
        .bit_eq(&dense_after(11)),
            "sparse stepping across an evict/rehydrate diverged");

    // Reset rewinds the board; the map from the pre-reset trajectory
    // must go with it, and post-reset sparse steps replay exactly.
    step_sparse(&mut reg, id, 4);
    reg.reset(&backend, id).unwrap();
    assert_fresh_map(&reg, id, "reset");
    step_sparse(&mut reg, id, 9);
    assert!(reg
        .read_board(&backend, id)
        .unwrap()
        .bit_eq(&dense_after(9)),
            "sparse stepping after reset diverged");

    activity::set_override(None);
    let _ = std::fs::remove_dir_all(&dir);
}
