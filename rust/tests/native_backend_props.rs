//! Property tests pitting the bit-packed/tiled `NativeBackend` against
//! the naive per-cell simulators in `automata/` — the correctness
//! contract of the native execution path. Runs on default features: no
//! artifacts, no XLA, no network.

use cax::automata::lenia::LeniaParams;
use cax::automata::{EcaSim, LeniaSim, LifeSim, WolframRule};
use cax::backend::{Backend, CaProgram, NativeBackend};
use cax::coordinator::{Path, Simulator};
use cax::prop_assert;
use cax::tensor::Tensor;
use cax::util::check::{check, Gen};
use cax::util::rng::Rng;

// ------------------------------------------------------------------ ECA

#[test]
fn prop_eca_bitpacked_matches_naive() {
    // Random rules and boards over widths straddling the u64 word size
    // (including widths not divisible by 64) must agree bit-exactly.
    let backend = NativeBackend::new();
    check(0xECA0, 60, |g: &mut Gen| {
        let rule = WolframRule::new(g.usize_in(0, 256) as u8);
        let widths = [5, 31, 63, 64, 65, 100, 127, 128, 129, 200];
        let w = widths[g.usize_in(0, widths.len())];
        let b = g.usize_in(1, 4);
        let steps = g.usize_in(1, 17);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let state = Tensor::new(vec![b, w], rng.binary_vec(b * w, 0.5))
            .unwrap();

        let mut naive = EcaSim::from_tensor(rule, &state);
        naive.run(steps);
        let native = backend
            .rollout(&CaProgram::Eca { rule }, &state, steps)
            .map_err(|e| format!("rollout failed: {e}"))?;
        prop_assert!(native.bit_eq(&naive.to_tensor()),
                     "rule {} w={w} b={b} steps={steps} diverged",
                     rule.number);
        Ok(())
    })
    .unwrap();
}

#[test]
fn rule_90_sierpinski_spacetime() {
    // Rule 90 is left XOR right; from a single centre seed the
    // space-time diagram is the Sierpinski triangle. Check the native
    // kernel row-by-row against (a) the closed-form XOR recurrence and
    // (b) the naive oracle, over a width not divisible by 64.
    let backend = NativeBackend::new();
    let rule = WolframRule::new(90);
    let w = 129;
    let steps = 48;
    let mut state = Tensor::zeros(&[1, w]);
    state.set(&[0, w / 2], 1.0);
    let mut naive = EcaSim::from_tensor(rule, &state);

    let mut current = state.clone();
    for t in 0..steps {
        let prev = current.clone();
        current = backend
            .rollout(&CaProgram::Eca { rule }, &current, 1)
            .unwrap();
        naive.step();
        assert!(current.bit_eq(&naive.to_tensor()),
                "native != naive at step {t}");
        for x in 0..w {
            let l = prev.at(&[0, (x + w - 1) % w]) as u8;
            let r = prev.at(&[0, (x + 1) % w]) as u8;
            assert_eq!(current.at(&[0, x]) as u8, l ^ r,
                       "rule-90 recurrence broke at step {t}, cell {x}");
        }
    }
    // The triangle keeps growing inside the light cone: row `steps`
    // of a Sierpinski triangle from a point seed is non-empty.
    assert!(current.data().iter().sum::<f32>() > 0.0);
}

// ----------------------------------------------------------------- Life

#[test]
fn prop_life_bitpacked_matches_naive() {
    let backend = NativeBackend::new();
    check(0x11FE, 40, |g: &mut Gen| {
        let heights = [3, 5, 8, 16];
        let widths = [3, 17, 63, 64, 65, 96, 130];
        let h = heights[g.usize_in(0, heights.len())];
        let w = widths[g.usize_in(0, widths.len())];
        let b = g.usize_in(1, 4);
        let steps = g.usize_in(1, 9);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let mut naive = LifeSim::random(b, h, w, 0.35, &mut rng);
        let state = naive.to_tensor();

        naive.run(steps);
        let native = backend
            .rollout(&CaProgram::Life, &state, steps)
            .map_err(|e| format!("rollout failed: {e}"))?;
        prop_assert!(native.bit_eq(&naive.to_tensor()),
                     "{h}x{w} b={b} steps={steps} diverged");
        Ok(())
    })
    .unwrap();
}

#[test]
fn glider_translates_by_one_cell_every_four_steps() {
    let backend = NativeBackend::new();
    let sim = LifeSim::gliders(2, 16, 16);
    let state = sim.to_tensor();
    let mut current = state.clone();
    for period in 1..=3 {
        current = backend.rollout(&CaProgram::Life, &current, 4).unwrap();
        for i in 0..2 {
            for y in 0..16 {
                for x in 0..16 {
                    assert_eq!(
                        current.at(&[i, (y + period) % 16,
                                     (x + period) % 16]),
                        state.at(&[i, y, x]),
                        "glider broke: batch {i} period {period} ({y},{x})"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------- Lenia

#[test]
fn lenia_tiled_kernel_within_tolerance_of_naive() {
    // The tiled kernel preserves the oracle's accumulation order, so
    // the 1e-5 contract holds with margin (it is in fact bit-exact).
    let backend = NativeBackend::new();
    let params = LeniaParams { radius: 5, ..Default::default() };
    let size = 48;
    let steps = 8;
    let mut rng = Rng::new(0x1E21A);
    let mut boards = Vec::new();
    let mut naive_out = Vec::new();
    for _ in 0..2 {
        let mut sim =
            LeniaSim::random_patch(params, size, 24, &mut rng);
        boards.push(sim.state().clone());
        sim.run(steps);
        naive_out.push(sim.state().clone());
    }
    let state = Tensor::stack(&boards).unwrap();
    let native = backend
        .rollout(&CaProgram::Lenia { params }, &state, steps)
        .unwrap();
    let expect = Tensor::stack(&naive_out).unwrap();
    let diff = native.max_abs_diff(&expect).unwrap();
    assert!(diff <= 1e-5, "Lenia native drifted {diff} from naive");
}

// -------------------------------------------------- simulator dispatch

#[test]
fn simulator_native_path_agrees_with_naive_path_end_to_end() {
    // The Table-1 classic scenarios through the coordinator's dispatch
    // surface: Path::Native vs Path::Naive on the same states.
    let sim = Simulator::native_only();
    let mut rng = Rng::new(0xD15);

    let eca_state = Simulator::random_binary_state(&[4, 200], &mut rng);
    let rule = WolframRule::new(110);
    let a = sim.run_eca(Path::Naive, &eca_state, rule, 24).unwrap();
    let b = sim.run_eca(Path::Native, &eca_state, rule, 24).unwrap();
    assert!(a.bit_eq(&b), "eca paths disagree");

    let life_state = Simulator::random_binary_state(&[3, 24, 40], &mut rng);
    let a = sim.run_life(Path::Naive, &life_state, 12).unwrap();
    let b = sim.run_life(Path::Native, &life_state, 12).unwrap();
    assert!(a.bit_eq(&b), "life paths disagree");

    let lenia_state =
        Simulator::random_binary_state(&[2, 40, 40], &mut rng);
    let a = sim.run_lenia(Path::Naive, &lenia_state, 4).unwrap();
    let b = sim.run_lenia(Path::Native, &lenia_state, 4).unwrap();
    let diff = a.max_abs_diff(&b).unwrap();
    assert!(diff <= 1e-5, "lenia paths drifted {diff}");
}

#[test]
fn prop_thread_count_never_changes_results() {
    check(0x7412, 20, |g: &mut Gen| {
        let w = g.usize_in(10, 150);
        let b = g.usize_in(1, 6);
        let steps = g.usize_in(1, 8);
        let rule = WolframRule::new(g.usize_in(0, 256) as u8);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let state = Tensor::new(vec![b, w], rng.binary_vec(b * w, 0.5))
            .unwrap();
        let prog = CaProgram::Eca { rule };
        let seq = NativeBackend::with_threads(1)
            .rollout(&prog, &state, steps)
            .map_err(|e| format!("{e}"))?;
        let par = NativeBackend::with_threads(7)
            .rollout(&prog, &state, steps)
            .map_err(|e| format!("{e}"))?;
        prop_assert!(seq.bit_eq(&par), "thread count changed the result");
        Ok(())
    })
    .unwrap();
}
