//! Binary PPM (P6) image writer — zero-dependency raster output.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// RGB8 raster image.
#[derive(Clone, Debug)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    /// Row-major RGB triples.
    pub pixels: Vec<[u8; 3]>,
}

impl Image {
    pub fn new(width: usize, height: usize) -> Image {
        Image { width, height, pixels: vec![[0, 0, 0]; width * height] }
    }

    pub fn set(&mut self, y: usize, x: usize, rgb: [u8; 3]) {
        debug_assert!(y < self.height && x < self.width);
        self.pixels[y * self.width + x] = rgb;
    }

    pub fn get(&self, y: usize, x: usize) -> [u8; 3] {
        self.pixels[y * self.width + x]
    }

    /// Nearest-neighbour upscale (crisp cell boundaries for CA renders).
    pub fn upscale(&self, factor: usize) -> Image {
        assert!(factor >= 1);
        let mut out = Image::new(self.width * factor, self.height * factor);
        for y in 0..out.height {
            for x in 0..out.width {
                out.set(y, x, self.get(y / factor, x / factor));
            }
        }
        out
    }

    /// Serialize as binary P6 bytes (HTTP snapshot responses and
    /// [`write_ppm`](Self::write_ppm) share this encoder).
    pub fn ppm_bytes(&self) -> Result<Vec<u8>> {
        if self.width == 0 || self.height == 0 {
            bail!("ppm_bytes: empty image");
        }
        let mut buf = Vec::with_capacity(32 + self.pixels.len() * 3);
        write!(buf, "P6\n{} {}\n255\n", self.width, self.height)?;
        for px in &self.pixels {
            buf.extend_from_slice(px);
        }
        Ok(buf)
    }

    /// Write binary P6.
    pub fn write_ppm(&self, path: &Path) -> Result<()> {
        let buf = self.ppm_bytes()?;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, buf)
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Horizontal strip of images separated by 1px dividers (Fig. 7 layout).
    pub fn hstrip(images: &[Image], divider: [u8; 3]) -> Image {
        assert!(!images.is_empty());
        let h = images.iter().map(|i| i.height).max().unwrap();
        let w: usize =
            images.iter().map(|i| i.width).sum::<usize>() + images.len() - 1;
        let mut out = Image::new(w, h);
        for px in &mut out.pixels {
            *px = divider;
        }
        let mut x0 = 0;
        for img in images {
            for y in 0..img.height {
                for x in 0..img.width {
                    out.set(y, x0 + x, img.get(y, x));
                }
            }
            x0 += img.width + 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppm_header_and_size() {
        let dir = std::env::temp_dir().join("cax_ppm_test");
        let path = dir.join("img.ppm");
        let mut img = Image::new(3, 2);
        img.set(0, 0, [255, 0, 0]);
        img.set(1, 2, [0, 0, 255]);
        img.write_ppm(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P6\n3 2\n255\n"));
        assert_eq!(bytes.len(), "P6\n3 2\n255\n".len() + 18);
        assert_eq!(&bytes[11..14], &[255, 0, 0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn upscale_replicates_pixels() {
        let mut img = Image::new(2, 1);
        img.set(0, 0, [1, 2, 3]);
        img.set(0, 1, [4, 5, 6]);
        let big = img.upscale(3);
        assert_eq!(big.width, 6);
        assert_eq!(big.height, 3);
        assert_eq!(big.get(2, 2), [1, 2, 3]);
        assert_eq!(big.get(0, 3), [4, 5, 6]);
    }

    #[test]
    fn hstrip_concatenates_with_divider() {
        let a = Image::new(2, 2);
        let mut b = Image::new(3, 1);
        b.set(0, 0, [9, 9, 9]);
        let strip = Image::hstrip(&[a, b], [7, 7, 7]);
        assert_eq!(strip.width, 2 + 1 + 3);
        assert_eq!(strip.height, 2);
        assert_eq!(strip.get(0, 2), [7, 7, 7]); // divider column
        assert_eq!(strip.get(0, 3), [9, 9, 9]);
        assert_eq!(strip.get(1, 3), [7, 7, 7]); // below the short image
    }

    #[test]
    fn empty_image_rejected() {
        let img = Image::new(0, 0);
        assert!(img.write_ppm(Path::new("/tmp/should_not_exist.ppm")).is_err());
        assert!(img.ppm_bytes().is_err());
    }

    #[test]
    fn ppm_bytes_match_file_output() {
        let mut img = Image::new(2, 2);
        img.set(1, 1, [9, 8, 7]);
        let bytes = img.ppm_bytes().unwrap();
        assert!(bytes.starts_with(b"P6\n2 2\n255\n"));
        assert_eq!(bytes.len(), "P6\n2 2\n255\n".len() + 12);
        assert_eq!(&bytes[bytes.len() - 3..], &[9, 8, 7]);
    }
}
