//! Visualization stack: PPM images, palettes, space-time diagrams (Fig. 8)
//! and RGBA state rendering (Fig. 4/5/7).

pub mod colormap;
pub mod ppm;
pub mod spacetime;
