//! Space-time diagram and state renderers for the paper's figures.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::viz::colormap;
use crate::viz::ppm::Image;

/// Render a binary/continuous 1D space-time tensor [T, W] (rows = time).
pub fn render_spacetime_1d(traj: &Tensor) -> Result<Image> {
    if traj.shape().len() != 2 {
        bail!("render_spacetime_1d wants [T, W], got {:?}", traj.shape());
    }
    let (t, w) = (traj.shape()[0], traj.shape()[1]);
    let mut img = Image::new(w, t);
    for y in 0..t {
        for x in 0..w {
            img.set(y, x, colormap::gray(1.0 - traj.at(&[y, x])));
        }
    }
    Ok(img)
}

/// Render an ARC color-logit trajectory [T, W, 10] as a Fig. 8 diagram:
/// per-cell argmax color per row of time.
pub fn render_spacetime_arc(traj: &Tensor) -> Result<Image> {
    if traj.shape().len() != 3 {
        bail!("render_spacetime_arc wants [T, W, C], got {:?}", traj.shape());
    }
    let (t, w, c) = (traj.shape()[0], traj.shape()[1], traj.shape()[2]);
    let mut img = Image::new(w, t);
    for y in 0..t {
        for x in 0..w {
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for ch in 0..c {
                let v = traj.at(&[y, x, ch]);
                if v > best_v {
                    best_v = v;
                    best = ch;
                }
            }
            img.set(y, x, colormap::arc_color(best as u8));
        }
    }
    Ok(img)
}

/// Render one ARC row (colors, not logits) as a 1-pixel-tall strip.
pub fn render_arc_row(row: &[u8]) -> Image {
    let mut img = Image::new(row.len(), 1);
    for (x, &c) in row.iter().enumerate() {
        img.set(0, x, colormap::arc_color(c));
    }
    img
}

/// Render an NCA state's RGBA channels [H, W, C>=4] over white.
pub fn render_rgba_state(state: &Tensor) -> Result<Image> {
    if state.shape().len() != 3 || state.shape()[2] < 4 {
        bail!("render_rgba_state wants [H, W, C>=4], got {:?}", state.shape());
    }
    let (h, w) = (state.shape()[0], state.shape()[1]);
    let mut img = Image::new(w, h);
    for y in 0..h {
        for x in 0..w {
            let rgba = [
                state.at(&[y, x, 0]),
                state.at(&[y, x, 1]),
                state.at(&[y, x, 2]),
                state.at(&[y, x, 3]),
            ];
            img.set(y, x, colormap::rgba_over_white(rgba));
        }
    }
    Ok(img)
}

/// Render a grayscale field [H, W] with the viridis map (Lenia frames).
pub fn render_field(field: &Tensor) -> Result<Image> {
    if field.shape().len() != 2 {
        bail!("render_field wants [H, W], got {:?}", field.shape());
    }
    let (h, w) = (field.shape()[0], field.shape()[1]);
    let mut img = Image::new(w, h);
    for y in 0..h {
        for x in 0..w {
            img.set(y, x, colormap::viridis(field.at(&[y, x])));
        }
    }
    Ok(img)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spacetime_1d_dimensions_and_polarity() {
        let mut traj = Tensor::zeros(&[4, 8]);
        traj.set(&[1, 3], 1.0);
        let img = render_spacetime_1d(&traj).unwrap();
        assert_eq!((img.width, img.height), (8, 4));
        assert_eq!(img.get(1, 3), [0, 0, 0]); // live cell = black ink
        assert_eq!(img.get(0, 0), [255, 255, 255]);
        assert!(render_spacetime_1d(&Tensor::zeros(&[2])).is_err());
    }

    #[test]
    fn spacetime_arc_argmax_colors() {
        let mut traj = Tensor::zeros(&[2, 3, 10]);
        traj.set(&[0, 0, 2], 5.0); // red wins
        traj.set(&[1, 2, 4], 1.0); // yellow wins
        let img = render_spacetime_arc(&traj).unwrap();
        assert_eq!(img.get(0, 0), colormap::arc_color(2));
        assert_eq!(img.get(1, 2), colormap::arc_color(4));
        assert_eq!(img.get(0, 1), colormap::arc_color(0));
    }

    #[test]
    fn rgba_state_render() {
        let mut state = Tensor::zeros(&[2, 2, 6]);
        state.set(&[0, 0, 0], 1.0); // red
        state.set(&[0, 0, 3], 1.0); // opaque
        let img = render_rgba_state(&state).unwrap();
        assert_eq!(img.get(0, 0), [255, 0, 0]);
        assert_eq!(img.get(1, 1), [255, 255, 255]); // transparent -> white
        assert!(render_rgba_state(&Tensor::zeros(&[2, 2, 3])).is_err());
    }

    #[test]
    fn arc_row_strip() {
        let img = render_arc_row(&[0, 1, 2]);
        assert_eq!((img.width, img.height), (3, 1));
        assert_eq!(img.get(0, 1), colormap::arc_color(1));
    }

    #[test]
    fn field_render_shape() {
        let img = render_field(&Tensor::full(&[3, 5], 0.5)).unwrap();
        assert_eq!((img.width, img.height), (5, 3));
    }
}
