//! Color palettes: grayscale, a viridis-like continuous map, and the ARC
//! 10-color palette used by the Fig. 8 space-time diagrams.

/// Map v in [0,1] to grayscale.
pub fn gray(v: f32) -> [u8; 3] {
    let g = (v.clamp(0.0, 1.0) * 255.0) as u8;
    [g, g, g]
}

/// Map v in [0,1] through a compact viridis-like gradient
/// (piecewise-linear through 5 anchor colors).
pub fn viridis(v: f32) -> [u8; 3] {
    const ANCHORS: [[f32; 3]; 5] = [
        [0.267, 0.005, 0.329],
        [0.229, 0.322, 0.546],
        [0.127, 0.566, 0.551],
        [0.369, 0.789, 0.383],
        [0.993, 0.906, 0.144],
    ];
    let v = v.clamp(0.0, 1.0) * (ANCHORS.len() - 1) as f32;
    let lo = (v.floor() as usize).min(ANCHORS.len() - 2);
    let frac = v - lo as f32;
    let mut rgb = [0u8; 3];
    for (i, out) in rgb.iter_mut().enumerate() {
        let c = ANCHORS[lo][i] * (1.0 - frac) + ANCHORS[lo + 1][i] * frac;
        *out = (c * 255.0) as u8;
    }
    rgb
}

/// The ARC palette (10 colors, index 0 = background black).
pub fn arc_color(index: u8) -> [u8; 3] {
    const PALETTE: [[u8; 3]; 10] = [
        [0, 0, 0],        // 0 background
        [0, 116, 217],    // 1 blue
        [255, 65, 54],    // 2 red
        [46, 204, 64],    // 3 green
        [255, 220, 0],    // 4 yellow
        [170, 170, 170],  // 5 grey
        [240, 18, 190],   // 6 magenta
        [255, 133, 27],   // 7 orange
        [127, 219, 255],  // 8 light blue
        [135, 12, 37],    // 9 maroon
    ];
    PALETTE[(index as usize).min(9)]
}

/// Composite an RGBA cell (premultiplied-ish, alpha in [0,1]) over white —
/// the paper's figures render growing-NCA states on white.
pub fn rgba_over_white(rgba: [f32; 4]) -> [u8; 3] {
    let a = rgba[3].clamp(0.0, 1.0);
    let mut out = [0u8; 3];
    for (i, o) in out.iter_mut().enumerate() {
        let c = rgba[i].clamp(0.0, 1.0) * a + (1.0 - a);
        *o = (c * 255.0) as u8;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gray_endpoints() {
        assert_eq!(gray(0.0), [0, 0, 0]);
        assert_eq!(gray(1.0), [255, 255, 255]);
        assert_eq!(gray(2.0), [255, 255, 255]); // clamps
        assert_eq!(gray(-1.0), [0, 0, 0]);
    }

    #[test]
    fn viridis_monotone_luminance() {
        let lum = |rgb: [u8; 3]| {
            0.2126 * rgb[0] as f32 + 0.7152 * rgb[1] as f32
                + 0.0722 * rgb[2] as f32
        };
        let mut prev = lum(viridis(0.0));
        for i in 1..=10 {
            let cur = lum(viridis(i as f32 / 10.0));
            assert!(cur >= prev - 1.0, "luminance dipped at {i}");
            prev = cur;
        }
    }

    #[test]
    fn arc_palette_distinct() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10u8 {
            assert!(seen.insert(arc_color(i)), "duplicate color {i}");
        }
        assert_eq!(arc_color(0), [0, 0, 0]);
        assert_eq!(arc_color(200), arc_color(9)); // clamps
    }

    #[test]
    fn rgba_compositing() {
        assert_eq!(rgba_over_white([0.0, 0.0, 0.0, 0.0]), [255, 255, 255]);
        assert_eq!(rgba_over_white([1.0, 0.0, 0.0, 1.0]), [255, 0, 0]);
        let half = rgba_over_white([0.0, 0.0, 0.0, 0.5]);
        assert!(half[0] > 100 && half[0] < 150);
    }
}
