//! PJRT-backed execution (`pjrt` feature): adapts [`Engine`] to the
//! backend traits so coordinators can dispatch XLA artifacts and native
//! kernels through one interface.

use anyhow::{bail, Result};

use crate::backend::{Backend, CaProgram, ProgramBackend, Value};
use crate::runtime::manifest::Manifest;
use crate::runtime::Engine;
use crate::tensor::Tensor;

impl ProgramBackend for Engine {
    fn manifest(&self) -> &Manifest {
        // Inherent methods win resolution; these delegate, not recurse.
        Engine::manifest(self)
    }

    fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        Engine::execute(self, name, inputs)
    }

    fn load_params(&self, blob: &str) -> Result<Tensor> {
        Engine::load_params(self, blob)
    }
}

/// Classic-CA execution over the per-step XLA artifacts. The fused
/// (whole-rollout-in-one-program) paths stay on
/// [`crate::coordinator::Simulator`], which knows the artifact naming
/// scheme; this adapter is the generic per-step route.
pub struct PjrtBackend<'e> {
    engine: &'e Engine,
}

impl<'e> PjrtBackend<'e> {
    pub fn new(engine: &'e Engine) -> PjrtBackend<'e> {
        PjrtBackend { engine }
    }

    pub fn engine(&self) -> &'e Engine {
        self.engine
    }
}

impl Backend for PjrtBackend<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn supports(&self, prog: &CaProgram) -> bool {
        !matches!(prog, CaProgram::Nca(_) | CaProgram::LeniaMulti(_))
    }

    fn rollout(&self, prog: &CaProgram, state: &Tensor, steps: usize)
        -> Result<Tensor> {
        crate::backend::validate_state(prog, state)?;
        let mut current = state.clone();
        match prog {
            CaProgram::Eca { rule } => {
                let rule_t =
                    Tensor::new(vec![8], rule.table_f32().to_vec()).unwrap();
                for _ in 0..steps {
                    let out = self.engine.execute(
                        "eca_step",
                        &[Value::F32(current), Value::F32(rule_t.clone())],
                    )?;
                    current = out.into_iter().next().unwrap();
                }
            }
            CaProgram::Life => {
                for _ in 0..steps {
                    let out = self
                        .engine
                        .execute("life_step", &[Value::F32(current)])?;
                    current = out.into_iter().next().unwrap();
                }
            }
            CaProgram::Lenia { .. } => {
                let kfft = crate::backend::lenia_kernel_fft(self.engine)?;
                for _ in 0..steps {
                    let out = self.engine.execute(
                        "lenia_step",
                        &[Value::F32(current), Value::F32(kfft.clone())],
                    )?;
                    current = out.into_iter().next().unwrap();
                }
            }
            CaProgram::Nca(_) => {
                bail!(
                    "PjrtBackend has no generic NCA program; use the named \
                     rollout artifacts via ProgramBackend::execute"
                )
            }
            CaProgram::LeniaMulti(_) => {
                bail!(
                    "multi-kernel Lenia worlds run on the native spectral \
                     path (`--backend native`); no artifact exists for them"
                )
            }
        }
        Ok(current)
    }

    fn train_step(&self, program: &str, inputs: &[Value])
        -> Result<Vec<Tensor>> {
        self.engine.execute(program, inputs)
    }
}
