//! Scoped-thread worker pool for batch-parallel kernels.
//!
//! Batch elements of a CA are independent, so every native kernel
//! parallelizes the same way: split the state buffer into one contiguous
//! chunk per batch element and let a small crew of scoped threads pull
//! chunks off a shared queue. `std::thread::scope` keeps borrows safe
//! (kernels capture `&self` state like kernel taps) with zero unsafe.

use std::sync::Mutex;

/// A fixed-width crew of scoped worker threads.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::new()
    }
}

impl WorkerPool {
    /// Pool sized to the machine (`available_parallelism`).
    pub fn new() -> WorkerPool {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        WorkerPool { threads }
    }

    /// Pool with an explicit thread count (min 1). `with_threads(1)`
    /// degrades to sequential execution — handy for determinism checks.
    pub fn with_threads(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i, chunk_i)` over consecutive `chunk`-sized pieces of
    /// `data`, in parallel. `data.len()` must be a multiple of `chunk`;
    /// chunk `i` covers `data[i*chunk .. (i+1)*chunk]`.
    ///
    /// Chunks are disjoint `&mut` borrows, so workers never contend on
    /// the data itself — only on the (cheap) chunk queue.
    pub fn for_each_chunk<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk > 0, "for_each_chunk: zero chunk size");
        assert_eq!(
            data.len() % chunk,
            0,
            "for_each_chunk: {} not a multiple of chunk {chunk}",
            data.len()
        );
        let jobs = data.len() / chunk;
        let threads = self.threads.min(jobs);
        if threads <= 1 {
            for (i, piece) in data.chunks_mut(chunk).enumerate() {
                f(i, piece);
            }
            return;
        }
        let queue = Mutex::new(data.chunks_mut(chunk).enumerate());
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let job = queue.lock().expect("worker queue").next();
                    match job {
                        Some((i, piece)) => f(i, piece),
                        None => break,
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_chunk_exactly_once() {
        let pool = WorkerPool::with_threads(4);
        let mut data = vec![0u32; 64];
        pool.for_each_chunk(&mut data, 8, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += 1 + i as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, 1 + (i / 8) as u32, "cell {i}");
        }
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let work = |i: usize, chunk: &mut [u64]| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (i * 1_000 + j) as u64;
            }
        };
        let mut a = vec![0u64; 300];
        let mut b = vec![0u64; 300];
        WorkerPool::with_threads(1).for_each_chunk(&mut a, 50, work);
        WorkerPool::with_threads(8).for_each_chunk(&mut b, 50, work);
        assert_eq!(a, b);
    }

    #[test]
    fn handles_fewer_jobs_than_threads_and_empty_input() {
        let pool = WorkerPool::with_threads(16);
        let mut one = vec![0u8; 4];
        pool.for_each_chunk(&mut one, 4, |_, c| c.fill(7));
        assert_eq!(one, vec![7; 4]);
        let mut empty: Vec<u8> = vec![];
        pool.for_each_chunk(&mut empty, 4, |_, _| panic!("no chunks"));
    }

    #[test]
    #[should_panic]
    fn rejects_misaligned_lengths() {
        WorkerPool::with_threads(2).for_each_chunk(&mut [0u8; 5], 2,
                                                   |_, _| {});
    }

    #[test]
    fn default_pool_has_threads() {
        assert!(WorkerPool::new().threads() >= 1);
    }
}
