//! `cax::backend` — pluggable execution backends.
//!
//! The paper's framing is "one modular library, many substrates". This
//! module is that boundary in Rust: coordinators describe *what* to run
//! ([`CaProgram`] for classic CAs, named manifest programs for neural
//! CAs) and backends decide *how*:
//!
//! - [`NativeBackend`] (always available): pure-Rust kernels —
//!   bit-packed u64 SWAR for the discrete CAs (64 cells per word),
//!   cache-tiled f32 for the continuous/neural paths, spectral FFT
//!   Lenia above the size crossover (in-tree transforms, no deps) —
//!   parallelized across batch elements with a scoped-thread
//!   [`workers::WorkerPool`].
//! - [`NativeTrainBackend`] (always available): hand-rolled BPTT +
//!   Adam train/eval programs for the growing-NCA, MNIST-classifier
//!   and 1D-ARC workloads (`native::nca_grad` / `native::opt` /
//!   `native::train`).
//! - `PjrtBackend` (`pjrt` feature): wraps `runtime::Engine`,
//!   executing AOT-lowered HLO artifacts through PJRT.
//!
//! Two traits split the surface:
//!
//! - [`Backend`]: "execute a classic-CA program on a batch of states"
//!   (step / rollout) plus a named train-step hook.
//! - [`ProgramBackend`]: "execute a named, manifest-described program" —
//!   the contract the trainer/evaluator/experiment layers dispatch
//!   through; implemented by `Engine` when the `pjrt` feature is on and
//!   by [`NativeTrainBackend`] everywhere. The named programs both
//!   implementations serve are catalogued on the trait.
//!
//! See `rust/README.md` for the layer diagram and the backend feature
//! matrix.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod workers;

use anyhow::{bail, Result};

use crate::automata::lenia::{LeniaParams, LeniaWorld};
use crate::automata::WolframRule;
use crate::runtime::manifest::{Dtype, Manifest};
use crate::tensor::Tensor;

pub use native::train::NativeTrainBackend;
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use workers::WorkerPool;

/// A typed input value for a program call (formerly `runtime::Value`;
/// re-exported there for compatibility).
#[derive(Clone, Debug)]
pub enum Value {
    /// Dense f32 tensor (the common case).
    F32(Tensor),
    /// i32 scalar (train-step counters).
    I32(i32),
    /// u32 scalar (PRNG seeds).
    U32(u32),
}

impl Value {
    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(_) => Dtype::F32,
            Value::I32(_) => Dtype::I32,
            Value::U32(_) => Dtype::U32,
        }
    }

    pub fn shape(&self) -> Vec<usize> {
        match self {
            Value::F32(t) => t.shape().to_vec(),
            Value::I32(_) | Value::U32(_) => vec![],
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

/// A classic-CA program: everything a backend needs to run one of the
/// Table-1 non-neural scenarios, independent of any artifact manifest.
#[derive(Clone, Debug)]
pub enum CaProgram {
    /// Elementary CA on `[B, W]` {0,1} states, periodic boundary.
    Eca { rule: WolframRule },
    /// Conway's Game of Life on `[B, H, W]` {0,1} states, periodic.
    Life,
    /// Lenia on `[B, H, W]` states in `[0,1]`, periodic.
    Lenia { params: LeniaParams },
    /// Generalized multi-channel / multi-kernel Lenia on `[B, C, H, W]`
    /// states in `[0,1]`, periodic — runs the native spectral path.
    LeniaMulti(LeniaWorld),
    /// A neural-CA forward cell (depthwise perceive + per-cell MLP) on
    /// `[B, H, W, C]` states — the native NCA inference path.
    Nca(native::nca::NcaModel),
}

impl CaProgram {
    pub fn name(&self) -> &'static str {
        match self {
            CaProgram::Eca { .. } => "eca",
            CaProgram::Life => "life",
            CaProgram::Lenia { .. } => "lenia",
            CaProgram::LeniaMulti(_) => "lenia-multi",
            CaProgram::Nca(_) => "nca",
        }
    }

    /// Tensor rank a state for this program must have (batch included).
    pub fn state_rank(&self) -> usize {
        match self {
            CaProgram::Eca { .. } => 2,
            CaProgram::Life | CaProgram::Lenia { .. } => 3,
            CaProgram::LeniaMulti(_) | CaProgram::Nca(_) => 4,
        }
    }
}

/// A single CA board held in a backend's *internal* representation
/// between calls — the session currency of the `serve` layer.
///
/// `step`/`rollout` cross the f32 tensor boundary on every call; for a
/// long-lived session stepped a few updates at a time that boundary
/// (pack/unpack, allocation) dominates the actual kernel work. A
/// `Resident` stays in whatever form the backend steps fastest — bit
/// planes for the discrete CAs, flat kernel-layout f32 for the
/// continuous ones — and only materializes a [`Tensor`] when a caller
/// asks to read it.
///
/// The shape carried here is the *un-batched* board shape (one rank
/// below [`CaProgram::state_rank`]): `[W]` for ECA, `[H, W]` for
/// Life/Lenia, `[C, H, W]` for Lenia worlds, `[H, W, C]` for NCA.
#[derive(Clone, Debug)]
pub enum Resident {
    /// Host tensor — the fallback representation every backend can
    /// serve via the default trait methods.
    Host(Tensor),
    /// Bit-packed discrete state (native ECA/Life): 64 cells per u64,
    /// LSB-first, rows padded to whole words (`native::bits`). The
    /// activity map carries which tiles changed last step across calls
    /// (sparse resident stepping); `None` until a sparse launch touches
    /// the board, and cleared by any dense/HashLife launch.
    Bits {
        words: Vec<u64>,
        shape: Vec<usize>,
        activity: Option<native::activity::ActivityMap>,
    },
    /// Flat f32 state in kernel layout (native Lenia/NCA boards), with
    /// the same cross-call activity map as [`Resident::Bits`].
    Board {
        data: Vec<f32>,
        shape: Vec<usize>,
        activity: Option<native::activity::ActivityMap>,
    },
}

impl Resident {
    /// The un-batched board shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Resident::Host(t) => t.shape(),
            Resident::Bits { shape, .. } | Resident::Board { shape, .. } => {
                shape
            }
        }
    }

    /// Short name of the representation (error messages).
    pub fn kind(&self) -> &'static str {
        match self {
            Resident::Host(_) => "host",
            Resident::Bits { .. } => "bits",
            Resident::Board { .. } => "board",
        }
    }
}

/// An execution backend for classic-CA programs.
///
/// `step`/`rollout` take and return batched f32 tensors (the host data
/// currency); backends are free to run any internal representation —
/// the native backend packs discrete states 64 cells to a word and only
/// converts at the boundary, so `rollout` is much cheaper than `steps`
/// calls to `step`. States are validated against the program
/// ([`validate_state`]) before dispatch, so shape bugs surface as
/// errors, not kernel panics.
///
/// The `admit`/`read_resident`/`step_resident` family is the
/// session-resident entry the `serve` layer batches through: a state is
/// admitted ONCE into the backend's internal representation, stepped in
/// place (many sessions per launch), and only unpacked when read. The
/// default implementations round-trip through `rollout`, so every
/// backend supports residents; [`NativeBackend`] overrides them with
/// true packed residency.
pub trait Backend {
    /// Short stable name (CLI surface, bench rows).
    fn name(&self) -> &'static str;

    /// Whether this backend can run `prog` at all.
    fn supports(&self, prog: &CaProgram) -> bool;

    /// One update of every cell in the batch.
    fn step(&self, prog: &CaProgram, state: &Tensor) -> Result<Tensor> {
        self.rollout(prog, state, 1)
    }

    /// `steps` updates; backends may fuse the loop internally.
    fn rollout(&self, prog: &CaProgram, state: &Tensor, steps: usize)
        -> Result<Tensor>;

    /// Execute a named train-step program. [`NativeBackend`] runs the
    /// native NCA train steps (BPTT + Adam on the host); artifact-backed
    /// backends run their fused in-graph equivalents; the default
    /// refuses with a clear error.
    fn train_step(&self, program: &str, _inputs: &[Value])
        -> Result<Vec<Tensor>> {
        bail!(
            "backend {:?} cannot run train-step program {program:?}",
            self.name()
        )
    }

    /// Admit one un-batched board into this backend's resident
    /// representation. The board is validated against `prog` (same
    /// contract as [`validate_state`], minus the batch axis).
    fn admit(&self, prog: &CaProgram, board: &Tensor) -> Result<Resident> {
        validate_board(prog, board)?;
        Ok(Resident::Host(board.clone()))
    }

    /// Materialize a resident back into a host tensor (un-batched).
    fn read_resident(&self, prog: &CaProgram, resident: &Resident)
        -> Result<Tensor> {
        let _ = prog;
        match resident {
            Resident::Host(t) => Ok(t.clone()),
            other => bail!(
                "backend {:?} cannot read resident representation {:?}",
                self.name(),
                other.kind()
            ),
        }
    }

    /// Step a *uniform* batch of residents in place: every entry must
    /// run the same `prog` and carry the same board shape (the caller —
    /// the serve coalescer — groups by that shape class). Backends are
    /// free to pack the batch into one internal launch; each board's
    /// trajectory must be bitwise identical to stepping it alone
    /// through [`rollout`](Backend::rollout).
    ///
    /// The default implementation round-trips every resident through
    /// `rollout` one by one — correct everywhere, coalesced nowhere.
    fn step_resident(&self, prog: &CaProgram, batch: &mut [&mut Resident],
                     steps: usize) -> Result<()> {
        for resident in batch.iter_mut() {
            let board = self.read_resident(prog, resident)?;
            let stacked = Tensor::stack(&[board])?;
            let out = self.rollout(prog, &stacked, steps)?;
            **resident = self.admit(prog, &out.index_axis0(0))?;
        }
        Ok(())
    }
}

/// A backend that executes *named* programs described by an artifact
/// [`Manifest`] — the contract the trainer, evaluators and experiment
/// drivers dispatch through. `runtime::Engine` implements this when the
/// `pjrt` feature is enabled; [`NativeTrainBackend`] implements it on
/// every build.
///
/// # Named program contract
///
/// Callers discover each program's geometry from
/// [`manifest`](ProgramBackend::manifest) (batch shapes from the input
/// specs, scenario metadata from `meta`) instead of hard-coding it, so
/// the same coordinator code drives any implementation. Train-step
/// programs share one calling convention, enforced by
/// [`train_loop`](crate::coordinator::trainer::train_loop):
///
/// ```text
/// inputs:  (params, m, v, step, <batch...>, seed)
/// outputs: (params', m', v', loss, <extra...>)
/// ```
///
/// The programs both backends serve today (shapes are the *default*
/// specs; custom specs/artifacts re-shape them through the manifest):
///
/// | program | batch inputs | outputs beyond the contract |
/// |---|---|---|
/// | `growing_seed` | — | seed state `[H, W, C]` |
/// | `growing_train_step` | `states [B,H,W,C]`, `target [H,W,4]` | evolved states `[B,H,W,C]` (pool write-back) |
/// | `mnist_train_step` | `images [B,H,W]`, `labels [B,10]` | — |
/// | `arc_train_step` | `inputs [B,W,10]`, `targets [B,W,10]` | — |
/// | `arc_eval` | `(params, inputs [B,W,10])` only | logits `[B,W,10]` |
/// | `arc_traj` | `(params, input [W,10])` only | logit frames `[T+1,W,10]` |
///
/// `arc_eval`/`arc_traj` are deterministic fixed-length rollouts, not
/// train steps — they take no optimizer state and return no loss. The
/// `pjrt` artifact set adds further scenarios (`diffusing_*`,
/// `conditional_*`, `vae_*`, `autoenc3d_*`, `mnist_eval`, classic-CA
/// rollouts) under the same discovery rules.
pub trait ProgramBackend {
    /// The manifest describing every program this backend can run —
    /// the introspection surface for batch shapes and metadata.
    fn manifest(&self) -> &Manifest;

    /// Execute a named program; returns one tensor per manifest output.
    /// Unknown names and shape mismatches are errors, not panics.
    fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>>;

    /// Load an initial-parameter blob as a rank-1 tensor — the starting
    /// point of [`TrainState`](crate::coordinator::trainer::TrainState).
    /// Artifact backends read blob files; the native backend draws the
    /// deterministic in-memory init.
    fn load_params(&self, blob: &str) -> Result<Tensor> {
        let data = self.manifest().load_blob(blob)?;
        let n = data.len();
        Tensor::new(vec![n], data)
    }
}

/// The FFT'd Lenia ring kernel the `lenia_*` artifacts expect, shaped
/// from the manifest (shared by the Simulator and the PJRT adapter).
pub fn lenia_kernel_fft(program: &dyn ProgramBackend) -> Result<Tensor> {
    let info = program.manifest().artifact("lenia_step")?;
    let spec = &info.inputs[1];
    let data = program.manifest().load_blob("lenia_kfft")?;
    Tensor::new(spec.shape.clone(), data)
}

/// Validate one *un-batched* board against a program — the
/// [`validate_state`] contract minus the batch axis (the resident /
/// serve-session form).
pub fn validate_board(prog: &CaProgram, board: &Tensor) -> Result<()> {
    let mut shape = vec![1];
    shape.extend_from_slice(board.shape());
    validate_state_shape(prog, &shape)
}

/// Validate a state tensor against a program before dispatch, so shape
/// bugs surface as precise errors rather than kernel panics.
pub fn validate_state(prog: &CaProgram, state: &Tensor) -> Result<()> {
    validate_state_shape(prog, state.shape())
}

/// The shape-only core of [`validate_state`] — callers that have no
/// tensor yet (or do not want to touch its data) validate against the
/// would-be batched shape directly.
pub fn validate_state_shape(prog: &CaProgram, shape: &[usize])
    -> Result<()> {
    let rank = prog.state_rank();
    if shape.len() != rank {
        bail!(
            "program {:?} wants a rank-{rank} batched state, got shape {:?}",
            prog.name(),
            shape
        );
    }
    if shape.iter().any(|&d| d == 0) {
        bail!(
            "program {:?}: empty dimension in state shape {:?}",
            prog.name(),
            shape
        );
    }
    match prog {
        CaProgram::Nca(model) => {
            let c = *shape.last().unwrap();
            if c != model.channels {
                bail!(
                    "nca model has {} channels but state shape {:?} \
                     carries {c}",
                    model.channels,
                    shape
                );
            }
        }
        CaProgram::Lenia { params } => {
            // The ring kernel has no cells strictly inside the ring
            // below radius 2 — its zero sum would normalize to NaN.
            if params.radius < 2 {
                bail!(
                    "lenia radius {} < 2 (the ring kernel is empty below \
                     radius 2)",
                    params.radius
                );
            }
            // The wrap index `(y + h + r - ky) % h` (shared with the
            // naive oracle) needs h, w >= radius to stay non-negative.
            let (h, w) = (shape[1], shape[2]);
            if h < params.radius || w < params.radius {
                bail!(
                    "lenia radius {r} needs a board of at least {r}x{r}, \
                     got {h}x{w}",
                    r = params.radius
                );
            }
        }
        CaProgram::LeniaMulti(world) => {
            world.validate()?;
            let (c, h, w) = (shape[1], shape[2], shape[3]);
            if c != world.channels {
                bail!(
                    "lenia world has {} channels but state shape {:?} \
                     carries {c}",
                    world.channels,
                    shape
                );
            }
            let r = world.max_radius();
            if h < r || w < r {
                bail!(
                    "lenia radius {r} needs a board of at least {r}x{r}, \
                     got {h}x{w}"
                );
            }
        }
        CaProgram::Eca { .. } | CaProgram::Life => {}
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_dtypes_and_shapes() {
        let t = Tensor::zeros(&[2, 3]);
        let v: Value = t.into();
        assert_eq!(v.dtype(), Dtype::F32);
        assert_eq!(v.shape(), vec![2, 3]);
        assert_eq!(Value::I32(4).dtype(), Dtype::I32);
        assert_eq!(Value::U32(4).dtype(), Dtype::U32);
        assert!(Value::I32(0).shape().is_empty());
    }

    #[test]
    fn program_ranks() {
        assert_eq!(CaProgram::Eca { rule: WolframRule::new(30) }.state_rank(),
                   2);
        assert_eq!(CaProgram::Life.state_rank(), 3);
        assert_eq!(
            CaProgram::Lenia { params: LeniaParams::default() }.state_rank(),
            3
        );
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let prog = CaProgram::Life;
        assert!(validate_state(&prog, &Tensor::zeros(&[2, 8, 8])).is_ok());
        assert!(validate_state(&prog, &Tensor::zeros(&[2, 8])).is_err());
        assert!(validate_state(&prog, &Tensor::zeros(&[0, 8, 8])).is_err());
    }

    #[test]
    fn validate_checks_lenia_world_shape_and_wiring() {
        let world = LeniaWorld::demo(2, 4);
        let prog = CaProgram::LeniaMulti(world.clone());
        assert_eq!(prog.state_rank(), 4);
        assert_eq!(prog.name(), "lenia-multi");
        assert!(validate_state(&prog, &Tensor::zeros(&[1, 2, 16, 16]))
            .is_ok());
        // Channel count must match the world.
        assert!(validate_state(&prog, &Tensor::zeros(&[1, 3, 16, 16]))
            .is_err());
        // Board must fit the largest radius.
        assert!(validate_state(&prog, &Tensor::zeros(&[1, 2, 3, 3]))
            .is_err());
        // Structural problems surface too.
        let mut bad = world;
        bad.kernels[0].src = 9;
        assert!(validate_state(
            &CaProgram::LeniaMulti(bad),
            &Tensor::zeros(&[1, 2, 16, 16])
        )
        .is_err());
    }

    #[test]
    fn validate_board_drops_the_batch_axis() {
        let prog = CaProgram::Life;
        assert!(validate_board(&prog, &Tensor::zeros(&[8, 8])).is_ok());
        assert!(validate_board(&prog, &Tensor::zeros(&[2, 8, 8])).is_err());
        let lenia = CaProgram::Lenia {
            params: LeniaParams { radius: 10, ..Default::default() },
        };
        assert!(validate_board(&lenia, &Tensor::zeros(&[8, 8])).is_err());
        assert!(validate_board(&lenia, &Tensor::zeros(&[32, 32])).is_ok());
    }

    #[test]
    fn resident_shape_and_kind() {
        let host = Resident::Host(Tensor::zeros(&[4, 4]));
        assert_eq!(host.shape(), &[4, 4]);
        assert_eq!(host.kind(), "host");
        let bits = Resident::Bits {
            words: vec![0; 2],
            shape: vec![70],
            activity: None,
        };
        assert_eq!(bits.shape(), &[70]);
        assert_eq!(bits.kind(), "bits");
        let board = Resident::Board {
            data: vec![0.0; 6],
            shape: vec![2, 3],
            activity: None,
        };
        assert_eq!(board.kind(), "board");
    }

    #[test]
    fn validate_rejects_lenia_radius_larger_than_board() {
        let prog = CaProgram::Lenia {
            params: LeniaParams { radius: 10, ..Default::default() },
        };
        let err =
            validate_state(&prog, &Tensor::zeros(&[1, 8, 8])).unwrap_err();
        assert!(format!("{err}").contains("radius 10"));
        assert!(validate_state(&prog, &Tensor::zeros(&[1, 32, 32])).is_ok());
        // Radius < 2 would normalize the empty ring kernel to NaN.
        let tiny = CaProgram::Lenia {
            params: LeniaParams { radius: 1, ..Default::default() },
        };
        assert!(validate_state(&tiny, &Tensor::zeros(&[1, 32, 32]))
            .is_err());
    }
}
