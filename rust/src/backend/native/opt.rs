//! Host-side optimizer for the native train step: Adam, global-norm
//! gradient clipping and the staircase lr schedule — the pieces the
//! `pjrt` train-step artifacts run in-graph (DESIGN.md §4.2), rebuilt
//! here so the default feature set can train.
//!
//! Everything is sequential and order-fixed, so a train step is
//! bit-identical for any worker-thread count.

/// Staircase-exponential learning rate: `base * decay^(step / every)`,
/// the reference growing-NCA schedule (2e-3, x0.1 at step 2000).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base: f32,
    pub decay: f32,
    pub decay_every: usize,
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule { base: 2e-3, decay: 0.1, decay_every: 2000 }
    }
}

impl LrSchedule {
    /// Constant learning rate (no decay).
    pub fn constant(base: f32) -> LrSchedule {
        LrSchedule { base, decay: 1.0, decay_every: 1 }
    }

    /// Learning rate at a (0-based) optimizer step.
    pub fn lr(&self, step: i32) -> f32 {
        let k = step.max(0) as usize / self.decay_every.max(1);
        self.base * self.decay.powi(k as i32)
    }
}

/// Scale `grad` so its global L2 norm is at most `max_norm`; returns the
/// pre-clip norm. The norm is accumulated in f64 in index order.
pub fn clip_global_norm(grad: &mut [f32], max_norm: f32) -> f32 {
    let norm = grad
        .iter()
        .map(|&g| g as f64 * g as f64)
        .sum::<f64>()
        .sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grad.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

/// Adam (Kingma & Ba) with bias correction.
#[derive(Clone, Copy, Debug)]
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for Adam {
    fn default() -> Self {
        Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

impl Adam {
    /// One in-place update. `step` counts *completed* updates (0 on the
    /// first call, as [`crate::coordinator::trainer::TrainState`] hands
    /// it to the train-step program), so bias correction uses `step + 1`.
    pub fn update(&self, params: &mut [f32], m: &mut [f32], v: &mut [f32],
                  grad: &[f32], step: i32, lr: f32) {
        assert_eq!(params.len(), grad.len(), "adam: param/grad length");
        assert_eq!(params.len(), m.len(), "adam: param/m length");
        assert_eq!(params.len(), v.len(), "adam: param/v length");
        let t = step.max(0) + 1;
        let bc1 = 1.0 - self.beta1.powi(t);
        let bc2 = 1.0 - self.beta2.powi(t);
        for i in 0..params.len() {
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * grad[i];
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let m_hat = m[i] / bc1;
            let v_hat = v[i] / bc2;
            params[i] -= lr * m_hat / (v_hat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_staircases() {
        let s = LrSchedule { base: 1.0, decay: 0.1, decay_every: 100 };
        assert_eq!(s.lr(0), 1.0);
        assert_eq!(s.lr(99), 1.0);
        assert!((s.lr(100) - 0.1).abs() < 1e-9);
        assert!((s.lr(250) - 0.01).abs() < 1e-9);
        let c = LrSchedule::constant(3e-3);
        assert_eq!(c.lr(0), c.lr(10_000));
    }

    #[test]
    fn clip_caps_large_norms_and_keeps_small_ones() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((post - 1.0).abs() < 1e-6);
        assert!((g[0] - 0.6).abs() < 1e-6);

        let mut small = vec![0.3f32, 0.4]; // norm 0.5 <= 1
        clip_global_norm(&mut small, 1.0);
        assert_eq!(small, vec![0.3, 0.4]);

        let mut zero = vec![0.0f32; 4];
        assert_eq!(clip_global_norm(&mut zero, 1.0), 0.0);
    }

    #[test]
    fn adam_minimizes_a_quadratic() {
        // f(p) = sum (p_i - target_i)^2; grad = 2 (p - target).
        let target = [1.0f32, -2.0, 0.5];
        let mut p = vec![0.0f32; 3];
        let mut m = vec![0.0f32; 3];
        let mut v = vec![0.0f32; 3];
        let adam = Adam::default();
        // Decaying schedule so the iterates settle instead of cycling.
        let sched = LrSchedule { base: 0.05, decay: 0.5, decay_every: 100 };
        for step in 0..800 {
            let grad: Vec<f32> =
                p.iter().zip(&target).map(|(&a, &t)| 2.0 * (a - t)).collect();
            adam.update(&mut p, &mut m, &mut v, &grad, step, sched.lr(step));
        }
        for (a, t) in p.iter().zip(&target) {
            assert!((a - t).abs() < 0.05, "param {a} vs target {t}");
        }
    }

    #[test]
    fn adam_first_step_moves_by_about_lr() {
        // With zero m/v, the bias-corrected first step is ~lr * sign(g).
        let mut p = vec![0.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        Adam::default().update(&mut p, &mut m, &mut v, &[0.3], 0, 1e-2);
        assert!((p[0] + 1e-2).abs() < 1e-4, "first step {}", p[0]);
    }
}
