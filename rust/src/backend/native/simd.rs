//! Runtime SIMD dispatch for the native f32 kernels.
//!
//! The three scalar f32 hot loops — the Lenia sparse-tap convolution,
//! the shared Lenia growth/update stage, and the NCA perceive + MLP
//! cell — carry an explicit AVX2 path (stable `target_feature`
//! intrinsics, no nightly `std::simd`). The scalar code stays compiled
//! everywhere and remains the source of truth; the SIMD paths are a
//! pure re-arrangement of the same arithmetic.
//!
//! # The dispatch contract
//!
//! - **One lane = one output cell.** Every vector lane computes one
//!   cell with the *exact* scalar accumulation order (same tap order,
//!   same `mul`-then-`add` pairs, never FMA — fused rounding would
//!   change bits). SIMD and scalar therefore produce bit-identical
//!   boards, including NaN payloads and denormals, and the existing
//!   bit-identity / thread-determinism suites hold in both modes.
//! - **Transcendentals stay scalar.** `exp` inside
//!   [`crate::automata::lenia::growth`] has no lane-exact vector form,
//!   so the growth mapping runs scalar per lane on the vector-computed
//!   potentials.
//! - **Edges stay scalar.** Wrapped boundary columns (and boards too
//!   narrow for a full 8-lane interior block) run the unchanged scalar
//!   per-cell code.
//!
//! # Detection
//!
//! [`active`] probes the CPU once per process (cached), honours the
//! `CAX_SIMD=off` escape hatch, and logs the decision through
//! [`crate::obs`] logging (`CAX_LOG=info`). Non-x86_64 targets always
//! report scalar; the intrinsics are not even compiled there.

use std::sync::OnceLock;

/// f32 lanes per vector in the AVX2 paths (256 bits / 32 bits).
pub const LANES: usize = 8;

/// `(simd active, human-readable reason)` — computed once.
fn detect() -> (bool, &'static str) {
    if super::env_disabled("CAX_SIMD") {
        return (false, "scalar (CAX_SIMD=off)");
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            (true, "avx2")
        } else {
            (false, "scalar (cpu lacks avx2)")
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        (false, "scalar (non-x86_64)")
    }
}

fn cached() -> (bool, &'static str) {
    static STATUS: OnceLock<(bool, &'static str)> = OnceLock::new();
    *STATUS.get_or_init(|| {
        let s = detect();
        crate::log_info!("native simd dispatch: {}", s.1);
        s
    })
}

/// Whether the AVX2 paths are taken. Detected once per process:
/// x86_64 + runtime AVX2 + `CAX_SIMD` not set to `off`/`0`.
pub fn active() -> bool {
    cached().0
}

/// Human-readable dispatch status: `"avx2"`, or `"scalar (...)"` with
/// the reason. Stable strings — surfaced by `cax serve` startup and
/// the bench reports.
pub fn status() -> &'static str {
    cached().1
}

/// Strided 8-lane load/store helpers shared by the AVX2 kernels in
/// [`super::lenia`] and [`super::nca`]. Channels-last NCA boards put 8
/// consecutive cells `stride = channels` floats apart, so lanes are
/// gathered/scattered with scalar moves; contiguous Lenia rows use
/// plain unaligned vector loads at the call sites.
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use std::arch::x86_64::*;

    /// Lane `i` = `data[base + i * stride]` for `i` in `0..8`.
    ///
    /// # Safety
    ///
    /// Caller must ensure AVX2 is available ([`super::active`]); the
    /// slice accesses themselves are bounds-checked.
    #[target_feature(enable = "avx2")]
    #[inline]
    pub unsafe fn load8_strided(data: &[f32], base: usize, stride: usize)
                                -> __m256 {
        _mm256_set_ps(
            data[base + 7 * stride],
            data[base + 6 * stride],
            data[base + 5 * stride],
            data[base + 4 * stride],
            data[base + 3 * stride],
            data[base + 2 * stride],
            data[base + stride],
            data[base],
        )
    }

    /// `data[base + i * stride] = lane i` for `i` in `0..8`.
    ///
    /// # Safety
    ///
    /// Caller must ensure AVX2 is available ([`super::active`]); the
    /// slice accesses themselves are bounds-checked.
    #[target_feature(enable = "avx2")]
    #[inline]
    pub unsafe fn store8_strided(data: &mut [f32], base: usize,
                                 stride: usize, v: __m256) {
        let mut tmp = [0.0f32; 8];
        _mm256_storeu_ps(tmp.as_mut_ptr(), v);
        for (i, t) in tmp.iter().enumerate() {
            data[base + i * stride] = *t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detection_is_cached_and_consistent() {
        let first = (active(), status());
        let second = (active(), status());
        assert_eq!(first, second);
        if first.0 {
            assert_eq!(first.1, "avx2");
        } else {
            assert!(first.1.starts_with("scalar"), "got {:?}", first.1);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn strided_helpers_roundtrip() {
        if !active() {
            return; // nothing to probe without avx2
        }
        let src: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut dst = vec![0.0f32; 64];
        unsafe {
            let v = x86::load8_strided(&src, 3, 4);
            x86::store8_strided(&mut dst, 3, 4, v);
        }
        for i in 0..8 {
            assert_eq!(dst[3 + i * 4], src[3 + i * 4]);
        }
    }
}
