//! The native execution backend: pure-Rust kernels, no XLA, no Python.
//!
//! - [`activity`]: per-tile dirty bitmaps (the sparse step paths) and
//!   the dense/sparse/hashlife cost model, with the `CAX_SPARSE=off`
//!   escape hatch.
//! - [`bits`]: bit-packed row substrate (64 cells per u64, periodic).
//! - [`eca`]: SWAR elementary-CA kernel.
//! - [`life`]: SWAR Game-of-Life kernel (carry-save neighbour counts).
//! - [`hashlife`]: memoizing quadtree (Life) / binary-tree (ECA)
//!   engines for superspeed power-of-two macro-steps on big boards.
//! - [`fft`]: in-tree FFTs (iterative Cooley–Tukey + Bluestein).
//! - [`lenia`]: cache-tiled sparse-tap Lenia kernel, the spectral
//!   FFT kernel (single- and multi-kernel worlds), and the
//!   size-adaptive crossover between them.
//! - [`nca`]: depthwise-conv + per-cell-MLP neural-CA forward kernel,
//!   dimension-parametric over [`nca::Grid`] (2D torus, 1D ring).
//! - [`nca_grad`]: reverse-mode BPTT through the NCA cell (training),
//!   parametric over the same grid geometries.
//! - [`opt`]: Adam, gradient clipping and the lr schedule.
//! - [`simd`]: runtime AVX2 dispatch for the f32 hot loops.
//! - [`train`]: [`train::NativeTrainBackend`] — the native train/eval
//!   programs (growing, MNIST, 1D-ARC) behind the
//!   [`crate::backend::ProgramBackend`] contract.
//!
//! [`NativeBackend`] packs/unpacks at the tensor boundary ONCE per
//! rollout and parallelizes across batch elements with the scoped
//! worker pool, so `rollout(prog, state, T)` costs far less than `T`
//! boundary crossings.
//!
//! # SIMD dispatch contract
//!
//! The f32 hot loops — the Lenia sparse-tap convolution, the shared
//! Lenia growth/update stage, and the NCA perceive + MLP cell — carry
//! explicit AVX2 paths behind a single runtime switch,
//! [`simd::active`]: probed once per process
//! (`is_x86_feature_detected!("avx2")`), overridable with
//! `CAX_SIMD=off`, and logged through [`crate::obs`] logging the first
//! time a backend is built (`CAX_LOG=info` to see it). The contract
//! every SIMD path obeys:
//!
//! - **bit identity** — one vector lane computes one output cell in
//!   the exact scalar accumulation order (`mul` + `add` pairs, never
//!   FMA), transcendentals (`exp` in the Lenia growth) stay scalar per
//!   lane, and wrapped boundary cells run the unchanged scalar code.
//!   SIMD on/off therefore never changes a board, a NaN payload, a
//!   denormal, or a training gradient (`nca_grad` replays
//!   pre-activations scalar over SIMD forwards and stays exact).
//! - **always-compiled fallback** — the scalar kernels remain the
//!   source of truth (`step_scalar`, `step_frozen_scalar`,
//!   `update_stage_scalar`) and run on non-x86_64 targets, on CPUs
//!   without AVX2, under `CAX_SIMD=off`, and on boards too narrow for
//!   a full 8-lane interior block.
//!
//! `tests/native_simd_props.rs` holds the differential fuzz battery;
//! `benches/fig3_native.rs` / `fig3_lenia.rs` report SIMD-vs-scalar
//! rows.

pub mod activity;
pub mod bits;
pub mod eca;
pub mod fft;
pub mod hashlife;
pub mod lenia;
pub mod life;
pub mod nca;
pub mod nca_grad;
pub mod opt;
pub mod simd;
pub mod train;

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, ensure, Result};

/// Shared parser for the backend's env escape hatches (`CAX_SIMD`,
/// `CAX_SPARSE`): a feature is disabled iff the variable is set to
/// `off` (any case) or exactly `0`. One helper so the hatches can
/// never drift apart in what they accept — anything else (unset,
/// empty, `on`, `1`, stray whitespace) leaves the feature on.
pub fn env_disabled(name: &str) -> bool {
    matches!(std::env::var(name),
             Ok(v) if v.eq_ignore_ascii_case("off") || v == "0")
}

use self::activity::{ActivityMap, StepPath};
use crate::backend::workers::WorkerPool;
use crate::backend::{
    validate_board, validate_state, Backend, CaProgram, ProgramBackend,
    Resident, Value,
};
use crate::obs;
use crate::tensor::Tensor;

/// Wrapped (periodic-boundary) index `(i + plus - minus) mod n` without
/// going negative, for `minus <= i + n + plus` (the Lenia kernel sweeps
/// `minus` up to `2 * radius` with `radius <= n`). The single wrap rule
/// shared by every f32 grid kernel (`lenia`, `nca`, `nca_grad`) — the
/// `plus`/`minus` split keeps it in unsigned arithmetic on the hot paths.
#[inline(always)]
pub fn wrap_shift(i: usize, n: usize, plus: usize, minus: usize) -> usize {
    debug_assert!(i < n && minus <= i + n + plus);
    (i + n + plus - minus) % n
}

/// The wrapped 3-neighborhood `[i-1, i, i+1]` on an axis of length `n` —
/// the row/column triple the 3x3 perceive stencils sweep.
#[inline(always)]
pub fn wrap3(i: usize, n: usize) -> [usize; 3] {
    [wrap_shift(i, n, 0, 1), i, wrap_shift(i, n, 1, 0)]
}

/// Pure-Rust multi-threaded backend. Always available; the default
/// execution path of the hermetic build.
///
/// # Example
///
/// Run a rule-90 elementary CA for one step — no artifacts, no XLA:
///
/// ```
/// use cax::automata::WolframRule;
/// use cax::backend::{Backend, CaProgram, NativeBackend};
/// use cax::Tensor;
///
/// let backend = NativeBackend::with_threads(1);
/// let state = Tensor::new(
///     vec![1, 8],
///     vec![0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0],
/// ).unwrap();
/// let prog = CaProgram::Eca { rule: WolframRule::new(90) };
/// let next = backend.rollout(&prog, &state, 1).unwrap();
/// // Rule 90 XORs the neighbours: the single live cell splits in two.
/// assert_eq!(next.data(), &[0.0, 0.0, 1.0, 0.0, 1.0, 0.0, 0.0, 0.0]);
/// ```
#[derive(Clone, Debug, Default)]
pub struct NativeBackend {
    pool: WorkerPool,
}

impl NativeBackend {
    /// Backend sized to the machine.
    pub fn new() -> NativeBackend {
        // Resolve (and log) the SIMD + activity dispatch decisions
        // eagerly so they land at startup, not in the middle of the
        // first launch.
        simd::active();
        activity::enabled();
        NativeBackend { pool: WorkerPool::new() }
    }

    /// Backend with an explicit worker count (1 = sequential).
    pub fn with_threads(threads: usize) -> NativeBackend {
        simd::active();
        activity::enabled();
        NativeBackend { pool: WorkerPool::with_threads(threads) }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Which f32 kernel path this backend's launches take: `"avx2"` or
    /// `"scalar (...)"` with the reason (see [`simd::status`]).
    pub fn simd_status(&self) -> &'static str {
        simd::status()
    }

    /// Whether launches may take the sparse/HashLife step paths (see
    /// [`activity::status`]).
    pub fn activity_status(&self) -> &'static str {
        activity::status()
    }

    fn eca_rollout(&self, rule: &crate::automata::WolframRule,
                   state: &Tensor, steps: usize) -> Result<Tensor> {
        let _span = obs::span("kernel_eca");
        let (b, w) = (state.shape()[0], state.shape()[1]);
        let prog = CaProgram::Eca { rule: *rule };
        let path = activity::select_step_path(&prog, state.shape(), steps);
        activity::note_path(path);
        let nw = bits::words_for(w);
        let mut packed = vec![0u64; b * nw];
        for i in 0..b {
            bits::pack_row(&state.data()[i * w..(i + 1) * w],
                           &mut packed[i * nw..(i + 1) * nw]);
        }
        match path {
            StepPath::Dense => {
                self.pool.for_each_chunk(&mut packed, nw, |_, row| {
                    eca::rollout_row(rule, row, w, steps);
                });
            }
            StepPath::Sparse => {
                let (rec, skp) = (AtomicU64::new(0), AtomicU64::new(0));
                self.pool.for_each_chunk(&mut packed, nw, |_, row| {
                    let mut map = ActivityMap::new(0, 1, nw);
                    let (r, s) =
                        eca::rollout_row_sparse(rule, row, w, steps,
                                                &mut map);
                    rec.fetch_add(r, Ordering::Relaxed);
                    skp.fetch_add(s, Ordering::Relaxed);
                });
                activity::note_tiles(rec.into_inner(), skp.into_inner());
            }
            StepPath::HashLife => {
                self.pool.for_each_chunk(&mut packed, nw, |_, row| {
                    let mut hl = hashlife::EcaHash::new(
                        rule.number, hashlife::DEFAULT_NODE_CAP);
                    hl.advance(row, w, steps);
                });
            }
        }
        let mut out = vec![0.0f32; b * w];
        for i in 0..b {
            bits::unpack_row(&packed[i * nw..(i + 1) * nw],
                             &mut out[i * w..(i + 1) * w]);
        }
        Tensor::new(vec![b, w], out)
    }

    fn life_rollout(&self, state: &Tensor, steps: usize) -> Result<Tensor> {
        let _span = obs::span("kernel_life");
        let (b, h, w) =
            (state.shape()[0], state.shape()[1], state.shape()[2]);
        let path = activity::select_step_path(&CaProgram::Life,
                                              state.shape(), steps);
        activity::note_path(path);
        let wpr = bits::words_for(w);
        let words = h * wpr;
        let mut packed = vec![0u64; b * words];
        for i in 0..b {
            life::pack_board(&state.data()[i * h * w..(i + 1) * h * w], h, w,
                             &mut packed[i * words..(i + 1) * words]);
        }
        match path {
            StepPath::Dense => {
                self.pool.for_each_chunk(&mut packed, words, |_, grid| {
                    let mut kern = life::LifeKernel::new(h, w);
                    kern.rollout(grid, steps);
                });
            }
            StepPath::Sparse => {
                let (rec, skp) = (AtomicU64::new(0), AtomicU64::new(0));
                self.pool.for_each_chunk(&mut packed, words, |_, grid| {
                    let mut kern = life::LifeKernel::new(h, w);
                    let mut map = ActivityMap::new(0, h, wpr);
                    let (r, s) = kern.rollout_sparse(grid, steps, &mut map);
                    rec.fetch_add(r, Ordering::Relaxed);
                    skp.fetch_add(s, Ordering::Relaxed);
                });
                activity::note_tiles(rec.into_inner(), skp.into_inner());
            }
            StepPath::HashLife => {
                self.pool.for_each_chunk(&mut packed, words, |_, grid| {
                    let mut hl = hashlife::LifeHash::default();
                    hl.advance(grid, w, steps);
                });
            }
        }
        let mut out = vec![0.0f32; b * h * w];
        for i in 0..b {
            life::unpack_board(&packed[i * words..(i + 1) * words], h, w,
                               &mut out[i * h * w..(i + 1) * h * w]);
        }
        Tensor::new(vec![b, h, w], out)
    }

    /// Size-adaptive Lenia: sparse-tap (bit-exact with the oracle) below
    /// the [`lenia::select_path`] crossover, spectral FFT above it. The
    /// choice depends only on (radius, h, w), so results are
    /// deterministic for a given program + state shape.
    fn lenia_rollout(&self, params: crate::automata::lenia::LeniaParams,
                     state: &Tensor, steps: usize) -> Result<Tensor> {
        let (b, h, w) =
            (state.shape()[0], state.shape()[1], state.shape()[2]);
        let mut data = state.data().to_vec();
        let prog = CaProgram::Lenia { params };
        let path = activity::select_step_path(&prog, state.shape(), steps);
        activity::note_path(path);
        if path == StepPath::Sparse {
            let _span = obs::span("kernel_lenia_sparse");
            let kernel = lenia::LeniaKernel::new(params);
            let (tr, tc) = lenia::LeniaKernel::tile_dims(h, w);
            let (rec, skp) = (AtomicU64::new(0), AtomicU64::new(0));
            self.pool.for_each_chunk(&mut data, h * w, |_, board| {
                let mut scratch = vec![0.0f32; h * w];
                let mut map = ActivityMap::new(0, tr, tc);
                let (r, s) = kernel.rollout_sparse(board, &mut scratch, h,
                                                   w, steps, &mut map);
                rec.fetch_add(r, Ordering::Relaxed);
                skp.fetch_add(s, Ordering::Relaxed);
            });
            activity::note_tiles(rec.into_inner(), skp.into_inner());
            return Tensor::new(vec![b, h, w], data);
        }
        match lenia::select_path(params.radius, h, w) {
            lenia::LeniaPath::SparseTap => {
                let _span = obs::span("kernel_lenia_sparse");
                let kernel = lenia::LeniaKernel::new(params);
                self.pool.for_each_chunk(&mut data, h * w, |_, board| {
                    let mut scratch = vec![0.0f32; h * w];
                    kernel.rollout(board, &mut scratch, h, w, steps);
                });
            }
            lenia::LeniaPath::Fft => {
                let _span = obs::span("kernel_lenia_fft");
                let plan = lenia::LeniaFft::new(params, h, w)?;
                self.pool.for_each_chunk(&mut data, h * w, |_, board| {
                    plan.rollout(board, steps);
                });
            }
        }
        Tensor::new(vec![b, h, w], data)
    }

    /// Generalized multi-channel / multi-kernel Lenia on `[B, C, H, W]`
    /// states — always spectral (the whole point of the multi form is
    /// large/many kernels).
    fn lenia_world_rollout(&self, world: &crate::automata::lenia::LeniaWorld,
                           state: &Tensor, steps: usize) -> Result<Tensor> {
        let _span = obs::span("kernel_lenia_world");
        let shape = state.shape().to_vec();
        let (c, h, w) = (shape[1], shape[2], shape[3]);
        let plan = lenia::LeniaFft::for_world(world.clone(), h, w)?;
        let mut data = state.data().to_vec();
        self.pool.for_each_chunk(&mut data, c * h * w, |_, board| {
            plan.rollout(board, steps);
        });
        Tensor::new(shape, data)
    }

    /// Pull the mutable inner buffers (and their cross-call activity
    /// maps) of a uniform resident batch, refusing mixed
    /// representations — the shared preamble of
    /// [`step_resident`](Backend::step_resident).
    #[allow(clippy::type_complexity)]
    fn resident_bits<'a>(&self, prog: &CaProgram,
                         batch: &'a mut [&mut Resident])
                         -> Result<Vec<(&'a mut Vec<u64>,
                                        &'a mut Option<ActivityMap>)>> {
        let mut rows = Vec::with_capacity(batch.len());
        for r in batch.iter_mut() {
            match &mut **r {
                Resident::Bits { words, activity, .. } => {
                    rows.push((words, activity));
                }
                other => bail!(
                    "native step_resident({}): wants a bits resident, \
                     got {:?} (admit the state through this backend)",
                    prog.name(),
                    other.kind()
                ),
            }
        }
        Ok(rows)
    }

    #[allow(clippy::type_complexity)]
    fn resident_boards<'a>(&self, prog: &CaProgram,
                           batch: &'a mut [&mut Resident])
                           -> Result<Vec<(&'a mut Vec<f32>,
                                          &'a mut Option<ActivityMap>)>> {
        let mut boards = Vec::with_capacity(batch.len());
        for r in batch.iter_mut() {
            match &mut **r {
                Resident::Board { data, activity, .. } => {
                    boards.push((data, activity));
                }
                other => bail!(
                    "native step_resident({}): wants an f32 board \
                     resident, got {:?} (admit the state through this \
                     backend)",
                    prog.name(),
                    other.kind()
                ),
            }
        }
        Ok(boards)
    }

    fn nca_rollout(&self, model: &nca::NcaModel, state: &Tensor,
                   steps: usize) -> Result<Tensor> {
        let _span = obs::span("kernel_nca");
        let shape = state.shape();
        let (b, h, w, c) = (shape[0], shape[1], shape[2], shape[3]);
        // NCA's selector is just the on/off gate — no need to clone the
        // model into a CaProgram to ask it.
        let path = if activity::enabled() {
            StepPath::Sparse
        } else {
            StepPath::Dense
        };
        activity::note_path(path);
        let mut data = state.data().to_vec();
        if path == StepPath::Sparse {
            let (tr, tc) = nca::NcaModel::tile_dims(h, w);
            let (rec, skp) = (AtomicU64::new(0), AtomicU64::new(0));
            self.pool.for_each_chunk(&mut data, h * w * c, |_, board| {
                let mut scratch = vec![0.0f32; h * w * c];
                let mut map = ActivityMap::new(0, tr, tc);
                let (r, s) = model.rollout_sparse(board, &mut scratch, h,
                                                  w, steps, &mut map);
                rec.fetch_add(r, Ordering::Relaxed);
                skp.fetch_add(s, Ordering::Relaxed);
            });
            activity::note_tiles(rec.into_inner(), skp.into_inner());
        } else {
            self.pool.for_each_chunk(&mut data, h * w * c, |_, board| {
                let mut scratch = vec![0.0f32; h * w * c];
                model.rollout(board, &mut scratch, h, w, steps);
            });
        }
        Tensor::new(shape.to_vec(), data)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, _prog: &CaProgram) -> bool {
        true
    }

    fn rollout(&self, prog: &CaProgram, state: &Tensor, steps: usize)
        -> Result<Tensor> {
        validate_state(prog, state)?;
        match prog {
            CaProgram::Eca { rule } => self.eca_rollout(rule, state, steps),
            CaProgram::Life => self.life_rollout(state, steps),
            CaProgram::Lenia { params } => {
                self.lenia_rollout(*params, state, steps)
            }
            CaProgram::LeniaMulti(world) => {
                self.lenia_world_rollout(world, state, steps)
            }
            CaProgram::Nca(model) => self.nca_rollout(model, state, steps),
        }
    }

    /// Hand-rolled BPTT + Adam on the host: the cell geometry is inferred
    /// from the call's own tensors, hyperparameters are the
    /// [`train::NcaTrainSpec`] / [`train::ArcTrainSpec`] defaults.
    /// Construct a [`train::NativeTrainBackend`] directly to control
    /// them.
    fn train_step(&self, program: &str, inputs: &[Value])
        -> Result<Vec<Tensor>> {
        let tb = train::NativeTrainBackend::for_call(
            self.threads(), program, inputs)?;
        tb.execute(program, inputs)
    }

    /// Admit a board into the native representation: bit planes for the
    /// discrete CAs (ECA/Life — the f32 boundary is paid exactly once),
    /// flat kernel-layout f32 for the continuous/neural ones.
    fn admit(&self, prog: &CaProgram, board: &Tensor) -> Result<Resident> {
        validate_board(prog, board)?;
        let shape = board.shape().to_vec();
        Ok(match prog {
            CaProgram::Eca { .. } => {
                let mut words = vec![0u64; bits::words_for(shape[0])];
                bits::pack_row(board.data(), &mut words);
                Resident::Bits { words, shape, activity: None }
            }
            CaProgram::Life => {
                let (h, w) = (shape[0], shape[1]);
                let mut words = vec![0u64; h * bits::words_for(w)];
                life::pack_board(board.data(), h, w, &mut words);
                Resident::Bits { words, shape, activity: None }
            }
            CaProgram::Lenia { .. }
            | CaProgram::LeniaMulti(_)
            | CaProgram::Nca(_) => Resident::Board {
                data: board.data().to_vec(),
                shape,
                activity: None,
            },
        })
    }

    fn read_resident(&self, prog: &CaProgram, resident: &Resident)
        -> Result<Tensor> {
        match (prog, resident) {
            (CaProgram::Eca { .. }, Resident::Bits { words, shape, .. }) => {
                let mut out = vec![0.0f32; shape[0]];
                bits::unpack_row(words, &mut out);
                Tensor::new(shape.clone(), out)
            }
            (CaProgram::Life, Resident::Bits { words, shape, .. }) => {
                let (h, w) = (shape[0], shape[1]);
                let mut out = vec![0.0f32; h * w];
                life::unpack_board(words, h, w, &mut out);
                Tensor::new(shape.clone(), out)
            }
            (_, Resident::Board { data, shape, .. }) => {
                Tensor::new(shape.clone(), data.clone())
            }
            (_, Resident::Host(t)) => Ok(t.clone()),
            (p, r) => bail!(
                "native backend: program {:?} cannot read a {:?} resident",
                p.name(),
                r.kind()
            ),
        }
    }

    /// One batched in-place launch over the worker pool — the coalesced
    /// fast path of the serve layer. Runs the exact same kernels (and,
    /// for Lenia, the same [`lenia::select_path`] crossover) as
    /// [`rollout`](Backend::rollout), so each board's trajectory is
    /// bitwise identical to stepping it solo; it just never crosses the
    /// f32 boundary and never reallocates per call.
    fn step_resident(&self, prog: &CaProgram, batch: &mut [&mut Resident],
                     steps: usize) -> Result<()> {
        if batch.is_empty() || steps == 0 {
            return Ok(());
        }
        let shape = batch[0].shape().to_vec();
        ensure!(
            shape.len() + 1 == prog.state_rank(),
            "step_resident({}): board rank {} does not fit the program \
             (want {})",
            prog.name(),
            shape.len(),
            prog.state_rank() - 1
        );
        for r in batch.iter() {
            ensure!(
                r.shape() == shape,
                "step_resident({}): mixed shapes in one batch ({:?} vs \
                 {:?}) — group by shape class first",
                prog.name(),
                r.shape(),
                shape
            );
        }
        match prog {
            CaProgram::Eca { rule } => {
                let _span = obs::span("kernel_eca");
                let w = shape[0];
                let path = activity::select_step_path(prog, &shape, steps);
                activity::note_path(path);
                let mut rows = self.resident_bits(prog, batch)?;
                match path {
                    StepPath::Dense => {
                        self.pool.for_each_chunk(&mut rows, 1, |_, item| {
                            let (words, act) = &mut item[0];
                            **act = None;
                            eca::rollout_row(rule, words.as_mut_slice(), w,
                                             steps);
                        });
                    }
                    StepPath::Sparse => {
                        let key = activity::prog_key(prog);
                        let nw = bits::words_for(w);
                        let (rec, skp) =
                            (AtomicU64::new(0), AtomicU64::new(0));
                        self.pool.for_each_chunk(&mut rows, 1, |_, item| {
                            let (words, act) = &mut item[0];
                            let map =
                                activity::ensure_map(*act, key, 1, nw);
                            let (r, s) = eca::rollout_row_sparse(
                                rule, words.as_mut_slice(), w, steps, map);
                            rec.fetch_add(r, Ordering::Relaxed);
                            skp.fetch_add(s, Ordering::Relaxed);
                        });
                        activity::note_tiles(rec.into_inner(),
                                             skp.into_inner());
                    }
                    StepPath::HashLife => {
                        self.pool.for_each_chunk(&mut rows, 1, |_, item| {
                            let (words, act) = &mut item[0];
                            **act = None;
                            let mut hl = hashlife::EcaHash::new(
                                rule.number, hashlife::DEFAULT_NODE_CAP);
                            hl.advance(words.as_mut_slice(), w, steps);
                        });
                    }
                }
            }
            CaProgram::Life => {
                let _span = obs::span("kernel_life");
                let (h, w) = (shape[0], shape[1]);
                let path = activity::select_step_path(prog, &shape, steps);
                activity::note_path(path);
                let mut grids = self.resident_bits(prog, batch)?;
                match path {
                    StepPath::Dense => {
                        self.pool.for_each_chunk(&mut grids, 1, |_, item| {
                            let (words, act) = &mut item[0];
                            **act = None;
                            let mut kern = life::LifeKernel::new(h, w);
                            kern.rollout(words.as_mut_slice(), steps);
                        });
                    }
                    StepPath::Sparse => {
                        let key = activity::prog_key(prog);
                        let wpr = bits::words_for(w);
                        let (rec, skp) =
                            (AtomicU64::new(0), AtomicU64::new(0));
                        self.pool.for_each_chunk(&mut grids, 1, |_, item| {
                            let (words, act) = &mut item[0];
                            let map =
                                activity::ensure_map(*act, key, h, wpr);
                            let mut kern = life::LifeKernel::new(h, w);
                            let (r, s) = kern.rollout_sparse(
                                words.as_mut_slice(), steps, map);
                            rec.fetch_add(r, Ordering::Relaxed);
                            skp.fetch_add(s, Ordering::Relaxed);
                        });
                        activity::note_tiles(rec.into_inner(),
                                             skp.into_inner());
                    }
                    StepPath::HashLife => {
                        self.pool.for_each_chunk(&mut grids, 1, |_, item| {
                            let (words, act) = &mut item[0];
                            **act = None;
                            let mut hl = hashlife::LifeHash::default();
                            hl.advance(words.as_mut_slice(), w, steps);
                        });
                    }
                }
            }
            CaProgram::Lenia { params } => {
                let (h, w) = (shape[0], shape[1]);
                let path = activity::select_step_path(prog, &shape, steps);
                activity::note_path(path);
                let mut boards = self.resident_boards(prog, batch)?;
                if path == StepPath::Sparse {
                    let _span = obs::span("kernel_lenia_sparse");
                    let kernel = lenia::LeniaKernel::new(*params);
                    let key = activity::prog_key(prog);
                    let (tr, tc) = lenia::LeniaKernel::tile_dims(h, w);
                    let (rec, skp) = (AtomicU64::new(0), AtomicU64::new(0));
                    self.pool.for_each_chunk(&mut boards, 1, |_, item| {
                        let (data, act) = &mut item[0];
                        let map = activity::ensure_map(*act, key, tr, tc);
                        let mut scratch = vec![0.0f32; h * w];
                        let (r, s) = kernel.rollout_sparse(
                            data.as_mut_slice(), &mut scratch, h, w, steps,
                            map);
                        rec.fetch_add(r, Ordering::Relaxed);
                        skp.fetch_add(s, Ordering::Relaxed);
                    });
                    activity::note_tiles(rec.into_inner(),
                                         skp.into_inner());
                } else {
                    match lenia::select_path(params.radius, h, w) {
                        lenia::LeniaPath::SparseTap => {
                            let _span = obs::span("kernel_lenia_sparse");
                            let kernel = lenia::LeniaKernel::new(*params);
                            self.pool.for_each_chunk(&mut boards, 1,
                                                     |_, item| {
                                let (data, act) = &mut item[0];
                                **act = None;
                                let mut scratch = vec![0.0f32; h * w];
                                kernel.rollout(data.as_mut_slice(),
                                               &mut scratch, h, w, steps);
                            });
                        }
                        lenia::LeniaPath::Fft => {
                            let _span = obs::span("kernel_lenia_fft");
                            let plan = lenia::LeniaFft::new(*params, h, w)?;
                            self.pool.for_each_chunk(&mut boards, 1,
                                                     |_, item| {
                                let (data, act) = &mut item[0];
                                **act = None;
                                plan.rollout(data.as_mut_slice(), steps);
                            });
                        }
                    }
                }
            }
            CaProgram::LeniaMulti(world) => {
                let _span = obs::span("kernel_lenia_world");
                activity::note_path(StepPath::Dense);
                let (h, w) = (shape[1], shape[2]);
                let plan = lenia::LeniaFft::for_world(world.clone(), h, w)?;
                let mut boards = self.resident_boards(prog, batch)?;
                self.pool.for_each_chunk(&mut boards, 1, |_, item| {
                    let (data, act) = &mut item[0];
                    **act = None;
                    plan.rollout(data.as_mut_slice(), steps);
                });
            }
            CaProgram::Nca(model) => {
                let _span = obs::span("kernel_nca");
                let (h, w, c) = (shape[0], shape[1], shape[2]);
                let path = activity::select_step_path(prog, &shape, steps);
                activity::note_path(path);
                let mut boards = self.resident_boards(prog, batch)?;
                if path == StepPath::Sparse {
                    let key = activity::prog_key(prog);
                    let (tr, tc) = nca::NcaModel::tile_dims(h, w);
                    let (rec, skp) = (AtomicU64::new(0), AtomicU64::new(0));
                    self.pool.for_each_chunk(&mut boards, 1, |_, item| {
                        let (data, act) = &mut item[0];
                        let map = activity::ensure_map(*act, key, tr, tc);
                        let mut scratch = vec![0.0f32; h * w * c];
                        let (r, s) = model.rollout_sparse(
                            data.as_mut_slice(), &mut scratch, h, w, steps,
                            map);
                        rec.fetch_add(r, Ordering::Relaxed);
                        skp.fetch_add(s, Ordering::Relaxed);
                    });
                    activity::note_tiles(rec.into_inner(),
                                         skp.into_inner());
                } else {
                    self.pool.for_each_chunk(&mut boards, 1, |_, item| {
                        let (data, act) = &mut item[0];
                        **act = None;
                        let mut scratch = vec![0.0f32; h * w * c];
                        model.rollout(data.as_mut_slice(), &mut scratch, h,
                                      w, steps);
                    });
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::WolframRule;
    use crate::util::rng::Rng;

    #[test]
    fn zero_steps_is_identity_and_step_is_rollout_1() {
        let backend = NativeBackend::with_threads(2);
        let mut rng = Rng::new(8);
        let state =
            Tensor::new(vec![3, 70], rng.binary_vec(3 * 70, 0.5)).unwrap();
        let prog = CaProgram::Eca { rule: WolframRule::new(110) };
        let same = backend.rollout(&prog, &state, 0).unwrap();
        assert!(same.bit_eq(&state));
        let one = backend.step(&prog, &state).unwrap();
        let roll = backend.rollout(&prog, &state, 1).unwrap();
        assert!(one.bit_eq(&roll));
    }

    #[test]
    fn thread_counts_do_not_change_results() {
        let mut rng = Rng::new(12);
        let state =
            Tensor::new(vec![5, 9, 33], rng.binary_vec(5 * 9 * 33, 0.4))
                .unwrap();
        let a = NativeBackend::with_threads(1)
            .rollout(&CaProgram::Life, &state, 7)
            .unwrap();
        let b = NativeBackend::with_threads(8)
            .rollout(&CaProgram::Life, &state, 7)
            .unwrap();
        assert!(a.bit_eq(&b));
    }

    #[test]
    fn train_step_rejects_unknown_programs() {
        let backend = NativeBackend::new();
        let err = backend.train_step("frobnicate_train_step", &[])
            .unwrap_err();
        assert!(format!("{err:#}").contains("growing_train_step"),
                "error should list the native train programs: {err:#}");
    }

    #[test]
    fn wrap_helpers_cover_edges() {
        // Decrement wraps 0 -> n-1, increment wraps n-1 -> 0.
        assert_eq!(wrap3(0, 7), [6, 0, 1]);
        assert_eq!(wrap3(6, 7), [5, 6, 0]);
        assert_eq!(wrap3(3, 7), [2, 3, 4]);
        // Single-cell axis: every neighbor is the cell itself.
        assert_eq!(wrap3(0, 1), [0, 0, 0]);
        // The Lenia form (y + h + r - ky) % h, incl. ky up to 2r > h.
        assert_eq!(wrap_shift(0, 8, 5, 0), 5);
        assert_eq!(wrap_shift(0, 8, 5, 10), 3); // 0 + 8 + 5 - 10 = 3
        assert_eq!(wrap_shift(7, 8, 0, 1), 6);
        assert_eq!(wrap_shift(7, 8, 1, 0), 0);
        // Identity: no shift.
        for i in 0..5 {
            assert_eq!(wrap_shift(i, 5, 0, 0), i);
            assert_eq!(wrap_shift(i, 5, 2, 2), i);
        }
    }

    #[test]
    fn rejects_wrong_rank() {
        let backend = NativeBackend::new();
        let state = Tensor::zeros(&[4, 4]);
        assert!(backend.rollout(&CaProgram::Life, &state, 1).is_err());
    }

    #[test]
    fn resident_roundtrip_is_exact() {
        let backend = NativeBackend::with_threads(2);
        let mut rng = Rng::new(0x51D);
        // Discrete programs pack to bits; continuous stay f32 — all read
        // back bitwise.
        let eca_prog = CaProgram::Eca { rule: WolframRule::new(30) };
        let row = Tensor::new(vec![70], rng.binary_vec(70, 0.5)).unwrap();
        let r = backend.admit(&eca_prog, &row).unwrap();
        assert_eq!(r.kind(), "bits");
        assert!(backend.read_resident(&eca_prog, &r).unwrap().bit_eq(&row));

        let lenia_prog = CaProgram::Lenia {
            params: crate::automata::lenia::LeniaParams::default(),
        };
        let board =
            Tensor::new(vec![16, 16], rng.vec_f32(256)).unwrap();
        let r = backend.admit(&lenia_prog, &board).unwrap();
        assert_eq!(r.kind(), "board");
        assert!(backend
            .read_resident(&lenia_prog, &r)
            .unwrap()
            .bit_eq(&board));
    }

    #[test]
    fn step_resident_matches_solo_rollout() {
        let backend = NativeBackend::with_threads(2);
        let mut rng = Rng::new(0xBA7C);
        let prog = CaProgram::Life;
        let boards: Vec<Tensor> = (0..5)
            .map(|_| {
                Tensor::new(vec![9, 33], rng.binary_vec(9 * 33, 0.4))
                    .unwrap()
            })
            .collect();
        let mut residents: Vec<Resident> = boards
            .iter()
            .map(|b| backend.admit(&prog, b).unwrap())
            .collect();
        // Two resident ticks of 3 steps == one solo rollout of 6.
        for _ in 0..2 {
            let mut refs: Vec<&mut Resident> =
                residents.iter_mut().collect();
            backend.step_resident(&prog, &mut refs, 3).unwrap();
        }
        for (b, r) in boards.iter().zip(&residents) {
            let solo = backend
                .rollout(&prog, &Tensor::stack(&[b.clone()]).unwrap(), 6)
                .unwrap()
                .index_axis0(0);
            assert!(backend
                .read_resident(&prog, r)
                .unwrap()
                .bit_eq(&solo));
        }
    }

    #[test]
    fn step_resident_rejects_mixed_batches() {
        let backend = NativeBackend::with_threads(1);
        let prog = CaProgram::Life;
        let mut a = backend.admit(&prog, &Tensor::zeros(&[8, 8])).unwrap();
        let mut b = backend.admit(&prog, &Tensor::zeros(&[8, 16])).unwrap();
        let err = backend
            .step_resident(&prog, &mut [&mut a, &mut b], 1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("mixed shapes"));
        // Wrong representation for the program is refused too.
        let lenia = CaProgram::Lenia {
            params: crate::automata::lenia::LeniaParams::default(),
        };
        let mut c = backend
            .admit(&lenia, &Tensor::zeros(&[32, 32]))
            .unwrap();
        let err = backend
            .step_resident(&CaProgram::Life, &mut [&mut c], 1)
            .unwrap_err();
        assert!(format!("{err:#}").contains("bits"),
                "wanted a repr complaint, got {err:#}");
    }

    /// Pins the escape-hatch grammar shared by `CAX_SIMD` and
    /// `CAX_SPARSE`: `off` in any case or exactly `0` disables;
    /// everything else leaves the feature on. One unique variable per
    /// assertion — env vars are process-global and other tests run
    /// concurrently.
    #[test]
    fn env_disabled_accepts_one_token_set() {
        let disabled = [("off", "A"), ("OFF", "B"), ("Off", "C"),
                        ("0", "D")];
        for (value, tag) in disabled {
            let name = format!("CAX_TEST_ENV_DISABLED_{tag}");
            std::env::set_var(&name, value);
            assert!(env_disabled(&name), "{value:?} should disable");
            std::env::remove_var(&name);
        }
        let enabled = [("", "E"), ("on", "F"), ("1", "G"), ("no", "H"),
                       (" off ", "I"), ("false", "J")];
        for (value, tag) in enabled {
            let name = format!("CAX_TEST_ENV_DISABLED_{tag}");
            std::env::set_var(&name, value);
            assert!(!env_disabled(&name),
                    "{value:?} should leave the feature on");
            std::env::remove_var(&name);
        }
        assert!(!env_disabled("CAX_TEST_ENV_DISABLED_UNSET"));
    }
}
