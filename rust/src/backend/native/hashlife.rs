//! HashLife: memoizing tree-compressed stepping for Life and ECA.
//!
//! The classic Gosper algorithm — states are hash-consed quadtrees
//! (binary trees in 1D) so identical regions share one node, and the
//! "advance the centre of this node 2^j steps" function is memoized on
//! the canonical node id. On structured boards (guns, oscillators,
//! large dead regions) whole subtrees repeat, every repeated macro-cell
//! is a cache hit, and superspeed power-of-two steps come almost free.
//!
//! Two departures from textbook HashLife keep it a drop-in for the
//! dense kernels here:
//!
//! - **Torus wrap.** The SWAR kernels are periodic; classic HashLife is
//!   infinite-plane. A board `T` of side `S = 2^k` is advanced by
//!   `2^j <= S/2` steps as the centre of the 2x2 tiling
//!   `[[T,T],[T,T]]` — the periodic tiling evolves exactly like the
//!   torus, and the centre's dependency cone never leaves the tiling.
//!   The result is the torus shifted by `(S/2, S/2)`, un-shifted by a
//!   diagonal quadrant swap. Arbitrary step counts are walked as a sum
//!   of powers of two.
//! - **Bounded memory.** The interner + memo table are wiped whenever
//!   the node arena passes `node_cap`: the current root is serialized
//!   back to a packed grid and re-interned from scratch. Chaotic soups
//!   (where memoization cannot win) therefore plateau instead of
//!   growing without limit — `native_hashlife_props` pins this.
//!
//! Node ids below `2^16` *are* the leaf bitmap (a 4x4 `u16` in 2D, 16
//! cells in 1D), so leaves need no arena slots and no interning.
//! Results are bit-identical to the SWAR kernels on every board — the
//! differential battery proves it over step counts 1..=257.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use super::bits;

/// Ids below this are leaves; the id *is* the 16-bit cell bitmap.
const LEAF_BASE: u32 = 1 << 16;

/// Default arena bound: ~1M nodes (tens of MB with tables) before the
/// wipe-and-rebuild GC kicks in.
pub const DEFAULT_NODE_CAP: usize = 1 << 20;

/// FNV-ish 64-bit hasher for the small fixed-size keys here — the
/// SipHash default costs more than the table lookups it protects.
#[derive(Default)]
struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn mix(&mut self, v: u64) {
        self.hash = (self.hash.rotate_left(5) ^ v)
            .wrapping_mul(0x517c_c1b7_2722_0a95);
    }
}

impl Hasher for FxHasher {
    fn finish(&self) -> u64 {
        self.hash
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.mix(b as u64);
        }
    }
    fn write_u8(&mut self, n: u8) {
        self.mix(n as u64);
    }
    fn write_u32(&mut self, n: u32) {
        self.mix(n as u64);
    }
    fn write_u64(&mut self, n: u64) {
        self.mix(n);
    }
    fn write_usize(&mut self, n: usize) {
        self.mix(n as u64);
    }
}

type FxMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

// ================================================================ Life

/// Quadtree node: children in `[nw, ne, sw, se]` order. A node of
/// level `L` covers a `2^L x 2^L` square; leaves are level 2.
#[derive(Clone, Debug)]
struct Node {
    kids: [u32; 4],
    level: u8,
}

/// Memoizing HashLife engine for Conway's Game of Life on a square
/// power-of-two torus. Reusable across calls; keeps its caches warm.
#[derive(Debug)]
pub struct LifeHash {
    nodes: Vec<Node>,
    intern: FxMap<[u32; 4], u32>,
    memo: FxMap<(u32, u8), u32>,
    node_cap: usize,
    hits: u64,
}

impl Default for LifeHash {
    fn default() -> Self {
        LifeHash::new(DEFAULT_NODE_CAP)
    }
}

impl LifeHash {
    /// An engine whose arena is wiped and rebuilt past `node_cap`
    /// interned nodes.
    pub fn new(node_cap: usize) -> LifeHash {
        LifeHash {
            nodes: Vec::new(),
            intern: FxMap::default(),
            memo: FxMap::default(),
            node_cap: node_cap.max(64),
            hits: 0,
        }
    }

    /// Interned (non-leaf) nodes currently alive.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Memo-table hits since construction.
    pub fn memo_hits(&self) -> u64 {
        self.hits
    }

    /// Advance a packed Life board (`size` rows of `words_for(size)`
    /// u64 words, torus) by `steps`. Requires `size` to be a power of
    /// two, at least 4. Bit-identical to `LifeKernel::rollout`.
    pub fn advance(&mut self, grid: &mut [u64], size: usize, steps: usize) {
        assert!(size >= 4 && size.is_power_of_two(),
                "hashlife needs a power-of-two board side >= 4, got {size}");
        let wpr = bits::words_for(size);
        assert_eq!(grid.len(), size * wpr, "grid length mismatch");
        if steps == 0 {
            return;
        }
        let k = size.trailing_zeros() as u8;
        let mut root = self.build(grid, size);
        let mut remaining = steps;
        while remaining > 0 {
            // Largest power-of-two chunk the torus trick allows.
            let jmax = u32::from(k) - 1;
            let j = (usize::BITS - 1 - remaining.leading_zeros()).min(jmax);
            let wrapped = self.join([root, root, root, root]);
            let shifted = self.step(wrapped, j as u8);
            root = self.unshift(shifted);
            remaining -= 1usize << j;
            if self.nodes.len() >= self.node_cap && remaining > 0 {
                root = self.gc(root, grid, size);
            }
        }
        self.expand(root, grid, size);
        if self.nodes.len() >= self.node_cap {
            self.wipe();
        }
    }

    // ---------------------------------------------------- tree algebra

    fn level_of(&self, id: u32) -> u8 {
        if id < LEAF_BASE {
            2
        } else {
            self.nodes[(id - LEAF_BASE) as usize].level
        }
    }

    fn kids(&self, id: u32) -> [u32; 4] {
        debug_assert!(id >= LEAF_BASE, "leaf has no kids");
        self.nodes[(id - LEAF_BASE) as usize].kids
    }

    fn join(&mut self, kids: [u32; 4]) -> u32 {
        if let Some(&id) = self.intern.get(&kids) {
            return id;
        }
        let level = self.level_of(kids[0]) + 1;
        debug_assert!(kids.iter().all(|&c| self.level_of(c) + 1 == level));
        assert!(self.nodes.len() < (u32::MAX - LEAF_BASE) as usize,
                "hashlife arena overflow");
        let id = LEAF_BASE + self.nodes.len() as u32;
        self.nodes.push(Node { kids, level });
        self.intern.insert(kids, id);
        id
    }

    /// Horizontal middle of two side-by-side same-level nodes.
    fn hmid(&mut self, a: u32, b: u32) -> u32 {
        if a < LEAF_BASE {
            leaf_hmid(a as u16, b as u16) as u32
        } else {
            let (ka, kb) = (self.kids(a), self.kids(b));
            self.join([ka[1], kb[0], ka[3], kb[2]])
        }
    }

    /// Vertical middle of two stacked same-level nodes.
    fn vmid(&mut self, t: u32, b: u32) -> u32 {
        if t < LEAF_BASE {
            leaf_vmid(t as u16, b as u16) as u32
        } else {
            let (kt, kb) = (self.kids(t), self.kids(b));
            self.join([kt[2], kt[3], kb[0], kb[1]])
        }
    }

    /// Centre sub-node (one level down) of a level >= 3 node.
    fn centre(&mut self, id: u32) -> u32 {
        let k = self.kids(id);
        if self.level_of(id) == 3 {
            leaf_centre(k[0] as u16, k[1] as u16, k[2] as u16, k[3] as u16)
                as u32
        } else {
            let (nw, ne) = (self.kids(k[0]), self.kids(k[1]));
            let (sw, se) = (self.kids(k[2]), self.kids(k[3]));
            self.join([nw[3], ne[2], sw[1], se[0]])
        }
    }

    /// THE HashLife function: centre of `id` (level `L`) advanced
    /// `2^j` steps, `j <= L-2`; result has level `L-1`. Memoized on the
    /// canonical id, which is where all the speed comes from.
    fn step(&mut self, id: u32, j: u8) -> u32 {
        if let Some(&r) = self.memo.get(&(id, j)) {
            self.hits += 1;
            return r;
        }
        let level = self.level_of(id);
        debug_assert!(level >= 3 && j <= level - 2);
        let result = if level == 3 {
            let mut b = self.bits8(id);
            for _ in 0..1u32 << j {
                b = life8(b);
            }
            centre8(b) as u32
        } else {
            let k = self.kids(id);
            // Nine overlapping pseudo-children, one level down.
            let n = [
                k[0],
                self.hmid(k[0], k[1]),
                k[1],
                self.vmid(k[0], k[2]),
                self.centre(id),
                self.vmid(k[1], k[3]),
                k[2],
                self.hmid(k[2], k[3]),
                k[3],
            ];
            let full = j == level - 2;
            let j1 = if full { level - 3 } else { j };
            let mut t = [0u32; 9];
            for (ti, &ni) in t.iter_mut().zip(n.iter()) {
                *ti = self.step(ni, j1);
            }
            let q = [
                self.join([t[0], t[1], t[3], t[4]]),
                self.join([t[1], t[2], t[4], t[5]]),
                self.join([t[3], t[4], t[6], t[7]]),
                self.join([t[4], t[5], t[7], t[8]]),
            ];
            let mut r = [0u32; 4];
            for (ri, &qi) in r.iter_mut().zip(q.iter()) {
                *ri = if full {
                    // Second half of the 2^(L-2) advance.
                    self.step(qi, level - 3)
                } else {
                    // Already advanced far enough: just re-centre.
                    self.centre(qi)
                };
            }
            self.join(r)
        };
        self.memo.insert((id, j), result);
        result
    }

    /// Undo the `(S/2, S/2)` torus shift: swap quadrants diagonally.
    fn unshift(&mut self, id: u32) -> u32 {
        if id < LEAF_BASE {
            leaf_swap(id as u16) as u32
        } else {
            let k = self.kids(id);
            self.join([k[3], k[2], k[1], k[0]])
        }
    }

    /// 8x8 bitmap (bit `y*8+x`) of a level-3 node.
    fn bits8(&self, id: u32) -> u64 {
        let k = self.kids(id);
        let mut b = 0u64;
        for dy in 0..4 {
            let nw = (k[0] >> (4 * dy)) & 0xF;
            let ne = (k[1] >> (4 * dy)) & 0xF;
            let sw = (k[2] >> (4 * dy)) & 0xF;
            let se = (k[3] >> (4 * dy)) & 0xF;
            b |= ((nw as u64) | ((ne as u64) << 4)) << (8 * dy);
            b |= ((sw as u64) | ((se as u64) << 4)) << (8 * (dy + 4));
        }
        b
    }

    // ------------------------------------------------- grid conversion

    fn build(&mut self, grid: &[u64], size: usize) -> u32 {
        let wpr = bits::words_for(size);
        self.build_rec(grid, wpr, 0, 0, size)
    }

    fn build_rec(&mut self, grid: &[u64], wpr: usize, y0: usize,
                 x0: usize, sz: usize) -> u32 {
        if sz == 4 {
            let mut leaf = 0u16;
            for dy in 0..4 {
                let nib = (grid[(y0 + dy) * wpr + x0 / 64] >> (x0 % 64))
                    & 0xF;
                leaf |= (nib as u16) << (4 * dy);
            }
            leaf as u32
        } else {
            let h = sz / 2;
            let nw = self.build_rec(grid, wpr, y0, x0, h);
            let ne = self.build_rec(grid, wpr, y0, x0 + h, h);
            let sw = self.build_rec(grid, wpr, y0 + h, x0, h);
            let se = self.build_rec(grid, wpr, y0 + h, x0 + h, h);
            self.join([nw, ne, sw, se])
        }
    }

    fn expand(&self, root: u32, grid: &mut [u64], size: usize) {
        let wpr = bits::words_for(size);
        grid.fill(0);
        self.expand_rec(root, grid, wpr, 0, 0, size);
    }

    fn expand_rec(&self, id: u32, grid: &mut [u64], wpr: usize,
                  y0: usize, x0: usize, sz: usize) {
        if sz == 4 {
            for dy in 0..4 {
                let nib = ((id >> (4 * dy)) & 0xF) as u64;
                grid[(y0 + dy) * wpr + x0 / 64] |= nib << (x0 % 64);
            }
        } else {
            let h = sz / 2;
            let k = self.kids(id);
            self.expand_rec(k[0], grid, wpr, y0, x0, h);
            self.expand_rec(k[1], grid, wpr, y0, x0 + h, h);
            self.expand_rec(k[2], grid, wpr, y0 + h, x0, h);
            self.expand_rec(k[3], grid, wpr, y0 + h, x0 + h, h);
        }
    }

    fn wipe(&mut self) {
        self.nodes.clear();
        self.intern.clear();
        self.memo.clear();
    }

    /// Serialize `root`, wipe every table, re-intern from the grid.
    /// `grid` is the caller's buffer, used as scratch — it is rewritten
    /// by the final `expand` anyway.
    fn gc(&mut self, root: u32, grid: &mut [u64], size: usize) -> u32 {
        self.expand(root, grid, size);
        self.wipe();
        self.build(grid, size)
    }
}

// 4x4 leaf bitmaps: bit `y*4+x`, row-major, LSB first.

/// Columns 2..6 of the 4x8 strip `[a | b]`.
fn leaf_hmid(a: u16, b: u16) -> u16 {
    let mut out = 0u16;
    for y in 0..4 {
        let ar = (a >> (4 * y)) & 0xF;
        let br = (b >> (4 * y)) & 0xF;
        out |= (((ar >> 2) | (br << 2)) & 0xF) << (4 * y);
    }
    out
}

/// Rows 2..6 of the 8x4 strip `[t / b]`.
fn leaf_vmid(t: u16, b: u16) -> u16 {
    (t >> 8) | (b << 8)
}

/// Centre 4x4 of the 8x8 square assembled from four leaves.
fn leaf_centre(nw: u16, ne: u16, sw: u16, se: u16) -> u16 {
    leaf_vmid(leaf_hmid(nw, ne), leaf_hmid(sw, se))
}

/// Torus-shift a leaf by (2, 2): swap quadrants diagonally.
fn leaf_swap(v: u16) -> u16 {
    let mut out = 0u16;
    for y in 0..4 {
        let row = (v >> (4 * ((y + 2) % 4))) & 0xF;
        out |= (((row >> 2) | (row << 2)) & 0xF) << (4 * y);
    }
    out
}

/// One Life step of an 8x8 bitmap with dead cells outside — only the
/// shrinking centre cone is trusted by callers.
fn life8(b: u64) -> u64 {
    let mut out = 0u64;
    for y in 0..8i32 {
        for x in 0..8i32 {
            let mut n = 0;
            for dy in -1..=1i32 {
                for dx in -1..=1i32 {
                    if dy == 0 && dx == 0 {
                        continue;
                    }
                    let (yy, xx) = (y + dy, x + dx);
                    if (0..8).contains(&yy) && (0..8).contains(&xx) {
                        n += (b >> (yy * 8 + xx)) & 1;
                    }
                }
            }
            let alive = (b >> (y * 8 + x)) & 1 == 1;
            if n == 3 || (n == 2 && alive) {
                out |= 1 << (y * 8 + x);
            }
        }
    }
    out
}

/// Centre 4x4 of an 8x8 bitmap.
fn centre8(b: u64) -> u16 {
    let mut out = 0u16;
    for dy in 0..4 {
        out |= (((b >> ((dy + 2) * 8 + 2)) & 0xF) as u16) << (4 * dy);
    }
    out
}

// ================================================================= ECA

/// Binary-tree node for the 1D engine: `[left, right]`. A level-`L`
/// node covers `2^L` cells; leaves are level 4 (16 cells in the id).
#[derive(Clone, Debug)]
struct Node1 {
    kids: [u32; 2],
    level: u8,
}

/// The 1D HashLife analogue for elementary CAs on a power-of-two ring.
#[derive(Debug)]
pub struct EcaHash {
    rule: u8,
    nodes: Vec<Node1>,
    intern: FxMap<[u32; 2], u32>,
    memo: FxMap<(u32, u8), u32>,
    node_cap: usize,
    hits: u64,
}

impl EcaHash {
    pub fn new(rule: u8, node_cap: usize) -> EcaHash {
        EcaHash {
            rule,
            nodes: Vec::new(),
            intern: FxMap::default(),
            memo: FxMap::default(),
            node_cap: node_cap.max(64),
            hits: 0,
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn memo_hits(&self) -> u64 {
        self.hits
    }

    /// Advance a packed ECA row (width `w`, torus) by `steps`.
    /// Requires `w` to be a power of two, at least 16. Bit-identical to
    /// `eca::rollout_row`.
    pub fn advance(&mut self, row: &mut [u64], w: usize, steps: usize) {
        assert!(w >= 16 && w.is_power_of_two(),
                "1D hashlife needs a power-of-two width >= 16, got {w}");
        assert_eq!(row.len(), bits::words_for(w), "row length mismatch");
        if steps == 0 {
            return;
        }
        let m = w.trailing_zeros() as u8;
        let mut root = self.build(row, w);
        let mut remaining = steps;
        while remaining > 0 {
            let jmax = u32::from(m) - 1;
            let j = (usize::BITS - 1 - remaining.leading_zeros()).min(jmax);
            let wrapped = self.join([root, root]);
            let shifted = self.step(wrapped, j as u8);
            root = self.unshift(shifted);
            remaining -= 1usize << j;
            if self.nodes.len() >= self.node_cap && remaining > 0 {
                root = self.gc(root, row, w);
            }
        }
        self.expand(root, row, w);
        if self.nodes.len() >= self.node_cap {
            self.wipe();
        }
    }

    fn level_of(&self, id: u32) -> u8 {
        if id < LEAF_BASE {
            4
        } else {
            self.nodes[(id - LEAF_BASE) as usize].level
        }
    }

    fn kids(&self, id: u32) -> [u32; 2] {
        self.nodes[(id - LEAF_BASE) as usize].kids
    }

    fn join(&mut self, kids: [u32; 2]) -> u32 {
        if let Some(&id) = self.intern.get(&kids) {
            return id;
        }
        let level = self.level_of(kids[0]) + 1;
        debug_assert_eq!(self.level_of(kids[1]) + 1, level);
        assert!(self.nodes.len() < (u32::MAX - LEAF_BASE) as usize,
                "hashlife arena overflow");
        let id = LEAF_BASE + self.nodes.len() as u32;
        self.nodes.push(Node1 { kids, level });
        self.intern.insert(kids, id);
        id
    }

    /// Middle half of two adjacent same-level nodes.
    fn mid(&mut self, l: u32, r: u32) -> u32 {
        if l < LEAF_BASE {
            ((l >> 8) | (r << 8)) as u16 as u32
        } else {
            let (kl, kr) = (self.kids(l), self.kids(r));
            self.join([kl[1], kr[0]])
        }
    }

    fn centre(&mut self, id: u32) -> u32 {
        let k = self.kids(id);
        self.mid(k[0], k[1])
    }

    /// Centre half of `id` (level `L`) advanced `2^j` steps,
    /// `j <= L-2`; result level `L-1`. Memoized.
    fn step(&mut self, id: u32, j: u8) -> u32 {
        if let Some(&r) = self.memo.get(&(id, j)) {
            self.hits += 1;
            return r;
        }
        let level = self.level_of(id);
        debug_assert!(level >= 5 && j <= level - 2);
        let result = if level == 5 {
            let k = self.kids(id);
            let mut x = k[0] | (k[1] << 16);
            for _ in 0..1u32 << j {
                x = step32(self.rule, x);
            }
            (x >> 8) as u16 as u32
        } else {
            let k = self.kids(id);
            let m = self.mid(k[0], k[1]);
            let full = j == level - 2;
            let j1 = if full { level - 3 } else { j };
            let t0 = self.step(k[0], j1);
            let t1 = self.step(m, j1);
            let t2 = self.step(k[1], j1);
            let ql = self.join([t0, t1]);
            let qr = self.join([t1, t2]);
            let (rl, rr) = if full {
                (self.step(ql, level - 3), self.step(qr, level - 3))
            } else {
                (self.centre(ql), self.centre(qr))
            };
            self.join([rl, rr])
        };
        self.memo.insert((id, j), result);
        result
    }

    /// Undo the `w/2` torus shift: swap halves.
    fn unshift(&mut self, id: u32) -> u32 {
        if id < LEAF_BASE {
            let v = id as u16;
            ((v >> 8) | (v << 8)) as u32
        } else {
            let k = self.kids(id);
            self.join([k[1], k[0]])
        }
    }

    fn build(&mut self, row: &[u64], w: usize) -> u32 {
        self.build_rec(row, 0, w)
    }

    fn build_rec(&mut self, row: &[u64], p0: usize, sz: usize) -> u32 {
        if sz == 16 {
            ((row[p0 / 64] >> (p0 % 64)) & 0xFFFF) as u32
        } else {
            let h = sz / 2;
            let l = self.build_rec(row, p0, h);
            let r = self.build_rec(row, p0 + h, h);
            self.join([l, r])
        }
    }

    fn expand(&self, root: u32, row: &mut [u64], w: usize) {
        row.fill(0);
        self.expand_rec(root, row, 0, w);
    }

    fn expand_rec(&self, id: u32, row: &mut [u64], p0: usize, sz: usize) {
        if sz == 16 {
            row[p0 / 64] |= ((id & 0xFFFF) as u64) << (p0 % 64);
        } else {
            let h = sz / 2;
            let k = self.kids(id);
            self.expand_rec(k[0], row, p0, h);
            self.expand_rec(k[1], row, p0 + h, h);
        }
    }

    fn wipe(&mut self) {
        self.nodes.clear();
        self.intern.clear();
        self.memo.clear();
    }

    fn gc(&mut self, root: u32, row: &mut [u64], w: usize) -> u32 {
        self.expand(root, row, w);
        self.wipe();
        self.build(row, w)
    }
}

/// One ECA step of 32 cells with dead cells outside; callers trust only
/// the shrinking centre cone.
fn step32(rule: u8, x: u32) -> u32 {
    let mut out = 0u32;
    for i in 0..32u32 {
        let l = if i == 0 { 0 } else { (x >> (i - 1)) & 1 };
        let c = (x >> i) & 1;
        let r = if i == 31 { 0 } else { (x >> (i + 1)) & 1 };
        let p = (l << 2) | (c << 1) | r;
        out |= ((u32::from(rule) >> p) & 1) << i;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaf_swap_is_an_involution() {
        for v in [0u16, 0x8421, 0xFFFF, 0x1234, 0x0F0F] {
            assert_eq!(leaf_swap(leaf_swap(v)), v);
        }
        // Bit (0,0) moves to (2,2) = bit 10.
        assert_eq!(leaf_swap(1), 1 << 10);
    }

    #[test]
    fn life_grid_roundtrips_through_the_tree() {
        let size = 16;
        let wpr = bits::words_for(size);
        let mut grid = vec![0u64; size * wpr];
        for (i, word) in grid.iter_mut().enumerate() {
            *word = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        }
        for row in grid.chunks_mut(wpr) {
            bits::mask_tail(row, size);
        }
        let orig = grid.clone();
        let mut hl = LifeHash::new(1 << 12);
        let root = hl.build(&grid, size);
        grid.fill(0);
        hl.expand(root, &mut grid, size);
        assert_eq!(grid, orig);
    }

    #[test]
    fn eca_row_roundtrips_through_the_tree() {
        let w = 128;
        let mut row = vec![0xDEAD_BEEF_CAFE_F00Du64, 0x0123_4567_89AB_CDEF];
        let orig = row.clone();
        let mut hl = EcaHash::new(30, 1 << 12);
        let root = hl.build(&row, w);
        row.fill(0);
        hl.expand(root, &mut row, w);
        assert_eq!(row, orig);
    }

    #[test]
    fn blinker_oscillates_with_period_two() {
        // A horizontal blinker at rows 3, cols 2..5 of an 8x8 torus.
        let size = 8;
        let mut grid = vec![0u64; size];
        grid[3] = 0b0011_1000;
        let orig = grid.clone();
        let mut hl = LifeHash::default();
        hl.advance(&mut grid, size, 1);
        let mut vertical = vec![0u64; size];
        vertical[2] = 0b0001_0000;
        vertical[3] = 0b0001_0000;
        vertical[4] = 0b0001_0000;
        assert_eq!(grid, vertical, "after one step");
        hl.advance(&mut grid, size, 1);
        assert_eq!(grid, orig, "after two steps");
        hl.advance(&mut grid, size, 2);
        assert_eq!(grid, orig, "one macro-step of two");
    }
}
