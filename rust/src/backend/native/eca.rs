//! Bit-packed elementary-CA kernel (SWAR, 64 cells per word).
//!
//! The rule table is applied as boolean algebra over three whole-row
//! bitboards (left-neighbour, centre, right-neighbour): for every
//! pattern `p = 4l + 2c + r` with rule bit set, OR in the AND of the
//! three (possibly complemented) boards. At most 8 AND3/OR terms per
//! word — ~0.5 ops per cell versus the naive simulator's table lookup,
//! index arithmetic and bounds checks per cell. Bit-exact with
//! [`crate::automata::EcaSim`] by construction (same encoding:
//! `table[4l + 2c + r]`, periodic boundary).

use crate::automata::WolframRule;
use crate::backend::native::activity::ActivityMap;
use crate::backend::native::bits;

/// The rule applied to one word — the single source of truth both the
/// dense and the sparse stepper go through, so sparse stepping is
/// bit-identical by construction. Complemented boards set bits past the
/// row width; callers mask the tail word.
#[inline]
fn eca_word(number: u8, l: u64, c: u64, r: u64) -> u64 {
    let mut next = 0u64;
    for p in 0..8u8 {
        if (number >> p) & 1 == 1 {
            let a = if p & 4 != 0 { l } else { !l };
            let b = if p & 2 != 0 { c } else { !c };
            let d = if p & 1 != 0 { r } else { !r };
            next |= a & b & d;
        }
    }
    next
}

/// One rule application on a packed row; `left`/`right` are scratch
/// buffers of the same word length.
pub fn step_row(
    rule: &WolframRule,
    row: &mut [u64],
    left: &mut [u64],
    right: &mut [u64],
    w: usize,
) {
    bits::rot_up(row, left, w);
    bits::rot_down(row, right, w);
    let number = rule.number;
    for i in 0..row.len() {
        row[i] = eca_word(number, left[i], row[i], right[i]);
    }
    // Complemented boards set tail bits; restore the invariant.
    bits::mask_tail(row, w);
}

/// One activity-tracked rule application: recompute only the words the
/// map's halo says might change (tile = one u64 word = 64 cells), mark
/// the ones that did. Returns `(recomputed, skipped)` word counts.
/// Bit-identical to [`step_row`] — skipped words provably cannot
/// change, recomputed words go through the same [`eca_word`].
pub fn step_row_sparse(
    rule: &WolframRule,
    row: &mut [u64],
    left: &mut [u64],
    right: &mut [u64],
    w: usize,
    map: &mut ActivityMap,
) -> (u64, u64) {
    let nw = row.len();
    let total = nw as u64;
    let needed = map.begin_step(0, 1) as u64;
    if needed == 0 {
        return (0, total);
    }
    // Whole-row rotation is O(nw) shifts — cheap next to the per-word
    // rule algebra, and it keeps the wrap carries exact.
    bits::rot_up(row, left, w);
    bits::rot_down(row, right, w);
    let number = rule.number;
    let rem = w % 64;
    for wi in 0..map.words_per_row() {
        let mut tiles = map.needs_word(0, wi);
        while tiles != 0 {
            let i = wi * 64 + tiles.trailing_zeros() as usize;
            tiles &= tiles - 1;
            let mut next = eca_word(number, left[i], row[i], right[i]);
            if i == nw - 1 && rem != 0 {
                next &= (1u64 << rem) - 1;
            }
            if next != row[i] {
                map.mark(0, i);
                row[i] = next;
            }
        }
    }
    (needed, total - needed)
}

/// Run `steps` rule applications on one packed row.
pub fn rollout_row(rule: &WolframRule, row: &mut [u64], w: usize,
                   steps: usize) {
    let mut left = vec![0u64; row.len()];
    let mut right = vec![0u64; row.len()];
    for _ in 0..steps {
        step_row(rule, row, &mut left, &mut right, w);
    }
}

/// Run `steps` activity-tracked rule applications; the map carries
/// dirty state across steps (and across calls, for resident rows).
/// Returns summed `(recomputed, skipped)` word-tile counts.
pub fn rollout_row_sparse(rule: &WolframRule, row: &mut [u64], w: usize,
                          steps: usize, map: &mut ActivityMap)
    -> (u64, u64) {
    let mut left = vec![0u64; row.len()];
    let mut right = vec![0u64; row.len()];
    let (mut recomputed, mut skipped) = (0, 0);
    for _ in 0..steps {
        let (r, s) =
            step_row_sparse(rule, row, &mut left, &mut right, w, map);
        recomputed += r;
        skipped += s;
    }
    (recomputed, skipped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::EcaSim;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn packed_vs_naive(rule_no: u8, w: usize, steps: usize, seed: u64) {
        let rule = WolframRule::new(rule_no);
        let mut rng = Rng::new(seed);
        let cells = rng.binary_vec(w, 0.5);
        let state = Tensor::new(vec![1, w], cells.clone()).unwrap();

        let mut sim = EcaSim::from_tensor(rule, &state);
        sim.run(steps);
        let expect = sim.to_tensor();

        let mut row = vec![0u64; bits::words_for(w)];
        bits::pack_row(&cells, &mut row);
        rollout_row(&rule, &mut row, w, steps);
        let mut got = vec![0.0f32; w];
        bits::unpack_row(&row, &mut got);

        assert_eq!(got, expect.data(),
                   "rule {rule_no} w={w} steps={steps} diverged");
    }

    #[test]
    fn matches_naive_across_rules_and_widths() {
        for (i, &rule) in [30u8, 90, 110, 184, 45, 250].iter().enumerate() {
            for &w in &[8usize, 63, 64, 65, 130, 256] {
                packed_vs_naive(rule, w, 12, 100 + i as u64);
            }
        }
    }

    #[test]
    fn rule_2_wraps_periodically() {
        // Rule 2: cell lights iff only the right neighbour is alive; a
        // single live cell at x=0 must light x=w-1 through the wrap.
        let w = 67;
        let mut row = vec![0u64; bits::words_for(w)];
        row[0] = 1;
        rollout_row(&WolframRule::new(2), &mut row, w, 1);
        let mut cells = vec![0.0f32; w];
        bits::unpack_row(&row, &mut cells);
        assert_eq!(cells[w - 1], 1.0);
        assert_eq!(cells.iter().filter(|&&c| c == 1.0).count(), 1);
    }
}
