//! Bit-packed elementary-CA kernel (SWAR, 64 cells per word).
//!
//! The rule table is applied as boolean algebra over three whole-row
//! bitboards (left-neighbour, centre, right-neighbour): for every
//! pattern `p = 4l + 2c + r` with rule bit set, OR in the AND of the
//! three (possibly complemented) boards. At most 8 AND3/OR terms per
//! word — ~0.5 ops per cell versus the naive simulator's table lookup,
//! index arithmetic and bounds checks per cell. Bit-exact with
//! [`crate::automata::EcaSim`] by construction (same encoding:
//! `table[4l + 2c + r]`, periodic boundary).

use crate::automata::WolframRule;
use crate::backend::native::bits;

/// One rule application on a packed row; `left`/`right` are scratch
/// buffers of the same word length.
pub fn step_row(
    rule: &WolframRule,
    row: &mut [u64],
    left: &mut [u64],
    right: &mut [u64],
    w: usize,
) {
    bits::rot_up(row, left, w);
    bits::rot_down(row, right, w);
    let number = rule.number;
    for i in 0..row.len() {
        let (l, c, r) = (left[i], row[i], right[i]);
        let mut next = 0u64;
        for p in 0..8u8 {
            if (number >> p) & 1 == 1 {
                let a = if p & 4 != 0 { l } else { !l };
                let b = if p & 2 != 0 { c } else { !c };
                let d = if p & 1 != 0 { r } else { !r };
                next |= a & b & d;
            }
        }
        row[i] = next;
    }
    // Complemented boards set tail bits; restore the invariant.
    bits::mask_tail(row, w);
}

/// Run `steps` rule applications on one packed row.
pub fn rollout_row(rule: &WolframRule, row: &mut [u64], w: usize,
                   steps: usize) {
    let mut left = vec![0u64; row.len()];
    let mut right = vec![0u64; row.len()];
    for _ in 0..steps {
        step_row(rule, row, &mut left, &mut right, w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::EcaSim;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn packed_vs_naive(rule_no: u8, w: usize, steps: usize, seed: u64) {
        let rule = WolframRule::new(rule_no);
        let mut rng = Rng::new(seed);
        let cells = rng.binary_vec(w, 0.5);
        let state = Tensor::new(vec![1, w], cells.clone()).unwrap();

        let mut sim = EcaSim::from_tensor(rule, &state);
        sim.run(steps);
        let expect = sim.to_tensor();

        let mut row = vec![0u64; bits::words_for(w)];
        bits::pack_row(&cells, &mut row);
        rollout_row(&rule, &mut row, w, steps);
        let mut got = vec![0.0f32; w];
        bits::unpack_row(&row, &mut got);

        assert_eq!(got, expect.data(),
                   "rule {rule_no} w={w} steps={steps} diverged");
    }

    #[test]
    fn matches_naive_across_rules_and_widths() {
        for (i, &rule) in [30u8, 90, 110, 184, 45, 250].iter().enumerate() {
            for &w in &[8usize, 63, 64, 65, 130, 256] {
                packed_vs_naive(rule, w, 12, 100 + i as u64);
            }
        }
    }

    #[test]
    fn rule_2_wraps_periodically() {
        // Rule 2: cell lights iff only the right neighbour is alive; a
        // single live cell at x=0 must light x=w-1 through the wrap.
        let w = 67;
        let mut row = vec![0u64; bits::words_for(w)];
        row[0] = 1;
        rollout_row(&WolframRule::new(2), &mut row, w, 1);
        let mut cells = vec![0.0f32; w];
        bits::unpack_row(&row, &mut cells);
        assert_eq!(cells[w - 1], 1.0);
        assert_eq!(cells.iter().filter(|&&c| c == 1.0).count(), 1);
    }
}
