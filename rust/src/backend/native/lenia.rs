//! Native Lenia kernels: the cache-tiled sparse-tap path and the
//! spectral FFT path, plus the size-adaptive crossover between them.
//!
//! **Sparse-tap** ([`LeniaKernel`]): semantics *identical* to
//! [`crate::automata::LeniaSim`] — same ring kernel, growth mapping and
//! clip, and crucially the same f32 accumulation order (kernel-row-major
//! taps) — so results are bit-exact with the naive oracle. The speed
//! comes from three mechanical changes, none of which alter the math:
//!
//! - zero-weight kernel taps are skipped (the ring kernel is ~2/3
//!   zeros; adding `0.0 * s` never changes a non-negative f32 sum),
//! - direct slice indexing instead of per-element tensor offset
//!   arithmetic,
//! - the output is walked in cache-sized tiles so the wrapped input
//!   rows a tile touches stay resident.
//!
//! **Spectral** ([`LeniaFft`]): each ring kernel's torus-embedded
//! spectrum is computed once; a step is then FFT → multiply → inverse
//! FFT per kernel (f64 via [`super::fft`]) followed by the same f32
//! growth/update stage. Per-cell cost is `O(log hw)` instead of
//! `O(radius^2)`, which is the paper's Fig. 3 Lenia speedup mechanism.
//! The spectral path also runs the generalized multi-channel /
//! multi-kernel [`LeniaWorld`]s. Convolution in f64 is exact at f32
//! resolution, so it matches the oracle to ~1e-6 per step; over long
//! horizons the differential contract is 1e-4 (see
//! `tests/native_fft_props.rs` for why trajectories in the
//! narrow-growth regime cannot be compared much tighter).
//!
//! [`select_path`] picks between the two per (radius, board): sparse-tap
//! below the measured crossover, FFT above it.
//!
//! Both paths carry an AVX2 SIMD lane ([`super::simd`]): the sparse-tap
//! convolution vectorizes 8 output cells per vector (one lane = one
//! cell, scalar tap order per lane), and the shared growth/update stage
//! vectorizes the kernel-weight mix + residual + clamp the same way.
//! The growth `exp` stays scalar per lane, so SIMD results are
//! bit-identical to the scalar code — `bit_exact_with_naive_oracle`
//! below holds in both modes. `CAX_SIMD=off` forces scalar.
//!
//! Batch elements are independent; the backend parallelizes across
//! them with the worker pool in both paths.

use anyhow::{bail, Result};

use super::fft::{Complex, Fft2};
#[cfg(target_arch = "x86_64")]
use super::simd::LANES;
use super::wrap_shift;
use crate::automata::lenia::{growth, ring_kernel, LeniaParams, LeniaWorld};

/// Precomputed sparse ring kernel + growth parameters.
#[derive(Clone, Debug)]
pub struct LeniaKernel {
    pub params: LeniaParams,
    /// Non-zero taps as (ky, kx, weight), kernel-row-major — the same
    /// accumulation order as the naive oracle.
    taps: Vec<(usize, usize, f32)>,
}

/// Output tile edge (f32 cells); 32x32 keeps tile + touched input rows
/// well under typical L1/L2 sizes for paper-scale grids.
const TILE: usize = 32;

impl LeniaKernel {
    pub fn new(params: LeniaParams) -> LeniaKernel {
        let kernel = ring_kernel(params.radius);
        let ksz = 2 * params.radius + 1;
        let mut taps = Vec::new();
        for ky in 0..ksz {
            for kx in 0..ksz {
                let weight = kernel.at(&[ky, kx]);
                if weight != 0.0 {
                    taps.push((ky, kx, weight));
                }
            }
        }
        LeniaKernel { params, taps }
    }

    pub fn taps(&self) -> usize {
        self.taps.len()
    }

    /// One step on a single `[H, W]` board held as a row-major slice.
    ///
    /// Dispatches to the AVX2 path when [`super::simd::active`] and the
    /// board has a full 8-lane wrap-free interior; otherwise (and for
    /// the wrapped edge columns of the SIMD path itself) runs the
    /// scalar per-cell code. Both produce bit-identical boards.
    pub fn step(&self, state: &[f32], next: &mut [f32], h: usize, w: usize) {
        debug_assert_eq!(state.len(), h * w);
        debug_assert_eq!(next.len(), h * w);
        #[cfg(target_arch = "x86_64")]
        if super::simd::active() && w >= 2 * self.params.radius + LANES {
            // SAFETY: active() verified AVX2 at runtime.
            unsafe { self.step_avx2(state, next, h, w) };
            return;
        }
        self.step_scalar(state, next, h, w);
    }

    /// The always-compiled scalar step — the reference the SIMD path
    /// must match bit for bit (the differential suite in
    /// `tests/native_simd_props.rs` compares against it directly).
    pub fn step_scalar(&self, state: &[f32], next: &mut [f32], h: usize,
                       w: usize) {
        debug_assert_eq!(state.len(), h * w);
        debug_assert_eq!(next.len(), h * w);
        let mut ty = 0;
        while ty < h {
            let y_end = (ty + TILE).min(h);
            let mut tx = 0;
            while tx < w {
                let x_end = (tx + TILE).min(w);
                for y in ty..y_end {
                    for x in tx..x_end {
                        self.cell_scalar(state, next, h, w, y, x);
                    }
                }
                tx = x_end;
            }
            ty = y_end;
        }
    }

    /// One output cell, scalar — the single copy of the per-cell math:
    /// the tiled sweep above and the SIMD path's edge columns both call
    /// it, so their accumulation order can never drift apart.
    #[inline]
    fn cell_scalar(&self, state: &[f32], next: &mut [f32], h: usize,
                   w: usize, y: usize, x: usize) {
        let r = self.params.radius;
        let mut u = 0.0f32;
        for &(ky, kx, weight) in &self.taps {
            let sy = wrap_shift(y, h, r, ky);
            let sx = wrap_shift(x, w, r, kx);
            u += weight * state[sy * w + sx];
        }
        let g = growth(u, self.params.mu, self.params.sigma);
        let v = state[y * w + x] + self.params.dt * g;
        next[y * w + x] = v.clamp(0.0, 1.0);
    }

    /// AVX2 step: 8 consecutive output cells per vector across the
    /// wrap-free interior columns `[r, w - r)`, scalar on the wrapped
    /// edges. Lane `i` accumulates cell `x0 + i` in the exact scalar
    /// tap order (`mul` + `add`, no FMA), and the growth mapping runs
    /// scalar per lane, so the result is bit-identical to
    /// [`step_scalar`](Self::step_scalar) — NaNs and denormals
    /// included.
    ///
    /// # Safety
    ///
    /// AVX2 must be available (guaranteed by [`super::simd::active`]).
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn step_avx2(&self, state: &[f32], next: &mut [f32], h: usize,
                        w: usize) {
        use std::arch::x86_64::*;
        let r = self.params.radius;
        let (mu, sigma, dt) = (self.params.mu, self.params.sigma,
                               self.params.dt);
        debug_assert!(w >= 2 * r + LANES);
        // Columns in [lo, hi) never wrap in x for any tap:
        // x + r - kx stays in [x - r, x + r] ⊆ [0, w - 1].
        let (lo, hi) = (r, w - r);
        for y in 0..h {
            for x in 0..lo {
                self.cell_scalar(state, next, h, w, y, x);
            }
            let mut x0 = lo;
            while x0 + LANES <= hi {
                let mut u = _mm256_setzero_ps();
                for &(ky, kx, weight) in &self.taps {
                    let sy = wrap_shift(y, h, r, ky);
                    let base = sy * w + (x0 + r - kx);
                    let sv = _mm256_loadu_ps(state[base..].as_ptr());
                    u = _mm256_add_ps(
                        u, _mm256_mul_ps(_mm256_set1_ps(weight), sv));
                }
                let mut us = [0.0f32; LANES];
                _mm256_storeu_ps(us.as_mut_ptr(), u);
                for (i, &ui) in us.iter().enumerate() {
                    let x = x0 + i;
                    let g = growth(ui, mu, sigma);
                    let v = state[y * w + x] + dt * g;
                    next[y * w + x] = v.clamp(0.0, 1.0);
                }
                x0 += LANES;
            }
            for x in x0..w {
                self.cell_scalar(state, next, h, w, y, x);
            }
        }
    }

    /// Run `steps` updates in place on one board; `scratch` must be the
    /// same length as `board`.
    pub fn rollout(&self, board: &mut [f32], scratch: &mut [f32], h: usize,
                   w: usize, steps: usize) {
        for _ in 0..steps {
            self.step(board, scratch, h, w);
            board.copy_from_slice(scratch);
        }
    }

    /// Activity-map tile grid for an `h x w` board (TILE-edge tiles,
    /// matching the cache tiles of [`step_scalar`](Self::step_scalar)).
    pub fn tile_dims(h: usize, w: usize) -> (usize, usize) {
        (h.div_ceil(TILE), w.div_ceil(TILE))
    }

    /// Dirty-dilation halo in tiles: `radius` cells rounded up.
    pub fn halo_tiles(&self) -> usize {
        self.params.radius.div_ceil(TILE).max(1)
    }

    /// One activity-tracked step: recompute only tiles whose
    /// radius-halo changed last step, then commit + re-mark by exact
    /// f32 *bit* comparison (so the changed-mask is exact, `-0.0` vs
    /// `+0.0` and NaN included). Two passes keep read-before-write: all
    /// recomputes read `board` (old), write `scratch`; the commit pass
    /// copies back. Returns `(recomputed, skipped)` tile counts.
    ///
    /// Bit-identical to [`step`](Self::step): skipped tiles provably
    /// cannot change, recomputed cells run the same
    /// [`cell_scalar`](Self::cell_scalar) the dense sweep runs (and the
    /// AVX2 lanes match bit for bit — `native_simd_props`).
    ///
    /// When most tiles are active the per-cell scalar recompute would
    /// lose to the dense AVX2 sweep, so past ~60% occupancy this falls
    /// back to one dense step plus a full diff — the worst case costs a
    /// dense step plus one compare per cell, never more.
    pub fn step_sparse(&self, board: &mut [f32], scratch: &mut [f32],
                       h: usize, w: usize, map: &mut super::activity::ActivityMap)
        -> (u64, u64) {
        let (tr, tc) = Self::tile_dims(h, w);
        let total = (tr * tc) as u64;
        let halo = self.halo_tiles();
        let needed = map.begin_step(halo, halo) as u64;
        if needed == 0 {
            return (0, total);
        }
        if needed * 8 > total * 5 {
            // > 62.5% of tiles active: dense step + exact diff.
            self.step(board, scratch, h, w);
            for ty in 0..tr {
                for tx in 0..tc {
                    if tile_bits_differ(board, scratch, h, w, ty, tx) {
                        map.mark(ty, tx);
                    }
                }
            }
            board.copy_from_slice(scratch);
            return (total, 0);
        }
        // Pass 1: recompute needed tiles into scratch; `board` stays
        // the old state throughout, so tiles can be done in any order.
        for ty in 0..tr {
            if !map.row_needed(ty) {
                continue;
            }
            for wi in 0..map.words_per_row() {
                let mut tiles = map.needs_word(ty, wi);
                while tiles != 0 {
                    let tx = wi * 64 + tiles.trailing_zeros() as usize;
                    tiles &= tiles - 1;
                    let (y1, x1) = (((ty + 1) * TILE).min(h),
                                    ((tx + 1) * TILE).min(w));
                    for y in ty * TILE..y1 {
                        for x in tx * TILE..x1 {
                            self.cell_scalar(board, scratch, h, w, y, x);
                        }
                    }
                }
            }
        }
        // Pass 2: commit recomputed tiles, marking exact bit changes.
        for ty in 0..tr {
            if !map.row_needed(ty) {
                continue;
            }
            for wi in 0..map.words_per_row() {
                let mut tiles = map.needs_word(ty, wi);
                while tiles != 0 {
                    let tx = wi * 64 + tiles.trailing_zeros() as usize;
                    tiles &= tiles - 1;
                    if tile_bits_differ(board, scratch, h, w, ty, tx) {
                        map.mark(ty, tx);
                    }
                    let (y1, x1) = (((ty + 1) * TILE).min(h),
                                    ((tx + 1) * TILE).min(w));
                    for y in ty * TILE..y1 {
                        let (a, b) = (y * w + tx * TILE, y * w + x1);
                        board[a..b].copy_from_slice(&scratch[a..b]);
                    }
                }
            }
        }
        (needed, total - needed)
    }

    /// Run `steps` activity-tracked updates; the map carries dirty
    /// state across steps (and calls). Returns summed
    /// `(recomputed, skipped)` tile counts.
    pub fn rollout_sparse(&self, board: &mut [f32], scratch: &mut [f32],
                          h: usize, w: usize, steps: usize,
                          map: &mut super::activity::ActivityMap)
        -> (u64, u64) {
        let (mut recomputed, mut skipped) = (0, 0);
        for _ in 0..steps {
            let (r, s) = self.step_sparse(board, scratch, h, w, map);
            recomputed += r;
            skipped += s;
        }
        (recomputed, skipped)
    }
}

/// Whether any cell of tile (`ty`, `tx`) differs between `a` and `b`
/// as raw f32 bits — the exactness the activity contract needs
/// (`==` would call `-0.0` unchanged and NaN changed-forever).
fn tile_bits_differ(a: &[f32], b: &[f32], h: usize, w: usize, ty: usize,
                    tx: usize) -> bool {
    let (y1, x1) = (((ty + 1) * TILE).min(h), ((tx + 1) * TILE).min(w));
    for y in ty * TILE..y1 {
        for x in tx * TILE..x1 {
            if a[y * w + x].to_bits() != b[y * w + x].to_bits() {
                return true;
            }
        }
    }
    false
}

// ----------------------------------------------------- path selection

/// Which kernel implementation the native backend runs for a Lenia
/// radius on an `h x w` board.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeniaPath {
    /// Cache-tiled direct convolution — bit-exact with the naive
    /// oracle, `O(radius^2)` per cell.
    SparseTap,
    /// Spectral convolution — `O(log hw)` per cell, ~1e-6/step from
    /// the oracle.
    Fft,
}

impl LeniaPath {
    pub fn name(&self) -> &'static str {
        match self {
            LeniaPath::SparseTap => "sparse-tap",
            LeniaPath::Fft => "fft",
        }
    }
}

/// Crossover constant, calibrated with `benches/fig3_lenia.rs` (see the
/// README's crossover note): per-cell sparse-tap cost is the tap count
/// (~`pi r^2` f32 mul-adds), per-cell spectral cost is ~this many
/// equivalent tap-ops per `log2` unit of transform length (f64 complex
/// butterflies across forward + inverse, spread over the board).
const FFT_COST_PER_LOG2: f64 = 48.0;

/// Bluestein runs a chirp-modulated power-of-two convolution at ~2-4x
/// the length, so non-power-of-two axes count this much extra.
const BLUESTEIN_PENALTY: f64 = 4.0;

/// Pick the cheaper Lenia path for one radius on an `h x w` board.
///
/// The decision depends only on the geometry — never on thread count or
/// data — so results stay deterministic for a given state shape. The
/// paper-default radius 10 stays on the bit-exact sparse-tap path for
/// every paper-scale grid; the model's crossover sits at radius 16 on a
/// 256x256 board (15 at 128x128) and radius 32 on a 250x250 Bluestein
/// board. The constant is deliberately conservative: measured FFT
/// per-step cost is usually below the model, so everything at or above
/// the crossover is safely spectral.
pub fn select_path(radius: usize, h: usize, w: usize) -> LeniaPath {
    let taps = std::f64::consts::PI * (radius as f64) * (radius as f64);
    let axis = |n: usize| {
        let l = (n.max(2) as f64).log2();
        if n.is_power_of_two() {
            l
        } else {
            BLUESTEIN_PENALTY * l
        }
    };
    if taps > FFT_COST_PER_LOG2 * (axis(h) + axis(w)) {
        LeniaPath::Fft
    } else {
        LeniaPath::SparseTap
    }
}

// ----------------------------------------------------- spectral kernel

/// Spectral Lenia stepper over a [`LeniaWorld`] on a fixed `h x w`
/// torus: every ring kernel's spectrum is precomputed once, each step
/// does one forward FFT per *used* source channel and one multiply +
/// inverse FFT per kernel, then the shared f32 growth/update stage.
///
/// The classic single-kernel case is [`LeniaFft::new`], which wraps the
/// `1 x 1` [`LeniaWorld::single`] embedding — there is exactly one code
/// path, so the multi-kernel engine reproduces single-kernel behavior
/// bit for bit on that embedding.
#[derive(Clone, Debug)]
pub struct LeniaFft {
    world: LeniaWorld,
    h: usize,
    w: usize,
    fft: Fft2,
    /// Per-kernel spectrum of the torus-embedded ring kernel.
    khat: Vec<Vec<Complex>>,
    /// Which channels at least one kernel reads (others skip their
    /// forward transform).
    src_used: Vec<bool>,
}

/// Reusable per-board scratch for [`LeniaFft::step_with`] — one
/// spectrum per channel, one frequency workspace, one growth field per
/// kernel. [`LeniaFft::rollout`] allocates it once per board.
#[derive(Clone, Debug)]
pub struct LeniaFftScratch {
    chat: Vec<Vec<Complex>>,
    freq: Vec<Complex>,
    growths: Vec<f32>,
}

impl LeniaFftScratch {
    pub fn new(plan: &LeniaFft) -> LeniaFftScratch {
        let hw = plan.h * plan.w;
        LeniaFftScratch {
            chat: vec![vec![Complex::ZERO; hw]; plan.world.channels],
            freq: vec![Complex::ZERO; hw],
            growths: vec![0.0f32; plan.world.kernels.len() * hw],
        }
    }
}

impl LeniaFft {
    /// Plan for the classic single-channel, single-kernel case.
    pub fn new(params: LeniaParams, h: usize, w: usize) -> Result<LeniaFft> {
        LeniaFft::for_world(LeniaWorld::single(params), h, w)
    }

    /// Plan for a generalized world on an `h x w` torus.
    pub fn for_world(world: LeniaWorld, h: usize, w: usize)
        -> Result<LeniaFft> {
        world.validate()?;
        let r = world.max_radius();
        if h < r || w < r {
            bail!(
                "LeniaFft: radius {r} needs a board of at least {r}x{r}, \
                 got {h}x{w}"
            );
        }
        let fft = Fft2::new(h, w);
        let mut khat = Vec::with_capacity(world.kernels.len());
        let mut src_used = vec![false; world.channels];
        for spec in &world.kernels {
            src_used[spec.src] = true;
            let dense = ring_kernel(spec.radius);
            let ksz = 2 * spec.radius + 1;
            let mut grid = vec![Complex::ZERO; h * w];
            for ky in 0..ksz {
                for kx in 0..ksz {
                    let v = dense.at(&[ky, kx]) as f64;
                    if v != 0.0 {
                        // The oracle taps s[(y + r - ky) mod h], i.e.
                        // kernel cell (ky, kx) convolves from offset
                        // (ky - r, kx - r): embed it there on the torus.
                        // Offsets that collide under wrap (2r >= h)
                        // accumulate, exactly as the wrapped taps do.
                        let ey = (ky + h - spec.radius) % h;
                        let ex = (kx + w - spec.radius) % w;
                        grid[ey * w + ex].re += v;
                    }
                }
            }
            fft.forward(&mut grid);
            khat.push(grid);
        }
        Ok(LeniaFft { world, h, w, fft, khat, src_used })
    }

    pub fn world(&self) -> &LeniaWorld {
        &self.world
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    /// Whether any axis runs the Bluestein (non-power-of-two) path.
    pub fn is_bluestein(&self) -> bool {
        !(self.h.is_power_of_two() && self.w.is_power_of_two())
    }

    /// The circular ring-kernel convolution `u_k` of kernel `k` over one
    /// `[H, W]` field — the raw neighborhood potential, before growth
    /// (the differential tests compare it directly against tap sums).
    pub fn convolve(&self, k: usize, field: &[f32]) -> Vec<f32> {
        assert_eq!(field.len(), self.h * self.w);
        let mut freq = vec![Complex::ZERO; self.h * self.w];
        self.fft.load_real(field, &mut freq);
        self.fft.forward(&mut freq);
        for (v, &kv) in freq.iter_mut().zip(&self.khat[k]) {
            *v = *v * kv;
        }
        self.fft.inverse(&mut freq);
        freq.iter().map(|c| c.re as f32).collect()
    }

    /// One spectral step on a `[C, H, W]` board, reusing `scratch`.
    pub fn step_with(&self, state: &[f32], next: &mut [f32],
                     scratch: &mut LeniaFftScratch) {
        let hw = self.h * self.w;
        let c = self.world.channels;
        assert_eq!(state.len(), c * hw, "LeniaFft: state length");
        assert_eq!(next.len(), c * hw, "LeniaFft: next length");
        for ch in 0..c {
            if !self.src_used[ch] {
                continue;
            }
            let buf = &mut scratch.chat[ch];
            self.fft.load_real(&state[ch * hw..(ch + 1) * hw], buf);
            self.fft.forward(buf);
        }
        for (k, spec) in self.world.kernels.iter().enumerate() {
            scratch.freq.copy_from_slice(&scratch.chat[spec.src]);
            for (v, &kv) in scratch.freq.iter_mut().zip(&self.khat[k]) {
                *v = *v * kv;
            }
            self.fft.inverse(&mut scratch.freq);
            let g = &mut scratch.growths[k * hw..(k + 1) * hw];
            for (gv, fv) in g.iter_mut().zip(&scratch.freq) {
                *gv = growth(fv.re as f32, spec.mu, spec.sigma);
            }
        }
        let dt = self.world.dt;
        let mut wk = vec![0.0f32; self.world.kernels.len()];
        for ch in 0..c {
            for (k, spec) in self.world.kernels.iter().enumerate() {
                wk[k] = spec.weights[ch];
            }
            update_stage(&state[ch * hw..(ch + 1) * hw], &scratch.growths,
                         hw, &wk, dt, &mut next[ch * hw..(ch + 1) * hw]);
        }
    }

    /// One spectral step with throwaway scratch.
    pub fn step(&self, state: &[f32], next: &mut [f32]) {
        let mut scratch = LeniaFftScratch::new(self);
        self.step_with(state, next, &mut scratch);
    }

    /// Run `steps` spectral updates in place on one `[C, H, W]` board.
    pub fn rollout(&self, board: &mut [f32], steps: usize) {
        let mut scratch = LeniaFftScratch::new(self);
        let mut next = vec![0.0f32; board.len()];
        for _ in 0..steps {
            self.step_with(board, &mut next, &mut scratch);
            board.copy_from_slice(&next);
        }
    }
}

// ------------------------------------------------- growth/update stage

/// The shared f32 update stage of the spectral path for one channel:
/// `next[i] = clamp(state[i] + dt * sum_k wk[k] * growths[k*hw + i])`.
/// Dispatches to AVX2 when [`super::simd::active`]; bit-identical to
/// [`update_stage_scalar`] either way (the growth mapping itself — the
/// `exp` — happens before this stage and stays scalar).
pub fn update_stage(state: &[f32], growths: &[f32], hw: usize, wk: &[f32],
                    dt: f32, next: &mut [f32]) {
    debug_assert_eq!(state.len(), hw);
    debug_assert_eq!(next.len(), hw);
    debug_assert!(growths.len() >= wk.len() * hw);
    #[cfg(target_arch = "x86_64")]
    if super::simd::active() && hw >= LANES {
        // SAFETY: active() verified AVX2 at runtime.
        unsafe { update_stage_avx2(state, growths, hw, wk, dt, next) };
        return;
    }
    update_stage_scalar(state, growths, hw, wk, dt, next);
}

/// Always-compiled scalar form of [`update_stage`] — the bit-identity
/// reference for the differential suite.
pub fn update_stage_scalar(state: &[f32], growths: &[f32], hw: usize,
                           wk: &[f32], dt: f32, next: &mut [f32]) {
    for (i, (n, &s)) in next.iter_mut().zip(state).enumerate() {
        *n = update_cell_scalar(s, growths, hw, i, wk, dt);
    }
}

/// One cell of the update stage — shared by the scalar sweep and the
/// SIMD path's ragged tail.
#[inline]
fn update_cell_scalar(state_i: f32, growths: &[f32], hw: usize, i: usize,
                      wk: &[f32], dt: f32) -> f32 {
    let mut acc = 0.0f32;
    for (k, &wkk) in wk.iter().enumerate() {
        acc += wkk * growths[k * hw + i];
    }
    (state_i + dt * acc).clamp(0.0, 1.0)
}

/// AVX2 update stage: 8 cells per vector, scalar tap order per lane.
/// The clamp is `min(1, max(0, v))` with the constant as the *first*
/// operand so a NaN `v` propagates and `-0.0` survives — exactly the
/// scalar `f32::clamp` semantics, bit for bit.
///
/// # Safety
///
/// AVX2 must be available (guaranteed by [`super::simd::active`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn update_stage_avx2(state: &[f32], growths: &[f32], hw: usize,
                            wk: &[f32], dt: f32, next: &mut [f32]) {
    use std::arch::x86_64::*;
    let zero = _mm256_setzero_ps();
    let one = _mm256_set1_ps(1.0);
    let dtv = _mm256_set1_ps(dt);
    let mut i = 0usize;
    while i + LANES <= hw {
        let mut acc = _mm256_setzero_ps();
        for (k, &wkk) in wk.iter().enumerate() {
            let g = _mm256_loadu_ps(growths[k * hw + i..].as_ptr());
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(wkk), g));
        }
        let sv = _mm256_loadu_ps(state[i..].as_ptr());
        let v = _mm256_add_ps(sv, _mm256_mul_ps(dtv, acc));
        let v = _mm256_min_ps(one, _mm256_max_ps(zero, v));
        _mm256_storeu_ps(next[i..].as_mut_ptr(), v);
        i += LANES;
    }
    for i in i..hw {
        next[i] = update_cell_scalar(state[i], growths, hw, i, wk, dt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::LeniaSim;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn skips_only_zero_taps() {
        let kernel = LeniaKernel::new(LeniaParams {
            radius: 5,
            ..Default::default()
        });
        let dense = ring_kernel(5);
        let nonzero = dense.data().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(kernel.taps(), nonzero);
        assert!(kernel.taps() < dense.numel(), "ring kernel has zeros");
    }

    #[test]
    fn bit_exact_with_naive_oracle() {
        let params = LeniaParams { radius: 4, ..Default::default() };
        let (h, w) = (33, 29); // deliberately non-round
        let mut rng = Rng::new(77);
        let mut sim = LeniaSim::random_patch(params, h.max(w), 16, &mut rng);
        // random_patch builds square boards; rebuild rectangular by hand.
        let mut board = Tensor::zeros(&[h, w]);
        for y in 0..h {
            for x in 0..w {
                board.set(&[y, x], sim.state().at(&[y.min(h - 1), x % w]));
            }
        }
        sim = LeniaSim::new(params, board.clone());

        let kernel = LeniaKernel::new(params);
        let mut data = board.data().to_vec();
        let mut scratch = vec![0.0f32; h * w];
        kernel.rollout(&mut data, &mut scratch, h, w, 5);

        sim.run(5);
        let expect = sim.state();
        for (i, (&a, &b)) in data.iter().zip(expect.data()).enumerate() {
            assert!(a.to_bits() == b.to_bits(),
                    "cell {i}: tiled {a} != naive {b}");
        }
    }

    #[test]
    fn tiled_result_in_unit_interval() {
        let params = LeniaParams { radius: 3, ..Default::default() };
        let kernel = LeniaKernel::new(params);
        let mut rng = Rng::new(3);
        let (h, w) = (40, 40);
        let mut board = rng.vec_f32(h * w);
        let mut scratch = vec![0.0f32; h * w];
        kernel.rollout(&mut board, &mut scratch, h, w, 6);
        assert!(board.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn select_path_crossover_is_sane() {
        // Paper-default radius stays on the bit-exact path at paper
        // scales; large radii go spectral.
        assert_eq!(select_path(10, 128, 128), LeniaPath::SparseTap);
        assert_eq!(select_path(10, 40, 40), LeniaPath::SparseTap);
        assert_eq!(select_path(32, 256, 256), LeniaPath::Fft);
        assert_eq!(select_path(32, 64, 64), LeniaPath::Fft);
        assert_eq!(select_path(64, 250, 250), LeniaPath::Fft);
        // Monotone in radius for a fixed board.
        let mut seen_fft = false;
        for r in 2..=64 {
            let fft = select_path(r, 256, 256) == LeniaPath::Fft;
            assert!(!seen_fft || fft, "path flipped back at radius {r}");
            seen_fft = fft;
        }
        assert!(seen_fft);
        assert_eq!(LeniaPath::SparseTap.name(), "sparse-tap");
        assert_eq!(LeniaPath::Fft.name(), "fft");
    }

    #[test]
    fn spectral_single_step_matches_naive_oracle() {
        // One step in the sensitive growth regime: convolution in f64
        // keeps the spectral path within ~1e-6 of the f32 tap sums.
        let params = LeniaParams { radius: 5, ..Default::default() };
        let (h, w) = (33, 29); // both Bluestein
        let mut rng = Rng::new(0xFF7A);
        let mut board = Tensor::zeros(&[h, w]);
        for y in 8..25 {
            for x in 6..22 {
                board.set(&[y, x], rng.next_f32());
            }
        }
        let mut sim = LeniaSim::new(params, board.clone());
        let plan = LeniaFft::new(params, h, w).unwrap();
        assert!(plan.is_bluestein());
        let mut next = vec![0.0f32; h * w];
        plan.step(board.data(), &mut next);
        sim.step();
        let mut worst = 0.0f32;
        for (&a, &b) in next.iter().zip(sim.state().data()) {
            worst = worst.max((a - b).abs());
        }
        assert!(worst <= 1e-5, "spectral drifted {worst} in one step");
    }

    #[test]
    fn spectral_new_is_the_single_world_embedding_bitwise() {
        let params = LeniaParams { radius: 4, ..Default::default() };
        let (h, w) = (24, 24);
        let single = LeniaFft::new(params, h, w).unwrap();
        let world =
            LeniaFft::for_world(LeniaWorld::single(params), h, w).unwrap();
        let mut rng = Rng::new(0xE0);
        let mut a = rng.vec_f32(h * w);
        let mut b = a.clone();
        single.rollout(&mut a, 4);
        world.rollout(&mut b, 4);
        assert!(
            a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "LeniaFft::new must be exactly the 1x1 world embedding"
        );
    }

    #[test]
    fn spectral_rollout_stays_in_unit_interval_and_reuses_scratch() {
        let world = LeniaWorld::demo(3, 4);
        let (h, w) = (20, 18);
        let plan = LeniaFft::for_world(world.clone(), h, w).unwrap();
        let mut rng = Rng::new(0x5C);
        let mut board = rng.vec_f32(world.channels * h * w);
        let stepped = {
            // step_with twice over one scratch == two fresh steps.
            let mut scratch = LeniaFftScratch::new(&plan);
            let mut cur = board.clone();
            let mut next = vec![0.0f32; cur.len()];
            for _ in 0..2 {
                plan.step_with(&cur, &mut next, &mut scratch);
                cur.copy_from_slice(&next);
            }
            cur
        };
        plan.rollout(&mut board, 2);
        assert!(board.iter().all(|v| (0.0..=1.0).contains(v)));
        assert!(
            board.iter().zip(&stepped).all(|(x, y)| x.to_bits() == y.to_bits())
        );
    }

    #[test]
    fn spectral_rejects_bad_geometry() {
        let params = LeniaParams { radius: 10, ..Default::default() };
        assert!(LeniaFft::new(params, 8, 8).is_err(), "board < radius");
        let mut world = LeniaWorld::single(params);
        world.kernels[0].src = 5;
        assert!(LeniaFft::for_world(world, 32, 32).is_err(), "bad wiring");
    }
}
