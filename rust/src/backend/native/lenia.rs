//! Cache-tiled Lenia kernel.
//!
//! Semantics are *identical* to [`crate::automata::LeniaSim`] — same
//! ring kernel, growth mapping and clip, and crucially the same f32
//! accumulation order (kernel-row-major taps) — so results are
//! bit-exact with the naive oracle. The speed comes from three
//! mechanical changes, none of which alter the math:
//!
//! - zero-weight kernel taps are skipped (the ring kernel is ~2/3
//!   zeros; adding `0.0 * s` never changes a non-negative f32 sum),
//! - direct slice indexing instead of per-element tensor offset
//!   arithmetic,
//! - the output is walked in cache-sized tiles so the wrapped input
//!   rows a tile touches stay resident.
//!
//! Batch elements are independent; the backend parallelizes across
//! them with the worker pool.

use super::wrap_shift;
use crate::automata::lenia::{ring_kernel, LeniaParams};

/// Precomputed sparse ring kernel + growth parameters.
#[derive(Clone, Debug)]
pub struct LeniaKernel {
    pub params: LeniaParams,
    /// Non-zero taps as (ky, kx, weight), kernel-row-major — the same
    /// accumulation order as the naive oracle.
    taps: Vec<(usize, usize, f32)>,
}

/// Output tile edge (f32 cells); 32x32 keeps tile + touched input rows
/// well under typical L1/L2 sizes for paper-scale grids.
const TILE: usize = 32;

impl LeniaKernel {
    pub fn new(params: LeniaParams) -> LeniaKernel {
        let kernel = ring_kernel(params.radius);
        let ksz = 2 * params.radius + 1;
        let mut taps = Vec::new();
        for ky in 0..ksz {
            for kx in 0..ksz {
                let weight = kernel.at(&[ky, kx]);
                if weight != 0.0 {
                    taps.push((ky, kx, weight));
                }
            }
        }
        LeniaKernel { params, taps }
    }

    pub fn taps(&self) -> usize {
        self.taps.len()
    }

    /// One step on a single `[H, W]` board held as a row-major slice.
    pub fn step(&self, state: &[f32], next: &mut [f32], h: usize, w: usize) {
        debug_assert_eq!(state.len(), h * w);
        debug_assert_eq!(next.len(), h * w);
        let r = self.params.radius;
        let (mu, sigma, dt) = (self.params.mu, self.params.sigma,
                               self.params.dt);
        let mut ty = 0;
        while ty < h {
            let y_end = (ty + TILE).min(h);
            let mut tx = 0;
            while tx < w {
                let x_end = (tx + TILE).min(w);
                for y in ty..y_end {
                    for x in tx..x_end {
                        let mut u = 0.0f32;
                        for &(ky, kx, weight) in &self.taps {
                            let sy = wrap_shift(y, h, r, ky);
                            let sx = wrap_shift(x, w, r, kx);
                            u += weight * state[sy * w + sx];
                        }
                        let z = (u - mu) / sigma;
                        let growth = 2.0 * (-0.5 * z * z).exp() - 1.0;
                        let v = state[y * w + x] + dt * growth;
                        next[y * w + x] = v.clamp(0.0, 1.0);
                    }
                }
                tx = x_end;
            }
            ty = y_end;
        }
    }

    /// Run `steps` updates in place on one board; `scratch` must be the
    /// same length as `board`.
    pub fn rollout(&self, board: &mut [f32], scratch: &mut [f32], h: usize,
                   w: usize, steps: usize) {
        for _ in 0..steps {
            self.step(board, scratch, h, w);
            board.copy_from_slice(scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::LeniaSim;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn skips_only_zero_taps() {
        let kernel = LeniaKernel::new(LeniaParams {
            radius: 5,
            ..Default::default()
        });
        let dense = ring_kernel(5);
        let nonzero = dense.data().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(kernel.taps(), nonzero);
        assert!(kernel.taps() < dense.numel(), "ring kernel has zeros");
    }

    #[test]
    fn bit_exact_with_naive_oracle() {
        let params = LeniaParams { radius: 4, ..Default::default() };
        let (h, w) = (33, 29); // deliberately non-round
        let mut rng = Rng::new(77);
        let mut sim = LeniaSim::random_patch(params, h.max(w), 16, &mut rng);
        // random_patch builds square boards; rebuild rectangular by hand.
        let mut board = Tensor::zeros(&[h, w]);
        for y in 0..h {
            for x in 0..w {
                board.set(&[y, x], sim.state().at(&[y.min(h - 1), x % w]));
            }
        }
        sim = LeniaSim::new(params, board.clone());

        let kernel = LeniaKernel::new(params);
        let mut data = board.data().to_vec();
        let mut scratch = vec![0.0f32; h * w];
        kernel.rollout(&mut data, &mut scratch, h, w, 5);

        sim.run(5);
        let expect = sim.state();
        for (i, (&a, &b)) in data.iter().zip(expect.data()).enumerate() {
            assert!(a.to_bits() == b.to_bits(),
                    "cell {i}: tiled {a} != naive {b}");
        }
    }

    #[test]
    fn tiled_result_in_unit_interval() {
        let params = LeniaParams { radius: 3, ..Default::default() };
        let kernel = LeniaKernel::new(params);
        let mut rng = Rng::new(3);
        let (h, w) = (40, 40);
        let mut board = rng.vec_f32(h * w);
        let mut scratch = vec![0.0f32; h * w];
        kernel.rollout(&mut board, &mut scratch, h, w, 6);
        assert!(board.iter().all(|v| (0.0..=1.0).contains(v)));
    }
}
