//! Native neural-CA forward cell: depthwise perceive + per-cell MLP,
//! parametric in the grid dimension.
//!
//! The standard NCA update (Mordvintsev et al. 2020, the cell every
//! Table-1 neural row builds on): each channel is filtered with a small
//! bank of fixed depthwise kernels (no cross-channel mixing in the
//! conv), the 3C perception vector goes through a shared two-layer MLP
//! per cell, and the result is added to the state. The same cell runs
//! on two geometries ([`Grid`]):
//!
//! - [`Grid::D2`]: identity + Sobel-x + Sobel-y over a wrapped 3x3
//!   support — the growing/MNIST cell. The kernel walks the grid
//!   row-by-row with precomputed wrapped row indices, so the three
//!   input rows a sweep touches stay in cache — the
//!   depthwise-conv/update analogue of the tiled Lenia path. On AVX2
//!   hosts the interior columns run 8 cells per vector (one lane = one
//!   cell, scalar accumulation order — see [`super::simd`]), bit-exact
//!   with the scalar cell.
//! - [`Grid::D1`]: identity + gradient + laplacian over a wrapped
//!   3-tap support — the 1D-ARC cell (§5.3). Three features per
//!   channel in both cases, so the `[3C, hidden]` weight layout (and
//!   every checkpoint/optimizer shape) is dimension-independent.

#[cfg(target_arch = "x86_64")]
use super::simd::LANES;
use super::wrap3;
use crate::util::rng::Rng;

/// Activity-tile edge for the sparse stepper (cells per side; all
/// channels of a cell share its tile).
const TILE: usize = 32;

/// Sobel-x, normalized by 8 as in the reference NCA perceive step.
/// Shared with the backward pass in [`super::nca_grad`].
pub(crate) const SOBEL_X: [[f32; 3]; 3] = [
    [-0.125, 0.0, 0.125],
    [-0.25, 0.0, 0.25],
    [-0.125, 0.0, 0.125],
];

/// 1D central-difference gradient `[left, center, right]`, normalized
/// like the Sobel bank (|taps| sum to 1). Shared with the transposed
/// scatter in [`super::nca_grad`].
pub(crate) const GRAD_1D: [f32; 3] = [-0.5, 0.0, 0.5];

/// 1D laplacian `[left, center, right]`, same normalization.
pub(crate) const LAP_1D: [f32; 3] = [0.25, -0.5, 0.25];

/// Periodic grid geometry of a native NCA board. The cell math is
/// parametric in this: [`NcaModel::step_frozen_on`] and the BPTT sweep
/// in [`super::nca_grad`] dispatch the perceive stencil (and its
/// transpose) on the variant, everything else — MLP, residual, frozen
/// channels, parameter layout — is shared.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Grid {
    /// One periodic row of `w` cells; state layout `[W, C]`.
    D1 { w: usize },
    /// An `h` x `w` torus; state layout `[H, W, C]`.
    D2 { h: usize, w: usize },
}

impl Grid {
    /// Number of cells (the state holds `cells() * channels` floats).
    pub fn cells(&self) -> usize {
        match *self {
            Grid::D1 { w } => w,
            Grid::D2 { h, w } => h * w,
        }
    }
}

/// Weights of a native NCA cell.
#[derive(Clone, Debug)]
pub struct NcaModel {
    pub channels: usize,
    pub hidden: usize,
    /// `[3*channels, hidden]` row-major: perception -> hidden.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// `[hidden, channels]` row-major: hidden -> state delta.
    pub w2: Vec<f32>,
    /// Update scale (the residual step size).
    pub dt: f32,
}

impl NcaModel {
    /// Random small-weight model (test/bench substrate; trained weights
    /// would come from a checkpoint).
    pub fn random(channels: usize, hidden: usize, rng: &mut Rng) -> NcaModel {
        assert!(channels > 0 && hidden > 0);
        let fan_in = 3 * channels;
        let scale1 = 1.0 / (fan_in as f32).sqrt();
        let scale2 = 0.1 / (hidden as f32).sqrt();
        NcaModel {
            channels,
            hidden,
            w1: (0..fan_in * hidden)
                .map(|_| rng.normal() * scale1)
                .collect(),
            b1: vec![0.0; hidden],
            w2: (0..hidden * channels)
                .map(|_| rng.normal() * scale2)
                .collect(),
            dt: 0.5,
        }
    }

    /// Number of trainable parameters (`w1`, `b1`, `w2`) of a cell with
    /// this geometry — the flat checkpoint/optimizer vector length.
    pub fn param_count(channels: usize, hidden: usize) -> usize {
        3 * channels * hidden + hidden + hidden * channels
    }

    /// Flatten the trainable parameters as `[w1, b1, w2]` — the layout of
    /// the native train-step parameter vector and of
    /// [`crate::coordinator::trainer::TrainState`] checkpoints.
    pub fn flatten(&self) -> Vec<f32> {
        let mut flat =
            Vec::with_capacity(Self::param_count(self.channels, self.hidden));
        flat.extend_from_slice(&self.w1);
        flat.extend_from_slice(&self.b1);
        flat.extend_from_slice(&self.w2);
        flat
    }

    /// Rebuild a model from the `[w1, b1, w2]` flat layout written by
    /// [`NcaModel::flatten`].
    pub fn from_flat(channels: usize, hidden: usize, dt: f32, flat: &[f32])
                     -> NcaModel {
        assert_eq!(flat.len(), Self::param_count(channels, hidden),
                   "from_flat: {} params for a {channels}-channel, \
                    {hidden}-hidden cell", flat.len());
        let n1 = 3 * channels * hidden;
        NcaModel {
            channels,
            hidden,
            w1: flat[..n1].to_vec(),
            b1: flat[n1..n1 + hidden].to_vec(),
            w2: flat[n1 + hidden..].to_vec(),
            dt,
        }
    }

    /// One forward update of a `[H, W, C]` channels-last board.
    pub fn step(&self, state: &[f32], next: &mut [f32], h: usize, w: usize) {
        self.step_frozen(state, next, h, w, 0);
    }

    /// One forward update with the first `frozen` channels pinned: their
    /// residual delta is zeroed, so they pass through unchanged (the
    /// self-classifying-MNIST input channel, the 1D-ARC one-hot task
    /// encoding). They still feed perception.
    ///
    /// Dispatches to the AVX2 row kernel when [`super::simd::active`]
    /// and the row has a full 8-lane wrap-free interior; the result is
    /// bit-identical to [`step_frozen_scalar`](Self::step_frozen_scalar)
    /// either way, so the BPTT recompute in [`super::nca_grad`] (which
    /// replays pre-activations scalar) stays exact over SIMD forwards.
    pub fn step_frozen(&self, state: &[f32], next: &mut [f32], h: usize,
                       w: usize, frozen: usize) {
        #[cfg(target_arch = "x86_64")]
        if super::simd::active() && w >= LANES + 2 {
            // SAFETY: active() verified AVX2 at runtime.
            unsafe { self.step_frozen_avx2(state, next, h, w, frozen) };
            return;
        }
        self.step_frozen_scalar(state, next, h, w, frozen);
    }

    /// The always-compiled scalar forward — the bit-identity reference
    /// for the differential suite in `tests/native_simd_props.rs`.
    pub fn step_frozen_scalar(&self, state: &[f32], next: &mut [f32],
                              h: usize, w: usize, frozen: usize) {
        let c = self.channels;
        debug_assert!(frozen <= c);
        debug_assert_eq!(state.len(), h * w * c);
        debug_assert_eq!(next.len(), state.len());
        let mut perception = vec![0.0f32; 3 * c];
        let mut hidden = vec![0.0f32; self.hidden];

        for y in 0..h {
            let rows = wrap3(y, h);
            for x in 0..w {
                let cols = wrap3(x, w);
                perceive_cell(state, w, c, &rows, &cols, &mut perception);
                self.cell_update(state, next, (y * w + x) * c, &perception,
                                 &mut hidden, frozen);
            }
        }
    }

    /// AVX2 forward: 8 consecutive cells of a row per vector across the
    /// wrap-free interior columns `[1, w - 1)`, scalar on the wrapped
    /// edge columns. Lane `i` is cell `x0 + i`; perception (strided
    /// gathers over the channels-last board), the MLP (broadcast
    /// weights, scalar accumulation order per lane, `mul` + `add`, no
    /// FMA) and the residual all match the scalar cell exactly, and the
    /// ReLU `max(acc, 0)` keeps the accumulator as the first operand so
    /// a NaN accumulator folds to `0.0` exactly like `f32::max`. (The
    /// one state `maxNum` leaves unspecified — an exactly `-0.0`
    /// accumulator — is unreachable here: `b1` is `+0.0` in every
    /// in-tree constructor and IEEE addition from a `+0.0` start never
    /// produces `-0.0`.)
    ///
    /// # Safety
    ///
    /// AVX2 must be available (guaranteed by [`super::simd::active`]).
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn step_frozen_avx2(&self, state: &[f32], next: &mut [f32],
                               h: usize, w: usize, frozen: usize) {
        use std::arch::x86_64::*;

        use super::simd::x86::{load8_strided, store8_strided};
        let c = self.channels;
        debug_assert!(frozen <= c);
        debug_assert_eq!(state.len(), h * w * c);
        debug_assert_eq!(next.len(), state.len());
        debug_assert!(w >= LANES + 2);
        let mut perception = vec![0.0f32; 3 * c];
        let mut hidden = vec![0.0f32; self.hidden];
        let zero = _mm256_setzero_ps();
        let dtv = _mm256_set1_ps(self.dt);
        // Per-lane-block SoA: perception vectors (3 per channel) and
        // hidden activations, one __m256 per feature.
        let mut pvec = vec![zero; 3 * c];
        let mut hvec = vec![zero; self.hidden];

        for y in 0..h {
            let rows = wrap3(y, h);
            // Wrapped edge columns x = 0 and x in [x0_end, w) run the
            // unchanged scalar cell.
            {
                let cols = wrap3(0, w);
                perceive_cell(state, w, c, &rows, &cols, &mut perception);
                self.cell_update(state, next, (y * w) * c, &perception,
                                 &mut hidden, frozen);
            }
            let mut x0 = 1usize;
            while x0 + LANES <= w - 1 {
                // Perceive: id / Sobel-x / Sobel-y per channel, taps in
                // the scalar (ky outer, kx inner) order per lane.
                for ch in 0..c {
                    let mut gx = zero;
                    let mut gy = zero;
                    for (ky, &sy) in rows.iter().enumerate() {
                        for kx in 0..3 {
                            let base = (sy * w + x0 + kx - 1) * c + ch;
                            let v = load8_strided(state, base, c);
                            gx = _mm256_add_ps(
                                gx,
                                _mm256_mul_ps(
                                    _mm256_set1_ps(SOBEL_X[ky][kx]), v));
                            gy = _mm256_add_ps(
                                gy,
                                _mm256_mul_ps(
                                    _mm256_set1_ps(SOBEL_X[kx][ky]), v));
                        }
                    }
                    let base = (y * w + x0) * c + ch;
                    pvec[ch * 3] = load8_strided(state, base, c);
                    pvec[ch * 3 + 1] = gx;
                    pvec[ch * 3 + 2] = gy;
                }
                // MLP hidden layer: relu(p . W1 + b1), scalar k order.
                for (j, slot) in hvec.iter_mut().enumerate() {
                    let mut acc = _mm256_set1_ps(self.b1[j]);
                    for (k, &p) in pvec.iter().enumerate() {
                        acc = _mm256_add_ps(
                            acc,
                            _mm256_mul_ps(
                                p,
                                _mm256_set1_ps(
                                    self.w1[k * self.hidden + j])));
                    }
                    *slot = _mm256_max_ps(acc, zero);
                }
                // Residual update per channel; frozen channels store
                // the state lanes unchanged.
                for ch in 0..c {
                    let base = (y * w + x0) * c + ch;
                    let sv = load8_strided(state, base, c);
                    let out = if ch < frozen {
                        sv
                    } else {
                        let mut delta = zero;
                        for (j, &hv) in hvec.iter().enumerate() {
                            delta = _mm256_add_ps(
                                delta,
                                _mm256_mul_ps(
                                    hv,
                                    _mm256_set1_ps(self.w2[j * c + ch])));
                        }
                        _mm256_add_ps(sv, _mm256_mul_ps(dtv, delta))
                    };
                    store8_strided(next, base, c, out);
                }
                x0 += LANES;
            }
            for x in x0..w {
                let cols = wrap3(x, w);
                perceive_cell(state, w, c, &rows, &cols, &mut perception);
                self.cell_update(state, next, (y * w + x) * c, &perception,
                                 &mut hidden, frozen);
            }
        }
    }

    /// One forward update of a `[W, C]` row with the first `frozen`
    /// channels pinned — the 1D variant of [`NcaModel::step_frozen`]
    /// (identity + gradient + laplacian perceive, same MLP).
    pub fn step_frozen_1d(&self, state: &[f32], next: &mut [f32], w: usize,
                          frozen: usize) {
        let c = self.channels;
        debug_assert!(frozen <= c);
        debug_assert_eq!(state.len(), w * c);
        debug_assert_eq!(next.len(), state.len());
        let mut perception = vec![0.0f32; 3 * c];
        let mut hidden = vec![0.0f32; self.hidden];

        for x in 0..w {
            let cols = wrap3(x, w);
            perceive_cell_1d(state, c, &cols, &mut perception);
            self.cell_update(state, next, x * c, &perception, &mut hidden,
                             frozen);
        }
    }

    /// One frozen-aware forward update on either geometry.
    pub fn step_frozen_on(&self, grid: Grid, state: &[f32],
                          next: &mut [f32], frozen: usize) {
        match grid {
            Grid::D1 { w } => self.step_frozen_1d(state, next, w, frozen),
            Grid::D2 { h, w } => self.step_frozen(state, next, h, w, frozen),
        }
    }

    /// The shared per-cell tail of every forward step: MLP
    /// `relu(p . W1 + b1) . W2`, residual add, frozen pass-through.
    /// `base` is the cell's channel-0 offset; `hidden` is a scratch
    /// buffer of `self.hidden` floats.
    #[inline]
    fn cell_update(&self, state: &[f32], next: &mut [f32], base: usize,
                   perception: &[f32], hidden: &mut [f32], frozen: usize) {
        let c = self.channels;
        for (j, slot) in hidden.iter_mut().enumerate() {
            let mut acc = self.b1[j];
            for (k, &p) in perception.iter().enumerate() {
                acc += p * self.w1[k * self.hidden + j];
            }
            *slot = acc.max(0.0);
        }
        for ch in 0..c {
            let idx = base + ch;
            if ch < frozen {
                next[idx] = state[idx];
                continue;
            }
            let mut delta = 0.0f32;
            for (j, &hv) in hidden.iter().enumerate() {
                delta += hv * self.w2[j * c + ch];
            }
            next[idx] = state[idx] + self.dt * delta;
        }
    }

    /// Run `steps` updates in place; `scratch` must match `board`'s length.
    pub fn rollout(&self, board: &mut [f32], scratch: &mut [f32], h: usize,
                   w: usize, steps: usize) {
        for _ in 0..steps {
            self.step(board, scratch, h, w);
            board.copy_from_slice(scratch);
        }
    }

    /// Activity-map tile grid for an `h x w` board (32-cell tiles, all
    /// channels of a cell belong to its tile).
    pub fn tile_dims(h: usize, w: usize) -> (usize, usize) {
        (h.div_ceil(TILE), w.div_ceil(TILE))
    }

    /// One activity-tracked forward update: recompute only tiles whose
    /// 1-tile halo changed (the 3x3 perceive reads one cell out), then
    /// commit + re-mark by exact f32 bit comparison across all
    /// channels. Two passes keep read-before-write. Returns
    /// `(recomputed, skipped)` tile counts.
    ///
    /// Bit-identical to [`step_frozen`](Self::step_frozen): recomputed
    /// cells run the same [`perceive_cell`] + `cell_update` pair, and
    /// the AVX2 lanes match the scalar cell bit for bit
    /// (`native_simd_props`). Past ~60% tile occupancy this falls back
    /// to one dense step plus a full diff so a fully-active board never
    /// pays more than dense + one compare per float.
    pub fn step_sparse(&self, board: &mut [f32], scratch: &mut [f32],
                       h: usize, w: usize, frozen: usize,
                       map: &mut super::activity::ActivityMap)
        -> (u64, u64) {
        let c = self.channels;
        let (tr, tcols) = Self::tile_dims(h, w);
        let total = (tr * tcols) as u64;
        let needed = map.begin_step(1, 1) as u64;
        if needed == 0 {
            return (0, total);
        }
        if needed * 8 > total * 5 {
            self.step_frozen(board, scratch, h, w, frozen);
            for ty in 0..tr {
                for tx in 0..tcols {
                    if nca_tile_bits_differ(board, scratch, h, w, c, ty,
                                            tx) {
                        map.mark(ty, tx);
                    }
                }
            }
            board.copy_from_slice(scratch);
            return (total, 0);
        }
        let mut perception = vec![0.0f32; 3 * c];
        let mut hidden = vec![0.0f32; self.hidden];
        // Pass 1: recompute needed tiles into scratch, reading only
        // the old `board`.
        for ty in 0..tr {
            if !map.row_needed(ty) {
                continue;
            }
            for wi in 0..map.words_per_row() {
                let mut tiles = map.needs_word(ty, wi);
                while tiles != 0 {
                    let tx = wi * 64 + tiles.trailing_zeros() as usize;
                    tiles &= tiles - 1;
                    let (y1, x1) = (((ty + 1) * TILE).min(h),
                                    ((tx + 1) * TILE).min(w));
                    for y in ty * TILE..y1 {
                        let rows = wrap3(y, h);
                        for x in tx * TILE..x1 {
                            let cols = wrap3(x, w);
                            perceive_cell(board, w, c, &rows, &cols,
                                          &mut perception);
                            self.cell_update(board, scratch,
                                             (y * w + x) * c, &perception,
                                             &mut hidden, frozen);
                        }
                    }
                }
            }
        }
        // Pass 2: commit recomputed tiles, marking exact bit changes.
        for ty in 0..tr {
            if !map.row_needed(ty) {
                continue;
            }
            for wi in 0..map.words_per_row() {
                let mut tiles = map.needs_word(ty, wi);
                while tiles != 0 {
                    let tx = wi * 64 + tiles.trailing_zeros() as usize;
                    tiles &= tiles - 1;
                    if nca_tile_bits_differ(board, scratch, h, w, c, ty,
                                            tx) {
                        map.mark(ty, tx);
                    }
                    let (y1, x1) = (((ty + 1) * TILE).min(h),
                                    ((tx + 1) * TILE).min(w));
                    for y in ty * TILE..y1 {
                        let (a, b) = ((y * w + tx * TILE) * c,
                                      (y * w + x1 - 1) * c + c);
                        board[a..b].copy_from_slice(&scratch[a..b]);
                    }
                }
            }
        }
        (needed, total - needed)
    }

    /// Run `steps` activity-tracked updates (no frozen channels, like
    /// [`rollout`](Self::rollout)); the map carries dirty state across
    /// steps and calls. Returns summed `(recomputed, skipped)` counts.
    pub fn rollout_sparse(&self, board: &mut [f32], scratch: &mut [f32],
                          h: usize, w: usize, steps: usize,
                          map: &mut super::activity::ActivityMap)
        -> (u64, u64) {
        let (mut recomputed, mut skipped) = (0, 0);
        for _ in 0..steps {
            let (r, s) = self.step_sparse(board, scratch, h, w, 0, map);
            recomputed += r;
            skipped += s;
        }
        (recomputed, skipped)
    }
}

/// Whether any channel of any cell of tile (`ty`, `tx`) differs
/// between `a` and `b` as raw f32 bits.
fn nca_tile_bits_differ(a: &[f32], b: &[f32], h: usize, w: usize,
                        c: usize, ty: usize, tx: usize) -> bool {
    let (y1, x1) = (((ty + 1) * TILE).min(h), ((tx + 1) * TILE).min(w));
    for y in ty * TILE..y1 {
        let (s, e) = ((y * w + tx * TILE) * c, (y * w + x1 - 1) * c + c);
        if a[s..e]
            .iter()
            .zip(b[s..e].iter())
            .any(|(x, y)| x.to_bits() != y.to_bits())
        {
            return true;
        }
    }
    false
}

/// Depthwise perceive at one cell: identity, Sobel-x, Sobel-y per
/// channel, written into `out` as `[id, gx, gy]` triples. The single
/// copy of the perceive arithmetic — the forward kernel above and the
/// backward recompute in [`super::nca_grad`] both call it, so their
/// accumulation order can never drift apart.
#[inline]
pub(crate) fn perceive_cell(state: &[f32], w: usize, c: usize,
                            rows: &[usize; 3], cols: &[usize; 3],
                            out: &mut [f32]) {
    let (y, x) = (rows[1], cols[1]);
    for ch in 0..c {
        let mut gx = 0.0f32;
        let mut gy = 0.0f32;
        for (ky, &sy) in rows.iter().enumerate() {
            for (kx, &sx) in cols.iter().enumerate() {
                let v = state[(sy * w + sx) * c + ch];
                gx += SOBEL_X[ky][kx] * v;
                // Sobel-y is the transpose of Sobel-x.
                gy += SOBEL_X[kx][ky] * v;
            }
        }
        out[ch * 3] = state[(y * w + x) * c + ch];
        out[ch * 3 + 1] = gx;
        out[ch * 3 + 2] = gy;
    }
}

/// Depthwise perceive at one 1D cell: identity, gradient, laplacian per
/// channel, written into `out` as `[id, grad, lap]` triples. Like
/// [`perceive_cell`], this is the single copy of the 1D perceive
/// arithmetic — forward kernel and backward recompute share it.
#[inline]
pub(crate) fn perceive_cell_1d(state: &[f32], c: usize, cols: &[usize; 3],
                               out: &mut [f32]) {
    let x = cols[1];
    for ch in 0..c {
        let mut g = 0.0f32;
        let mut l = 0.0f32;
        for (k, &sx) in cols.iter().enumerate() {
            let v = state[sx * c + ch];
            g += GRAD_1D[k] * v;
            l += LAP_1D[k] * v;
        }
        out[ch * 3] = state[x * c + ch];
        out[ch * 3 + 1] = g;
        out[ch * 3 + 2] = l;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> NcaModel {
        NcaModel::random(4, 8, &mut Rng::new(9))
    }

    #[test]
    fn frozen_channels_pass_through_and_still_feed_perception() {
        let m = model();
        let (h, w) = (4, 4);
        let mut rng = Rng::new(3);
        let board = rng.vec_f32(h * w * m.channels);
        let mut next = vec![0.0f32; board.len()];
        m.step_frozen(&board, &mut next, h, w, 2);
        for cell in 0..h * w {
            for ch in 0..2 {
                let idx = cell * m.channels + ch;
                assert_eq!(next[idx], board[idx], "frozen ch {ch} moved");
            }
        }
        assert_ne!(board, next, "free channels should still update");

        // Freezing everything makes the update the identity.
        let mut all = vec![0.0f32; board.len()];
        m.step_frozen(&board, &mut all, h, w, m.channels);
        assert_eq!(all, board);
    }

    #[test]
    fn flat_roundtrip_is_exact() {
        let m = model();
        let flat = m.flatten();
        assert_eq!(flat.len(), NcaModel::param_count(m.channels, m.hidden));
        let back = NcaModel::from_flat(m.channels, m.hidden, m.dt, &flat);
        assert_eq!(back.w1, m.w1);
        assert_eq!(back.b1, m.b1);
        assert_eq!(back.w2, m.w2);
        assert_eq!(back.dt, m.dt);
    }

    #[test]
    fn step_is_finite_and_shaped() {
        let m = model();
        let (h, w) = (7, 9);
        let mut rng = Rng::new(1);
        let board = rng.vec_f32(h * w * m.channels);
        let mut next = vec![0.0f32; board.len()];
        m.step(&board, &mut next, h, w);
        assert!(next.iter().all(|v| v.is_finite()));
        assert_ne!(board, next, "random model should move the state");
    }

    #[test]
    fn uniform_state_has_zero_gradients() {
        // On a constant field both Sobel responses vanish, so every cell
        // computes the identical update: the state stays uniform.
        let m = model();
        let (h, w) = (6, 6);
        let board = vec![0.3f32; h * w * m.channels];
        let mut next = vec![0.0f32; board.len()];
        m.step(&board, &mut next, h, w);
        for ch in 0..m.channels {
            let v0 = next[ch];
            for cell in 0..h * w {
                let v = next[cell * m.channels + ch];
                assert!((v - v0).abs() < 1e-6,
                        "cell {cell} ch {ch}: {v} vs {v0}");
            }
        }
    }

    #[test]
    fn grid_cells_and_dispatch() {
        assert_eq!(Grid::D1 { w: 9 }.cells(), 9);
        assert_eq!(Grid::D2 { h: 4, w: 5 }.cells(), 20);
        // step_frozen_on routes to the matching kernel.
        let m = model();
        let mut rng = Rng::new(6);
        let row = rng.vec_f32(7 * m.channels);
        let mut a = vec![0.0f32; row.len()];
        let mut b = vec![0.0f32; row.len()];
        m.step_frozen_1d(&row, &mut a, 7, 1);
        m.step_frozen_on(Grid::D1 { w: 7 }, &row, &mut b, 1);
        assert_eq!(a, b);
        let board = rng.vec_f32(4 * 5 * m.channels);
        let mut c2 = vec![0.0f32; board.len()];
        let mut d2 = vec![0.0f32; board.len()];
        m.step_frozen(&board, &mut c2, 4, 5, 2);
        m.step_frozen_on(Grid::D2 { h: 4, w: 5 }, &board, &mut d2, 2);
        assert_eq!(c2, d2);
    }

    #[test]
    fn frozen_channels_pass_through_in_1d_too() {
        let m = model();
        let w = 9;
        let mut rng = Rng::new(13);
        let row = rng.vec_f32(w * m.channels);
        let mut next = vec![0.0f32; row.len()];
        m.step_frozen_1d(&row, &mut next, w, 2);
        for cell in 0..w {
            for ch in 0..2 {
                let idx = cell * m.channels + ch;
                assert_eq!(next[idx], row[idx], "frozen ch {ch} moved");
            }
        }
        assert_ne!(row, next, "free channels should still update");
    }

    #[test]
    fn uniform_row_stays_uniform() {
        // Gradient and laplacian vanish on a constant row, so every
        // cell computes the identical update.
        let m = model();
        let w = 8;
        let row = vec![0.4f32; w * m.channels];
        let mut next = vec![0.0f32; row.len()];
        m.step_frozen_1d(&row, &mut next, w, 0);
        for ch in 0..m.channels {
            let v0 = next[ch];
            for cell in 0..w {
                let v = next[cell * m.channels + ch];
                assert!((v - v0).abs() < 1e-6, "cell {cell} ch {ch}");
            }
        }
    }

    #[test]
    fn translation_equivariant_on_ring() {
        let m = model();
        let w = 11;
        let c = m.channels;
        let mut rng = Rng::new(21);
        let row = rng.vec_f32(w * c);
        let mut shifted = vec![0.0f32; row.len()];
        for x in 0..w {
            for ch in 0..c {
                shifted[((x + 4) % w) * c + ch] = row[x * c + ch];
            }
        }
        let mut out_a = vec![0.0f32; row.len()];
        let mut out_b = vec![0.0f32; row.len()];
        m.step_frozen_1d(&row, &mut out_a, w, 0);
        m.step_frozen_1d(&shifted, &mut out_b, w, 0);
        for x in 0..w {
            for ch in 0..c {
                let a = out_a[x * c + ch];
                let b = out_b[((x + 4) % w) * c + ch];
                assert!((a - b).abs() < 1e-5,
                        "1D equivariance broke at ({x},{ch})");
            }
        }
    }

    #[test]
    fn perceive_1d_recovers_known_stencils() {
        // One channel, an impulse at x=2 on a 5-cell ring: id/grad/lap
        // at each cell are the stencil taps themselves.
        let state = [0.0f32, 0.0, 1.0, 0.0, 0.0];
        let mut out = [0.0f32; 3];
        // At x=1 the impulse is the right neighbour.
        perceive_cell_1d(&state, 1, &wrap3(1, 5), &mut out);
        assert_eq!(out, [0.0, 0.5, 0.25]);
        // At x=2 it is the centre.
        perceive_cell_1d(&state, 1, &wrap3(2, 5), &mut out);
        assert_eq!(out, [1.0, 0.0, -0.5]);
        // At x=3 it is the left neighbour.
        perceive_cell_1d(&state, 1, &wrap3(3, 5), &mut out);
        assert_eq!(out, [0.0, -0.5, 0.25]);
    }

    #[test]
    fn translation_equivariant_on_torus() {
        let m = model();
        let (h, w) = (8, 8);
        let c = m.channels;
        let mut rng = Rng::new(4);
        let board = rng.vec_f32(h * w * c);
        // Shift input by (2, 3) with wrap.
        let mut shifted = vec![0.0f32; board.len()];
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    shifted[(((y + 2) % h) * w + (x + 3) % w) * c + ch] =
                        board[(y * w + x) * c + ch];
                }
            }
        }
        let mut out_a = vec![0.0f32; board.len()];
        let mut out_b = vec![0.0f32; board.len()];
        m.step(&board, &mut out_a, h, w);
        m.step(&shifted, &mut out_b, h, w);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    let a = out_a[(y * w + x) * c + ch];
                    let b = out_b
                        [(((y + 2) % h) * w + (x + 3) % w) * c + ch];
                    assert!((a - b).abs() < 1e-5,
                            "equivariance broke at ({y},{x},{ch})");
                }
            }
        }
    }
}
