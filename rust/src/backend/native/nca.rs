//! Native neural-CA forward cell: depthwise 3x3 perceive + per-cell MLP.
//!
//! The standard NCA update (Mordvintsev et al. 2020, the cell every
//! Table-1 neural row builds on): each channel is filtered with the
//! identity, Sobel-x and Sobel-y kernels (depthwise — no cross-channel
//! mixing in the conv), the 3C perception vector goes through a shared
//! two-layer MLP per cell, and the result is added to the state. The
//! kernel walks the grid row-by-row with precomputed wrapped row
//! indices, so the three input rows a sweep touches stay in cache —
//! the depthwise-conv/update analogue of the tiled Lenia path.

use super::wrap3;
use crate::util::rng::Rng;

/// Sobel-x, normalized by 8 as in the reference NCA perceive step.
/// Shared with the backward pass in [`super::nca_grad`].
pub(crate) const SOBEL_X: [[f32; 3]; 3] = [
    [-0.125, 0.0, 0.125],
    [-0.25, 0.0, 0.25],
    [-0.125, 0.0, 0.125],
];

/// Weights of a native NCA cell.
#[derive(Clone, Debug)]
pub struct NcaModel {
    pub channels: usize,
    pub hidden: usize,
    /// `[3*channels, hidden]` row-major: perception -> hidden.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// `[hidden, channels]` row-major: hidden -> state delta.
    pub w2: Vec<f32>,
    /// Update scale (the residual step size).
    pub dt: f32,
}

impl NcaModel {
    /// Random small-weight model (test/bench substrate; trained weights
    /// would come from a checkpoint).
    pub fn random(channels: usize, hidden: usize, rng: &mut Rng) -> NcaModel {
        assert!(channels > 0 && hidden > 0);
        let fan_in = 3 * channels;
        let scale1 = 1.0 / (fan_in as f32).sqrt();
        let scale2 = 0.1 / (hidden as f32).sqrt();
        NcaModel {
            channels,
            hidden,
            w1: (0..fan_in * hidden)
                .map(|_| rng.normal() * scale1)
                .collect(),
            b1: vec![0.0; hidden],
            w2: (0..hidden * channels)
                .map(|_| rng.normal() * scale2)
                .collect(),
            dt: 0.5,
        }
    }

    /// Number of trainable parameters (`w1`, `b1`, `w2`) of a cell with
    /// this geometry — the flat checkpoint/optimizer vector length.
    pub fn param_count(channels: usize, hidden: usize) -> usize {
        3 * channels * hidden + hidden + hidden * channels
    }

    /// Flatten the trainable parameters as `[w1, b1, w2]` — the layout of
    /// the native train-step parameter vector and of
    /// [`crate::coordinator::trainer::TrainState`] checkpoints.
    pub fn flatten(&self) -> Vec<f32> {
        let mut flat =
            Vec::with_capacity(Self::param_count(self.channels, self.hidden));
        flat.extend_from_slice(&self.w1);
        flat.extend_from_slice(&self.b1);
        flat.extend_from_slice(&self.w2);
        flat
    }

    /// Rebuild a model from the `[w1, b1, w2]` flat layout written by
    /// [`NcaModel::flatten`].
    pub fn from_flat(channels: usize, hidden: usize, dt: f32, flat: &[f32])
                     -> NcaModel {
        assert_eq!(flat.len(), Self::param_count(channels, hidden),
                   "from_flat: {} params for a {channels}-channel, \
                    {hidden}-hidden cell", flat.len());
        let n1 = 3 * channels * hidden;
        NcaModel {
            channels,
            hidden,
            w1: flat[..n1].to_vec(),
            b1: flat[n1..n1 + hidden].to_vec(),
            w2: flat[n1 + hidden..].to_vec(),
            dt,
        }
    }

    /// One forward update of a `[H, W, C]` channels-last board.
    pub fn step(&self, state: &[f32], next: &mut [f32], h: usize, w: usize) {
        self.step_frozen(state, next, h, w, 0);
    }

    /// One forward update with the first `frozen` channels pinned: their
    /// residual delta is zeroed, so they pass through unchanged (the
    /// self-classifying-MNIST input channel). They still feed perception.
    pub fn step_frozen(&self, state: &[f32], next: &mut [f32], h: usize,
                       w: usize, frozen: usize) {
        let c = self.channels;
        debug_assert!(frozen <= c);
        debug_assert_eq!(state.len(), h * w * c);
        debug_assert_eq!(next.len(), state.len());
        let mut perception = vec![0.0f32; 3 * c];
        let mut hidden = vec![0.0f32; self.hidden];

        for y in 0..h {
            let rows = wrap3(y, h);
            for x in 0..w {
                let cols = wrap3(x, w);
                perceive_cell(state, w, c, &rows, &cols, &mut perception);

                // Per-cell MLP: relu(p . W1 + b1) . W2, residual add.
                for (j, slot) in hidden.iter_mut().enumerate() {
                    let mut acc = self.b1[j];
                    for (k, &p) in perception.iter().enumerate() {
                        acc += p * self.w1[k * self.hidden + j];
                    }
                    *slot = acc.max(0.0);
                }
                for ch in 0..c {
                    let idx = (y * w + x) * c + ch;
                    if ch < frozen {
                        next[idx] = state[idx];
                        continue;
                    }
                    let mut delta = 0.0f32;
                    for (j, &hv) in hidden.iter().enumerate() {
                        delta += hv * self.w2[j * c + ch];
                    }
                    next[idx] = state[idx] + self.dt * delta;
                }
            }
        }
    }

    /// Run `steps` updates in place; `scratch` must match `board`'s length.
    pub fn rollout(&self, board: &mut [f32], scratch: &mut [f32], h: usize,
                   w: usize, steps: usize) {
        for _ in 0..steps {
            self.step(board, scratch, h, w);
            board.copy_from_slice(scratch);
        }
    }
}

/// Depthwise perceive at one cell: identity, Sobel-x, Sobel-y per
/// channel, written into `out` as `[id, gx, gy]` triples. The single
/// copy of the perceive arithmetic — the forward kernel above and the
/// backward recompute in [`super::nca_grad`] both call it, so their
/// accumulation order can never drift apart.
#[inline]
pub(crate) fn perceive_cell(state: &[f32], w: usize, c: usize,
                            rows: &[usize; 3], cols: &[usize; 3],
                            out: &mut [f32]) {
    let (y, x) = (rows[1], cols[1]);
    for ch in 0..c {
        let mut gx = 0.0f32;
        let mut gy = 0.0f32;
        for (ky, &sy) in rows.iter().enumerate() {
            for (kx, &sx) in cols.iter().enumerate() {
                let v = state[(sy * w + sx) * c + ch];
                gx += SOBEL_X[ky][kx] * v;
                // Sobel-y is the transpose of Sobel-x.
                gy += SOBEL_X[kx][ky] * v;
            }
        }
        out[ch * 3] = state[(y * w + x) * c + ch];
        out[ch * 3 + 1] = gx;
        out[ch * 3 + 2] = gy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> NcaModel {
        NcaModel::random(4, 8, &mut Rng::new(9))
    }

    #[test]
    fn frozen_channels_pass_through_and_still_feed_perception() {
        let m = model();
        let (h, w) = (4, 4);
        let mut rng = Rng::new(3);
        let board = rng.vec_f32(h * w * m.channels);
        let mut next = vec![0.0f32; board.len()];
        m.step_frozen(&board, &mut next, h, w, 2);
        for cell in 0..h * w {
            for ch in 0..2 {
                let idx = cell * m.channels + ch;
                assert_eq!(next[idx], board[idx], "frozen ch {ch} moved");
            }
        }
        assert_ne!(board, next, "free channels should still update");

        // Freezing everything makes the update the identity.
        let mut all = vec![0.0f32; board.len()];
        m.step_frozen(&board, &mut all, h, w, m.channels);
        assert_eq!(all, board);
    }

    #[test]
    fn flat_roundtrip_is_exact() {
        let m = model();
        let flat = m.flatten();
        assert_eq!(flat.len(), NcaModel::param_count(m.channels, m.hidden));
        let back = NcaModel::from_flat(m.channels, m.hidden, m.dt, &flat);
        assert_eq!(back.w1, m.w1);
        assert_eq!(back.b1, m.b1);
        assert_eq!(back.w2, m.w2);
        assert_eq!(back.dt, m.dt);
    }

    #[test]
    fn step_is_finite_and_shaped() {
        let m = model();
        let (h, w) = (7, 9);
        let mut rng = Rng::new(1);
        let board = rng.vec_f32(h * w * m.channels);
        let mut next = vec![0.0f32; board.len()];
        m.step(&board, &mut next, h, w);
        assert!(next.iter().all(|v| v.is_finite()));
        assert_ne!(board, next, "random model should move the state");
    }

    #[test]
    fn uniform_state_has_zero_gradients() {
        // On a constant field both Sobel responses vanish, so every cell
        // computes the identical update: the state stays uniform.
        let m = model();
        let (h, w) = (6, 6);
        let board = vec![0.3f32; h * w * m.channels];
        let mut next = vec![0.0f32; board.len()];
        m.step(&board, &mut next, h, w);
        for ch in 0..m.channels {
            let v0 = next[ch];
            for cell in 0..h * w {
                let v = next[cell * m.channels + ch];
                assert!((v - v0).abs() < 1e-6,
                        "cell {cell} ch {ch}: {v} vs {v0}");
            }
        }
    }

    #[test]
    fn translation_equivariant_on_torus() {
        let m = model();
        let (h, w) = (8, 8);
        let c = m.channels;
        let mut rng = Rng::new(4);
        let board = rng.vec_f32(h * w * c);
        // Shift input by (2, 3) with wrap.
        let mut shifted = vec![0.0f32; board.len()];
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    shifted[(((y + 2) % h) * w + (x + 3) % w) * c + ch] =
                        board[(y * w + x) * c + ch];
                }
            }
        }
        let mut out_a = vec![0.0f32; board.len()];
        let mut out_b = vec![0.0f32; board.len()];
        m.step(&board, &mut out_a, h, w);
        m.step(&shifted, &mut out_b, h, w);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    let a = out_a[(y * w + x) * c + ch];
                    let b = out_b
                        [(((y + 2) % h) * w + (x + 3) % w) * c + ch];
                    assert!((a - b).abs() < 1e-5,
                            "equivariance broke at ({y},{x},{ch})");
                }
            }
        }
    }
}
