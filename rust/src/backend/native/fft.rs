//! In-tree fast Fourier transforms — the substrate of the spectral
//! Lenia path, with no external dependencies (matching the
//! vendored-everything policy of the hermetic build).
//!
//! Two transform kinds behind one [`Fft`] plan:
//!
//! - **Power-of-two sizes**: iterative Cooley–Tukey (bit-reversal
//!   permutation + in-place butterflies over a precomputed twiddle
//!   table).
//! - **Arbitrary sizes**: Bluestein's chirp-z algorithm — the size-`n`
//!   DFT is re-expressed as a circular convolution of chirp-modulated
//!   sequences, carried out with a power-of-two FFT of length
//!   `>= 2n - 1`. This keeps non-power-of-two Lenia boards (e.g. the
//!   paper's odd grids, or 40/96/250 in the test battery) on the fast
//!   path with full accuracy.
//!
//! All arithmetic is `f64`: the spectral Lenia step casts back to `f32`
//! only after the inverse transform, so the convolution it computes is
//! exact at `f32` resolution (roundtrip error ~1e-12, far below the
//! 1e-4 differential contract).
//!
//! Plans are immutable after construction (`&self` transforms), so one
//! plan is shared by every worker thread; transforms allocate only for
//! the Bluestein scratch, never for the power-of-two path.
//!
//! # Example
//!
//! A non-power-of-two roundtrip (size 6 exercises Bluestein):
//!
//! ```
//! use cax::backend::native::fft::{Complex, Fft};
//!
//! let fft = Fft::new(6);
//! let signal: Vec<Complex> =
//!     (0..6).map(|k| Complex::new(k as f64, 0.0)).collect();
//! let mut buf = signal.clone();
//! fft.forward(&mut buf);
//! // DC bin is the sum of the signal: 0 + 1 + ... + 5 = 15.
//! assert!((buf[0].re - 15.0).abs() < 1e-9);
//! fft.inverse(&mut buf);
//! for (a, b) in buf.iter().zip(&signal) {
//!     assert!((a.re - b.re).abs() < 1e-9 && a.im.abs() < 1e-9);
//! }
//! ```

use std::f64::consts::PI;
use std::ops::{Add, Mul, Sub};

/// A complex number in `f64` — the element type of every transform.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> Complex {
        Complex { re, im }
    }

    /// `e^{i theta}` on the unit circle.
    pub fn cis(theta: f64) -> Complex {
        Complex { re: theta.cos(), im: theta.sin() }
    }

    pub fn conj(self) -> Complex {
        Complex { re: self.re, im: -self.im }
    }

    pub fn scale(self, s: f64) -> Complex {
        Complex { re: self.re * s, im: self.im * s }
    }

    /// Squared magnitude `re^2 + im^2` (Parseval sums).
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }
}

impl Add for Complex {
    type Output = Complex;
    fn add(self, o: Complex) -> Complex {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }
}

impl Sub for Complex {
    type Output = Complex;
    fn sub(self, o: Complex) -> Complex {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for Complex {
    type Output = Complex;
    fn mul(self, o: Complex) -> Complex {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

// ------------------------------------------------------- power of two

/// Iterative in-place Cooley–Tukey plan for a power-of-two size.
#[derive(Clone, Debug)]
struct Pow2Fft {
    n: usize,
    /// Bit-reversal permutation of `0..n`.
    rev: Vec<u32>,
    /// Twiddles `W_n^k = e^{-2 pi i k / n}` for `k < n/2`; stage `len`
    /// reads `W_len^j` at stride `n / len`.
    tw: Vec<Complex>,
}

impl Pow2Fft {
    fn new(n: usize) -> Pow2Fft {
        debug_assert!(n.is_power_of_two());
        let bits = n.trailing_zeros();
        let rev = (0..n as u32)
            .map(|i| if bits == 0 { 0 } else { i.reverse_bits() >> (32 - bits) })
            .collect();
        let tw = (0..n / 2)
            .map(|k| Complex::cis(-2.0 * PI * k as f64 / n as f64))
            .collect();
        Pow2Fft { n, rev, tw }
    }

    /// Forward DFT (`e^{-2 pi i nk/N}` kernel, unnormalized), in place.
    fn forward(&self, a: &mut [Complex]) {
        let n = self.n;
        debug_assert_eq!(a.len(), n);
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                a.swap(i, j);
            }
        }
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let stride = n / len;
            let mut block = 0;
            while block < n {
                for j in 0..half {
                    let w = self.tw[j * stride];
                    let t = w * a[block + j + half];
                    let u = a[block + j];
                    a[block + j] = u + t;
                    a[block + j + half] = u - t;
                }
                block += len;
            }
            len *= 2;
        }
    }
}

// ------------------------------------------------------------ Bluestein

/// Bluestein chirp-z plan: size-`n` DFT as a circular convolution of
/// length `m = next_pow2(2n - 1)`.
#[derive(Clone, Debug)]
struct Bluestein {
    n: usize,
    m: usize,
    pow2: Pow2Fft,
    /// `chirp[k] = e^{-i pi k^2 / n}` (the quadratic phase ramp). The
    /// argument uses `k^2 mod 2n` — the phase has period `2n` in `k^2`,
    /// and keeping it small preserves precision for large `k`.
    chirp: Vec<Complex>,
    /// Forward FFT (length `m`) of the wrapped conjugate chirp.
    bhat: Vec<Complex>,
}

impl Bluestein {
    fn new(n: usize) -> Bluestein {
        let m = (2 * n - 1).next_power_of_two();
        let pow2 = Pow2Fft::new(m);
        let chirp: Vec<Complex> = (0..n)
            .map(|k| {
                let q = (k * k) % (2 * n);
                Complex::cis(-PI * q as f64 / n as f64)
            })
            .collect();
        let mut b = vec![Complex::ZERO; m];
        b[0] = Complex::ONE;
        for k in 1..n {
            // The linear-convolution kernel b[j] = e^{+i pi j^2/n} needs
            // indices -(n-1)..=(n-1); circular wrap puts -k at m - k.
            let v = chirp[k].conj();
            b[k] = v;
            b[m - k] = v;
        }
        pow2.forward(&mut b);
        Bluestein { n, m, pow2, chirp, bhat: b }
    }

    fn forward(&self, x: &mut [Complex]) {
        debug_assert_eq!(x.len(), self.n);
        // X_k = chirp_k * sum_j (x_j chirp_j) e^{+i pi (k-j)^2 / n}:
        // chirp-modulate, convolve with the conjugate chirp, demodulate.
        let mut a = vec![Complex::ZERO; self.m];
        for k in 0..self.n {
            a[k] = x[k] * self.chirp[k];
        }
        self.pow2.forward(&mut a);
        for (v, &b) in a.iter_mut().zip(&self.bhat) {
            *v = *v * b;
        }
        // Inverse length-m FFT via conj(forward(conj(.))) / m.
        for v in a.iter_mut() {
            *v = v.conj();
        }
        self.pow2.forward(&mut a);
        let s = 1.0 / self.m as f64;
        for k in 0..self.n {
            x[k] = a[k].conj().scale(s) * self.chirp[k];
        }
    }
}

// ------------------------------------------------------------- 1D plan

/// A 1D DFT plan of any size `n >= 1`. Power-of-two sizes run the
/// iterative Cooley–Tukey path; everything else runs Bluestein.
#[derive(Clone, Debug)]
pub struct Fft {
    n: usize,
    kind: Kind,
}

#[derive(Clone, Debug)]
enum Kind {
    Pow2(Pow2Fft),
    Bluestein(Bluestein),
}

impl Fft {
    pub fn new(n: usize) -> Fft {
        assert!(n >= 1, "Fft::new: size must be >= 1");
        let kind = if n.is_power_of_two() {
            Kind::Pow2(Pow2Fft::new(n))
        } else {
            Kind::Bluestein(Bluestein::new(n))
        };
        Fft { n, kind }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false // n >= 1 by construction
    }

    /// Whether this plan runs the Bluestein (non-power-of-two) path.
    pub fn is_bluestein(&self) -> bool {
        matches!(self.kind, Kind::Bluestein(_))
    }

    /// Forward DFT in place: `X_k = sum_j x_j e^{-2 pi i jk / n}`
    /// (unnormalized).
    pub fn forward(&self, data: &mut [Complex]) {
        assert_eq!(data.len(), self.n, "Fft::forward: length mismatch");
        match &self.kind {
            Kind::Pow2(p) => p.forward(data),
            Kind::Bluestein(b) => b.forward(data),
        }
    }

    /// Inverse DFT in place, normalized by `1/n` so
    /// `inverse(forward(x)) == x`.
    pub fn inverse(&self, data: &mut [Complex]) {
        assert_eq!(data.len(), self.n, "Fft::inverse: length mismatch");
        for v in data.iter_mut() {
            *v = v.conj();
        }
        self.forward(data);
        let s = 1.0 / self.n as f64;
        for v in data.iter_mut() {
            *v = v.conj().scale(s);
        }
    }
}

// ------------------------------------------------------------- 2D plan

/// A 2D DFT plan over row-major `[H, W]` grids: rows through a width-`w`
/// plan, then columns through a height-`h` plan. Real input enters
/// through [`Fft2::load_real`]; the spectral Lenia step reads only the
/// real part back after [`Fft2::inverse`].
#[derive(Clone, Debug)]
pub struct Fft2 {
    h: usize,
    w: usize,
    row: Fft,
    col: Fft,
}

impl Fft2 {
    pub fn new(h: usize, w: usize) -> Fft2 {
        Fft2 { h, w, row: Fft::new(w), col: Fft::new(h) }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    /// Load a real `[H, W]` field into a complex grid (imaginary 0).
    pub fn load_real(&self, src: &[f32], dst: &mut [Complex]) {
        assert_eq!(src.len(), self.h * self.w);
        assert_eq!(dst.len(), self.h * self.w);
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = Complex::new(s as f64, 0.0);
        }
    }

    /// Forward 2D DFT in place (unnormalized).
    pub fn forward(&self, grid: &mut [Complex]) {
        self.pass(grid, false);
    }

    /// Inverse 2D DFT in place, normalized by `1/(h*w)`.
    pub fn inverse(&self, grid: &mut [Complex]) {
        self.pass(grid, true);
    }

    fn pass(&self, grid: &mut [Complex], inverse: bool) {
        let (h, w) = (self.h, self.w);
        assert_eq!(grid.len(), h * w, "Fft2: grid length mismatch");
        for row in grid.chunks_mut(w) {
            if inverse {
                self.row.inverse(row);
            } else {
                self.row.forward(row);
            }
        }
        let mut col = vec![Complex::ZERO; h];
        for x in 0..w {
            for y in 0..h {
                col[y] = grid[y * w + x];
            }
            if inverse {
                self.col.inverse(&mut col);
            } else {
                self.col.forward(&mut col);
            }
            for y in 0..h {
                grid[y * w + x] = col[y];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Direct O(n^2) DFT — the definition, as the differential anchor.
    fn dft_naive(x: &[Complex]) -> Vec<Complex> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut acc = Complex::ZERO;
                for (j, &v) in x.iter().enumerate() {
                    let theta = -2.0 * PI * (j * k % n) as f64 / n as f64;
                    acc = acc + v * Complex::cis(theta);
                }
                acc
            })
            .collect()
    }

    fn random_signal(n: usize, rng: &mut Rng) -> Vec<Complex> {
        (0..n)
            .map(|_| {
                Complex::new(rng.next_f64() - 0.5, rng.next_f64() - 0.5)
            })
            .collect()
    }

    fn max_err(a: &[Complex], b: &[Complex]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| ((x.re - y.re).abs()).max((x.im - y.im).abs()))
            .fold(0.0, f64::max)
    }

    #[test]
    fn matches_naive_dft_pow2_and_bluestein() {
        let mut rng = Rng::new(0xFF7);
        for n in [1usize, 2, 3, 4, 5, 7, 8, 12, 16, 27, 40, 64, 96, 100] {
            let x = random_signal(n, &mut rng);
            let expect = dft_naive(&x);
            let fft = Fft::new(n);
            assert_eq!(fft.is_bluestein(), !n.is_power_of_two());
            let mut got = x.clone();
            fft.forward(&mut got);
            let err = max_err(&got, &expect);
            assert!(err < 1e-9, "n={n}: fft vs naive dft err {err}");
        }
    }

    #[test]
    fn fft2_matches_separable_naive_dft() {
        let mut rng = Rng::new(0xF2D);
        let (h, w) = (6, 10); // both Bluestein
        let grid = random_signal(h * w, &mut rng);
        // Naive: DFT rows, then DFT columns.
        let mut expect: Vec<Complex> = Vec::new();
        for row in grid.chunks(w) {
            expect.extend(dft_naive(row));
        }
        for x in 0..w {
            let col: Vec<Complex> =
                (0..h).map(|y| expect[y * w + x]).collect();
            for (y, v) in dft_naive(&col).into_iter().enumerate() {
                expect[y * w + x] = v;
            }
        }
        let fft = Fft2::new(h, w);
        let mut got = grid.clone();
        fft.forward(&mut got);
        let err = max_err(&got, &expect);
        assert!(err < 1e-9, "fft2 vs naive err {err}");
    }

    #[test]
    fn inverse_is_normalized_roundtrip() {
        let mut rng = Rng::new(0x1F);
        for n in [8usize, 24, 250] {
            let x = random_signal(n, &mut rng);
            let fft = Fft::new(n);
            let mut buf = x.clone();
            fft.forward(&mut buf);
            fft.inverse(&mut buf);
            let err = max_err(&buf, &x);
            assert!(err < 1e-10, "n={n}: roundtrip err {err}");
        }
    }

    #[test]
    fn load_real_zeroes_imaginary() {
        let fft = Fft2::new(2, 3);
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut dst = vec![Complex::ONE; 6];
        fft.load_real(&src, &mut dst);
        for (d, &s) in dst.iter().zip(&src) {
            assert_eq!(d.re, s as f64);
            assert_eq!(d.im, 0.0);
        }
    }
}
