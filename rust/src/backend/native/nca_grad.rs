//! Reverse-mode BPTT through the native NCA cell, parametric in the
//! grid dimension.
//!
//! The forward cell ([`NcaModel::step_frozen_on`]) is `s' = s + dt *
//! relu(P(s) W1 + b1) W2`, where `P` is the linear depthwise perceive —
//! identity + Sobel-x + Sobel-y on a [`Grid::D2`] torus, identity +
//! gradient + laplacian on a [`Grid::D1`] ring. This module unrolls it:
//! [`rollout_tape_on`] records every intermediate state, [`backward_on`]
//! walks the tape in reverse and accumulates exact parameter gradients
//! — residual pass-through, the ReLU mask, and the transposed perceive
//! stencil (a scatter with the same wrapped support as the forward
//! gather, sharing the forward's `perceive_cell`/`perceive_cell_1d` for
//! the recompute). Only the perceive gather and its transposed scatter
//! depend on the dimension; the per-cell MLP backward
//! (`mlp_backward_cell`) is one shared implementation.
//!
//! The hidden activations are *recomputed* from the cached states during
//! the backward sweep rather than stored: the tape then costs `(T+1) *
//! cells * C` floats instead of an extra `T * cells * hidden`, and the
//! recompute reuses the cache-resident input rows the scatter touches
//! anyway.
//!
//! # Gradient-check invariant
//!
//! `tests/native_train_props.rs` (2D) and `tests/native_arc_props.rs`
//! (1D) verify the gradients produced here against central finite
//! differences on small boards (relative error `< 1e-3` per parameter
//! group `w1`, `b1`, `w2`, for both the free and the frozen-channel
//! cell). Change the math here only with those tests in hand. All
//! accumulation is sequential per board in a fixed order, so results
//! are bit-identical for any worker-thread count.

use super::nca::{
    perceive_cell, perceive_cell_1d, Grid, NcaModel, GRAD_1D, LAP_1D,
    SOBEL_X,
};
use super::wrap3;

/// Gradients of the trainable parameter groups of one [`NcaModel`].
#[derive(Clone, Debug)]
pub struct NcaGrads {
    /// `[3*channels, hidden]` row-major, like [`NcaModel::w1`].
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// `[hidden, channels]` row-major, like [`NcaModel::w2`].
    pub w2: Vec<f32>,
}

impl NcaGrads {
    /// All-zero gradients shaped for `model`.
    pub fn zeros(model: &NcaModel) -> NcaGrads {
        NcaGrads {
            w1: vec![0.0; model.w1.len()],
            b1: vec![0.0; model.b1.len()],
            w2: vec![0.0; model.w2.len()],
        }
    }

    /// Accumulate `other` into `self` (fixed order: the batch reduction).
    pub fn add(&mut self, other: &NcaGrads) {
        debug_assert_eq!(self.w1.len(), other.w1.len());
        for (a, b) in self.w1.iter_mut().zip(&other.w1) {
            *a += b;
        }
        for (a, b) in self.b1.iter_mut().zip(&other.b1) {
            *a += b;
        }
        for (a, b) in self.w2.iter_mut().zip(&other.w2) {
            *a += b;
        }
    }

    /// Flatten as `[w1, b1, w2]` — the same layout as
    /// [`NcaModel::flatten`], so the optimizer walks parameters and
    /// gradients with one index.
    pub fn flatten(&self) -> Vec<f32> {
        let mut flat = Vec::with_capacity(
            self.w1.len() + self.b1.len() + self.w2.len());
        flat.extend_from_slice(&self.w1);
        flat.extend_from_slice(&self.b1);
        flat.extend_from_slice(&self.w2);
        flat
    }
}

/// Roll out `steps` frozen-aware 2D updates, recording every state —
/// see [`rollout_tape_on`].
pub fn rollout_tape(model: &NcaModel, board: &[f32], h: usize, w: usize,
                    steps: usize, frozen: usize) -> Vec<Vec<f32>> {
    rollout_tape_on(model, board, Grid::D2 { h, w }, steps, frozen)
}

/// Roll out `steps` frozen-aware updates
/// ([`NcaModel::step_frozen_on`]) on either geometry, recording every
/// state: `tape[0]` is (a copy of) `board`, `tape[steps]` the final
/// state.
pub fn rollout_tape_on(model: &NcaModel, board: &[f32], grid: Grid,
                       steps: usize, frozen: usize) -> Vec<Vec<f32>> {
    debug_assert_eq!(board.len(), grid.cells() * model.channels);
    let mut tape = Vec::with_capacity(steps + 1);
    tape.push(board.to_vec());
    for t in 0..steps {
        let mut next = vec![0.0f32; board.len()];
        model.step_frozen_on(grid, &tape[t], &mut next, frozen);
        tape.push(next);
    }
    tape
}

/// Backprop through a 2D [`rollout_tape`] tape — see [`backward_on`].
pub fn backward(model: &NcaModel, tape: &[Vec<f32>], h: usize, w: usize,
                frozen: usize, d_final: &[f32]) -> (NcaGrads, Vec<f32>) {
    backward_on(model, tape, Grid::D2 { h, w }, frozen, d_final)
}

/// Backprop `d_final = dL/d(state_T)` through a [`rollout_tape_on`]
/// tape. Returns the parameter gradients and `dL/d(state_0)`.
///
/// `grid` and `frozen` must match the forward call. Frozen channels
/// contribute no delta, so their only backward paths are the residual
/// identity and the perceive stencil reading them.
pub fn backward_on(model: &NcaModel, tape: &[Vec<f32>], grid: Grid,
                   frozen: usize, d_final: &[f32]) -> (NcaGrads, Vec<f32>) {
    let c = model.channels;
    debug_assert!(!tape.is_empty());
    debug_assert_eq!(d_final.len(), grid.cells() * c);
    debug_assert!(frozen <= c);

    let mut grads = NcaGrads::zeros(model);
    let mut g = d_final.to_vec();
    let mut perception = vec![0.0f32; 3 * c];
    let mut pre = vec![0.0f32; model.hidden];
    let mut d_hidden = vec![0.0f32; model.hidden];
    let mut d_perc = vec![0.0f32; 3 * c];

    // tape = [s_0, .., s_T]; step t maps s_t -> s_{t+1}.
    for t in (0..tape.len() - 1).rev() {
        let state = &tape[t];
        // Residual identity: dL/ds_t starts as a copy of dL/ds_{t+1};
        // the perceive scatter below adds the stencil contributions.
        let mut g_prev = g.clone();

        match grid {
            Grid::D2 { h, w } => {
                for y in 0..h {
                    let rows = wrap3(y, h);
                    for x in 0..w {
                        let cols = wrap3(x, w);
                        let cell = (y * w + x) * c;
                        // Skip the cell early if nothing flows through
                        // its MLP.
                        if !any_grad(&g, cell, frozen, c) {
                            continue;
                        }
                        perceive_cell(state, w, c, &rows, &cols,
                                      &mut perception);
                        mlp_backward_cell(model, &perception, &g, cell,
                                          frozen, &mut grads, &mut pre,
                                          &mut d_hidden, &mut d_perc);
                        // Transposed perceive: scatter dL/d(perception)
                        // back to the wrapped 3x3 input support.
                        for ch in 0..c {
                            g_prev[cell + ch] += d_perc[ch * 3];
                            let dgx = d_perc[ch * 3 + 1];
                            let dgy = d_perc[ch * 3 + 2];
                            if dgx == 0.0 && dgy == 0.0 {
                                continue;
                            }
                            for (ky, &sy) in rows.iter().enumerate() {
                                for (kx, &sx) in cols.iter().enumerate() {
                                    g_prev[(sy * w + sx) * c + ch] +=
                                        SOBEL_X[ky][kx] * dgx
                                        + SOBEL_X[kx][ky] * dgy;
                                }
                            }
                        }
                    }
                }
            }
            Grid::D1 { w } => {
                for x in 0..w {
                    let cols = wrap3(x, w);
                    let cell = x * c;
                    if !any_grad(&g, cell, frozen, c) {
                        continue;
                    }
                    perceive_cell_1d(state, c, &cols, &mut perception);
                    mlp_backward_cell(model, &perception, &g, cell, frozen,
                                      &mut grads, &mut pre, &mut d_hidden,
                                      &mut d_perc);
                    // Transposed 1D perceive: scatter back to the
                    // wrapped 3-tap support.
                    for ch in 0..c {
                        g_prev[cell + ch] += d_perc[ch * 3];
                        let dg = d_perc[ch * 3 + 1];
                        let dl = d_perc[ch * 3 + 2];
                        if dg == 0.0 && dl == 0.0 {
                            continue;
                        }
                        for (k, &sx) in cols.iter().enumerate() {
                            g_prev[sx * c + ch] +=
                                GRAD_1D[k] * dg + LAP_1D[k] * dl;
                        }
                    }
                }
            }
        }
        g = g_prev;
    }
    (grads, g)
}

/// Does any non-frozen channel of this cell carry upstream gradient?
#[inline]
fn any_grad(g: &[f32], cell: usize, frozen: usize, c: usize) -> bool {
    g[cell + frozen..cell + c].iter().any(|&v| v != 0.0)
}

/// The dimension-independent MLP backward at one cell: recompute the
/// pre-activations from `perception`, accumulate the `w2`/`b1`/`w1`
/// gradients from the upstream `dL/ds_{t+1}` slice at `cell`, and leave
/// `dL/d(perception)` in `d_perc` for the caller's transposed scatter.
/// `d(delta)` is `dt * dL/ds_{t+1}`, zero on frozen channels.
#[inline]
fn mlp_backward_cell(model: &NcaModel, perception: &[f32], g: &[f32],
                     cell: usize, frozen: usize, grads: &mut NcaGrads,
                     pre: &mut [f32], d_hidden: &mut [f32],
                     d_perc: &mut [f32]) {
    let c = model.channels;
    let hid = model.hidden;
    for (j, slot) in pre.iter_mut().enumerate() {
        let mut acc = model.b1[j];
        for (k, &p) in perception.iter().enumerate() {
            acc += p * model.w1[k * hid + j];
        }
        *slot = acc;
    }

    // Through w2: grads and dL/d(hidden).
    d_hidden.iter_mut().for_each(|v| *v = 0.0);
    for ch in frozen..c {
        let dd = model.dt * g[cell + ch];
        if dd == 0.0 {
            continue;
        }
        for j in 0..hid {
            grads.w2[j * c + ch] += pre[j].max(0.0) * dd;
            d_hidden[j] += model.w2[j * c + ch] * dd;
        }
    }

    // Through the ReLU and w1/b1: grads and dL/d(perception).
    d_perc.iter_mut().for_each(|v| *v = 0.0);
    for j in 0..hid {
        if pre[j] <= 0.0 || d_hidden[j] == 0.0 {
            continue;
        }
        let dp = d_hidden[j];
        grads.b1[j] += dp;
        for k in 0..3 * c {
            grads.w1[k * hid + j] += perception[k] * dp;
            d_perc[k] += model.w1[k * hid + j] * dp;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn model() -> NcaModel {
        NcaModel::random(4, 6, &mut Rng::new(11))
    }

    #[test]
    fn tape_endpoints_match_rollout() {
        let m = model();
        let (h, w, steps) = (6, 5, 4);
        let mut rng = Rng::new(5);
        let board = rng.vec_f32(h * w * m.channels);
        let tape = rollout_tape(&m, &board, h, w, steps, 0);
        assert_eq!(tape.len(), steps + 1);
        assert_eq!(tape[0], board);
        let mut rolled = board.clone();
        let mut scratch = vec![0.0f32; board.len()];
        m.rollout(&mut rolled, &mut scratch, h, w, steps);
        assert_eq!(tape[steps], rolled, "tape end != plain rollout");
    }

    #[test]
    fn tape_endpoints_match_rollout_1d() {
        let m = model();
        let (w, steps) = (9, 4);
        let grid = Grid::D1 { w };
        let mut rng = Rng::new(15);
        let board = rng.vec_f32(w * m.channels);
        let tape = rollout_tape_on(&m, &board, grid, steps, 1);
        assert_eq!(tape.len(), steps + 1);
        assert_eq!(tape[0], board);
        let mut rolled = board.clone();
        let mut scratch = vec![0.0f32; board.len()];
        for _ in 0..steps {
            m.step_frozen_1d(&rolled, &mut scratch, w, 1);
            rolled.copy_from_slice(&scratch);
        }
        assert_eq!(tape[steps], rolled, "1D tape end != plain rollout");
    }

    #[test]
    fn zero_upstream_gradient_means_zero_grads() {
        let m = model();
        let (h, w) = (4, 4);
        let mut rng = Rng::new(7);
        let board = rng.vec_f32(h * w * m.channels);
        let tape = rollout_tape(&m, &board, h, w, 3, 0);
        let d_final = vec![0.0f32; board.len()];
        let (grads, d0) = backward(&m, &tape, h, w, 0, &d_final);
        assert!(grads.w1.iter().all(|&v| v == 0.0));
        assert!(grads.b1.iter().all(|&v| v == 0.0));
        assert!(grads.w2.iter().all(|&v| v == 0.0));
        assert!(d0.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn zero_upstream_gradient_means_zero_grads_1d() {
        let m = model();
        let grid = Grid::D1 { w: 8 };
        let mut rng = Rng::new(17);
        let board = rng.vec_f32(8 * m.channels);
        let tape = rollout_tape_on(&m, &board, grid, 3, 0);
        let d_final = vec![0.0f32; board.len()];
        let (grads, d0) = backward_on(&m, &tape, grid, 0, &d_final);
        assert!(grads.w1.iter().all(|&v| v == 0.0));
        assert!(grads.b1.iter().all(|&v| v == 0.0));
        assert!(grads.w2.iter().all(|&v| v == 0.0));
        assert!(d0.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn grads_flatten_matches_model_layout() {
        let m = model();
        let mut grads = NcaGrads::zeros(&m);
        grads.w1[0] = 1.0;
        grads.b1[0] = 2.0;
        grads.w2[0] = 3.0;
        let flat = grads.flatten();
        assert_eq!(flat.len(), m.flatten().len());
        let n1 = m.w1.len();
        assert_eq!(flat[0], 1.0);
        assert_eq!(flat[n1], 2.0);
        assert_eq!(flat[n1 + m.b1.len()], 3.0);
    }
}
