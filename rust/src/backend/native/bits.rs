//! Bit-packed row substrate for the discrete-CA SWAR kernels.
//!
//! A row of W binary cells is stored LSB-first in `ceil(W/64)` u64
//! words: cell `x` lives in word `x / 64`, bit `x % 64`. All rotations
//! treat the row as one W-bit ring (periodic boundary), and every
//! operation keeps the tail bits (positions `>= W` of the last word)
//! zero — the invariant the neighbour-count logic relies on.

/// Words needed for a `w`-cell row.
#[inline]
pub fn words_for(w: usize) -> usize {
    w.div_ceil(64)
}

/// Pack f32 {0,1} cells (threshold 0.5, matching the naive sims) into
/// `out`, which must hold exactly `words_for(cells.len())` words.
pub fn pack_row(cells: &[f32], out: &mut [u64]) {
    debug_assert_eq!(out.len(), words_for(cells.len()));
    for word in out.iter_mut() {
        *word = 0;
    }
    for (x, &v) in cells.iter().enumerate() {
        if v > 0.5 {
            out[x / 64] |= 1u64 << (x % 64);
        }
    }
}

/// Unpack a row back to f32 {0.0, 1.0} cells.
pub fn unpack_row(words: &[u64], cells: &mut [f32]) {
    debug_assert_eq!(words.len(), words_for(cells.len()));
    for (x, cell) in cells.iter_mut().enumerate() {
        *cell = ((words[x / 64] >> (x % 64)) & 1) as f32;
    }
}

/// Zero the bits at positions `>= w` in the last word.
#[inline]
pub fn mask_tail(words: &mut [u64], w: usize) {
    let rem = w % 64;
    if rem != 0 {
        let last = words.len() - 1;
        words[last] &= (1u64 << rem) - 1;
    }
}

/// `dst[x] = src[(x + w - 1) % w]` — every cell reads its LEFT
/// neighbour, i.e. the ring rotated one position toward higher indices.
pub fn rot_up(src: &[u64], dst: &mut [u64], w: usize) {
    debug_assert_eq!(src.len(), words_for(w));
    debug_assert_eq!(dst.len(), src.len());
    let nw = src.len();
    let top = (w - 1) % 64; // bit position of cell w-1 in the last word
    let mut carry = (src[nw - 1] >> top) & 1;
    for i in 0..nw {
        let next_carry = src[i] >> 63;
        dst[i] = (src[i] << 1) | carry;
        carry = next_carry;
    }
    mask_tail(dst, w);
}

/// `dst[x] = src[(x + 1) % w]` — every cell reads its RIGHT neighbour,
/// i.e. the ring rotated one position toward lower indices.
pub fn rot_down(src: &[u64], dst: &mut [u64], w: usize) {
    debug_assert_eq!(src.len(), words_for(w));
    debug_assert_eq!(dst.len(), src.len());
    let nw = src.len();
    let top = (w - 1) % 64;
    let wrap = src[0] & 1; // cell 0 becomes cell w-1's right neighbour
    for i in 0..nw {
        let hi = if i + 1 < nw { src[i + 1] & 1 } else { 0 };
        dst[i] = (src[i] >> 1) | (hi << 63);
    }
    dst[nw - 1] |= wrap << top;
    mask_tail(dst, w);
}

/// Number of live cells in a packed row.
pub fn popcount(words: &[u64]) -> usize {
    words.iter().map(|w| w.count_ones() as usize).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pack_bits(bits: &[u8]) -> Vec<u64> {
        let cells: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
        let mut out = vec![0u64; words_for(bits.len())];
        pack_row(&cells, &mut out);
        out
    }

    fn unpack_bits(words: &[u64], w: usize) -> Vec<u8> {
        let mut cells = vec![0.0f32; w];
        unpack_row(words, &mut cells);
        cells.iter().map(|&c| c as u8).collect()
    }

    #[test]
    fn pack_unpack_roundtrip_odd_widths() {
        for w in [1usize, 5, 63, 64, 65, 100, 128, 130, 200] {
            let bits: Vec<u8> =
                (0..w).map(|x| ((x * 7 + 3) % 5 == 0) as u8).collect();
            let packed = pack_bits(&bits);
            assert_eq!(packed.len(), words_for(w));
            assert_eq!(unpack_bits(&packed, w), bits, "width {w}");
            assert_eq!(popcount(&packed),
                       bits.iter().map(|&b| b as usize).sum::<usize>());
        }
    }

    #[test]
    fn rotations_match_index_arithmetic() {
        for w in [1usize, 2, 7, 63, 64, 65, 127, 128, 129, 190] {
            let bits: Vec<u8> =
                (0..w).map(|x| ((x * 13 + 1) % 3 == 0) as u8).collect();
            let src = pack_bits(&bits);
            let mut up = vec![0u64; src.len()];
            let mut down = vec![0u64; src.len()];
            rot_up(&src, &mut up, w);
            rot_down(&src, &mut down, w);
            let up_bits = unpack_bits(&up, w);
            let down_bits = unpack_bits(&down, w);
            for x in 0..w {
                assert_eq!(up_bits[x], bits[(x + w - 1) % w],
                           "rot_up w={w} x={x}");
                assert_eq!(down_bits[x], bits[(x + 1) % w],
                           "rot_down w={w} x={x}");
            }
        }
    }

    #[test]
    fn rotations_keep_tail_clean() {
        let w = 70;
        let bits: Vec<u8> = (0..w).map(|_| 1u8).collect();
        let src = pack_bits(&bits);
        let mut out = vec![0u64; src.len()];
        rot_up(&src, &mut out, w);
        assert_eq!(out[1] >> (w % 64), 0, "tail bits leaked (rot_up)");
        rot_down(&src, &mut out, w);
        assert_eq!(out[1] >> (w % 64), 0, "tail bits leaked (rot_down)");
    }

    #[test]
    fn mask_tail_noop_on_exact_words() {
        let mut words = vec![u64::MAX, u64::MAX];
        mask_tail(&mut words, 128);
        assert_eq!(words, vec![u64::MAX, u64::MAX]);
        mask_tail(&mut words, 100);
        assert_eq!(words[1], (1u64 << 36) - 1);
    }
}
