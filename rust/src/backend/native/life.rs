//! Bit-packed Game-of-Life kernel (SWAR neighbour counting).
//!
//! A board is `h` packed rows (see [`bits`]). One step rotates every
//! row left/right once, then per word sums the eight neighbour planes
//! with a carry-save adder chain into four binary counter planes
//! (counts 0..8 fit in 4 bits) and applies B3/S23 as boolean algebra:
//! `next = (n == 3) | (alive & n == 2)` =
//! `c1 & !c2 & !c3 & (c0 | alive)`. 64 cells per word, bit-exact with
//! [`crate::automata::LifeSim`] (same periodic Moore neighbourhood).

use crate::backend::native::bits;

/// Reusable per-board scratch (rotated row planes + next grid).
pub struct LifeKernel {
    h: usize,
    w: usize,
    wpr: usize, // words per row
    left: Vec<u64>,
    right: Vec<u64>,
    next: Vec<u64>,
}

impl LifeKernel {
    pub fn new(h: usize, w: usize) -> LifeKernel {
        let wpr = bits::words_for(w);
        LifeKernel {
            h,
            w,
            wpr,
            left: vec![0; h * wpr],
            right: vec![0; h * wpr],
            next: vec![0; h * wpr],
        }
    }

    pub fn words(&self) -> usize {
        self.h * self.wpr
    }

    /// One Life step in place on a packed `h * words_per_row` grid.
    pub fn step(&mut self, grid: &mut [u64]) {
        let (h, w, wpr) = (self.h, self.w, self.wpr);
        debug_assert_eq!(grid.len(), h * wpr);

        for y in 0..h {
            let row = &grid[y * wpr..(y + 1) * wpr];
            bits::rot_up(row, &mut self.left[y * wpr..(y + 1) * wpr], w);
            bits::rot_down(row, &mut self.right[y * wpr..(y + 1) * wpr], w);
        }

        for y in 0..h {
            let up = (y + h - 1) % h;
            let down = (y + 1) % h;
            for i in 0..wpr {
                let planes = [
                    self.left[up * wpr + i],
                    grid[up * wpr + i],
                    self.right[up * wpr + i],
                    self.left[y * wpr + i],
                    self.right[y * wpr + i],
                    self.left[down * wpr + i],
                    grid[down * wpr + i],
                    self.right[down * wpr + i],
                ];
                // Carry-save accumulation into binary counter planes.
                let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
                for plane in planes {
                    let mut carry = plane;
                    let t0 = c0 & carry;
                    c0 ^= carry;
                    carry = t0;
                    let t1 = c1 & carry;
                    c1 ^= carry;
                    carry = t1;
                    let t2 = c2 & carry;
                    c2 ^= carry;
                    carry = t2;
                    c3 |= carry;
                }
                let alive = grid[y * wpr + i];
                // n == 3 -> born/survive; n == 2 -> survive if alive.
                self.next[y * wpr + i] = c1 & !c2 & !c3 & (c0 | alive);
            }
            bits::mask_tail(&mut self.next[y * wpr..(y + 1) * wpr], w);
        }

        grid.copy_from_slice(&self.next);
    }

    /// Run `steps` updates in place.
    pub fn rollout(&mut self, grid: &mut [u64], steps: usize) {
        for _ in 0..steps {
            self.step(grid);
        }
    }
}

/// Pack a `[H, W]` f32 board (row-major) into `h * words_for(w)` words.
pub fn pack_board(cells: &[f32], h: usize, w: usize, out: &mut [u64]) {
    let wpr = bits::words_for(w);
    debug_assert_eq!(cells.len(), h * w);
    debug_assert_eq!(out.len(), h * wpr);
    for y in 0..h {
        bits::pack_row(&cells[y * w..(y + 1) * w],
                       &mut out[y * wpr..(y + 1) * wpr]);
    }
}

/// Unpack a packed board back to f32 {0.0, 1.0} cells.
pub fn unpack_board(words: &[u64], h: usize, w: usize, cells: &mut [f32]) {
    let wpr = bits::words_for(w);
    debug_assert_eq!(cells.len(), h * w);
    debug_assert_eq!(words.len(), h * wpr);
    for y in 0..h {
        bits::unpack_row(&words[y * wpr..(y + 1) * wpr],
                         &mut cells[y * w..(y + 1) * w]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::LifeSim;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn packed_vs_naive(h: usize, w: usize, steps: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut sim = LifeSim::random(1, h, w, 0.4, &mut rng);
        let start = sim.to_tensor();

        let wpr = bits::words_for(w);
        let mut grid = vec![0u64; h * wpr];
        pack_board(start.data(), h, w, &mut grid);
        let mut kern = LifeKernel::new(h, w);
        kern.rollout(&mut grid, steps);
        let mut got = vec![0.0f32; h * w];
        unpack_board(&grid, h, w, &mut got);

        sim.run(steps);
        let expect = sim.to_tensor();
        assert_eq!(got, expect.data(), "{h}x{w} steps={steps} diverged");
    }

    #[test]
    fn matches_naive_including_non_word_widths() {
        for (i, &(h, w)) in [(8usize, 8usize), (5, 63), (7, 64), (6, 65),
                             (9, 100), (4, 128), (3, 130)]
            .iter()
            .enumerate()
        {
            packed_vs_naive(h, w, 6, 1_000 + i as u64);
        }
    }

    #[test]
    fn blinker_oscillates_across_word_boundary() {
        // Horizontal blinker straddling cells 63..66 of a 128-wide board.
        let (h, w) = (9, 128);
        let mut board = Tensor::zeros(&[h, w]);
        for x in [63usize, 64, 65] {
            board.set(&[4, x], 1.0);
        }
        let wpr = bits::words_for(w);
        let mut grid = vec![0u64; h * wpr];
        pack_board(board.data(), h, w, &mut grid);
        let before = grid.clone();
        let mut kern = LifeKernel::new(h, w);
        kern.step(&mut grid);
        assert_ne!(grid, before, "blinker must flip to vertical");
        kern.step(&mut grid);
        assert_eq!(grid, before, "blinker must return after two steps");
    }
}
