//! Bit-packed Game-of-Life kernel (SWAR neighbour counting).
//!
//! A board is `h` packed rows (see [`bits`]). One step rotates every
//! row left/right once, then per word sums the eight neighbour planes
//! with a carry-save adder chain into four binary counter planes
//! (counts 0..8 fit in 4 bits) and applies B3/S23 as boolean algebra:
//! `next = (n == 3) | (alive & n == 2)` =
//! `c1 & !c2 & !c3 & (c0 | alive)`. 64 cells per word, bit-exact with
//! [`crate::automata::LifeSim`] (same periodic Moore neighbourhood).

use crate::backend::native::activity::ActivityMap;
use crate::backend::native::bits;

/// B3/S23 applied to one word given its eight neighbour planes — the
/// single source of truth for both the dense and the sparse stepper,
/// so sparse stepping is bit-identical by construction. Tail bits stay
/// clean: every plane has a clean tail and the carry-save chain only
/// ANDs/XORs/ORs them.
#[inline]
fn life_word(planes: [u64; 8], alive: u64) -> u64 {
    // Carry-save accumulation into binary counter planes (0..8 fits
    // in 4 bits).
    let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
    for plane in planes {
        let mut carry = plane;
        let t0 = c0 & carry;
        c0 ^= carry;
        carry = t0;
        let t1 = c1 & carry;
        c1 ^= carry;
        carry = t1;
        let t2 = c2 & carry;
        c2 ^= carry;
        carry = t2;
        c3 |= carry;
    }
    // n == 3 -> born/survive; n == 2 -> survive if alive.
    c1 & !c2 & !c3 & (c0 | alive)
}

/// Reusable per-board scratch (rotated row planes + next grid).
pub struct LifeKernel {
    h: usize,
    w: usize,
    wpr: usize, // words per row
    left: Vec<u64>,
    right: Vec<u64>,
    next: Vec<u64>,
    /// Rows the sparse stepper must snapshot+rotate this step.
    row_in: Vec<bool>,
}

impl LifeKernel {
    pub fn new(h: usize, w: usize) -> LifeKernel {
        let wpr = bits::words_for(w);
        LifeKernel {
            h,
            w,
            wpr,
            left: vec![0; h * wpr],
            right: vec![0; h * wpr],
            next: vec![0; h * wpr],
            row_in: vec![false; h],
        }
    }

    pub fn words(&self) -> usize {
        self.h * self.wpr
    }

    /// One Life step in place on a packed `h * words_per_row` grid.
    pub fn step(&mut self, grid: &mut [u64]) {
        let (h, w, wpr) = (self.h, self.w, self.wpr);
        debug_assert_eq!(grid.len(), h * wpr);

        for y in 0..h {
            let row = &grid[y * wpr..(y + 1) * wpr];
            bits::rot_up(row, &mut self.left[y * wpr..(y + 1) * wpr], w);
            bits::rot_down(row, &mut self.right[y * wpr..(y + 1) * wpr], w);
        }

        for y in 0..h {
            let up = (y + h - 1) % h;
            let down = (y + 1) % h;
            for i in 0..wpr {
                let planes = [
                    self.left[up * wpr + i],
                    grid[up * wpr + i],
                    self.right[up * wpr + i],
                    self.left[y * wpr + i],
                    self.right[y * wpr + i],
                    self.left[down * wpr + i],
                    grid[down * wpr + i],
                    self.right[down * wpr + i],
                ];
                let alive = grid[y * wpr + i];
                self.next[y * wpr + i] = life_word(planes, alive);
            }
            bits::mask_tail(&mut self.next[y * wpr..(y + 1) * wpr], w);
        }

        grid.copy_from_slice(&self.next);
    }

    /// Run `steps` updates in place.
    pub fn rollout(&mut self, grid: &mut [u64], steps: usize) {
        for _ in 0..steps {
            self.step(grid);
        }
    }

    /// One activity-tracked Life step: recompute only word-tiles whose
    /// 1-tile halo changed last step (the map's protocol), mark the
    /// tiles that changed now. Quiescent rows cost nothing — not even
    /// the rotation pass. Returns `(recomputed, skipped)` tile counts.
    /// Bit-identical to [`step`](Self::step): skipped tiles provably
    /// cannot change, recomputed ones go through the same
    /// [`life_word`].
    pub fn step_sparse(&mut self, grid: &mut [u64],
                       map: &mut ActivityMap) -> (u64, u64) {
        let (h, w, wpr) = (self.h, self.w, self.wpr);
        debug_assert_eq!(grid.len(), h * wpr);
        let total = (h * wpr) as u64;
        let needed = map.begin_step(1, 1) as u64;
        if needed == 0 {
            return (0, total);
        }

        // Input rows: every row a needed tile reads (needed rows
        // dilated one row with wrap). Only these get snapshotted and
        // rotated.
        self.row_in.fill(false);
        for y in 0..h {
            if map.row_needed(y) {
                self.row_in[(y + h - 1) % h] = true;
                self.row_in[y] = true;
                self.row_in[(y + 1) % h] = true;
            }
        }
        // Snapshot old centres into `next` (reused as the old-value
        // plane so in-place writes below can't corrupt reads) and
        // build the rotated planes for input rows.
        for y in 0..h {
            if !self.row_in[y] {
                continue;
            }
            let row = &grid[y * wpr..(y + 1) * wpr];
            self.next[y * wpr..(y + 1) * wpr].copy_from_slice(row);
            bits::rot_up(row, &mut self.left[y * wpr..(y + 1) * wpr], w);
            bits::rot_down(row, &mut self.right[y * wpr..(y + 1) * wpr],
                           w);
        }

        let rem = w % 64;
        for y in 0..h {
            if !map.row_needed(y) {
                continue;
            }
            let up = (y + h - 1) % h;
            let down = (y + 1) % h;
            for wi in 0..map.words_per_row() {
                let mut tiles = map.needs_word(y, wi);
                while tiles != 0 {
                    let i = wi * 64 + tiles.trailing_zeros() as usize;
                    tiles &= tiles - 1;
                    let planes = [
                        self.left[up * wpr + i],
                        self.next[up * wpr + i],
                        self.right[up * wpr + i],
                        self.left[y * wpr + i],
                        self.right[y * wpr + i],
                        self.left[down * wpr + i],
                        self.next[down * wpr + i],
                        self.right[down * wpr + i],
                    ];
                    let alive = self.next[y * wpr + i];
                    let mut out = life_word(planes, alive);
                    if i == wpr - 1 && rem != 0 {
                        out &= (1u64 << rem) - 1;
                    }
                    if out != alive {
                        map.mark(y, i);
                        grid[y * wpr + i] = out;
                    }
                }
            }
        }
        (needed, total - needed)
    }

    /// Run `steps` activity-tracked updates; the map carries dirty
    /// state across steps (and across calls, for resident boards).
    /// Returns summed `(recomputed, skipped)` tile counts.
    pub fn rollout_sparse(&mut self, grid: &mut [u64], steps: usize,
                          map: &mut ActivityMap) -> (u64, u64) {
        let (mut recomputed, mut skipped) = (0, 0);
        for _ in 0..steps {
            let (r, s) = self.step_sparse(grid, map);
            recomputed += r;
            skipped += s;
        }
        (recomputed, skipped)
    }
}

/// Pack a `[H, W]` f32 board (row-major) into `h * words_for(w)` words.
pub fn pack_board(cells: &[f32], h: usize, w: usize, out: &mut [u64]) {
    let wpr = bits::words_for(w);
    debug_assert_eq!(cells.len(), h * w);
    debug_assert_eq!(out.len(), h * wpr);
    for y in 0..h {
        bits::pack_row(&cells[y * w..(y + 1) * w],
                       &mut out[y * wpr..(y + 1) * wpr]);
    }
}

/// Unpack a packed board back to f32 {0.0, 1.0} cells.
pub fn unpack_board(words: &[u64], h: usize, w: usize, cells: &mut [f32]) {
    let wpr = bits::words_for(w);
    debug_assert_eq!(cells.len(), h * w);
    debug_assert_eq!(words.len(), h * wpr);
    for y in 0..h {
        bits::unpack_row(&words[y * wpr..(y + 1) * wpr],
                         &mut cells[y * w..(y + 1) * w]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::LifeSim;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn packed_vs_naive(h: usize, w: usize, steps: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut sim = LifeSim::random(1, h, w, 0.4, &mut rng);
        let start = sim.to_tensor();

        let wpr = bits::words_for(w);
        let mut grid = vec![0u64; h * wpr];
        pack_board(start.data(), h, w, &mut grid);
        let mut kern = LifeKernel::new(h, w);
        kern.rollout(&mut grid, steps);
        let mut got = vec![0.0f32; h * w];
        unpack_board(&grid, h, w, &mut got);

        sim.run(steps);
        let expect = sim.to_tensor();
        assert_eq!(got, expect.data(), "{h}x{w} steps={steps} diverged");
    }

    #[test]
    fn matches_naive_including_non_word_widths() {
        for (i, &(h, w)) in [(8usize, 8usize), (5, 63), (7, 64), (6, 65),
                             (9, 100), (4, 128), (3, 130)]
            .iter()
            .enumerate()
        {
            packed_vs_naive(h, w, 6, 1_000 + i as u64);
        }
    }

    #[test]
    fn blinker_oscillates_across_word_boundary() {
        // Horizontal blinker straddling cells 63..66 of a 128-wide board.
        let (h, w) = (9, 128);
        let mut board = Tensor::zeros(&[h, w]);
        for x in [63usize, 64, 65] {
            board.set(&[4, x], 1.0);
        }
        let wpr = bits::words_for(w);
        let mut grid = vec![0u64; h * wpr];
        pack_board(board.data(), h, w, &mut grid);
        let before = grid.clone();
        let mut kern = LifeKernel::new(h, w);
        kern.step(&mut grid);
        assert_ne!(grid, before, "blinker must flip to vertical");
        kern.step(&mut grid);
        assert_eq!(grid, before, "blinker must return after two steps");
    }
}
