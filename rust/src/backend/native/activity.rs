//! Activity tracking: per-tile dirty bitmaps that let a step skip any
//! tile whose halo neighborhood is unchanged, plus the cost model that
//! picks between the dense, sparse and HashLife step paths.
//!
//! # The skip rule
//!
//! A "tile" is the unit the kernels already work in — one u64 word of
//! 64 cells for the bit-packed automata (ECA/Life), one 32x32 cache
//! tile for the f32 automata (Lenia/NCA). After every step the kernel
//! records which tiles *changed* (`dirty`). Before the next step the
//! map dilates `dirty` by the rule's halo (1 tile for a 3x3 stencil,
//! `radius/32` tiles for a Lenia kernel) into `needs`: the set of tiles
//! whose inputs might differ from last step. Every other tile would be
//! recomputed from bit-identical inputs by a deterministic local rule,
//! so skipping it reproduces the dense result *exactly* — there is no
//! approximation anywhere in this module.
//!
//! For ECA/Life that argument is bitwise by construction. For the f32
//! automata the dirty mask itself is exact: a recomputed cell is
//! compared against its previous value as raw `f32` bits, so a tile is
//! clean only when every one of its cells came out bit-identical.
//!
//! A fresh map starts all-dirty, so the first step after admission (or
//! after a dense/HashLife step invalidated the map) is a full dense
//! step in disguise; the savings come from every step after it.
//!
//! # The escape hatch
//!
//! `CAX_SPARSE=off` (or `0`) pins every path selection to `Dense`,
//! mirroring the `CAX_SIMD` hatch. Tests and benches can also force the
//! decision in-process with [`set_override`], which wins over the
//! environment.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::{bits, lenia};
use crate::backend::CaProgram;

// ------------------------------------------------------------ dispatch

/// Read the `CAX_SPARSE` escape hatch once.
fn detect() -> (bool, &'static str) {
    if super::env_disabled("CAX_SPARSE") {
        (false, "dense only (CAX_SPARSE=off)")
    } else {
        (true, "sparse+hashlife")
    }
}

fn cached() -> (bool, &'static str) {
    static STATUS: OnceLock<(bool, &'static str)> = OnceLock::new();
    *STATUS.get_or_init(|| {
        let s = detect();
        crate::log_info!("native activity tracking: {}", s.1);
        s
    })
}

/// In-process override: 0 = follow the environment, 1 = force on,
/// 2 = force off. Exists so one process (tests, `serve_load`) can
/// compare sparse-on vs sparse-off without re-execing; the env hatch
/// is a `OnceLock` and cannot toggle.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force activity tracking on/off for this process (`None` returns to
/// the `CAX_SPARSE` environment setting). Test/bench hook.
pub fn set_override(force: Option<bool>) {
    let v = match force {
        None => 0,
        Some(true) => 1,
        Some(false) => 2,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

/// Whether sparse/HashLife paths may be selected at all.
pub fn enabled() -> bool {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => cached().0,
    }
}

/// Human-readable dispatch status for CLI/status surfaces.
pub fn status() -> &'static str {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => "sparse+hashlife (forced)",
        2 => "dense only (forced)",
        _ => cached().1,
    }
}

// ----------------------------------------------------------- cost model

/// Which stepping strategy a launch takes. Selected per call by
/// [`select_step_path`] the same way PR 4's `select_path` picks
/// sparse-tap vs FFT Lenia.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPath {
    /// Recompute every cell (the pre-activity behavior).
    Dense,
    /// Dirty-tile tracking: recompute only tiles whose halo changed.
    Sparse,
    /// Memoizing quadtree (Life) / binary tree (ECA) — superspeed
    /// power-of-two macro-steps on big structured boards.
    HashLife,
}

impl StepPath {
    pub fn name(&self) -> &'static str {
        match self {
            StepPath::Dense => "dense",
            StepPath::Sparse => "sparse",
            StepPath::HashLife => "hashlife",
        }
    }
}

/// HashLife needs enough cells and enough steps per call to amortize
/// tree construction + interning; below these it loses to the SWAR
/// kernels even on empty boards. Boards also must be square (2D) with
/// power-of-two sides for the torus-wrap trick.
pub const HASHLIFE_MIN_LIFE_CELLS: usize = 1 << 22; // 2048 x 2048
pub const HASHLIFE_MIN_ECA_WIDTH: usize = 1 << 16;
pub const HASHLIFE_MIN_STEPS: usize = 256;

/// Pick the step path for one launch of `prog` on an unbatched board
/// of `shape`, advancing `steps`. Deterministic in its inputs: geometry
/// and horizon, never board content — so the reported path is the
/// executed path.
pub fn select_step_path(prog: &CaProgram, shape: &[usize], steps: usize)
    -> StepPath {
    if !enabled() {
        return StepPath::Dense;
    }
    match prog {
        CaProgram::Eca { .. } => {
            let w = shape[shape.len() - 1];
            if w.is_power_of_two()
                && w >= HASHLIFE_MIN_ECA_WIDTH
                && steps >= HASHLIFE_MIN_STEPS
            {
                StepPath::HashLife
            } else {
                StepPath::Sparse
            }
        }
        CaProgram::Life => {
            let (h, w) = (shape[shape.len() - 2], shape[shape.len() - 1]);
            if h == w
                && h.is_power_of_two()
                && h * w >= HASHLIFE_MIN_LIFE_CELLS
                && steps >= HASHLIFE_MIN_STEPS
            {
                StepPath::HashLife
            } else {
                StepPath::Sparse
            }
        }
        // The sparse-tap kernel recomputes per cell, so dirty tiles
        // compose with it; the FFT path is global (every output cell
        // reads every input cell) and stays dense.
        CaProgram::Lenia { params } => {
            let (h, w) = (shape[shape.len() - 2], shape[shape.len() - 1]);
            match lenia::select_path(params.radius, h, w) {
                lenia::LeniaPath::SparseTap => StepPath::Sparse,
                lenia::LeniaPath::Fft => StepPath::Dense,
            }
        }
        // Multi-kernel worlds run the spectral plan — global, dense.
        CaProgram::LeniaMulti(_) => StepPath::Dense,
        CaProgram::Nca(_) => StepPath::Sparse,
    }
}

// ------------------------------------------------------------- counters

/// Bump the `step_path_*_total` obs counter for one launch.
pub fn note_path(path: StepPath) {
    let name = match path {
        StepPath::Dense => "step_path_dense_total",
        StepPath::Sparse => "step_path_sparse_total",
        StepPath::HashLife => "step_path_hashlife_total",
    };
    crate::obs::Registry::global().counter(name).inc();
}

/// Record a launch's tile accounting in the global registry.
pub fn note_tiles(recomputed: u64, skipped: u64) {
    let reg = crate::obs::Registry::global();
    reg.counter("sparse_tiles_recomputed_total").add(recomputed);
    reg.counter("sparse_tiles_skipped_total").add(skipped);
}

/// Current skipped-tile counter value (bench/test hook).
pub fn tiles_skipped_total() -> u64 {
    crate::obs::Registry::global()
        .counter("sparse_tiles_skipped_total")
        .get()
}

// ----------------------------------------------------- program identity

/// Fingerprint of the *rule* a resident's activity map was built under.
/// A map is only valid while the rule is unchanged (the serve scheduler
/// never mutates a session's program, but the `Resident` API does not
/// enforce that) — on mismatch the map resets to all-dirty.
pub fn prog_key(prog: &CaProgram) -> u64 {
    // FNV-1a over the rule's defining bits; no hashing dependency.
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(PRIME);
    };
    match prog {
        CaProgram::Eca { rule } => {
            mix(1);
            mix(rule.number as u64);
        }
        CaProgram::Life => mix(2),
        CaProgram::Lenia { params } => {
            mix(3);
            mix(params.radius as u64);
            mix(params.mu.to_bits() as u64);
            mix(params.sigma.to_bits() as u64);
            mix(params.dt.to_bits() as u64);
        }
        CaProgram::LeniaMulti(world) => {
            mix(4);
            mix(world.channels as u64);
            mix(world.kernels.len() as u64);
        }
        CaProgram::Nca(model) => {
            mix(5);
            for v in model.flatten() {
                mix(v.to_bits() as u64);
            }
        }
    }
    h
}

/// Reuse `slot`'s map when it matches this rule + geometry; otherwise
/// install a fresh all-dirty map.
pub fn ensure_map<'a>(
    slot: &'a mut Option<ActivityMap>,
    key: u64,
    rows: usize,
    cols: usize,
) -> &'a mut ActivityMap {
    let stale = match slot {
        Some(m) => !m.matches(key, rows, cols),
        None => true,
    };
    if stale {
        *slot = Some(ActivityMap::new(key, rows, cols));
    }
    slot.as_mut().expect("activity map just installed")
}

// ------------------------------------------------------------ the map

/// A bit-packed `rows x cols` tile-activity bitmap with the
/// dirty -> dilate -> needs -> recompute -> re-mark protocol described
/// in the module docs. Both axes wrap (every kernel here is toroidal).
#[derive(Clone, Debug)]
pub struct ActivityMap {
    key: u64,
    rows: usize,
    cols: usize,
    /// Words per bitmap row (`cols.div_ceil(64)`).
    wpr: usize,
    /// Tiles that changed during the last executed step.
    dirty: Vec<u64>,
    /// Tiles the *next* step must recompute (dirty dilated by halo).
    needs: Vec<u64>,
    /// Scratch rows for the dilation passes.
    scratch: Vec<u64>,
    /// True until the first `begin_step`: everything needs recompute.
    fresh: bool,
}

impl ActivityMap {
    /// A fresh map: every tile dirty, so the first step is dense.
    pub fn new(key: u64, rows: usize, cols: usize) -> ActivityMap {
        assert!(rows > 0 && cols > 0, "activity map with no tiles");
        let wpr = bits::words_for(cols);
        ActivityMap {
            key,
            rows,
            cols,
            wpr,
            dirty: vec![0; rows * wpr],
            needs: vec![0; rows * wpr],
            scratch: vec![0; 2 * wpr.max(1)],
            fresh: true,
        }
    }

    pub fn matches(&self, key: u64, rows: usize, cols: usize) -> bool {
        self.key == key && self.rows == rows && self.cols == cols
    }

    /// Total tiles tracked.
    pub fn tiles(&self) -> usize {
        self.rows * self.cols
    }

    pub fn words_per_row(&self) -> usize {
        self.wpr
    }

    /// Start a step: fill `needs` with `dirty` dilated by
    /// (`halo_y`, `halo_x`) tiles (wrapping both axes), clear `dirty`
    /// for the kernel to re-mark, and return how many tiles need
    /// recompute. A fresh map needs everything.
    pub fn begin_step(&mut self, halo_y: usize, halo_x: usize) -> usize {
        if self.fresh {
            self.fresh = false;
            for row in self.needs.chunks_mut(self.wpr) {
                row.fill(u64::MAX);
                bits::mask_tail(row, self.cols);
            }
            self.dirty.fill(0);
            return self.tiles();
        }
        self.needs.copy_from_slice(&self.dirty);
        self.dirty.fill(0);
        // Chebyshev dilation is separable: dilate x then y.
        for _ in 0..halo_x {
            let (up, down) = self.scratch.split_at_mut(self.wpr);
            for row in self.needs.chunks_mut(self.wpr) {
                bits::rot_up(row, up, self.cols);
                bits::rot_down(row, down, self.cols);
                for (w, (&u, &d)) in
                    row.iter_mut().zip(up.iter().zip(down.iter()))
                {
                    *w |= u | d;
                }
            }
        }
        for _ in 0..halo_y {
            let prev = self.needs.clone();
            for r in 0..self.rows {
                let above = (r + self.rows - 1) % self.rows;
                let below = (r + 1) % self.rows;
                for i in 0..self.wpr {
                    self.needs[r * self.wpr + i] |= prev
                        [above * self.wpr + i]
                        | prev[below * self.wpr + i];
                }
            }
        }
        self.needs.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// One word of the `needs` bitmap — kernels scan these with
    /// `trailing_zeros` so iteration and [`mark`](Self::mark) don't
    /// fight the borrow checker.
    pub fn needs_word(&self, row: usize, word: usize) -> u64 {
        self.needs[row * self.wpr + word]
    }

    /// Whether any tile in bitmap row `row` needs recompute.
    pub fn row_needed(&self, row: usize) -> bool {
        self.needs[row * self.wpr..(row + 1) * self.wpr]
            .iter()
            .any(|&w| w != 0)
    }

    pub fn needs(&self, row: usize, col: usize) -> bool {
        self.needs[row * self.wpr + col / 64] >> (col % 64) & 1 == 1
    }

    /// Record that tile (`row`, `col`) changed during this step.
    pub fn mark(&mut self, row: usize, col: usize) {
        self.dirty[row * self.wpr + col / 64] |= 1 << (col % 64);
    }

    /// Mark every tile dirty (used after a dense fallback step diffs
    /// nothing, or by tests).
    pub fn mark_all(&mut self) {
        for row in self.dirty.chunks_mut(self.wpr) {
            row.fill(u64::MAX);
            bits::mask_tail(row, self.cols);
        }
    }

    /// Tiles currently marked dirty (i.e. changed during the last
    /// executed step).
    pub fn dirty_count(&self) -> usize {
        self.dirty.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::automata::WolframRule;

    #[test]
    fn fresh_map_needs_everything_once() {
        let mut m = ActivityMap::new(7, 3, 70);
        assert_eq!(m.begin_step(1, 1), 3 * 70);
        // Nothing marked dirty -> next step needs nothing.
        assert_eq!(m.begin_step(1, 1), 0);
    }

    #[test]
    fn dilation_wraps_both_axes() {
        let mut m = ActivityMap::new(0, 4, 4);
        m.begin_step(1, 1);
        m.mark(0, 0);
        let needed = m.begin_step(1, 1);
        assert_eq!(needed, 9, "3x3 halo around a corner tile");
        for (r, c) in [(3, 3), (3, 0), (3, 1), (0, 3), (1, 1)] {
            assert!(m.needs(r, c), "tile ({r},{c}) in wrapped halo");
        }
        assert!(!m.needs(2, 2));
    }

    #[test]
    fn wider_halo_dilates_further() {
        let mut m = ActivityMap::new(0, 8, 8);
        m.begin_step(1, 1);
        m.mark(4, 4);
        assert_eq!(m.begin_step(2, 2), 25, "5x5 halo");
    }

    #[test]
    fn one_dimensional_map_dilates_in_x_only() {
        let mut m = ActivityMap::new(0, 1, 130);
        m.begin_step(0, 1);
        m.mark(0, 129);
        let needed = m.begin_step(0, 1);
        assert_eq!(needed, 3);
        assert!(m.needs(0, 128) && m.needs(0, 129) && m.needs(0, 0));
    }

    #[test]
    fn ensure_map_resets_on_rule_or_shape_change() {
        let mut slot = None;
        let m = ensure_map(&mut slot, 1, 4, 4);
        m.begin_step(1, 1); // no longer fresh
        assert_eq!(ensure_map(&mut slot, 1, 4, 4).begin_step(1, 1), 0);
        // Different rule key -> fresh all-dirty map.
        assert_eq!(ensure_map(&mut slot, 2, 4, 4).begin_step(1, 1), 16);
    }

    #[test]
    fn prog_keys_distinguish_rules() {
        let r30 = CaProgram::Eca { rule: WolframRule::new(30) };
        let r110 = CaProgram::Eca { rule: WolframRule::new(110) };
        assert_ne!(prog_key(&r30), prog_key(&r110));
        assert_eq!(prog_key(&r30), prog_key(&r30));
        assert_ne!(prog_key(&r30), prog_key(&CaProgram::Life));
    }

    /// The override is process-global; tests that flip it take this
    /// lock so they cannot interleave.
    static OVERRIDE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn override_beats_environment() {
        let _guard = OVERRIDE_LOCK.lock().unwrap();
        // Only exercises the override plumbing; the env default is
        // covered by whichever leg CI runs this under.
        set_override(Some(false));
        assert!(!enabled());
        assert_eq!(status(), "dense only (forced)");
        set_override(Some(true));
        assert!(enabled());
        set_override(None);
    }

    #[test]
    fn selector_honours_geometry_gates() {
        let _guard = OVERRIDE_LOCK.lock().unwrap();
        set_override(Some(true));
        let life = CaProgram::Life;
        assert_eq!(select_step_path(&life, &[256, 256], 1000),
                   StepPath::Sparse);
        assert_eq!(select_step_path(&life, &[4096, 4096], 1000),
                   StepPath::HashLife);
        assert_eq!(select_step_path(&life, &[4096, 4096], 16),
                   StepPath::Sparse, "short horizons stay sparse");
        assert_eq!(select_step_path(&life, &[4096, 2048], 1000),
                   StepPath::Sparse, "non-square stays sparse");
        let eca = CaProgram::Eca { rule: WolframRule::new(30) };
        assert_eq!(select_step_path(&eca, &[1024], 1000),
                   StepPath::Sparse);
        assert_eq!(select_step_path(&eca, &[1 << 17], 1000),
                   StepPath::HashLife);
        set_override(Some(false));
        assert_eq!(select_step_path(&life, &[4096, 4096], 1000),
                   StepPath::Dense, "escape hatch pins dense");
        set_override(None);
    }
}
