//! Native NCA training: the train-step programs of the default build.
//!
//! [`NativeTrainBackend`] implements [`ProgramBackend`] — the same
//! contract the `pjrt` engine offers — for a small family of *native*
//! programs, so `coordinator::trainer`, `coordinator::experiments`,
//! the evaluators and the sample pool drive growing-NCA,
//! self-classifying-MNIST and 1D-ARC training on the default feature
//! set with zero code changes above the trait:
//!
//! - `growing_seed`: the single-seed-cell initial state `[H, W, C]`.
//! - `growing_train_step`: `(params, m, v, step, states[B,H,W,C],
//!   target[H,W,4], seed) -> (params', m', v', loss, states')` — the
//!   App. B recipe: worst-of-batch reseed, unrolled rollout, BPTT
//!   ([`super::nca_grad`]), global-norm clip, Adam with the staircase
//!   lr schedule ([`super::opt`]), evolved states out for pool
//!   write-back.
//! - `mnist_train_step`: `(params, m, v, step, images[B,H,W],
//!   labels[B,10], seed) -> (params', m', v', loss)` — digit pinned in
//!   channel 0 (frozen), per-cell logits in channels 1..=10, MSE to the
//!   one-hot label over ink cells.
//! - `arc_train_step`: `(params, m, v, step, inputs[B,W,10],
//!   targets[B,W,10], seed) -> (params', m', v', loss)` — the §5.3
//!   1D-ARC cell on a [`Grid::D1`] ring: the one-hot task input pinned
//!   in the first 10 (frozen) channels, per-cell color logits in the
//!   next 10, MSE between the final logits and the one-hot target.
//! - `arc_eval`: `(params, inputs[B,W,10]) -> logits[B,W,10]` — a
//!   fixed-length deterministic rollout for the exact-match evaluator.
//! - `arc_traj`: `(params, input[W,10]) -> logits[T+1,W,10]` — every
//!   intermediate logit frame of one rollout (the Fig. 8 space-time
//!   diagram).
//!
//! Batch elements are independent, so the BPTT runs one scoped worker
//! per sample; the gradient/loss reduction and the optimizer update are
//! sequential in fixed order, which makes a train step bit-identical
//! for any worker-thread count (asserted in
//! `tests/native_train_props.rs` and `tests/native_arc_props.rs`).

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use super::nca::{Grid, NcaModel};
use super::nca_grad::{self, NcaGrads};
use super::opt::{clip_global_norm, Adam, LrSchedule};
use crate::backend::workers::WorkerPool;
use crate::backend::{ProgramBackend, Value};
use crate::datasets::arc1d::NUM_COLORS;
use crate::runtime::manifest::{
    ArtifactInfo, BlobInfo, Dtype, Manifest, Spec,
};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Hyperparameters of one natively-trained NCA scenario.
#[derive(Clone, Debug)]
pub struct NcaTrainSpec {
    pub height: usize,
    pub width: usize,
    /// State channels (RGBA + hidden for growing; input + 10 logits +
    /// hidden for MNIST).
    pub channels: usize,
    /// Hidden width of the per-cell MLP.
    pub hidden: usize,
    pub batch: usize,
    /// Rollout length is drawn uniformly from `[rollout_min,
    /// rollout_max]` per train step (the App. B unroll jitter),
    /// deterministically from the step's seed input.
    pub rollout_min: usize,
    pub rollout_max: usize,
    pub lr: LrSchedule,
    /// Global L2 gradient clip.
    pub clip_norm: f32,
    /// Seed of the initial parameter draw (`load_params`).
    pub param_seed: u64,
    /// Residual update scale of the cell.
    pub dt: f32,
}

impl NcaTrainSpec {
    /// Growing-NCA defaults, sized for host execution.
    pub fn growing() -> NcaTrainSpec {
        NcaTrainSpec {
            height: 16,
            width: 16,
            channels: 12,
            hidden: 32,
            batch: 8,
            rollout_min: 16,
            rollout_max: 28,
            lr: LrSchedule::default(),
            clip_norm: 1.0,
            param_seed: 0x6402,
            dt: 0.5,
        }
    }

    /// Self-classifying-MNIST defaults (channel 0 input, 1..=10 logits).
    pub fn mnist() -> NcaTrainSpec {
        NcaTrainSpec {
            height: 16,
            width: 16,
            channels: 16,
            hidden: 48,
            batch: 8,
            rollout_min: 12,
            rollout_max: 20,
            lr: LrSchedule::default(),
            clip_norm: 1.0,
            param_seed: 0x3157,
            dt: 0.5,
        }
    }

    /// Flat parameter-vector length of this cell geometry.
    pub fn param_count(&self) -> usize {
        NcaModel::param_count(self.channels, self.hidden)
    }

    fn validate(&self, what: &str, min_channels: usize) {
        assert!(self.height > 0 && self.width > 0, "{what}: empty grid");
        assert!(self.channels >= min_channels,
                "{what}: needs >= {min_channels} channels, has {}",
                self.channels);
        assert!(self.hidden > 0 && self.batch > 0, "{what}: empty cell");
        assert!(self.rollout_min >= 1 && self.rollout_min <= self.rollout_max,
                "{what}: bad rollout range [{}, {}]",
                self.rollout_min, self.rollout_max);
    }
}

/// Hyperparameters of the natively-trained 1D-ARC NCA (§5.3).
///
/// The cell state is `[W, C]` with `C = 2 * NUM_COLORS + extra`: the
/// one-hot task input pinned in channels `0..10` (frozen — the cell
/// reads the task at every step but cannot overwrite it), per-cell
/// color logits in channels `10..20` (zero at t=0, decoded by argmax),
/// and `extra` free hidden channels for intermediate computation.
#[derive(Clone, Debug)]
pub struct ArcTrainSpec {
    /// Row width (the generators need >= 16).
    pub width: usize,
    /// Free hidden channels beyond the 10 input + 10 logit channels.
    pub extra: usize,
    /// Hidden width of the per-cell MLP.
    pub hidden: usize,
    pub batch: usize,
    /// Training rollout length is drawn uniformly from `[rollout_min,
    /// rollout_max]` per train step, deterministically from the step's
    /// seed input (the same unroll jitter as the 2D cells).
    pub rollout_min: usize,
    pub rollout_max: usize,
    /// Fixed rollout length of `arc_eval` / `arc_traj` (deterministic
    /// evaluation; keep it inside the training range).
    pub eval_steps: usize,
    pub lr: LrSchedule,
    /// Global L2 gradient clip.
    pub clip_norm: f32,
    /// Seed of the initial parameter draw (`load_params`).
    pub param_seed: u64,
    /// Residual update scale of the cell.
    pub dt: f32,
}

impl Default for ArcTrainSpec {
    /// Defaults sized for host training of all 18 Table-2 tasks
    /// (prototype-validated: Move-1 reaches 100% exact match within
    /// 200 train steps, Denoise ~90%).
    fn default() -> ArcTrainSpec {
        ArcTrainSpec {
            width: 32,
            extra: 4,
            hidden: 48,
            batch: 8,
            rollout_min: 12,
            rollout_max: 24,
            eval_steps: 18,
            lr: LrSchedule::default(),
            clip_norm: 1.0,
            param_seed: 0xA2C1D,
            dt: 0.5,
        }
    }
}

impl ArcTrainSpec {
    /// Total state channels: 10 frozen input + 10 logits + `extra`.
    pub fn channels(&self) -> usize {
        2 * NUM_COLORS + self.extra
    }

    /// Flat parameter-vector length of this cell geometry.
    pub fn param_count(&self) -> usize {
        NcaModel::param_count(self.channels(), self.hidden)
    }

    fn validate(&self) {
        assert!(self.width >= 16,
                "arc spec: 1D-ARC rows need width >= 16, got {}",
                self.width);
        assert!(self.hidden > 0 && self.batch > 0, "arc spec: empty cell");
        assert!(self.rollout_min >= 1 && self.rollout_min <= self.rollout_max,
                "arc spec: bad rollout range [{}, {}]",
                self.rollout_min, self.rollout_max);
        assert!(self.eval_steps >= 1, "arc spec: eval_steps must be >= 1");
    }
}

/// Channels below this index are pinned in the MNIST cell (the digit
/// input); logits live in `1..=10`.
const MNIST_FROZEN: usize = 1;
/// Ink threshold: cells whose input intensity exceeds this carry the
/// classification loss.
const MNIST_INK: f32 = 0.1;
/// Channels below this index are pinned in the ARC cell (the one-hot
/// task input); color logits live in `NUM_COLORS..2*NUM_COLORS`.
const ARC_FROZEN: usize = NUM_COLORS;

/// Pure-Rust training backend. Always available; see the module docs.
#[derive(Clone, Debug)]
pub struct NativeTrainBackend {
    pool: WorkerPool,
    growing: NcaTrainSpec,
    mnist: NcaTrainSpec,
    arc: ArcTrainSpec,
    manifest: Manifest,
}

impl Default for NativeTrainBackend {
    fn default() -> Self {
        NativeTrainBackend::new()
    }
}

impl NativeTrainBackend {
    /// Default specs, pool sized to the machine.
    pub fn new() -> NativeTrainBackend {
        NativeTrainBackend::with_threads(WorkerPool::new().threads())
    }

    /// Default specs with an explicit worker count (1 = sequential).
    pub fn with_threads(threads: usize) -> NativeTrainBackend {
        NativeTrainBackend::with_all_specs(
            NcaTrainSpec::growing(),
            NcaTrainSpec::mnist(),
            ArcTrainSpec::default(),
            threads,
        )
    }

    /// Custom 2D scenario hyperparameters (tests, benches,
    /// experiments); the ARC spec stays at its defaults.
    pub fn with_specs(growing: NcaTrainSpec, mnist: NcaTrainSpec,
                      threads: usize) -> NativeTrainBackend {
        NativeTrainBackend::with_all_specs(growing, mnist,
                                           ArcTrainSpec::default(), threads)
    }

    /// Custom 1D-ARC hyperparameters; the 2D specs stay at their
    /// defaults.
    pub fn with_arc_spec(arc: ArcTrainSpec, threads: usize)
                         -> NativeTrainBackend {
        NativeTrainBackend::with_all_specs(NcaTrainSpec::growing(),
                                           NcaTrainSpec::mnist(), arc,
                                           threads)
    }

    /// Every scenario's hyperparameters, explicitly.
    pub fn with_all_specs(growing: NcaTrainSpec, mnist: NcaTrainSpec,
                          arc: ArcTrainSpec, threads: usize)
                          -> NativeTrainBackend {
        growing.validate("growing spec", 4);
        mnist.validate("mnist spec", 11);
        arc.validate();
        let manifest = build_manifest(&growing, &mnist, &arc);
        NativeTrainBackend {
            pool: WorkerPool::with_threads(threads),
            growing,
            mnist,
            arc,
            manifest,
        }
    }

    /// Backend for one bare [`crate::backend::Backend::train_step`]
    /// call: grid/batch geometry is inferred from the call's tensors,
    /// the MLP width from the parameter count, everything else from the
    /// scenario defaults.
    pub fn for_call(threads: usize, program: &str, inputs: &[Value])
                    -> Result<NativeTrainBackend> {
        let mut growing = NcaTrainSpec::growing();
        let mut mnist = NcaTrainSpec::mnist();
        let mut arc = ArcTrainSpec::default();
        match program {
            "arc_train_step" | "arc_eval" => {
                let params = f32_arg(inputs, 0, "params")?;
                let idx = if program == "arc_train_step" { 4 } else { 1 };
                let ins = f32_arg(inputs, idx, "inputs")?;
                ensure!(ins.shape().len() == 3
                        && ins.shape()[2] == NUM_COLORS,
                        "{program}: inputs must be [B, W, {NUM_COLORS}], \
                         got {:?}", ins.shape());
                let s = ins.shape();
                ensure!(s[0] > 0 && s[1] >= 16,
                        "{program}: inputs shape {s:?} needs a non-empty \
                         batch and width >= 16");
                arc.batch = s[0];
                arc.width = s[1];
                arc.hidden =
                    infer_hidden(params.numel(), arc.channels())?;
            }
            "arc_traj" => {
                let params = f32_arg(inputs, 0, "params")?;
                let input = f32_arg(inputs, 1, "input")?;
                ensure!(input.shape().len() == 2
                        && input.shape()[1] == NUM_COLORS
                        && input.shape()[0] >= 16,
                        "arc_traj: input must be [W >= 16, {NUM_COLORS}], \
                         got {:?}", input.shape());
                arc.width = input.shape()[0];
                arc.hidden =
                    infer_hidden(params.numel(), arc.channels())?;
            }
            "growing_train_step" => {
                let params = f32_arg(inputs, 0, "params")?;
                let states = f32_arg(inputs, 4, "states")?;
                ensure!(states.shape().len() == 4,
                        "growing_train_step: states must be [B, H, W, C], \
                         got {:?}", states.shape());
                let s = states.shape();
                ensure!(s.iter().all(|&d| d > 0) && s[3] >= 4,
                        "growing_train_step: states shape {s:?} needs \
                         non-empty dims and >= 4 (RGBA) channels");
                growing.batch = s[0];
                growing.height = s[1];
                growing.width = s[2];
                growing.channels = s[3];
                growing.hidden =
                    infer_hidden(params.numel(), growing.channels)?;
            }
            "mnist_train_step" => {
                let params = f32_arg(inputs, 0, "params")?;
                let images = f32_arg(inputs, 4, "images")?;
                ensure!(images.shape().len() == 3,
                        "mnist_train_step: images must be [B, H, W], \
                         got {:?}", images.shape());
                let s = images.shape();
                ensure!(s.iter().all(|&d| d > 0),
                        "mnist_train_step: empty dim in images shape {s:?}");
                mnist.batch = s[0];
                mnist.height = s[1];
                mnist.width = s[2];
                mnist.hidden = infer_hidden(params.numel(), mnist.channels)?;
            }
            "growing_seed" => {}
            other => bail!(
                "the native backend trains these programs: growing_seed, \
                 growing_train_step, mnist_train_step, arc_train_step, \
                 arc_eval, arc_traj — not {other:?}"
            ),
        }
        Ok(NativeTrainBackend::with_all_specs(growing, mnist, arc, threads))
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn growing_spec(&self) -> &NcaTrainSpec {
        &self.growing
    }

    pub fn mnist_spec(&self) -> &NcaTrainSpec {
        &self.mnist
    }

    pub fn arc_spec(&self) -> &ArcTrainSpec {
        &self.arc
    }

    /// The single-seed-cell growing start state: alpha + hidden channels
    /// lit at the center cell, everything else zero.
    fn growing_seed_state(&self) -> Tensor {
        let spec = &self.growing;
        let mut t =
            Tensor::zeros(&[spec.height, spec.width, spec.channels]);
        let (cy, cx) = (spec.height / 2, spec.width / 2);
        for ch in 3..spec.channels {
            t.set(&[cy, cx, ch], 1.0);
        }
        t
    }

    fn growing_train_step(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let spec = &self.growing;
        ensure!(inputs.len() == 7,
                "growing_train_step wants 7 inputs (params, m, v, step, \
                 states, target, seed), got {}", inputs.len());
        let params = f32_arg(inputs, 0, "params")?;
        let m = f32_arg(inputs, 1, "m")?;
        let v = f32_arg(inputs, 2, "v")?;
        let step = i32_arg(inputs, 3, "step")?;
        let states = f32_arg(inputs, 4, "states")?;
        let target = f32_arg(inputs, 5, "target")?;
        let seed = u32_arg(inputs, 6, "seed")?;

        let (b, h, w, c) =
            (spec.batch, spec.height, spec.width, spec.channels);
        check_opt_state(params, m, v, spec.param_count())?;
        ensure!(states.shape() == &[b, h, w, c],
                "growing_train_step: states shape {:?}, spec wants \
                 [{b}, {h}, {w}, {c}]", states.shape());
        ensure!(target.shape() == &[h, w, 4],
                "growing_train_step: target shape {:?}, wants [{h}, {w}, 4]",
                target.shape());

        let model = NcaModel::from_flat(c, spec.hidden, spec.dt,
                                        params.data());
        let steps =
            rollout_steps(spec.rollout_min, spec.rollout_max, step, seed);
        let cell = h * w * c;

        // Worst-of-batch reseed: the sample farthest from the target
        // restarts from the seed state (keeps the pool anchored).
        let mut boards: Vec<Vec<f32>> = (0..b)
            .map(|i| states.data()[i * cell..(i + 1) * cell].to_vec())
            .collect();
        if b > 1 {
            let losses: Vec<f64> = boards
                .iter()
                .map(|board| rgba_mse(board, target.data(), h * w, c))
                .collect();
            let worst = losses
                .iter()
                .enumerate()
                .max_by(|(_, x), (_, y)| x.total_cmp(y))
                .map(|(i, _)| i)
                .unwrap();
            boards[worst]
                .copy_from_slice(self.growing_seed_state().data());
        }

        // Per-sample BPTT in parallel; reduction stays sequential.
        let mut slots: Vec<Slot> = boards
            .into_iter()
            .map(|board| Slot {
                board,
                grads: NcaGrads::zeros(&model),
                loss: 0.0,
            })
            .collect();
        let tgt = target.data();
        let denom = (h * w * 4) as f32 * b as f32;
        self.pool.for_each_chunk(&mut slots, 1, |_, chunk| {
            let slot = &mut chunk[0];
            let tape =
                nca_grad::rollout_tape(&model, &slot.board, h, w, steps, 0);
            let fin = tape.last().unwrap();
            let mut d_final = vec![0.0f32; cell];
            let mut sum = 0.0f64;
            for px in 0..h * w {
                for ch in 0..4 {
                    let d = fin[px * c + ch] - tgt[px * 4 + ch];
                    sum += d as f64 * d as f64;
                    d_final[px * c + ch] = 2.0 * d / denom;
                }
            }
            slot.loss = sum / (h * w * 4) as f64;
            let (grads, _) = nca_grad::backward(&model, &tape, h, w, 0,
                                                &d_final);
            slot.grads = grads;
            slot.board.copy_from_slice(fin);
        });

        let (mut result, loss) =
            self.finish_step(c, spec.hidden, spec.clip_norm, &spec.lr,
                             params, m, v, step, &slots);
        result.push(Tensor::scalar(loss));
        let mut evolved = Vec::with_capacity(b * cell);
        for slot in &slots {
            evolved.extend_from_slice(&slot.board);
        }
        result.push(Tensor::new(vec![b, h, w, c], evolved)?);
        Ok(result)
    }

    fn mnist_train_step(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let spec = &self.mnist;
        ensure!(inputs.len() == 7,
                "mnist_train_step wants 7 inputs (params, m, v, step, \
                 images, labels, seed), got {}", inputs.len());
        let params = f32_arg(inputs, 0, "params")?;
        let m = f32_arg(inputs, 1, "m")?;
        let v = f32_arg(inputs, 2, "v")?;
        let step = i32_arg(inputs, 3, "step")?;
        let images = f32_arg(inputs, 4, "images")?;
        let labels = f32_arg(inputs, 5, "labels")?;
        let seed = u32_arg(inputs, 6, "seed")?;

        let (b, h, w, c) =
            (spec.batch, spec.height, spec.width, spec.channels);
        check_opt_state(params, m, v, spec.param_count())?;
        ensure!(images.shape() == &[b, h, w],
                "mnist_train_step: images shape {:?}, spec wants \
                 [{b}, {h}, {w}]", images.shape());
        ensure!(labels.shape() == &[b, 10],
                "mnist_train_step: labels shape {:?}, wants [{b}, 10]",
                labels.shape());

        let model = NcaModel::from_flat(c, spec.hidden, spec.dt,
                                        params.data());
        let steps =
            rollout_steps(spec.rollout_min, spec.rollout_max, step, seed);
        let cell = h * w * c;

        // State: digit pinned in channel 0, everything else zero.
        let mut slots: Vec<Slot> = (0..b)
            .map(|i| {
                let img = &images.data()[i * h * w..(i + 1) * h * w];
                let mut board = vec![0.0f32; cell];
                for (px, &ink) in img.iter().enumerate() {
                    board[px * c] = ink;
                }
                Slot { board, grads: NcaGrads::zeros(&model), loss: 0.0 }
            })
            .collect();
        let label_data = labels.data();
        self.pool.for_each_chunk(&mut slots, 1, |i, chunk| {
            let slot = &mut chunk[0];
            let tape = nca_grad::rollout_tape(&model, &slot.board, h, w,
                                              steps, MNIST_FROZEN);
            let fin = tape.last().unwrap();
            let ink: Vec<usize> = (0..h * w)
                .filter(|&px| fin[px * c] > MNIST_INK)
                .collect();
            if ink.is_empty() {
                slot.loss = 0.0;
                slot.board.copy_from_slice(fin);
                return;
            }
            let denom = (ink.len() * 10) as f32 * b as f32;
            let mut d_final = vec![0.0f32; cell];
            let mut sum = 0.0f64;
            for &px in &ink {
                for cls in 0..10 {
                    let d = fin[px * c + 1 + cls]
                        - label_data[i * 10 + cls];
                    sum += d as f64 * d as f64;
                    d_final[px * c + 1 + cls] = 2.0 * d / denom;
                }
            }
            slot.loss = sum / (ink.len() * 10) as f64;
            let (grads, _) = nca_grad::backward(&model, &tape, h, w,
                                                MNIST_FROZEN, &d_final);
            slot.grads = grads;
            slot.board.copy_from_slice(fin);
        });

        let (mut result, loss) =
            self.finish_step(c, spec.hidden, spec.clip_norm, &spec.lr,
                             params, m, v, step, &slots);
        result.push(Tensor::scalar(loss));
        Ok(result)
    }

    /// The `[W, C]` initial ARC board for one one-hot input row
    /// (`[W, 10]` flat): task encoding pinned in the frozen channels,
    /// logits and hidden channels zero.
    fn arc_board(&self, onehot_row: &[f32]) -> Vec<f32> {
        let (w, c) = (self.arc.width, self.arc.channels());
        debug_assert_eq!(onehot_row.len(), w * NUM_COLORS);
        let mut board = vec![0.0f32; w * c];
        for x in 0..w {
            board[x * c..x * c + NUM_COLORS].copy_from_slice(
                &onehot_row[x * NUM_COLORS..(x + 1) * NUM_COLORS]);
        }
        board
    }

    fn arc_train_step(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let spec = &self.arc;
        ensure!(inputs.len() == 7,
                "arc_train_step wants 7 inputs (params, m, v, step, \
                 inputs, targets, seed), got {}", inputs.len());
        let params = f32_arg(inputs, 0, "params")?;
        let m = f32_arg(inputs, 1, "m")?;
        let v = f32_arg(inputs, 2, "v")?;
        let step = i32_arg(inputs, 3, "step")?;
        let ins = f32_arg(inputs, 4, "inputs")?;
        let tgts = f32_arg(inputs, 5, "targets")?;
        let seed = u32_arg(inputs, 6, "seed")?;

        let (b, w, c) = (spec.batch, spec.width, spec.channels());
        check_opt_state(params, m, v, spec.param_count())?;
        ensure!(ins.shape() == &[b, w, NUM_COLORS],
                "arc_train_step: inputs shape {:?}, spec wants \
                 [{b}, {w}, {NUM_COLORS}]", ins.shape());
        ensure!(tgts.shape() == &[b, w, NUM_COLORS],
                "arc_train_step: targets shape {:?}, wants \
                 [{b}, {w}, {NUM_COLORS}]", tgts.shape());

        let model = NcaModel::from_flat(c, spec.hidden, spec.dt,
                                        params.data());
        let steps =
            rollout_steps(spec.rollout_min, spec.rollout_max, step, seed);
        let grid = Grid::D1 { w };
        let row = w * NUM_COLORS;

        let mut slots: Vec<Slot> = (0..b)
            .map(|i| Slot {
                board: self.arc_board(&ins.data()[i * row..(i + 1) * row]),
                grads: NcaGrads::zeros(&model),
                loss: 0.0,
            })
            .collect();
        let tgt = tgts.data();
        let denom = row as f32 * b as f32;
        self.pool.for_each_chunk(&mut slots, 1, |i, chunk| {
            let slot = &mut chunk[0];
            let tape = nca_grad::rollout_tape_on(&model, &slot.board, grid,
                                                 steps, ARC_FROZEN);
            let fin = tape.last().unwrap();
            let mut d_final = vec![0.0f32; w * c];
            let mut sum = 0.0f64;
            for x in 0..w {
                for col in 0..NUM_COLORS {
                    let d = fin[x * c + NUM_COLORS + col]
                        - tgt[i * row + x * NUM_COLORS + col];
                    sum += d as f64 * d as f64;
                    d_final[x * c + NUM_COLORS + col] = 2.0 * d / denom;
                }
            }
            slot.loss = sum / row as f64;
            let (grads, _) = nca_grad::backward_on(&model, &tape, grid,
                                                   ARC_FROZEN, &d_final);
            slot.grads = grads;
            // No board write-back: ARC training has no sample pool —
            // every step re-embeds fresh one-hot inputs.
        });

        let (mut result, loss) =
            self.finish_step(c, spec.hidden, spec.clip_norm, &spec.lr,
                             params, m, v, step, &slots);
        result.push(Tensor::scalar(loss));
        Ok(result)
    }

    fn arc_eval(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let spec = &self.arc;
        ensure!(inputs.len() == 2,
                "arc_eval wants 2 inputs (params, inputs), got {}",
                inputs.len());
        let params = f32_arg(inputs, 0, "params")?;
        let ins = f32_arg(inputs, 1, "inputs")?;
        let (b, w, c) = (spec.batch, spec.width, spec.channels());
        ensure!(params.numel() == spec.param_count(),
                "arc_eval: params has {} values, spec wants {}",
                params.numel(), spec.param_count());
        ensure!(ins.shape() == &[b, w, NUM_COLORS],
                "arc_eval: inputs shape {:?}, spec wants \
                 [{b}, {w}, {NUM_COLORS}]", ins.shape());

        let model = NcaModel::from_flat(c, spec.hidden, spec.dt,
                                        params.data());
        let row = w * NUM_COLORS;
        let mut boards: Vec<f32> = Vec::with_capacity(b * w * c);
        for i in 0..b {
            boards.extend(
                self.arc_board(&ins.data()[i * row..(i + 1) * row]));
        }
        self.pool.for_each_chunk(&mut boards, w * c, |_, board| {
            let mut scratch = vec![0.0f32; board.len()];
            for _ in 0..spec.eval_steps {
                model.step_frozen_1d(board, &mut scratch, w, ARC_FROZEN);
                board.copy_from_slice(&scratch);
            }
        });

        // Slice the logit channels out as [B, W, 10].
        let mut logits = Vec::with_capacity(b * row);
        for i in 0..b {
            for x in 0..w {
                let base = (i * w + x) * c + NUM_COLORS;
                logits.extend_from_slice(&boards[base..base + NUM_COLORS]);
            }
        }
        Ok(vec![Tensor::new(vec![b, w, NUM_COLORS], logits)?])
    }

    fn arc_traj(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let spec = &self.arc;
        ensure!(inputs.len() == 2,
                "arc_traj wants 2 inputs (params, input), got {}",
                inputs.len());
        let params = f32_arg(inputs, 0, "params")?;
        let input = f32_arg(inputs, 1, "input")?;
        let (w, c) = (spec.width, spec.channels());
        ensure!(params.numel() == spec.param_count(),
                "arc_traj: params has {} values, spec wants {}",
                params.numel(), spec.param_count());
        ensure!(input.shape() == &[w, NUM_COLORS],
                "arc_traj: input shape {:?}, spec wants \
                 [{w}, {NUM_COLORS}]", input.shape());

        let model = NcaModel::from_flat(c, spec.hidden, spec.dt,
                                        params.data());
        let board = self.arc_board(input.data());
        let tape = nca_grad::rollout_tape_on(&model, &board,
                                             Grid::D1 { w },
                                             spec.eval_steps, ARC_FROZEN);
        let mut traj = Vec::with_capacity(tape.len() * w * NUM_COLORS);
        for state in &tape {
            for x in 0..w {
                let base = x * c + NUM_COLORS;
                traj.extend_from_slice(&state[base..base + NUM_COLORS]);
            }
        }
        Ok(vec![Tensor::new(vec![tape.len(), w, NUM_COLORS], traj)?])
    }

    /// Shared tail of every train step: fixed-order gradient reduction,
    /// clip, Adam. Returns `[params', m', v']` and the mean loss.
    #[allow(clippy::too_many_arguments)]
    fn finish_step(&self, channels: usize, hidden: usize, clip_norm: f32,
                   lr: &LrSchedule, params: &Tensor, m: &Tensor, v: &Tensor,
                   step: i32, slots: &[Slot]) -> (Vec<Tensor>, f32) {
        let mut grad = NcaGrads {
            w1: vec![0.0; 3 * channels * hidden],
            b1: vec![0.0; hidden],
            w2: vec![0.0; hidden * channels],
        };
        let mut loss = 0.0f64;
        for slot in slots {
            grad.add(&slot.grads);
            loss += slot.loss;
        }
        loss /= slots.len() as f64;

        let mut gflat = grad.flatten();
        clip_global_norm(&mut gflat, clip_norm);
        let mut new_params = params.data().to_vec();
        let mut new_m = m.data().to_vec();
        let mut new_v = v.data().to_vec();
        Adam::default().update(&mut new_params, &mut new_m, &mut new_v,
                               &gflat, step, lr.lr(step));
        let p = new_params.len();
        (
            vec![
                Tensor::new(vec![p], new_params).unwrap(),
                Tensor::new(vec![p], new_m).unwrap(),
                Tensor::new(vec![p], new_v).unwrap(),
            ],
            loss as f32,
        )
    }
}

impl ProgramBackend for NativeTrainBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let _span = crate::obs::span(match name {
            "growing_seed" => "train_growing_seed",
            "growing_train_step" => "train_growing_step",
            "mnist_train_step" => "train_mnist_step",
            "arc_train_step" => "train_arc_step",
            "arc_eval" => "train_arc_eval",
            "arc_traj" => "train_arc_traj",
            _ => "train_unknown",
        });
        match name {
            "growing_seed" => Ok(vec![self.growing_seed_state()]),
            "growing_train_step" => self.growing_train_step(inputs),
            "mnist_train_step" => self.mnist_train_step(inputs),
            "arc_train_step" => self.arc_train_step(inputs),
            "arc_eval" => self.arc_eval(inputs),
            "arc_traj" => self.arc_traj(inputs),
            other => bail!(
                "native train backend has no program {other:?} (programs: \
                 growing_seed, growing_train_step, mnist_train_step, \
                 arc_train_step, arc_eval, arc_traj)"
            ),
        }
    }

    /// Initial parameters are drawn in memory (no blob files): the same
    /// `NcaModel::random` init as the inference substrate, from the
    /// spec's `param_seed`.
    fn load_params(&self, blob: &str) -> Result<Tensor> {
        let (channels, hidden, seed) = match blob {
            "growing_params" => (self.growing.channels, self.growing.hidden,
                                 self.growing.param_seed),
            "mnist_params" => (self.mnist.channels, self.mnist.hidden,
                               self.mnist.param_seed),
            "arc_params" => (self.arc.channels(), self.arc.hidden,
                             self.arc.param_seed),
            other => bail!(
                "native train backend has no parameter blob {other:?} \
                 (blobs: growing_params, mnist_params, arc_params)"
            ),
        };
        let model = NcaModel::random(channels, hidden, &mut Rng::new(seed));
        let flat = model.flatten();
        let n = flat.len();
        Tensor::new(vec![n], flat)
    }
}

/// Per-sample workspace of the parallel section.
struct Slot {
    board: Vec<f32>,
    grads: NcaGrads,
    loss: f64,
}

/// Mean squared RGBA error of one `[H*W, C]` board vs a `[H*W, 4]`
/// target (both as flat slices).
fn rgba_mse(board: &[f32], target: &[f32], pixels: usize, c: usize) -> f64 {
    let mut sum = 0.0f64;
    for px in 0..pixels {
        for ch in 0..4 {
            let d = board[px * c + ch] - target[px * 4 + ch];
            sum += d as f64 * d as f64;
        }
    }
    sum / (pixels * 4) as f64
}

/// Rollout length for one train step: uniform in `[min, max]`,
/// deterministic in (step, seed).
fn rollout_steps(min: usize, max: usize, step: i32, seed: u32) -> usize {
    if max <= min {
        return min;
    }
    let mut rng = Rng::new(((step as i64 as u64) << 32) ^ seed as u64)
        .fold_in(0x9CA);
    rng.range(min, max + 1)
}

/// Solve `P = hidden * (4 * channels + 1)` for the MLP width.
fn infer_hidden(param_count: usize, channels: usize) -> Result<usize> {
    let per = 4 * channels + 1;
    ensure!(param_count > 0 && param_count % per == 0,
            "parameter vector of {param_count} does not factor as a \
             {channels}-channel NCA cell (hidden * {per})");
    Ok(param_count / per)
}

fn check_opt_state(params: &Tensor, m: &Tensor, v: &Tensor, p: usize)
                   -> Result<()> {
    ensure!(params.numel() == p,
            "params: {} values, spec wants {p}", params.numel());
    ensure!(m.numel() == p && v.numel() == p,
            "optimizer state ({}, {}) does not match {p} params",
            m.numel(), v.numel());
    Ok(())
}

fn f32_arg<'a>(inputs: &'a [Value], i: usize, what: &str)
               -> Result<&'a Tensor> {
    match inputs.get(i) {
        Some(Value::F32(t)) => Ok(t),
        other => bail!(
            "train-step input {i} ({what}): wanted an f32 tensor, \
             got {other:?}"
        ),
    }
}

fn i32_arg(inputs: &[Value], i: usize, what: &str) -> Result<i32> {
    match inputs.get(i) {
        Some(Value::I32(x)) => Ok(*x),
        other => bail!(
            "train-step input {i} ({what}): wanted an i32 scalar, \
             got {other:?}"
        ),
    }
}

fn u32_arg(inputs: &[Value], i: usize, what: &str) -> Result<u32> {
    match inputs.get(i) {
        Some(Value::U32(x)) => Ok(*x),
        other => bail!(
            "train-step input {i} ({what}): wanted a u32 scalar, \
             got {other:?}"
        ),
    }
}

fn spec_in(name: &str, dtype: Dtype, shape: Vec<usize>) -> Spec {
    Spec { name: name.to_string(), dtype, shape }
}

fn spec_out(shape: Vec<usize>) -> Spec {
    Spec { name: String::new(), dtype: Dtype::F32, shape }
}

fn meta_for(ca: &str, spec: &NcaTrainSpec) -> BTreeMap<String, Json> {
    let mut meta = BTreeMap::new();
    meta.insert("ca".to_string(), Json::from(ca));
    meta.insert("steps".to_string(), Json::from(spec.rollout_max));
    meta.insert("channels".to_string(), Json::from(spec.channels));
    meta.insert("hidden".to_string(), Json::from(spec.hidden));
    meta.insert("batch".to_string(), Json::from(spec.batch));
    meta
}

/// The in-memory manifest describing the native train programs — the
/// same introspection surface (`inputs[4]` batch shapes, `meta`) the
/// experiment drivers read off artifact manifests.
fn build_manifest(growing: &NcaTrainSpec, mnist: &NcaTrainSpec,
                  arc: &ArcTrainSpec) -> Manifest {
    let mut artifacts = BTreeMap::new();
    let gp = growing.param_count();
    let (gb, gh, gw, gc) =
        (growing.batch, growing.height, growing.width, growing.channels);
    artifacts.insert(
        "growing_seed".to_string(),
        ArtifactInfo {
            name: "growing_seed".to_string(),
            file: "<native>".to_string(),
            inputs: vec![],
            outputs: vec![spec_out(vec![gh, gw, gc])],
            meta: meta_for("growing", growing),
        },
    );
    artifacts.insert(
        "growing_train_step".to_string(),
        ArtifactInfo {
            name: "growing_train_step".to_string(),
            file: "<native>".to_string(),
            inputs: vec![
                spec_in("params", Dtype::F32, vec![gp]),
                spec_in("m", Dtype::F32, vec![gp]),
                spec_in("v", Dtype::F32, vec![gp]),
                spec_in("step", Dtype::I32, vec![]),
                spec_in("states", Dtype::F32, vec![gb, gh, gw, gc]),
                spec_in("target", Dtype::F32, vec![gh, gw, 4]),
                spec_in("seed", Dtype::U32, vec![]),
            ],
            outputs: vec![
                spec_out(vec![gp]),
                spec_out(vec![gp]),
                spec_out(vec![gp]),
                spec_out(vec![]),
                spec_out(vec![gb, gh, gw, gc]),
            ],
            meta: meta_for("growing", growing),
        },
    );
    let mp = mnist.param_count();
    let (mb, mh, mw) = (mnist.batch, mnist.height, mnist.width);
    artifacts.insert(
        "mnist_train_step".to_string(),
        ArtifactInfo {
            name: "mnist_train_step".to_string(),
            file: "<native>".to_string(),
            inputs: vec![
                spec_in("params", Dtype::F32, vec![mp]),
                spec_in("m", Dtype::F32, vec![mp]),
                spec_in("v", Dtype::F32, vec![mp]),
                spec_in("step", Dtype::I32, vec![]),
                spec_in("images", Dtype::F32, vec![mb, mh, mw]),
                spec_in("labels", Dtype::F32, vec![mb, 10]),
                spec_in("seed", Dtype::U32, vec![]),
            ],
            outputs: vec![
                spec_out(vec![mp]),
                spec_out(vec![mp]),
                spec_out(vec![mp]),
                spec_out(vec![]),
            ],
            meta: meta_for("mnist", mnist),
        },
    );
    let ap = arc.param_count();
    let (ab, aw) = (arc.batch, arc.width);
    let mut arc_meta = BTreeMap::new();
    arc_meta.insert("ca".to_string(), Json::from("arc"));
    arc_meta.insert("steps".to_string(), Json::from(arc.eval_steps));
    arc_meta.insert("channels".to_string(), Json::from(arc.channels()));
    arc_meta.insert("hidden".to_string(), Json::from(arc.hidden));
    arc_meta.insert("batch".to_string(), Json::from(arc.batch));
    artifacts.insert(
        "arc_train_step".to_string(),
        ArtifactInfo {
            name: "arc_train_step".to_string(),
            file: "<native>".to_string(),
            inputs: vec![
                spec_in("params", Dtype::F32, vec![ap]),
                spec_in("m", Dtype::F32, vec![ap]),
                spec_in("v", Dtype::F32, vec![ap]),
                spec_in("step", Dtype::I32, vec![]),
                spec_in("inputs", Dtype::F32, vec![ab, aw, NUM_COLORS]),
                spec_in("targets", Dtype::F32, vec![ab, aw, NUM_COLORS]),
                spec_in("seed", Dtype::U32, vec![]),
            ],
            outputs: vec![
                spec_out(vec![ap]),
                spec_out(vec![ap]),
                spec_out(vec![ap]),
                spec_out(vec![]),
            ],
            meta: arc_meta.clone(),
        },
    );
    artifacts.insert(
        "arc_eval".to_string(),
        ArtifactInfo {
            name: "arc_eval".to_string(),
            file: "<native>".to_string(),
            inputs: vec![
                spec_in("params", Dtype::F32, vec![ap]),
                spec_in("inputs", Dtype::F32, vec![ab, aw, NUM_COLORS]),
            ],
            outputs: vec![spec_out(vec![ab, aw, NUM_COLORS])],
            meta: arc_meta.clone(),
        },
    );
    artifacts.insert(
        "arc_traj".to_string(),
        ArtifactInfo {
            name: "arc_traj".to_string(),
            file: "<native>".to_string(),
            inputs: vec![
                spec_in("params", Dtype::F32, vec![ap]),
                spec_in("input", Dtype::F32, vec![aw, NUM_COLORS]),
            ],
            outputs: vec![spec_out(vec![arc.eval_steps + 1, aw,
                                        NUM_COLORS])],
            meta: arc_meta,
        },
    );

    let mut blobs = BTreeMap::new();
    blobs.insert(
        "growing_params".to_string(),
        BlobInfo {
            name: "growing_params".to_string(),
            file: "<native>".to_string(),
            shape: vec![gp],
        },
    );
    blobs.insert(
        "mnist_params".to_string(),
        BlobInfo {
            name: "mnist_params".to_string(),
            file: "<native>".to_string(),
            shape: vec![mp],
        },
    );
    blobs.insert(
        "arc_params".to_string(),
        BlobInfo {
            name: "arc_params".to_string(),
            file: "<native>".to_string(),
            shape: vec![ap],
        },
    );

    Manifest {
        preset: "native-train".to_string(),
        dir: std::path::PathBuf::new(),
        artifacts,
        blobs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NativeTrainBackend {
        let growing = NcaTrainSpec {
            height: 6,
            width: 6,
            channels: 5,
            hidden: 8,
            batch: 2,
            rollout_min: 2,
            rollout_max: 3,
            ..NcaTrainSpec::growing()
        };
        let mnist = NcaTrainSpec {
            height: 8,
            width: 8,
            channels: 12,
            hidden: 8,
            batch: 2,
            rollout_min: 2,
            rollout_max: 3,
            ..NcaTrainSpec::mnist()
        };
        NativeTrainBackend::with_specs(growing, mnist, 2)
    }

    fn train_inputs(backend: &NativeTrainBackend) -> Vec<Value> {
        let spec = backend.growing_spec().clone();
        let p = spec.param_count();
        let params = backend.load_params("growing_params").unwrap();
        let seed = backend.growing_seed_state();
        let states =
            Tensor::stack(&vec![seed; spec.batch]).unwrap();
        let mut target = Tensor::zeros(&[spec.height, spec.width, 4]);
        target.set(&[2, 2, 3], 1.0);
        assert_eq!(params.numel(), p);
        vec![
            Value::F32(params),
            Value::F32(Tensor::zeros(&[p])),
            Value::F32(Tensor::zeros(&[p])),
            Value::I32(0),
            Value::F32(states),
            Value::F32(target),
            Value::U32(9),
        ]
    }

    #[test]
    fn manifest_describes_the_trainer_contract() {
        let backend = tiny();
        let info =
            backend.manifest().artifact("growing_train_step").unwrap();
        assert_eq!(info.inputs.len(), 7);
        assert!(info.outputs.len() >= 4, "train_loop wants >= 4 outputs");
        assert_eq!(info.inputs[4].shape[0], 2, "batch from inputs[4]");
        assert_eq!(info.inputs[5].shape, vec![6, 6, 4], "target spec");
        let m = backend.manifest().artifact("mnist_train_step").unwrap();
        assert_eq!(m.inputs[4].shape, vec![2, 8, 8]);
        assert_eq!(m.outputs.len(), 4);
    }

    #[test]
    fn growing_step_moves_params_and_reports_finite_loss() {
        let backend = tiny();
        let inputs = train_inputs(&backend);
        let out = backend.execute("growing_train_step", &inputs).unwrap();
        assert_eq!(out.len(), 5);
        let Value::F32(params0) = &inputs[0] else { unreachable!() };
        assert!(out[0].max_abs_diff(params0).unwrap() > 0.0,
                "params must move");
        let loss = out[3].data()[0];
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        let spec = backend.growing_spec();
        assert_eq!(out[4].shape(),
                   &[spec.batch, spec.height, spec.width, spec.channels]);
    }

    #[test]
    fn seed_state_is_single_cell() {
        let backend = tiny();
        let seed = backend.growing_seed_state();
        let spec = backend.growing_spec();
        let lit: usize =
            seed.data().iter().filter(|&&v| v != 0.0).count();
        assert_eq!(lit, spec.channels - 3, "one cell, alpha+hidden lit");
        assert_eq!(seed.at(&[3, 3, 3]), 1.0, "alpha at the center");
        assert_eq!(seed.at(&[3, 3, 0]), 0.0, "rgb stays dark");
    }

    #[test]
    fn unknown_programs_and_blobs_are_refused() {
        let backend = tiny();
        assert!(backend.execute("nope", &[]).is_err());
        assert!(backend.load_params("nope_params").is_err());
    }

    #[test]
    fn rollout_steps_deterministic_and_in_range() {
        let spec = NcaTrainSpec::growing();
        for step in 0..20 {
            let a = rollout_steps(spec.rollout_min, spec.rollout_max,
                                  step, 7);
            let b = rollout_steps(spec.rollout_min, spec.rollout_max,
                                  step, 7);
            assert_eq!(a, b);
            assert!((spec.rollout_min..=spec.rollout_max).contains(&a));
        }
        // Degenerate range pins the length.
        assert_eq!(rollout_steps(5, 5, 3, 1), 5);
    }

    fn tiny_arc() -> ArcTrainSpec {
        ArcTrainSpec {
            width: 16,
            extra: 2,
            hidden: 6,
            batch: 2,
            rollout_min: 2,
            rollout_max: 3,
            eval_steps: 3,
            ..ArcTrainSpec::default()
        }
    }

    #[test]
    fn arc_manifest_describes_the_trainer_contract() {
        let backend = NativeTrainBackend::with_arc_spec(tiny_arc(), 2);
        let info = backend.manifest().artifact("arc_train_step").unwrap();
        assert_eq!(info.inputs.len(), 7);
        assert_eq!(info.outputs.len(), 4);
        assert_eq!(info.inputs[4].shape, vec![2, 16, NUM_COLORS]);
        assert_eq!(info.inputs[5].shape, vec![2, 16, NUM_COLORS]);
        let eval = backend.manifest().artifact("arc_eval").unwrap();
        assert_eq!(eval.inputs[1].shape, vec![2, 16, NUM_COLORS]);
        assert_eq!(eval.outputs[0].shape, vec![2, 16, NUM_COLORS]);
        let traj = backend.manifest().artifact("arc_traj").unwrap();
        assert_eq!(traj.inputs[1].shape, vec![16, NUM_COLORS]);
        assert_eq!(traj.outputs[0].shape, vec![4, 16, NUM_COLORS]);
    }

    #[test]
    fn arc_board_pins_the_onehot_input() {
        let backend = NativeTrainBackend::with_arc_spec(tiny_arc(), 1);
        let spec = backend.arc_spec();
        let (w, c) = (spec.width, spec.channels());
        let mut row = vec![0.0f32; w * NUM_COLORS];
        row[3 * NUM_COLORS + 7] = 1.0; // cell 3 is color 7
        row[5 * NUM_COLORS] = 1.0; // cell 5 is background
        let board = backend.arc_board(&row);
        assert_eq!(board.len(), w * c);
        assert_eq!(board[3 * c + 7], 1.0);
        assert_eq!(board[5 * c], 1.0);
        // Logit and hidden channels start dark.
        for x in 0..w {
            for ch in NUM_COLORS..c {
                assert_eq!(board[x * c + ch], 0.0, "cell {x} ch {ch}");
            }
        }
    }

    #[test]
    fn arc_train_step_moves_params_and_reports_finite_loss() {
        let backend = NativeTrainBackend::with_arc_spec(tiny_arc(), 2);
        let spec = backend.arc_spec().clone();
        let p = spec.param_count();
        let params = backend.load_params("arc_params").unwrap();
        assert_eq!(params.numel(), p);
        // One-hot batches: a colored cell per row, targets shifted by 1.
        let (b, w) = (spec.batch, spec.width);
        let mut ins = Tensor::zeros(&[b, w, NUM_COLORS]);
        let mut tgts = Tensor::zeros(&[b, w, NUM_COLORS]);
        for i in 0..b {
            for x in 0..w {
                let color = if x == 4 + i { 3 } else { 0 };
                ins.set(&[i, x, color], 1.0);
                let tcolor = if x == 5 + i { 3 } else { 0 };
                tgts.set(&[i, x, tcolor], 1.0);
            }
        }
        let inputs = vec![
            Value::F32(params.clone()),
            Value::F32(Tensor::zeros(&[p])),
            Value::F32(Tensor::zeros(&[p])),
            Value::I32(0),
            Value::F32(ins),
            Value::F32(tgts),
            Value::U32(9),
        ];
        let out = backend.execute("arc_train_step", &inputs).unwrap();
        assert_eq!(out.len(), 4);
        assert!(out[0].max_abs_diff(&params).unwrap() > 0.0,
                "params must move");
        let loss = out[3].data()[0];
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    }

    #[test]
    fn arc_eval_and_traj_have_contract_shapes() {
        let backend = NativeTrainBackend::with_arc_spec(tiny_arc(), 2);
        let spec = backend.arc_spec().clone();
        let params = backend.load_params("arc_params").unwrap();
        let (b, w) = (spec.batch, spec.width);
        let mut ins = Tensor::zeros(&[b, w, NUM_COLORS]);
        for i in 0..b {
            for x in 0..w {
                ins.set(&[i, x, if x == 2 { 5 } else { 0 }], 1.0);
            }
        }
        let out = backend
            .execute("arc_eval",
                     &[Value::F32(params.clone()), Value::F32(ins.clone())])
            .unwrap();
        assert_eq!(out[0].shape(), &[b, w, NUM_COLORS]);
        assert!(out[0].data().iter().all(|v| v.is_finite()));

        let one = ins.index_axis0(0);
        let traj = backend
            .execute("arc_traj", &[Value::F32(params), Value::F32(one)])
            .unwrap();
        assert_eq!(traj[0].shape(),
                   &[spec.eval_steps + 1, w, NUM_COLORS]);
        // Frame 0 is the zero-initialized logit state.
        assert!(traj[0].data()[..w * NUM_COLORS]
                    .iter()
                    .all(|&v| v == 0.0));
    }

    #[test]
    fn infer_hidden_solves_the_layout() {
        // P = hidden * (4c + 1).
        assert_eq!(infer_hidden(8 * 21, 5).unwrap(), 8);
        assert!(infer_hidden(100, 5).is_err());
        assert!(infer_hidden(0, 5).is_err());
    }
}
