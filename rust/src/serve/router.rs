//! The `--shards N` front process: a thin std-only shard router.
//!
//! `cax serve --shards N` does not scale one scheduler across cores —
//! it forks **N whole worker processes** (each a normal single-shard
//! `cax serve` with its own registry, coalescer and metric registry)
//! and puts this router in front of them. Sessions are *partitioned by
//! id*: every worker mints session ids satisfying
//! `id % shard_count == shard_index` (see
//! [`SessionRegistry::set_shard`](super::SessionRegistry::set_shard)),
//! so the router can route any `/sessions/:id/...` request statelessly
//! by `parse_id(id) % N` — no routing table, no shared state, no
//! rebalancing. Creates (`POST /sessions`) round-robin across workers.
//!
//! The router speaks the same HTTP surface as a worker:
//!
//! - `POST /sessions` → round-robin to a worker, relay the reply (the
//!   returned id encodes its shard forever).
//! - `/sessions/:id/...` (status, step, reset, destroy, snapshot,
//!   **stream**) → proxy to shard `id % N`. Proxied responses are
//!   relayed byte-for-byte until worker EOF, which transparently
//!   covers the chunked SSE stream route.
//! - `GET /healthz` → fan out, sum sessions/pending, AND the `ok`s.
//! - `GET /stats` → fan out, reply `{"shards": [{shard, addr, stats},
//!   ...]}` with each worker's full stats document embedded.
//! - `POST /shutdown` (or SIGINT/SIGTERM) → broadcast `/shutdown` to
//!   every worker, wait for each child to drain and exit, then exit.
//!
//! Workers bind ephemeral loopback ports; the router learns each
//! address by parsing the worker's `listening on ADDR` stdout line
//! (the same line the integration tests parse). Worker stdout is then
//! forwarded to the router's *stderr* under a `[shard i]` prefix so
//! the router's own stdout stays machine-parseable. With
//! `--state-dir DIR`, worker `i` persists under `DIR/shard-i/` —
//! checkpoint files never cross shards, keeping the bit-identity
//! contract per worker.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::serve::http::{self, ReadOutcome, Request, Response};
use crate::serve::session::parse_id;
use crate::serve::ServeConfig;
use crate::util::json::{obj, Json};

/// How long a worker gets to print its `listening on` line.
const WORKER_START_TIMEOUT: Duration = Duration::from_secs(30);
/// How long a worker gets to drain and exit after `/shutdown`.
const WORKER_DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

struct Worker {
    index: usize,
    addr: SocketAddr,
    child: Child,
}

/// Spawn worker `index` as a child `cax serve` process on an ephemeral
/// port and wait for it to report its address.
fn spawn_worker(cfg: &ServeConfig, index: usize) -> Result<Worker> {
    let exe = std::env::current_exe()
        .context("resolving the cax binary for worker spawn")?;
    let mut cmd = Command::new(exe);
    cmd.arg("--seed")
        .arg(cfg.seed.to_string())
        .arg("serve")
        .arg("--port")
        .arg("0")
        .arg("--threads")
        .arg(cfg.threads.to_string())
        .arg("--max-sessions")
        .arg(cfg.max_sessions.to_string())
        .arg("--max-batch")
        .arg(cfg.max_batch.to_string())
        .arg("--max-pending")
        .arg(cfg.max_pending.to_string())
        .arg("--max-steps")
        .arg(cfg.max_steps.to_string())
        .arg("--tick-us")
        .arg(cfg.tick_window.as_micros().to_string())
        .arg("--shard-index")
        .arg(index.to_string())
        .arg("--shard-count")
        .arg(cfg.shards.to_string());
    if let Some(dir) = &cfg.state_dir {
        cmd.arg("--state-dir").arg(dir.join(format!("shard-{index}")));
    }
    cmd.stdin(Stdio::null()).stdout(Stdio::piped());
    let mut child = cmd
        .spawn()
        .with_context(|| format!("spawning shard worker {index}"))?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let deadline = Instant::now() + WORKER_START_TIMEOUT;
    let addr = loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => {
                let status = child.wait().ok();
                bail!(
                    "shard worker {index} exited before listening \
                     (status {status:?})"
                );
            }
            Ok(_) => {
                if let Some(rest) = line.split("listening on ").nth(1) {
                    let token =
                        rest.split_whitespace().next().unwrap_or("");
                    break token.parse::<SocketAddr>().with_context(|| {
                        format!(
                            "shard worker {index}: bad listen address \
                             {token:?}"
                        )
                    })?;
                }
                eprint!("[shard {index}] {line}");
            }
            Err(e) => return Err(e).with_context(|| {
                format!("reading shard worker {index} stdout")
            }),
        }
        if Instant::now() > deadline {
            let _ = child.kill();
            bail!("shard worker {index} did not report an address");
        }
    };
    // Keep draining the worker's stdout (onto our stderr) so the child
    // never blocks on a full pipe.
    std::thread::spawn(move || {
        for line in reader.lines() {
            match line {
                Ok(line) => eprintln!("[shard {index}] {line}"),
                Err(_) => break,
            }
        }
    });
    Ok(Worker { index, addr, child })
}

/// One-shot HTTP client against a worker: send, read to EOF, split
/// status and body. Workers honor `Connection: close`, so EOF
/// delimits the response.
fn fetch(addr: SocketAddr, method: &str, path: &str, body: &[u8])
         -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to shard at {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    send_request(&mut stream, addr, method, path, body)?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading shard response")?;
    let header_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .context("shard response has no header terminator")?;
    let head = std::str::from_utf8(&raw[..header_end])
        .context("shard response head is not UTF-8")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .context("shard response has no status code")?;
    Ok((status, raw[header_end + 4..].to_vec()))
}

fn send_request(stream: &mut TcpStream, addr: SocketAddr, method: &str,
                path: &str, body: &[u8]) -> Result<()> {
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Relay one request to `addr` and copy the response back
/// byte-for-byte until the worker closes — content-length and chunked
/// (SSE) responses alike, with per-chunk flushes so streamed frames
/// reach the client promptly.
fn proxy(client: &mut TcpStream, addr: SocketAddr, req: &Request)
         -> Result<()> {
    let mut upstream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => {
            let resp = Response::error(
                503,
                &format!("shard at {addr} unreachable: {e}"),
            );
            let _ = http::respond(client, &resp, true);
            return Ok(());
        }
    };
    send_request(&mut upstream, addr, &req.method, &req.path, &req.body)?;
    let mut buf = [0u8; 8192];
    loop {
        match upstream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                if client.write_all(&buf[..n]).is_err() {
                    break; // client went away; drop the relay
                }
                let _ = client.flush();
            }
            Err(e) => {
                crate::log_warn!("router: relay from {addr} failed: {e}");
                break;
            }
        }
    }
    Ok(())
}

struct RouterCtx {
    addrs: Vec<SocketAddr>,
    next: AtomicUsize,
    shutdown: AtomicBool,
}

impl RouterCtx {
    fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || http::signalled()
    }

    fn shard_for(&self, id: u64) -> SocketAddr {
        self.addrs[(id % self.addrs.len() as u64) as usize]
    }
}

fn handle_healthz(ctx: &RouterCtx) -> Response {
    let mut ok = true;
    let (mut sessions, mut pending) = (0u64, 0u64);
    for &addr in &ctx.addrs {
        match fetch(addr, "GET", "/healthz", b"")
            .ok()
            .filter(|(status, _)| *status == 200)
            .and_then(|(_, body)| {
                Json::parse(std::str::from_utf8(&body).ok()?).ok()
            }) {
            Some(json) => {
                ok &= json.get("ok").and_then(Json::as_bool)
                    == Some(true);
                let num = |key| {
                    json.get(key)
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64
                };
                sessions += num("sessions");
                pending += num("pending");
            }
            None => ok = false,
        }
    }
    Response::json(
        if ok { 200 } else { 503 },
        &obj(vec![
            ("ok", Json::Bool(ok)),
            ("shards", Json::from(ctx.addrs.len())),
            ("sessions", Json::from(sessions)),
            ("pending", Json::from(pending)),
        ]),
    )
}

fn handle_stats(ctx: &RouterCtx) -> Response {
    let mut shards = Vec::with_capacity(ctx.addrs.len());
    for (index, &addr) in ctx.addrs.iter().enumerate() {
        let stats = fetch(addr, "GET", "/stats", b"")
            .ok()
            .filter(|(status, _)| *status == 200)
            .and_then(|(_, body)| {
                Json::parse(std::str::from_utf8(&body).ok()?).ok()
            })
            .unwrap_or(Json::Null);
        shards.push(obj(vec![
            ("shard", Json::from(index)),
            ("addr", Json::from(addr.to_string().as_str())),
            ("stats", stats),
        ]));
    }
    Response::json(
        200,
        &obj(vec![
            ("router", Json::Bool(true)),
            ("shards", Json::Arr(shards)),
        ]),
    )
}

/// Route one request: local aggregate endpoints answer here, anything
/// session-scoped relays to its shard. Returns `None` when the
/// response was already written (proxied).
fn route(ctx: &RouterCtx, client: &mut TcpStream, req: &Request)
         -> Result<Option<Response>> {
    let segments: Vec<&str> =
        req.path.split('/').filter(|s| !s.is_empty()).collect();
    let resp = match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => handle_healthz(ctx),
        ("GET", ["stats"]) => handle_stats(ctx),
        ("POST", ["shutdown"]) => {
            ctx.shutdown.store(true, Ordering::SeqCst);
            Response::json(
                200, &obj(vec![("draining", Json::Bool(true))]))
        }
        ("POST", ["sessions"]) => {
            let pick = ctx.next.fetch_add(1, Ordering::Relaxed)
                % ctx.addrs.len();
            proxy(client, ctx.addrs[pick], req)?;
            return Ok(None);
        }
        (_, ["sessions", id, ..]) => match parse_id(id) {
            Some(id) => {
                proxy(client, ctx.shard_for(id), req)?;
                return Ok(None);
            }
            None => {
                Response::error(404, &format!("bad session id {id:?}"))
            }
        },
        _ => Response::error(404, "no such route on the shard router"),
    };
    Ok(Some(resp))
}

fn handle_connection(ctx: Arc<RouterCtx>, stream: TcpStream) {
    let run = || -> Result<()> {
        stream.set_read_timeout(Some(http::READ_POLL))?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        loop {
            match http::read_request(&mut reader)? {
                ReadOutcome::Closed => return Ok(()),
                ReadOutcome::Idle => {
                    if ctx.stopping() {
                        return Ok(());
                    }
                }
                ReadOutcome::Request(req) => {
                    // One request per connection: proxied responses
                    // end at worker EOF, so close unconditionally.
                    if let Some(resp) = route(&ctx, &mut writer, &req)? {
                        http::respond(&mut writer, &resp, true)?;
                    }
                    return Ok(());
                }
            }
        }
    };
    if let Err(e) = run() {
        crate::log_warn!("router: connection error: {e:#}");
    }
}

/// Broadcast `/shutdown` and wait for every worker to drain and exit.
fn drain_workers(workers: &mut [Worker]) {
    for worker in workers.iter() {
        if let Err(e) =
            fetch(worker.addr, "POST", "/shutdown", b"")
        {
            crate::log_warn!(
                "router: shutdown of shard {} failed: {e:#}",
                worker.index
            );
        }
    }
    for worker in workers.iter_mut() {
        let deadline = Instant::now() + WORKER_DRAIN_TIMEOUT;
        loop {
            match worker.child.try_wait() {
                Ok(Some(status)) => {
                    if !status.success() {
                        crate::log_warn!(
                            "router: shard {} exited with {status}",
                            worker.index
                        );
                    }
                    break;
                }
                Ok(None) if Instant::now() > deadline => {
                    crate::log_warn!(
                        "router: shard {} did not drain; killing",
                        worker.index
                    );
                    let _ = worker.child.kill();
                    let _ = worker.child.wait();
                    break;
                }
                Ok(None) => {
                    std::thread::sleep(Duration::from_millis(20))
                }
                Err(e) => {
                    crate::log_warn!(
                        "router: waiting on shard {}: {e}",
                        worker.index
                    );
                    break;
                }
            }
        }
    }
}

/// Run the shard router until `/shutdown` or a signal: spawn the
/// workers, serve the routing front end, then drain the fleet.
pub fn run(cfg: &ServeConfig) -> Result<()> {
    if cfg.shards < 2 {
        bail!("router wants --shards >= 2, got {}", cfg.shards);
    }
    http::install_signal_handlers();
    let mut workers = Vec::with_capacity(cfg.shards);
    for index in 0..cfg.shards {
        match spawn_worker(cfg, index) {
            Ok(worker) => workers.push(worker),
            Err(e) => {
                drain_workers(&mut workers);
                return Err(e);
            }
        }
    }
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))
        .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let shard_list: Vec<String> =
        workers.iter().map(|w| w.addr.to_string()).collect();
    println!(
        "cax serve router listening on {addr} ({} shards: {})",
        cfg.shards,
        shard_list.join(", ")
    );
    std::io::stdout().flush().ok();

    let ctx = Arc::new(RouterCtx {
        addrs: workers.iter().map(|w| w.addr).collect(),
        next: AtomicUsize::new(0),
        shutdown: AtomicBool::new(false),
    });
    while !ctx.stopping() {
        match listener.accept() {
            Ok((stream, _)) => {
                let ctx = Arc::clone(&ctx);
                std::thread::spawn(move || handle_connection(ctx, stream));
            }
            Err(e) if is_timeout(e.kind()) => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                crate::log_warn!("router: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    crate::log_info!("router: draining {} shards", workers.len());
    drain_workers(&mut workers);
    Ok(())
}

fn is_timeout(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}
