//! The `--shards N` front process: a thin std-only shard router.
//!
//! `cax serve --shards N` does not scale one scheduler across cores —
//! it forks **N whole worker processes** (each a normal single-shard
//! `cax serve` with its own registry, coalescer and metric registry)
//! and puts this router in front of them. Sessions are *partitioned by
//! id*: every worker mints session ids satisfying
//! `id % shard_count == shard_index` (see
//! [`SessionRegistry::set_shard`](super::SessionRegistry::set_shard)),
//! so the router can route any `/sessions/:id/...` request statelessly
//! by `parse_id(id) % N` — no routing table, no shared state, no
//! rebalancing. Creates (`POST /sessions`) round-robin across workers.
//!
//! The router speaks the same HTTP surface as a worker:
//!
//! - `POST /sessions` → round-robin to a worker, relay the reply (the
//!   returned id encodes its shard forever).
//! - `/sessions/:id/...` (status, step, reset, destroy, snapshot,
//!   **stream**) → proxy to shard `id % N`. Proxied responses are
//!   relayed byte-for-byte until worker EOF, which transparently
//!   covers the chunked SSE stream route.
//! - `GET /healthz` → fan out, sum sessions/pending, AND the `ok`s.
//! - `GET /stats` → fan out, reply `{"shards": [{shard, addr, stats},
//!   ...]}` with each worker's full stats document embedded, plus a
//!   merged `fleet` roll-up (summed sessions/pending, max queue
//!   high-water, exact merged percentiles) and the router's own
//!   `proxy` stats.
//! - `GET /metrics` → scrape every worker's `/metrics.json`, merge the
//!   raw histogram buckets
//!   ([`merge_from`](crate::obs::HistogramSnapshot::merge_from)
//!   semantics), and serve one fleet-wide Prometheus page: merged
//!   totals with **exact** fleet p50/p95/p99 plus per-shard
//!   `shard="i"` labeled series, with the router's own proxy-latency
//!   and scrape-failure metrics in the same exposition.
//! - `GET /metrics.json` → the same scrape as JSON: per-shard exact
//!   snapshots and the merged fleet view (what `cax top` polls).
//! - `POST /shutdown` (or SIGINT/SIGTERM) → broadcast `/shutdown` to
//!   every worker, wait for each child to drain and exit, then exit.
//!
//! A background thread re-scrapes the fleet once per tick-interval
//! (floored at 250ms) to keep scrape-failure counters and the cached
//! last-good snapshot fresh; the handlers always scrape live and fall
//! back to the cache for a shard that fails mid-request. Every
//! proxied request is stamped with an `X-Cax-Trace` id and timed into
//! `router_proxy_seconds`; with `--trace FILE` armed, workers write
//! per-shard capture files that [`run`] merges into one Perfetto
//! timeline after the drain ([`trace::write_merged`]).
//!
//! Workers bind ephemeral loopback ports; the router learns each
//! address by parsing the worker's `listening on ADDR` stdout line
//! (the same line the integration tests parse). Worker stdout is then
//! forwarded to the router's *stderr* under a `[shard i]` prefix so
//! the router's own stdout stays machine-parseable. With
//! `--state-dir DIR`, worker `i` persists under `DIR/shard-i/` —
//! checkpoint files never cross shards, keeping the bit-identity
//! contract per worker.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::obs::{self, prometheus, trace, MetricSnapshot, PromWriter,
                 Registry};
use crate::serve::http::{self, hist_ms, ReadOutcome, Request, Response};
use crate::serve::session::parse_id;
use crate::serve::ServeConfig;
use crate::util::json::{obj, Json};

/// How long a worker gets to print its `listening on` line.
const WORKER_START_TIMEOUT: Duration = Duration::from_secs(30);
/// How long a worker gets to drain and exit after `/shutdown`.
const WORKER_DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

struct Worker {
    index: usize,
    addr: SocketAddr,
    child: Child,
}

/// The per-shard trace tmp file workers write when fleet tracing is
/// armed; [`run`] merges and removes them after the drain.
fn shard_trace_path(trace: &Path, index: usize) -> PathBuf {
    PathBuf::from(format!("{}.shard{index}.json", trace.display()))
}

/// Spawn worker `index` as a child `cax serve` process on an ephemeral
/// port and wait for it to report its address.
fn spawn_worker(cfg: &ServeConfig, index: usize, trace: Option<&Path>)
                -> Result<Worker> {
    let exe = std::env::current_exe()
        .context("resolving the cax binary for worker spawn")?;
    let mut cmd = Command::new(exe);
    if let Some(trace) = trace {
        // Each worker captures its own buffer and writes it on drain;
        // the router merges the per-shard files into one timeline.
        cmd.arg("--trace").arg(shard_trace_path(trace, index));
    }
    cmd.arg("--seed")
        .arg(cfg.seed.to_string())
        .arg("serve")
        .arg("--port")
        .arg("0")
        .arg("--threads")
        .arg(cfg.threads.to_string())
        .arg("--max-sessions")
        .arg(cfg.max_sessions.to_string())
        .arg("--max-batch")
        .arg(cfg.max_batch.to_string())
        .arg("--max-pending")
        .arg(cfg.max_pending.to_string())
        .arg("--max-steps")
        .arg(cfg.max_steps.to_string())
        .arg("--tick-us")
        .arg(cfg.tick_window.as_micros().to_string())
        .arg("--shard-index")
        .arg(index.to_string())
        .arg("--shard-count")
        .arg(cfg.shards.to_string());
    if let Some(dir) = &cfg.state_dir {
        cmd.arg("--state-dir").arg(dir.join(format!("shard-{index}")));
    }
    cmd.stdin(Stdio::null()).stdout(Stdio::piped());
    let mut child = cmd
        .spawn()
        .with_context(|| format!("spawning shard worker {index}"))?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let deadline = Instant::now() + WORKER_START_TIMEOUT;
    let addr = loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => {
                let status = child.wait().ok();
                bail!(
                    "shard worker {index} exited before listening \
                     (status {status:?})"
                );
            }
            Ok(_) => {
                if let Some(rest) = line.split("listening on ").nth(1) {
                    let token =
                        rest.split_whitespace().next().unwrap_or("");
                    break token.parse::<SocketAddr>().with_context(|| {
                        format!(
                            "shard worker {index}: bad listen address \
                             {token:?}"
                        )
                    })?;
                }
                eprint!("[shard {index}] {line}");
            }
            Err(e) => return Err(e).with_context(|| {
                format!("reading shard worker {index} stdout")
            }),
        }
        if Instant::now() > deadline {
            let _ = child.kill();
            bail!("shard worker {index} did not report an address");
        }
    };
    // Keep draining the worker's stdout (onto our stderr) so the child
    // never blocks on a full pipe.
    std::thread::spawn(move || {
        for line in reader.lines() {
            match line {
                Ok(line) => eprintln!("[shard {index}] {line}"),
                Err(_) => break,
            }
        }
    });
    Ok(Worker { index, addr, child })
}

/// One-shot HTTP client against a worker: send, read to EOF, split
/// status and body. Workers honor `Connection: close`, so EOF
/// delimits the response.
fn fetch(addr: SocketAddr, method: &str, path: &str, body: &[u8])
         -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to shard at {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    send_request(&mut stream, addr, method, path, body, None)?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading shard response")?;
    let header_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .context("shard response has no header terminator")?;
    let head = std::str::from_utf8(&raw[..header_end])
        .context("shard response head is not UTF-8")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .context("shard response has no status code")?;
    Ok((status, raw[header_end + 4..].to_vec()))
}

fn send_request(stream: &mut TcpStream, addr: SocketAddr, method: &str,
                path: &str, body: &[u8], trace_id: Option<u64>)
                -> Result<()> {
    let trace_header = match trace_id {
        Some(id) => format!("X-Cax-Trace: {id}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Length: {}\r\n{trace_header}Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Relay one request to `addr` and copy the response back
/// byte-for-byte until the worker closes — content-length and chunked
/// (SSE) responses alike, with per-chunk flushes so streamed frames
/// reach the client promptly. The request is stamped with a fresh
/// `X-Cax-Trace` id (the worker adopts it into its spans) and the
/// whole relay — including any SSE stream lifetime — is timed into
/// `router_proxy_seconds`.
fn proxy(ctx: &RouterCtx, client: &mut TcpStream, addr: SocketAddr,
         req: &Request) -> Result<()> {
    let trace_id = ctx.trace_seq.fetch_add(1, Ordering::Relaxed) + 1;
    let start = Instant::now();
    let mut upstream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(e) => {
            ctx.registry.counter("router_proxy_errors_total").inc();
            let resp = Response::error(
                503,
                &format!("shard at {addr} unreachable: {e}"),
            );
            let _ = http::respond(client, &resp, true);
            return Ok(());
        }
    };
    send_request(&mut upstream, addr, &req.method, &req.path, &req.body,
                 Some(trace_id))?;
    let mut buf = [0u8; 8192];
    loop {
        match upstream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                if client.write_all(&buf[..n]).is_err() {
                    break; // client went away; drop the relay
                }
                let _ = client.flush();
            }
            Err(e) => {
                crate::log_warn!("router: relay from {addr} failed: {e}");
                break;
            }
        }
    }
    let dur = start.elapsed();
    ctx.registry.counter("router_proxied_total").inc();
    if obs::recording() {
        ctx.registry
            .histogram("router_proxy_seconds")
            .record_duration(dur);
    }
    trace::record_complete_with_id("router_proxy", start, dur,
                                   Some(trace_id));
    Ok(())
}

struct RouterCtx {
    addrs: Vec<SocketAddr>,
    next: AtomicUsize,
    shutdown: AtomicBool,
    /// Router-side metrics: `router_proxy_seconds`,
    /// `router_proxied_total`, `router_scrape_failures_total` and the
    /// per-shard `router_scrape_failures_shard_{i}_total` counters.
    registry: Registry,
    /// Monotone `X-Cax-Trace` id source for proxied requests.
    trace_seq: AtomicU64,
    /// Last good scrape per shard; handlers fall back to it when a
    /// live scrape fails mid-request.
    cache: Mutex<Vec<Option<ShardScrape>>>,
}

impl RouterCtx {
    fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || http::signalled()
    }

    fn shard_for(&self, id: u64) -> SocketAddr {
        self.addrs[(id % self.addrs.len() as u64) as usize]
    }

    fn cache(&self)
             -> std::sync::MutexGuard<'_, Vec<Option<ShardScrape>>> {
        self.cache
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

// --------------------------------------------------- fleet scraping

/// One worker's exact metric snapshot, as scraped from its
/// `GET /metrics.json`.
#[derive(Clone)]
struct ShardScrape {
    shard: usize,
    addr: SocketAddr,
    /// Whether this data came from a live scrape (`false` = cached
    /// fallback after a failed scrape, or no data at all).
    ok: bool,
    sessions: u64,
    pending: u64,
    uptime_s: f64,
    metrics: Vec<(String, MetricSnapshot)>,
}

fn scrape_shard(shard: usize, addr: SocketAddr) -> Result<ShardScrape> {
    let (status, body) = fetch(addr, "GET", "/metrics.json", b"")?;
    if status != 200 {
        bail!("shard at {addr}: GET /metrics.json returned {status}");
    }
    let text = std::str::from_utf8(&body)
        .context("metrics.json body is not UTF-8")?;
    let json = Json::parse(text)?;
    let num = |key: &str| {
        json.get(key).and_then(Json::as_f64).unwrap_or(0.0)
    };
    let metrics = obs::metrics_from_json(
        json.get("metrics")
            .context("metrics.json: missing metrics object")?,
    )?;
    Ok(ShardScrape {
        shard,
        addr,
        ok: true,
        sessions: num("sessions") as u64,
        pending: num("pending") as u64,
        uptime_s: num("uptime_s"),
        metrics,
    })
}

/// Scrape every worker's `/metrics.json` live, refreshing the cache
/// on success; a failed shard bumps the scrape-failure counters and
/// falls back to its last good snapshot (flagged `ok: false`).
fn scrape_fleet(ctx: &RouterCtx) -> Vec<ShardScrape> {
    let mut out = Vec::with_capacity(ctx.addrs.len());
    for (index, &addr) in ctx.addrs.iter().enumerate() {
        match scrape_shard(index, addr) {
            Ok(scrape) => {
                ctx.cache()[index] = Some(scrape.clone());
                out.push(scrape);
            }
            Err(e) => {
                ctx.registry
                    .counter("router_scrape_failures_total")
                    .inc();
                ctx.registry
                    .counter(&format!(
                        "router_scrape_failures_shard_{index}_total"
                    ))
                    .inc();
                crate::log_warn!(
                    "router: scraping shard {index} at {addr} failed: {e:#}"
                );
                let cached = ctx.cache()[index].clone();
                out.push(match cached {
                    Some(mut stale) => {
                        stale.ok = false;
                        stale
                    }
                    None => ShardScrape {
                        shard: index,
                        addr,
                        ok: false,
                        sessions: 0,
                        pending: 0,
                        uptime_s: 0.0,
                        metrics: Vec::new(),
                    },
                });
            }
        }
    }
    out
}

/// Name-merge every scraped metric with
/// [`MetricSnapshot::merge_from`] fleet semantics — counters add,
/// gauges sum now / max high-water, histograms merge raw buckets, so
/// fleet quantiles are exact.
fn merge_scrapes(scrapes: &[ShardScrape])
                 -> BTreeMap<String, MetricSnapshot> {
    let mut merged = BTreeMap::new();
    for scrape in scrapes {
        for (name, snap) in &scrape.metrics {
            obs::merge_metric(&mut merged, name, snap);
        }
    }
    merged
}

fn merged_hist_ms(merged: &BTreeMap<String, MetricSnapshot>, name: &str)
                  -> Json {
    match merged.get(name) {
        Some(MetricSnapshot::Histogram(s)) => hist_ms(s),
        _ => Json::Null,
    }
}

fn handle_healthz(ctx: &RouterCtx) -> Response {
    let mut ok = true;
    let (mut sessions, mut pending) = (0u64, 0u64);
    for &addr in &ctx.addrs {
        match fetch(addr, "GET", "/healthz", b"")
            .ok()
            .filter(|(status, _)| *status == 200)
            .and_then(|(_, body)| {
                Json::parse(std::str::from_utf8(&body).ok()?).ok()
            }) {
            Some(json) => {
                ok &= json.get("ok").and_then(Json::as_bool)
                    == Some(true);
                let num = |key| {
                    json.get(key)
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64
                };
                sessions += num("sessions");
                pending += num("pending");
            }
            None => ok = false,
        }
    }
    Response::json(
        if ok { 200 } else { 503 },
        &obj(vec![
            ("ok", Json::Bool(ok)),
            ("shards", Json::from(ctx.addrs.len())),
            ("sessions", Json::from(sessions)),
            ("pending", Json::from(pending)),
        ]),
    )
}

fn handle_stats(ctx: &RouterCtx) -> Response {
    let mut shards = Vec::with_capacity(ctx.addrs.len());
    for (index, &addr) in ctx.addrs.iter().enumerate() {
        let stats = fetch(addr, "GET", "/stats", b"")
            .ok()
            .filter(|(status, _)| *status == 200)
            .and_then(|(_, body)| {
                Json::parse(std::str::from_utf8(&body).ok()?).ok()
            })
            .unwrap_or(Json::Null);
        shards.push(obj(vec![
            ("shard", Json::from(index)),
            ("addr", Json::from(addr.to_string().as_str())),
            ("stats", stats),
        ]));
    }
    // Merged roll-up from the exact metric snapshots — the fleet p99s
    // here come from merged raw buckets, not averaged percentiles.
    let scrapes = scrape_fleet(ctx);
    let merged = merge_scrapes(&scrapes);
    let queue_high_water = match merged.get("serve_queue_depth") {
        Some(MetricSnapshot::Gauge { high_water, .. }) => *high_water,
        _ => 0,
    };
    let fleet = obj(vec![
        (
            "sessions",
            Json::from(scrapes.iter().map(|s| s.sessions).sum::<u64>()),
        ),
        (
            "pending",
            Json::from(scrapes.iter().map(|s| s.pending).sum::<u64>()),
        ),
        ("queue_high_water", Json::from(queue_high_water)),
        ("request_wait", merged_hist_ms(&merged, "serve_wait_seconds")),
        ("step_latency", merged_hist_ms(&merged, "serve_step_seconds")),
        (
            "scraped_ok",
            Json::from(
                scrapes.iter().filter(|s| s.ok).count(),
            ),
        ),
    ]);
    let proxy_hist = ctx
        .registry
        .histogram("router_proxy_seconds")
        .snapshot();
    let proxy = obj(vec![
        (
            "proxied",
            Json::from(ctx.registry.counter("router_proxied_total").get()),
        ),
        (
            "errors",
            Json::from(
                ctx.registry.counter("router_proxy_errors_total").get(),
            ),
        ),
        (
            "scrape_failures",
            Json::from(
                ctx.registry
                    .counter("router_scrape_failures_total")
                    .get(),
            ),
        ),
        ("latency", hist_ms(&proxy_hist)),
    ]);
    Response::json(
        200,
        &obj(vec![
            ("router", Json::Bool(true)),
            ("fleet", fleet),
            ("proxy", proxy),
            ("shards", Json::Arr(shards)),
        ]),
    )
}

/// Router `GET /metrics`: one fleet-wide Prometheus page. The
/// router's own registry leads, then every scraped family as merged
/// totals plus per-shard `shard="i"` series.
fn handle_metrics(ctx: &RouterCtx) -> Response {
    let scrapes = scrape_fleet(ctx);
    let merged = merge_scrapes(&scrapes);
    let mut w = PromWriter::new();
    w.gauge("router_shards", ctx.addrs.len() as f64);
    w.registry(&ctx.registry);
    for (name, snap) in &merged {
        let shards: Vec<(u64, MetricSnapshot)> = scrapes
            .iter()
            .filter_map(|s| {
                s.metrics
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, m)| (s.shard as u64, m.clone()))
            })
            .collect();
        w.metric_fleet(name, snap, &shards);
    }
    Response {
        status: 200,
        content_type: prometheus::CONTENT_TYPE,
        body: w.finish().into_bytes(),
    }
}

/// Router `GET /metrics.json`: per-shard exact snapshots plus the
/// merged fleet view and the router's own metrics — the document
/// `cax top` polls.
fn handle_metrics_json(ctx: &RouterCtx) -> Response {
    let scrapes = scrape_fleet(ctx);
    let merged = merge_scrapes(&scrapes);
    let merged_pairs: Vec<(String, MetricSnapshot)> =
        merged.into_iter().collect();
    let shards: Vec<Json> = scrapes
        .iter()
        .map(|s| {
            obj(vec![
                ("shard", Json::from(s.shard)),
                ("addr", Json::from(s.addr.to_string().as_str())),
                ("ok", Json::Bool(s.ok)),
                ("sessions", Json::from(s.sessions)),
                ("pending", Json::from(s.pending)),
                ("uptime_s", Json::Num(s.uptime_s)),
                ("metrics", obs::metrics_to_json(&s.metrics)),
            ])
        })
        .collect();
    let router_metrics = ctx.registry.snapshot();
    Response::json(
        200,
        &obj(vec![
            ("router", Json::Bool(true)),
            ("shards", Json::Arr(shards)),
            (
                "merged",
                obj(vec![
                    (
                        "sessions",
                        Json::from(
                            scrapes.iter().map(|s| s.sessions).sum::<u64>(),
                        ),
                    ),
                    (
                        "pending",
                        Json::from(
                            scrapes.iter().map(|s| s.pending).sum::<u64>(),
                        ),
                    ),
                    ("metrics", obs::metrics_to_json(&merged_pairs)),
                ]),
            ),
            ("router_metrics", obs::metrics_to_json(&router_metrics)),
        ]),
    )
}

/// Route one request: local aggregate endpoints answer here, anything
/// session-scoped relays to its shard. Returns `None` when the
/// response was already written (proxied).
fn route(ctx: &RouterCtx, client: &mut TcpStream, req: &Request)
         -> Result<Option<Response>> {
    let segments: Vec<&str> =
        req.path.split('/').filter(|s| !s.is_empty()).collect();
    let resp = match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => handle_healthz(ctx),
        ("GET", ["stats"]) => handle_stats(ctx),
        ("GET", ["metrics"]) => handle_metrics(ctx),
        ("GET", ["metrics.json"]) => handle_metrics_json(ctx),
        ("POST", ["shutdown"]) => {
            ctx.shutdown.store(true, Ordering::SeqCst);
            Response::json(
                200, &obj(vec![("draining", Json::Bool(true))]))
        }
        ("POST", ["sessions"]) => {
            let pick = ctx.next.fetch_add(1, Ordering::Relaxed)
                % ctx.addrs.len();
            proxy(ctx, client, ctx.addrs[pick], req)?;
            return Ok(None);
        }
        (_, ["sessions", id, ..]) => match parse_id(id) {
            Some(id) => {
                proxy(ctx, client, ctx.shard_for(id), req)?;
                return Ok(None);
            }
            None => {
                Response::error(404, &format!("bad session id {id:?}"))
            }
        },
        _ => Response::error(404, "no such route on the shard router"),
    };
    Ok(Some(resp))
}

fn handle_connection(ctx: Arc<RouterCtx>, stream: TcpStream) {
    let run = || -> Result<()> {
        stream.set_read_timeout(Some(http::READ_POLL))?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        loop {
            match http::read_request(&mut reader)? {
                ReadOutcome::Closed => return Ok(()),
                ReadOutcome::Idle => {
                    if ctx.stopping() {
                        return Ok(());
                    }
                }
                ReadOutcome::Request(req) => {
                    // One request per connection: proxied responses
                    // end at worker EOF, so close unconditionally.
                    if let Some(resp) = route(&ctx, &mut writer, &req)? {
                        http::respond(&mut writer, &resp, true)?;
                    }
                    return Ok(());
                }
            }
        }
    };
    if let Err(e) = run() {
        crate::log_warn!("router: connection error: {e:#}");
    }
}

/// Broadcast `/shutdown` and wait for every worker to drain and exit.
fn drain_workers(workers: &mut [Worker]) {
    for worker in workers.iter() {
        if let Err(e) =
            fetch(worker.addr, "POST", "/shutdown", b"")
        {
            crate::log_warn!(
                "router: shutdown of shard {} failed: {e:#}",
                worker.index
            );
        }
    }
    for worker in workers.iter_mut() {
        let deadline = Instant::now() + WORKER_DRAIN_TIMEOUT;
        loop {
            match worker.child.try_wait() {
                Ok(Some(status)) => {
                    if !status.success() {
                        crate::log_warn!(
                            "router: shard {} exited with {status}",
                            worker.index
                        );
                    }
                    break;
                }
                Ok(None) if Instant::now() > deadline => {
                    crate::log_warn!(
                        "router: shard {} did not drain; killing",
                        worker.index
                    );
                    let _ = worker.child.kill();
                    let _ = worker.child.wait();
                    break;
                }
                Ok(None) => {
                    std::thread::sleep(Duration::from_millis(20))
                }
                Err(e) => {
                    crate::log_warn!(
                        "router: waiting on shard {}: {e}",
                        worker.index
                    );
                    break;
                }
            }
        }
    }
}

/// Run the shard router until `/shutdown` or a signal: spawn the
/// workers, serve the routing front end, then drain the fleet. With
/// `trace` set (the CLI's `--trace FILE`, already armed via
/// [`trace::start`]), each worker writes a per-shard capture on drain
/// and the router merges them — plus its own proxy spans — into one
/// Perfetto file at `trace`.
pub fn run(cfg: &ServeConfig, trace_out: Option<&Path>) -> Result<()> {
    if cfg.shards < 2 {
        bail!("router wants --shards >= 2, got {}", cfg.shards);
    }
    http::install_signal_handlers();
    let mut workers = Vec::with_capacity(cfg.shards);
    for index in 0..cfg.shards {
        match spawn_worker(cfg, index, trace_out) {
            Ok(worker) => workers.push(worker),
            Err(e) => {
                drain_workers(&mut workers);
                return Err(e);
            }
        }
    }
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))
        .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let shard_list: Vec<String> =
        workers.iter().map(|w| w.addr.to_string()).collect();
    println!(
        "cax serve router listening on {addr} ({} shards: {})",
        cfg.shards,
        shard_list.join(", ")
    );
    std::io::stdout().flush().ok();

    let ctx = Arc::new(RouterCtx {
        addrs: workers.iter().map(|w| w.addr).collect(),
        next: AtomicUsize::new(0),
        shutdown: AtomicBool::new(false),
        registry: Registry::new(),
        trace_seq: AtomicU64::new(0),
        cache: Mutex::new((0..cfg.shards).map(|_| None).collect()),
    });
    // Background scrape loop: one fleet scrape per tick-interval
    // (floored at 250ms) keeps the failure counters live and the
    // per-shard cache warm for handler fallback.
    {
        let ctx = Arc::clone(&ctx);
        let interval = cfg.tick_window.max(Duration::from_millis(250));
        std::thread::spawn(move || {
            while !ctx.stopping() {
                let _ = scrape_fleet(&ctx);
                std::thread::sleep(interval);
            }
        });
    }
    while !ctx.stopping() {
        match listener.accept() {
            Ok((stream, _)) => {
                let ctx = Arc::clone(&ctx);
                std::thread::spawn(move || handle_connection(ctx, stream));
            }
            Err(e) if is_timeout(e.kind()) => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                crate::log_warn!("router: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    crate::log_info!("router: draining {} shards", workers.len());
    drain_workers(&mut workers);
    if let Some(trace_path) = trace_out {
        // Workers wrote their per-shard captures while draining; fold
        // them (re-based and re-stamped) in with the router's own.
        let worker_traces: Vec<(u64, String, PathBuf)> = (0..cfg.shards)
            .map(|i| {
                (i as u64 + 2, format!("shard {i}"),
                 shard_trace_path(trace_path, i))
            })
            .collect();
        match trace::write_merged(trace_path, &worker_traces) {
            Ok(events) => crate::log_info!(
                "router: wrote merged fleet trace {} ({events} events)",
                trace_path.display()
            ),
            Err(e) => crate::log_warn!(
                "router: merged trace write failed: {e:#}"
            ),
        }
    }
    Ok(())
}

fn is_timeout(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}
