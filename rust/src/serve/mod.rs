//! `cax serve` — a coalescing multi-session simulation service.
//!
//! The paper's pitch is one accelerated substrate for many CA
//! workloads; this layer makes the substrate *multi-tenant*. Many
//! independent sessions (one live CA board each) are held
//! backend-resident, and a coalescing scheduler packs their pending
//! step requests into **one batched kernel launch per shape class per
//! tick** — the CAT insight (throughput comes from packing work into
//! large batched launches) applied to serving: N sessions stepping the
//! same program ride one `Backend::step_resident` call, not N solo
//! calls that each re-cross the f32/bit-plane boundary.
//!
//! Pieces (one module each):
//!
//! - [`session`]: [`ProgramSpec`] (what a session runs),
//!   [`SessionRegistry`] (create/read/reset/destroy, admission control,
//!   seeded-deterministic session ids).
//! - [`scheduler`]: [`Coalescer`] — the FIFO coalescing scheduler with
//!   its documented fairness/deadline policy and queue backpressure.
//! - [`http`]: a std-only HTTP/1.1 front end over `TcpListener`
//!   (JSON via `util::json`, PPM snapshots via `viz::ppm`), plus
//!   graceful SIGINT/SIGTERM shutdown that drains in-flight work.
//! - [`checkpoint`]: versioned on-disk session state (`--state-dir`),
//!   turning `max_sessions` into a working-set cap via LRU eviction
//!   and bit-identical lazy rehydration.
//! - [`stream`]: the SSE fan-out hub behind
//!   `GET /sessions/:id/stream` — live frames per scheduler tick with
//!   bounded per-subscriber queues (slow clients drop frames, never
//!   stall a tick).
//! - [`router`]: the `--shards N` front process — N forked workers,
//!   sessions hashed across them by id, so the serving fleet scales
//!   past one process while every invariant above stays cross-process.
//!
//! The whole pipeline is instrumented through [`crate::obs`]: request
//! wait / launch / tick latency histograms and queue gauges live in
//! each coalescer's own [`ServeStats`] registry, `GET /stats` reports
//! their p50/p95/p99, `GET /metrics` serves Prometheus text, and
//! `--trace out.json` captures per-launch spans and queue-depth
//! counters for <https://ui.perfetto.dev>.
//!
//! Everything is std + this crate — no new dependencies, matching the
//! repo's hermetic ethos. Start it from the CLI:
//!
//! ```sh
//! cax serve --port 7878 --threads 4 --max-sessions 256
//! ```
//!
//! and drive it with curl (see `rust/README.md` for the full tour):
//!
//! ```sh
//! curl -s -X POST localhost:7878/sessions \
//!      -d '{"program": "life", "size": 128}'          # -> {"id": "..."}
//! curl -s -X POST localhost:7878/sessions/<id>/step \
//!      -d '{"steps": 16}'
//! curl -s localhost:7878/sessions/<id>/snapshot.ppm -o board.ppm
//! ```

pub mod checkpoint;
pub mod http;
pub mod router;
pub mod scheduler;
pub mod session;
pub mod stream;

pub use checkpoint::CheckpointStore;
pub use http::{run, start, Server};
pub use scheduler::{Coalescer, ServeStats, StepDone, StepReply, StepRequest};
pub use session::{ProgramSpec, Session, SessionRegistry, FAMILIES};
pub use stream::StreamHub;

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Unwrap a lock/condvar acquisition, recovering from poisoning. A
/// connection thread that panics while holding the registry or queue
/// mutex poisons it; without recovery every *subsequent* request would
/// panic on `.lock().expect(..)` — one broken handler becoming a
/// process-wide cascade. The serve-layer invariants survive an unwound
/// holder (registry mutations are single `BTreeMap` inserts/removes,
/// queue pushes are single `VecDeque` ops), so the right response is
/// one 500 for the panicked request and business as usual after.
pub(crate) fn recover<G>(result: Result<G, PoisonError<G>>) -> G {
    use std::sync::atomic::{AtomicBool, Ordering};
    static LOGGED: AtomicBool = AtomicBool::new(false);
    match result {
        Ok(guard) => guard,
        Err(poisoned) => {
            if !LOGGED.swap(true, Ordering::Relaxed) {
                crate::log_warn!(
                    "serve: recovered a poisoned lock (a handler thread \
                     panicked); continuing"
                );
            }
            poisoned.into_inner()
        }
    }
}

/// [`recover`]-ing `Mutex::lock` — the serve layer's only way to take
/// its registry/queue locks.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    recover(m.lock())
}

/// Service knobs; the CLI maps `cax serve` flags onto these.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP port to bind on 127.0.0.1 (0 = pick an ephemeral port).
    pub port: u16,
    /// Worker threads of the batched backend.
    pub threads: usize,
    /// Session admission limit ([`SessionRegistry`]).
    pub max_sessions: usize,
    /// Largest number of sessions packed into one launch.
    pub max_batch: usize,
    /// Step-queue bound; submissions beyond it are rejected (503).
    pub max_pending: usize,
    /// Largest step count one request may ask for — bounds how long a
    /// single batched launch can hold the registry lock.
    pub max_steps: usize,
    /// Service seed: session ids and default initial boards derive from
    /// it deterministically.
    pub seed: u64,
    /// How long a woken scheduler waits for a request burst to
    /// accumulate before packing a batch (latency traded for batch
    /// size; zero = pack immediately).
    pub tick_window: Duration,
    /// Durable session state directory (`--state-dir`). With one set,
    /// `max_sessions` becomes a *working-set* cap: a full registry
    /// evicts its LRU session to a [`checkpoint`] file instead of
    /// refusing the create, and evicted sessions rehydrate lazily on
    /// next touch — bit-identically (see [`checkpoint`] for the format
    /// contract). Graceful shutdown checkpoints every resident session.
    pub state_dir: Option<PathBuf>,
    /// `cax serve --shards N`: with `N >= 2` the CLI starts the
    /// [`router`] — N forked worker processes with sessions hashed
    /// across them — instead of a single in-process server.
    pub shards: usize,
    /// Worker identity under the shard router (`index`, `count`):
    /// session ids are minted with `id % count == index`, so the
    /// router can route any `/sessions/:id/...` request statelessly.
    pub shard: Option<(u64, u64)>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            port: 7878,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            max_sessions: 256,
            max_batch: 64,
            max_pending: 1024,
            max_steps: 10_000,
            seed: 0,
            tick_window: Duration::from_micros(300),
            state_dir: None,
            shards: 1,
            shard: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.threads >= 1);
        assert!(cfg.max_batch >= 1 && cfg.max_sessions >= 1);
        assert!(cfg.max_pending >= cfg.max_batch);
    }
}
