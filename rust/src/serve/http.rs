//! Minimal std-only HTTP/1.1 front end for the serve layer.
//!
//! One accept loop (non-blocking listener polled against the shutdown
//! flag), one thread per connection with keep-alive, bounded request
//! sizes, JSON request/response bodies through `util::json`, and PPM
//! snapshot responses through `viz::ppm`. No TLS, no chunked encoding,
//! no routing table — a deliberate ~300-line surface that curl and the
//! load generator can drive.
//!
//! # Routes
//!
//! | method + path | body | effect |
//! |---|---|---|
//! | `GET /healthz` | — | liveness + session/queue counts |
//! | `GET /stats` | — | scheduler counters, latency percentiles, steps/sec |
//! | `GET /metrics` | — | Prometheus text exposition (`cax_*`) |
//! | `GET /metrics.json` | — | exact metric snapshot (scrape/`cax top`) |
//! | `POST /sessions` | [`ProgramSpec`] JSON | create session (201) |
//! | `GET /sessions/<id>` | — | status: program, shape, steps, mean |
//! | `POST /sessions/<id>/step` | `{"steps": N}` (default 1) | coalesced step |
//! | `POST /sessions/<id>/reset` | — | rewind to the seeded initial board |
//! | `DELETE /sessions/<id>` | — | destroy |
//! | `GET /sessions/<id>/snapshot.ppm` | — | P6 image of the board |
//! | `GET /sessions/<id>/stream` | — | SSE frames per tick (chunked) |
//! | `POST /shutdown` | — | graceful drain + exit |
//!
//! `/stream` is the one chunked-transfer route: the connection switches
//! to `text/event-stream` and the handler relays frames from the
//! [`super::stream::StreamHub`] until the client disconnects or the
//! server drains (see [`handle_stream`]).
//!
//! Every request is timed into a per-route latency histogram
//! (`http_{route}_seconds` in the coalescer's metric registry, exposed
//! by `/metrics`), and emits a trace span when `--trace` capture is
//! armed.
//!
//! # Graceful shutdown
//!
//! SIGINT/ctrl-c and SIGTERM set a process-wide flag (`POST /shutdown`
//! sets a per-server one); the accept loop stops taking connections,
//! the scheduler drains every queued step request (each gets its
//! reply), live connections finish their in-flight request, and `run`
//! returns `Ok` — so the CLI exits 0 with no leaked worker threads.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics;
use crate::obs::{self, prometheus, trace, HistogramSnapshot, PromWriter};
use crate::serve::scheduler::{Coalescer, StepRequest};
use crate::serve::session::{fmt_id, parse_id, ProgramSpec};
use crate::serve::ServeConfig;
use crate::tensor::Tensor;
use crate::util::json::{obj, Json};
use crate::viz::ppm::Image;
use crate::viz::spacetime;

/// Set by the SIGINT/SIGTERM handler; observed by every accept loop.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_signal(_sig: i32) {
    // One atomic store: async-signal-safe.
    SIGNALLED.store(true, Ordering::SeqCst);
}

/// Route SIGINT (ctrl-c) and SIGTERM into [`SIGNALLED`]. Declared
/// against the C runtime every Rust binary on unix already links — no
/// crate dependency.
#[cfg(unix)]
pub(crate) fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
pub(crate) fn install_signal_handlers() {}

/// Whether the process received a shutdown signal.
pub fn signalled() -> bool {
    SIGNALLED.load(Ordering::SeqCst)
}

// ----------------------------------------------------------- plumbing

const MAX_LINE: usize = 8 * 1024;
const MAX_HEADERS: usize = 64;
/// Request bodies are small JSON documents; `read_body` pre-allocates
/// `Content-Length` bytes, so this also bounds per-connection memory.
const MAX_BODY: usize = 1024 * 1024;
/// Thread-per-connection cap; connections beyond it get an immediate
/// 503 instead of an unbounded thread pile-up.
const MAX_CONNS: usize = 64;
/// Keep-alive connections idle longer than this are closed.
const KEEPALIVE_IDLE: Duration = Duration::from_secs(60);
pub(crate) const READ_POLL: Duration = Duration::from_millis(250);
/// How long a step handler waits for the scheduler's reply. The
/// launch is NOT cancelled on timeout — the steps may still be applied.
const STEP_REPLY_TIMEOUT: Duration = Duration::from_secs(120);

pub(crate) struct Request {
    pub(crate) method: String,
    pub(crate) path: String,
    pub(crate) body: Vec<u8>,
    pub(crate) keep_alive: bool,
    /// Cross-process trace id adopted from the router's `X-Cax-Trace`
    /// header, so worker trace events tie back to the proxy span.
    pub(crate) trace_id: Option<u64>,
}

pub(crate) enum ReadOutcome {
    Request(Request),
    /// Peer closed cleanly.
    Closed,
    /// Read timeout with no bytes consumed — poll the shutdown flag
    /// and listen again.
    Idle,
}

fn is_timeout(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// `read_line` with a hard length cap: at most `MAX_LINE + 1` bytes are
/// pulled per call, so a peer streaming bytes without a newline cannot
/// grow server memory unboundedly. Over-long lines surface as
/// `InvalidData`.
fn read_line_bounded(reader: &mut BufReader<TcpStream>, line: &mut String)
                     -> std::io::Result<usize> {
    let before = line.len();
    let n = reader
        .by_ref()
        .take((MAX_LINE + 1) as u64)
        .read_line(line)?;
    if line.len() > MAX_LINE && !line[before..].ends_with('\n') {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("line exceeds the {MAX_LINE}-byte limit"),
        ));
    }
    Ok(n)
}

pub(crate) fn read_request(reader: &mut BufReader<TcpStream>)
                           -> Result<ReadOutcome> {
    let mut line = String::new();
    // A started request line is read through timeouts (it may arrive
    // split across segments); only a timeout with zero bytes is Idle.
    let mut line_deadline: Option<Instant> = None;
    loop {
        match read_line_bounded(reader, &mut line) {
            Ok(0) if line.is_empty() => return Ok(ReadOutcome::Closed),
            Ok(0) => bail!("connection closed mid-request-line"),
            Ok(_) => break,
            Err(e) if is_timeout(e.kind()) => {
                if line.is_empty() {
                    return Ok(ReadOutcome::Idle);
                }
                let deadline = *line_deadline.get_or_insert_with(|| {
                    Instant::now() + Duration::from_secs(10)
                });
                if Instant::now() > deadline {
                    bail!("timed out reading the request line");
                }
            }
            Err(e) => return Err(e).context("reading request line"),
        }
    }
    if line.len() > MAX_LINE {
        bail!("request line too long");
    }
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(),
                                         parts.next()) {
        (Some(m), Some(p), Some(v)) => {
            (m.to_string(), p.to_string(), v.to_string())
        }
        _ => bail!("malformed request line {line:?}"),
    };

    let mut content_length = 0usize;
    let mut keep_alive = version != "HTTP/1.0";
    let mut trace_id: Option<u64> = None;
    let deadline = Instant::now() + Duration::from_secs(10);
    for _ in 0..MAX_HEADERS {
        let mut header = String::new();
        loop {
            match read_line_bounded(reader, &mut header) {
                Ok(0) => bail!("connection closed mid-headers"),
                Ok(_) => break,
                // A request is in flight: keep reading through timeouts
                // (but not past a stalled client).
                Err(e) if is_timeout(e.kind()) => {
                    if Instant::now() > deadline {
                        bail!("timed out reading headers");
                    }
                }
                Err(e) => return Err(e).context("reading header"),
            }
        }
        let header = header.trim();
        if header.is_empty() {
            let body = read_body(reader, content_length)?;
            return Ok(ReadOutcome::Request(Request {
                method,
                path,
                body,
                keep_alive,
                trace_id,
            }));
        }
        if let Some((name, value)) = header.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .parse()
                    .with_context(|| format!("content-length {value:?}"))?;
                if content_length > MAX_BODY {
                    bail!("body too large ({content_length} bytes)");
                }
            } else if name.eq_ignore_ascii_case("connection") {
                keep_alive = !value.eq_ignore_ascii_case("close");
            } else if name.eq_ignore_ascii_case("x-cax-trace") {
                trace_id = value.parse().ok();
            }
        }
    }
    bail!("too many headers")
}

fn read_body(reader: &mut BufReader<TcpStream>, len: usize)
             -> Result<Vec<u8>> {
    let mut body = vec![0u8; len];
    let mut got = 0;
    let deadline = Instant::now() + Duration::from_secs(10);
    while got < len {
        match reader.read(&mut body[got..]) {
            Ok(0) => bail!("connection closed mid-body"),
            Ok(n) => got += n,
            Err(e) if is_timeout(e.kind()) => {
                if Instant::now() > deadline {
                    bail!("timed out reading request body");
                }
            }
            Err(e) => return Err(e).context("reading body"),
        }
    }
    Ok(body)
}

pub(crate) struct Response {
    status: u16,
    content_type: &'static str,
    body: Vec<u8>,
}

impl Response {
    pub(crate) fn json(status: u16, value: &Json) -> Response {
        let mut body = value.to_string_pretty().into_bytes();
        body.push(b'\n');
        Response { status, content_type: "application/json", body }
    }

    pub(crate) fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &obj(vec![("error", Json::from(msg))]))
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

pub(crate) fn respond(stream: &mut TcpStream, resp: &Response, close: bool)
                      -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
         Connection: {}\r\n\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if close { "close" } else { "keep-alive" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

// ------------------------------------------------------------- routes

struct Ctx {
    coalescer: Arc<Coalescer>,
    /// Per-server shutdown flag (`POST /shutdown`); signals use the
    /// process-wide [`SIGNALLED`].
    shutdown: Arc<AtomicBool>,
}

impl Ctx {
    fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || signalled()
    }
}

/// Map an internal error message onto an HTTP status. Messages with
/// the `internal:` prefix (backend invariant violations, e.g. an empty
/// rollout batch) are the server's fault — 500, never a 4xx blaming
/// the client.
fn error_status(msg: &str) -> u16 {
    if msg.contains("internal:") {
        500
    } else if msg.contains("no session") {
        404
    } else if msg.contains("queue full")
        || msg.contains("shutting down")
        || msg.contains("busy")
    {
        503
    } else {
        400
    }
}

fn parse_body_json(body: &[u8]) -> Result<Json> {
    if body.is_empty() {
        return Ok(obj(vec![]));
    }
    let text = std::str::from_utf8(body).context("body is not UTF-8")?;
    Json::parse(text).map_err(|e| anyhow!("body is not JSON: {e}"))
}

/// Dispatch plus per-route observation: every request lands in an
/// `http_{route}_seconds` histogram (when recording is on) and a trace
/// span (when capture is armed). Labels are static so the hot path
/// allocates only the registry-lookup key.
fn route(ctx: &Ctx, req: &Request) -> Response {
    let start = Instant::now();
    // A panicking handler answers ITS request with one 500 and leaves
    // the connection (and, via the poison-recovering locks, the
    // registry) serviceable — never a process-wide cascade.
    let (label, resp) = match std::panic::catch_unwind(
        std::panic::AssertUnwindSafe(|| route_inner(ctx, req)),
    ) {
        Ok(routed) => routed,
        Err(_) => (
            "http_panic",
            Response::error(500, "internal error: handler panicked"),
        ),
    };
    let dur = start.elapsed();
    if obs::recording() {
        ctx.coalescer
            .stats()
            .registry()
            .histogram(&format!("{label}_seconds"))
            .record_duration(dur);
    }
    trace::record_complete_with_id(label, start, dur, req.trace_id);
    resp
}

fn route_inner(ctx: &Ctx, req: &Request) -> (&'static str, Response) {
    let segments: Vec<&str> =
        req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => ("http_healthz", handle_healthz(ctx)),
        ("GET", ["stats"]) => ("http_stats", handle_stats(ctx)),
        ("GET", ["metrics"]) => ("http_metrics", handle_metrics(ctx)),
        ("GET", ["metrics.json"]) => {
            ("http_metrics_json", handle_metrics_json(ctx))
        }
        ("POST", ["shutdown"]) => {
            ctx.shutdown.store(true, Ordering::SeqCst);
            let resp = Response::json(
                200, &obj(vec![("draining", Json::Bool(true))]));
            ("http_shutdown", resp)
        }
        ("POST", ["sessions"]) => {
            ("http_create", handle_create(ctx, &req.body))
        }
        (method, ["sessions", id, rest @ ..]) => {
            let Some(id) = parse_id(id) else {
                let resp = Response::error(
                    404, &format!("bad session id {id:?}"));
                return ("http_other", resp);
            };
            match (method, rest) {
                ("GET", []) => ("http_status", handle_status(ctx, id)),
                ("DELETE", []) => {
                    ("http_destroy", handle_destroy(ctx, id))
                }
                ("POST", ["step"]) => {
                    ("http_step", handle_step(ctx, id, &req.body))
                }
                ("POST", ["reset"]) => ("http_reset", handle_reset(ctx, id)),
                ("GET", ["snapshot.ppm"]) => {
                    ("http_snapshot", handle_snapshot(ctx, id))
                }
                _ => ("http_other", Response::error(404, "no such route")),
            }
        }
        _ => ("http_other", Response::error(404, "no such route")),
    }
}

fn handle_healthz(ctx: &Ctx) -> Response {
    let sessions = super::lock_recover(ctx.coalescer.registry()).len();
    Response::json(
        200,
        &obj(vec![
            ("ok", Json::Bool(true)),
            ("sessions", Json::from(sessions)),
            ("pending", Json::from(ctx.coalescer.pending())),
        ]),
    )
}

/// ns-recorded latency histogram as a `{count, mean_ms, p50_ms,
/// p95_ms, p99_ms, max_ms}` JSON object. Counters stay u64 all the
/// way into JSON (`From<u64> for Json`) — casting through `usize`
/// would silently truncate them at 2^32 on 32-bit targets.
pub(crate) fn hist_ms(snap: &HistogramSnapshot) -> Json {
    let max_ms =
        if snap.count == 0 { 0.0 } else { snap.max as f64 / 1e6 };
    obj(vec![
        ("count", Json::from(snap.count)),
        ("mean_ms", Json::Num(snap.mean() / 1e6)),
        ("p50_ms", Json::Num(snap.quantile(0.5) / 1e6)),
        ("p95_ms", Json::Num(snap.quantile(0.95) / 1e6)),
        ("p99_ms", Json::Num(snap.quantile(0.99) / 1e6)),
        ("max_ms", Json::Num(max_ms)),
    ])
}

/// Raw-valued histogram (batch sizes, queue depths) as JSON.
fn hist_raw(snap: &HistogramSnapshot) -> Json {
    let max = if snap.count == 0 { 0u64 } else { snap.max };
    obj(vec![
        ("count", Json::from(snap.count)),
        ("mean", Json::Num(snap.mean())),
        ("p50", Json::Num(snap.quantile(0.5))),
        ("max", Json::from(max)),
    ])
}

fn handle_stats(ctx: &Ctx) -> Response {
    let stats = ctx.coalescer.stats();
    let load =
        |c: &std::sync::atomic::AtomicU64| c.load(Ordering::Relaxed);
    let session_steps = load(&stats.session_steps);
    let secs = ctx.coalescer.uptime_secs();
    let families: Vec<(&str, Json)> = stats
        .family_requests()
        .into_iter()
        .map(|(f, n)| (f, Json::from(n)))
        .collect();
    let registry = super::lock_recover(ctx.coalescer.registry());
    Response::json(
        200,
        &obj(vec![
            ("sessions", Json::from(registry.len())),
            ("max_sessions", Json::from(registry.max_sessions())),
            ("pending", Json::from(ctx.coalescer.pending())),
            ("requests", Json::from(load(&stats.requests))),
            ("rejected", Json::from(load(&stats.rejected))),
            ("deferred", Json::from(load(&stats.deferred))),
            ("ticks", Json::from(load(&stats.ticks))),
            ("batches", Json::from(load(&stats.batches))),
            ("session_steps", Json::from(session_steps)),
            ("peak_batch", Json::from(load(&stats.peak_batch))),
            ("uptime_s", Json::Num(secs)),
            (
                "steps_per_s",
                Json::Num(metrics::per_second(session_steps as f64, secs)),
            ),
            ("request_wait", hist_ms(&stats.wait().snapshot())),
            ("step_latency", hist_ms(&stats.step_latency().snapshot())),
            ("tick", hist_ms(&stats.tick_duration().snapshot())),
            ("batch_size", hist_raw(&stats.batch_size().snapshot())),
            (
                "queue_depth",
                obj(vec![
                    ("now", Json::from(stats.queue_depth().get())),
                    (
                        "high_water",
                        Json::from(stats.queue_depth().high_water()),
                    ),
                    (
                        "samples",
                        hist_raw(&stats.queue_depth_samples().snapshot()),
                    ),
                ]),
            ),
            (
                "fleet",
                obj(vec![
                    ("evictions", Json::from(stats.evictions().get())),
                    (
                        "rehydrations",
                        Json::from(stats.rehydrations().get()),
                    ),
                    ("evicted", Json::from(registry.evicted())),
                    (
                        "total_sessions",
                        Json::from(registry.total_sessions()),
                    ),
                    (
                        "resident_bytes",
                        Json::from(registry.resident_bytes()),
                    ),
                ]),
            ),
            (
                "stream",
                obj(vec![
                    ("frames", Json::from(stats.stream_frames().get())),
                    ("dropped", Json::from(stats.stream_dropped().get())),
                    (
                        "subscribers",
                        Json::from(stats.stream_subscribers().get()),
                    ),
                ]),
            ),
            ("families", obj(families)),
        ]),
    )
}

/// Every metric this worker exposes, one name-merged map: the
/// scheduler's core counters/gauges, this coalescer's latency/queue
/// registry, and the process-global registry the kernel spans record
/// into — the shared basis of `GET /metrics` and `GET /metrics.json`.
fn worker_metrics(ctx: &Ctx, sessions: usize)
                  -> Vec<(String, obs::MetricSnapshot)> {
    let stats = ctx.coalescer.stats();
    let mut merged = std::collections::BTreeMap::new();
    for (name, snap) in stats
        .core_metrics(sessions, ctx.coalescer.pending())
        .into_iter()
        .chain(stats.registry().snapshot())
        .chain(obs::Registry::global().snapshot())
    {
        obs::merge_metric(&mut merged, &name, &snap);
    }
    merged.into_iter().collect()
}

/// `GET /metrics`: Prometheus text exposition of the scheduler's
/// counters, this coalescer's latency/queue registry, and the
/// process-global registry the kernel spans record into.
fn handle_metrics(ctx: &Ctx) -> Response {
    let sessions =
        super::lock_recover(ctx.coalescer.registry()).len();
    let mut w = PromWriter::new();
    // The scheduler's occupancy gauges are instantaneous readings
    // (high_water is a serialization artifact) — expose them plain,
    // with no `_high_water` companion family.
    const INSTANT_GAUGES: [&str; 3] =
        ["serve_peak_batch", "serve_sessions", "serve_pending"];
    for (name, snap) in worker_metrics(ctx, sessions) {
        match snap {
            obs::MetricSnapshot::Gauge { value, .. }
                if INSTANT_GAUGES.contains(&name.as_str()) =>
            {
                w.gauge(&name, value as f64);
            }
            other => w.metric(&name, &other),
        }
    }
    w.gauge("serve_uptime_seconds", ctx.coalescer.uptime_secs());
    Response {
        status: 200,
        content_type: prometheus::CONTENT_TYPE,
        body: w.finish().into_bytes(),
    }
}

/// `GET /metrics.json`: the exact-snapshot twin of `GET /metrics` —
/// raw histogram bucket counts, counters, gauge now/high-water —
/// serialized via `util::json` for the shard router's
/// scrape-and-merge and for `cax top`. Same metric names as the
/// Prometheus page; merging these snapshots across shards with
/// [`obs::MetricSnapshot::merge_from`] yields exact fleet quantiles.
fn handle_metrics_json(ctx: &Ctx) -> Response {
    let sessions =
        super::lock_recover(ctx.coalescer.registry()).len();
    let metrics = worker_metrics(ctx, sessions);
    let shard = match obs::log::shard() {
        Some(i) => Json::from(i),
        None => Json::Null,
    };
    Response::json(
        200,
        &obj(vec![
            ("shard", shard),
            ("uptime_s", Json::Num(ctx.coalescer.uptime_secs())),
            ("sessions", Json::from(sessions)),
            ("pending", Json::from(ctx.coalescer.pending())),
            ("metrics", obs::metrics_to_json(&metrics)),
        ]),
    )
}

fn handle_create(ctx: &Ctx, body: &[u8]) -> Response {
    let json = match parse_body_json(body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let spec = match ProgramSpec::from_json(&json) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let seed = match crate::serve::session::opt_usize(&json, "seed") {
        Ok(s) => s.map(|v| v as u64),
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let created = {
        let mut registry =
            super::lock_recover(ctx.coalescer.registry());
        registry.create(ctx.coalescer.backend(), spec.clone(), seed)
    };
    match created {
        Ok(id) => Response::json(
            201,
            &obj(vec![
                ("id", Json::from(fmt_id(id).as_str())),
                ("spec", spec.to_json()),
            ]),
        ),
        Err(e) => {
            let msg = format!("{e:#}");
            let status =
                if msg.contains("session limit") { 503 } else { 400 };
            Response::error(status, &msg)
        }
    }
}

fn handle_status(ctx: &Ctx, id: u64) -> Response {
    let mut registry = super::lock_recover(ctx.coalescer.registry());
    if registry.is_busy(id) {
        return Response::error(
            503,
            &format!("session {} is busy (stepping); retry", fmt_id(id)),
        );
    }
    // Lazily rehydrate an evicted session, then trim back to the
    // working-set cap (this id was just touched, so it is never the
    // trim victim).
    if let Err(e) = registry.ensure_resident(id) {
        let msg = format!("{e:#}");
        return Response::error(error_status(&msg), &msg);
    }
    let _ = registry.trim_to_cap();
    let (spec_json, steps_done) = match registry.get(id) {
        Some(session) => (session.spec.to_json(), session.steps_done),
        None => {
            return Response::error(
                404, &format!("no session {}", fmt_id(id)));
        }
    };
    let board = registry.read_board(ctx.coalescer.backend(), id);
    let mean = match board {
        Ok(b) => b.mean() as f64,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    Response::json(
        200,
        &obj(vec![
            ("id", Json::from(fmt_id(id).as_str())),
            ("spec", spec_json),
            ("steps_done", Json::from(steps_done)),
            ("mean", Json::Num(mean)),
        ]),
    )
}

fn handle_step(ctx: &Ctx, id: u64, body: &[u8]) -> Response {
    let json = match parse_body_json(body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let steps = match crate::serve::session::opt_usize(&json, "steps") {
        Ok(s) => s.unwrap_or(1),
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let (tx, rx) = channel();
    if let Err(e) = ctx.coalescer.submit(StepRequest::new(id, steps, tx)) {
        let msg = format!("{e:#}");
        return Response::error(error_status(&msg), &msg);
    }
    // The scheduler thread owns execution; wait for the scatter.
    match rx.recv_timeout(STEP_REPLY_TIMEOUT) {
        Ok(Ok(done)) => Response::json(
            200,
            &obj(vec![
                ("id", Json::from(fmt_id(id).as_str())),
                ("steps_done", Json::from(done.steps_done)),
                ("batch", Json::from(done.batch)),
            ]),
        ),
        Ok(Err(msg)) => Response::error(error_status(&msg), &msg),
        Err(_) => Response::error(
            503,
            "timed out waiting for the step reply — the launch is not \
             cancelled and the steps may still be applied; check \
             steps_done before retrying",
        ),
    }
}

fn handle_reset(ctx: &Ctx, id: u64) -> Response {
    let mut registry = super::lock_recover(ctx.coalescer.registry());
    match registry.reset(ctx.coalescer.backend(), id) {
        Ok(()) => Response::json(
            200,
            &obj(vec![
                ("id", Json::from(fmt_id(id).as_str())),
                ("steps_done", Json::from(0usize)),
            ]),
        ),
        Err(e) => {
            let msg = format!("{e:#}");
            Response::error(error_status(&msg), &msg)
        }
    }
}

fn handle_destroy(ctx: &Ctx, id: u64) -> Response {
    let mut registry = super::lock_recover(ctx.coalescer.registry());
    match registry.destroy(id) {
        Ok(()) => Response::json(
            200,
            &obj(vec![("deleted", Json::from(fmt_id(id).as_str()))]),
        ),
        Err(e) => {
            let msg = format!("{e:#}");
            Response::error(error_status(&msg), &msg)
        }
    }
}

fn handle_snapshot(ctx: &Ctx, id: u64) -> Response {
    let (spec, board) = {
        let mut registry = super::lock_recover(ctx.coalescer.registry());
        if registry.is_busy(id) {
            return Response::error(
                503,
                &format!("session {} is busy (stepping); retry",
                         fmt_id(id)),
            );
        }
        if let Err(e) = registry.ensure_resident(id) {
            let msg = format!("{e:#}");
            return Response::error(error_status(&msg), &msg);
        }
        let _ = registry.trim_to_cap();
        let Some(session) = registry.get(id) else {
            return Response::error(404,
                                   &format!("no session {}", fmt_id(id)));
        };
        let spec = session.spec.clone();
        match registry.read_board(ctx.coalescer.backend(), id) {
            Ok(b) => (spec, b),
            Err(e) => return Response::error(400, &format!("{e:#}")),
        }
    };
    match render_board(&spec, &board).and_then(|img| img.ppm_bytes()) {
        Ok(bytes) => Response {
            status: 200,
            content_type: "image/x-portable-pixmap",
            body: bytes,
        },
        Err(e) => Response::error(400, &format!("render: {e:#}")),
    }
}

/// Render one session board as an image, per program geometry (shared
/// with the SSE frame builder in [`super::stream`]).
pub(crate) fn render_board(spec: &ProgramSpec, board: &Tensor)
                           -> Result<Image> {
    match spec {
        ProgramSpec::Eca { .. } => {
            let w = board.shape()[0];
            spacetime::render_field(
                &board.clone().reshape(vec![1, w])?,
            )
        }
        ProgramSpec::Life { .. } | ProgramSpec::Lenia { .. } => {
            spacetime::render_field(board)
        }
        // Channel 0 of a multi-channel world.
        ProgramSpec::LeniaMulti { .. } => {
            spacetime::render_field(&board.index_axis0(0))
        }
        ProgramSpec::NcaGrowing => spacetime::render_rgba_state(board),
    }
}

// ---------------------------------------------------------- streaming

/// Heartbeat cadence of an idle SSE connection (an `: keepalive` SSE
/// comment), which doubles as the dead-client probe: the write fails
/// once the peer is gone, and the subscriber is torn down.
const STREAM_KEEPALIVE: Duration = Duration::from_secs(15);

/// `GET /sessions/<id>/stream` with a well-formed id, or `None` (the
/// request then flows through the normal router, which 404s bad ids).
fn stream_route(req: &Request) -> Option<u64> {
    if req.method != "GET" {
        return None;
    }
    let segments: Vec<&str> =
        req.path.split('/').filter(|s| !s.is_empty()).collect();
    match segments.as_slice() {
        ["sessions", id, "stream"] => parse_id(id),
        _ => None,
    }
}

/// One chunk of an HTTP/1.1 chunked-transfer body.
fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n", data.len())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

/// The SSE route: subscribe to the session's frame stream and relay
/// events until the client drops, the server drains, or the session's
/// publisher disappears. The subscriber queue is bounded
/// ([`super::stream::SUBSCRIBER_QUEUE`]); a client that reads slower
/// than the tick rate loses frames (counted in `/stats`), never
/// stalls the scheduler.
fn handle_stream(mut stream: TcpStream, ctx: &Ctx, id: u64) -> Result<()> {
    let start = Instant::now();
    // The session must exist (rehydrating it if evicted) before the
    // connection commits to the stream framing.
    let known = {
        let mut registry = super::lock_recover(ctx.coalescer.registry());
        match registry.ensure_resident(id) {
            Ok(known) => known || registry.is_busy(id),
            Err(e) => {
                let msg = format!("{e:#}");
                let resp = Response::error(error_status(&msg), &msg);
                let _ = respond(&mut stream, &resp, true);
                return Ok(());
            }
        }
    };
    if !known {
        let resp =
            Response::error(404, &format!("no session {}", fmt_id(id)));
        let _ = respond(&mut stream, &resp, true);
        return Ok(());
    }
    let (token, rx) = ctx.coalescer.hub().subscribe(id);
    let outcome = stream_events(&mut stream, ctx, id, &rx);
    ctx.coalescer.hub().unsubscribe(id, token);
    let dur = start.elapsed();
    if obs::recording() {
        ctx.coalescer
            .stats()
            .registry()
            .histogram("http_stream_seconds")
            .record_duration(dur);
    }
    trace::record_complete("http_stream", start, dur);
    outcome
}

fn stream_events(stream: &mut TcpStream, ctx: &Ctx, id: u64,
                 rx: &std::sync::mpsc::Receiver<String>) -> Result<()> {
    use std::sync::mpsc::RecvTimeoutError;
    stream
        .write_all(
            b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
              Cache-Control: no-cache\r\nTransfer-Encoding: chunked\r\n\
              Connection: close\r\n\r\n",
        )
        .context("writing stream header")?;
    // An immediate frame of the current board, so a subscriber sees
    // state without waiting for the next step.
    {
        let mut registry = super::lock_recover(ctx.coalescer.registry());
        let _ = registry.ensure_resident(id);
        if let Some(session) = registry.get(id) {
            if let Ok(event) = super::stream::frame_event(
                ctx.coalescer.backend(),
                session,
                0,
            ) {
                write_chunk(stream, event.as_bytes())
                    .context("writing initial frame")?;
            }
        }
    }
    let mut last_write = Instant::now();
    loop {
        if ctx.stopping() {
            break;
        }
        match rx.recv_timeout(READ_POLL) {
            Ok(event) => {
                write_chunk(stream, event.as_bytes())
                    .context("writing frame")?;
                last_write = Instant::now();
            }
            Err(RecvTimeoutError::Timeout) => {
                if last_write.elapsed() >= STREAM_KEEPALIVE {
                    write_chunk(stream, b": keepalive\n\n")
                        .context("writing keepalive")?;
                    last_write = Instant::now();
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Terminal chunk: a clean end of the chunked body.
    let _ = stream.write_all(b"0\r\n\r\n");
    let _ = stream.flush();
    Ok(())
}

// ------------------------------------------------------------- server

/// A running serve instance: accept loop + scheduler thread.
pub struct Server {
    addr: SocketAddr,
    handle: std::thread::JoinHandle<()>,
    coalescer: Arc<Coalescer>,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn coalescer(&self) -> &Arc<Coalescer> {
        &self.coalescer
    }

    /// Request a graceful shutdown (same path as `POST /shutdown`).
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Wait for the accept loop to drain and exit.
    pub fn join(self) -> Result<()> {
        self.handle
            .join()
            .map_err(|_| anyhow!("serve accept loop panicked"))
    }
}

/// Bind and spawn a server over a fresh coalescer.
pub fn start(cfg: &ServeConfig) -> Result<Server> {
    start_with(cfg, Arc::new(Coalescer::try_new(cfg)?))
}

/// Bind and spawn a server over an existing coalescer (tests drive the
/// coalescer directly and via HTTP at once).
pub fn start_with(cfg: &ServeConfig, coalescer: Arc<Coalescer>)
                  -> Result<Server> {
    let listener = TcpListener::bind(("127.0.0.1", cfg.port))
        .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
    let addr = listener.local_addr()?;
    listener
        .set_nonblocking(true)
        .context("non-blocking listener")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let scheduler = Coalescer::spawn(&coalescer);
    let ctx = Arc::new(Ctx {
        coalescer: Arc::clone(&coalescer),
        shutdown: Arc::clone(&shutdown),
    });
    let handle = std::thread::Builder::new()
        .name("cax-serve-accept".into())
        .spawn(move || accept_loop(listener, ctx, scheduler))
        .context("spawning accept loop")?;
    Ok(Server { addr, handle, coalescer, shutdown })
}

/// Decrements the live-connection count on drop, so the slot is
/// released even if a connection thread unwinds from a panic.
struct ActiveGuard(Arc<AtomicUsize>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn accept_loop(listener: TcpListener, ctx: Arc<Ctx>,
               scheduler: std::thread::JoinHandle<()>) {
    let active = Arc::new(AtomicUsize::new(0));
    while !ctx.stopping() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Thread-per-connection with a hard cap: refuse fast
                // rather than pile up OS threads.
                if active.load(Ordering::SeqCst) >= MAX_CONNS {
                    let mut stream = stream;
                    let resp =
                        Response::error(503, "too many connections");
                    let _ = respond(&mut stream, &resp, true);
                    continue;
                }
                let ctx = Arc::clone(&ctx);
                active.fetch_add(1, Ordering::SeqCst);
                let slot = ActiveGuard(Arc::clone(&active));
                let spawned = std::thread::Builder::new()
                    .name("cax-serve-conn".into())
                    .spawn(move || {
                        let _slot = slot;
                        if let Err(e) = handle_connection(stream, &ctx) {
                            crate::log_debug!("serve connection: {e:#}");
                        }
                    });
                // On spawn failure the closure is dropped unrun, and
                // dropping it drops the guard — the slot is released
                // either way, so there is nothing to undo here.
                if let Err(e) = spawned {
                    crate::log_warn!("serve: spawn failed: {e}");
                }
            }
            Err(e) if is_timeout(e.kind()) => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    // Graceful drain: stop accepting, serve every queued step request,
    // let live connections finish their in-flight request.
    crate::log_info!("serve: shutdown requested — draining in-flight work");
    ctx.coalescer.shutdown();
    let _ = scheduler.join();
    // With a state dir, park every resident session on disk so a
    // restarted server resumes the same trajectories bit-identically.
    match ctx.coalescer.checkpoint_all() {
        Ok(0) => {}
        Ok(n) => crate::log_info!("serve: checkpointed {n} sessions"),
        Err(e) => {
            crate::log_warn!("serve: final checkpoint failed: {e:#}");
        }
    }
    let deadline = Instant::now() + Duration::from_secs(3);
    while active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    crate::log_info!("serve: drained, exiting");
}

fn handle_connection(mut stream: TcpStream, ctx: &Ctx) -> Result<()> {
    stream.set_read_timeout(Some(READ_POLL))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut last_activity = Instant::now();
    loop {
        if ctx.stopping() {
            return Ok(());
        }
        let outcome = match read_request(&mut reader) {
            Ok(o) => o,
            Err(e) => {
                // Best-effort 400 before dropping a broken connection.
                let resp = Response::error(400, &format!("{e:#}"));
                let _ = respond(&mut stream, &resp, true);
                return Err(e);
            }
        };
        match outcome {
            ReadOutcome::Closed => return Ok(()),
            ReadOutcome::Idle => {
                // A keep-alive connection only holds its thread for so
                // long without sending anything.
                if last_activity.elapsed() > KEEPALIVE_IDLE {
                    return Ok(());
                }
                continue;
            }
            ReadOutcome::Request(req) => {
                last_activity = Instant::now();
                // The one route that takes over the raw connection:
                // `GET /sessions/:id/stream` switches to chunked
                // text/event-stream and never returns to keep-alive.
                if let Some(id) = stream_route(&req) {
                    return handle_stream(stream, ctx, id);
                }
                let resp = route(ctx, &req);
                let close = !req.keep_alive || ctx.stopping();
                respond(&mut stream, &resp, close)
                    .context("writing response")?;
                if close {
                    return Ok(());
                }
            }
        }
    }
}

/// The blocking CLI entry: bind, announce, serve until a shutdown
/// signal or `POST /shutdown`, drain, return `Ok` (exit code 0).
pub fn run(cfg: &ServeConfig) -> Result<()> {
    install_signal_handlers();
    if let Some((index, _)) = cfg.shard {
        // Direct worker stderr (crash logs, state-dir recovery) and
        // Perfetto lanes carry the shard identity even when they
        // bypass the router's forwarding prefix.
        obs::log::set_shard(index);
        trace::set_pid(index + 2);
    }
    let server = start(cfg)?;
    let mut extras = String::new();
    if let Some(dir) = &cfg.state_dir {
        extras.push_str(&format!(", state-dir {}", dir.display()));
    }
    if let Some((index, count)) = cfg.shard {
        extras.push_str(&format!(", shard {index}/{count}"));
    }
    println!(
        "cax serve listening on {} ({} worker threads, max {} sessions, \
         max batch {}, simd {}{})",
        server.addr(),
        cfg.threads,
        cfg.max_sessions,
        cfg.max_batch,
        crate::backend::native::simd::status(),
        extras
    );
    std::io::stdout().flush().ok();
    server.join()
}
