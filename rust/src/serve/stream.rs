//! SSE frame streaming: push session boards as the coalescer ticks.
//!
//! `GET /sessions/:id/stream` subscribes a client to a session; every
//! batched launch that steps the session publishes one *frame event*
//! through the [`StreamHub`] — a `text/event-stream` record whose JSON
//! payload carries the step counter, the batch size it rode, and the
//! rendered board as a base64 PPM. Clients observe a live trajectory
//! instead of polling `snapshot.ppm`.
//!
//! # Backpressure
//!
//! Each subscriber owns a bounded queue of [`SUBSCRIBER_QUEUE`]
//! already-formatted events. The publisher (the scheduler tick) only
//! ever `try_send`s: a slow client's full queue drops the frame for
//! that subscriber — counted in `serve_stream_dropped_total`, surfaced
//! in `/stats` — and never blocks the tick or any other subscriber.
//! Frames are ephemeral renderings, so dropping under pressure is
//! loss-free for correctness: session state itself lives in the
//! registry, not the stream.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::backend::{Backend, NativeBackend};
use crate::obs::{Counter, Gauge};
use crate::serve::session::{fmt_id, ProgramSpec, Session};
use crate::tensor::Tensor;
use crate::util::json::{obj, Json};

/// Bound of each subscriber's event queue; the publisher drops frames
/// (never blocks) once a slow client falls this far behind.
pub const SUBSCRIBER_QUEUE: usize = 8;

struct Subscriber {
    token: u64,
    tx: SyncSender<String>,
}

/// Fan-out point between the scheduler tick (publisher) and the SSE
/// connection handlers (subscribers). Shared via the owning
/// [`Coalescer`](super::Coalescer).
pub struct StreamHub {
    subs: Mutex<BTreeMap<u64, Vec<Subscriber>>>,
    next_token: AtomicU64,
    frames: Arc<Counter>,
    dropped: Arc<Counter>,
    subscribers: Arc<Gauge>,
}

impl StreamHub {
    pub(crate) fn new(frames: Arc<Counter>, dropped: Arc<Counter>,
                      subscribers: Arc<Gauge>) -> StreamHub {
        StreamHub {
            subs: Mutex::new(BTreeMap::new()),
            next_token: AtomicU64::new(1),
            frames,
            dropped,
            subscribers,
        }
    }

    /// Register a subscriber for one session. The token identifies it
    /// to [`unsubscribe`](Self::unsubscribe); dropping the receiver
    /// also works (the publisher prunes disconnected queues lazily).
    pub fn subscribe(&self, id: u64) -> (u64, Receiver<String>) {
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(SUBSCRIBER_QUEUE);
        let mut subs = super::lock_recover(&self.subs);
        subs.entry(id).or_default().push(Subscriber { token, tx });
        self.subscribers.set(Self::count(&subs));
        (token, rx)
    }

    pub fn unsubscribe(&self, id: u64, token: u64) {
        let mut subs = super::lock_recover(&self.subs);
        if let Some(list) = subs.get_mut(&id) {
            list.retain(|s| s.token != token);
            if list.is_empty() {
                subs.remove(&id);
            }
        }
        self.subscribers.set(Self::count(&subs));
    }

    fn count(subs: &BTreeMap<u64, Vec<Subscriber>>) -> u64 {
        subs.values().map(|l| l.len() as u64).sum()
    }

    /// Current subscriber total (tests/stats).
    pub fn subscriber_count(&self) -> u64 {
        Self::count(&super::lock_recover(&self.subs))
    }

    /// Deliver one already-formatted event to a session's subscribers:
    /// `try_send` per queue, dropping on full, pruning on disconnect.
    pub fn publish(&self, id: u64, event: &str) {
        let mut subs = super::lock_recover(&self.subs);
        let Some(list) = subs.get_mut(&id) else { return };
        list.retain(|s| match s.tx.try_send(event.to_string()) {
            Ok(()) => {
                self.frames.inc();
                true
            }
            Err(TrySendError::Full(_)) => {
                self.dropped.inc();
                true
            }
            Err(TrySendError::Disconnected(_)) => false,
        });
        if list.is_empty() {
            subs.remove(&id);
        }
        self.subscribers.set(Self::count(&subs));
    }

    /// Publish a frame for every just-stepped session that has
    /// subscribers. Called by the scheduler with the detached sessions
    /// (no registry lock held); a cheap no-op when nobody streams.
    pub(crate) fn publish_batch(&self, backend: &NativeBackend,
                                sessions: &[Session], batch: usize) {
        let wanted: Vec<u64> = {
            let subs = super::lock_recover(&self.subs);
            if subs.is_empty() {
                return;
            }
            sessions
                .iter()
                .map(|s| s.id)
                .filter(|id| subs.contains_key(id))
                .collect()
        };
        for session in sessions.iter().filter(|s| wanted.contains(&s.id)) {
            match frame_event(backend, session, batch) {
                Ok(event) => self.publish(session.id, &event),
                Err(e) => crate::log_warn!(
                    "serve: stream frame for {} failed: {e:#}",
                    session.id_str()
                ),
            }
        }
    }
}

impl std::fmt::Debug for StreamHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamHub")
            .field("subscribers", &self.subscriber_count())
            .finish()
    }
}

/// Format one SSE frame event for a session's current board.
pub(crate) fn frame_event(backend: &NativeBackend, session: &Session,
                          batch: usize) -> Result<String> {
    let board = backend.read_resident(&session.prog, &session.resident)?;
    build_event(&session.spec, &board, session.id, session.steps_done, batch)
}

/// The SSE wire form: `event: frame` + one compact-JSON `data:` line.
pub(crate) fn build_event(spec: &ProgramSpec, board: &Tensor, id: u64,
                          steps_done: u64, batch: usize) -> Result<String> {
    let mean = if board.data().is_empty() {
        0.0
    } else {
        board.data().iter().map(|&v| v as f64).sum::<f64>()
            / board.data().len() as f64
    };
    let ppm = super::http::render_board(spec, board)?.ppm_bytes()?;
    let payload = obj(vec![
        ("id", Json::from(fmt_id(id).as_str())),
        ("steps_done", Json::from(steps_done)),
        ("batch", Json::from(batch)),
        (
            "shape",
            Json::Arr(board.shape().iter().map(|&d| Json::from(d)).collect()),
        ),
        ("mean", Json::Num(mean)),
        ("ppm_base64", Json::from(base64(&ppm).as_str())),
    ]);
    Ok(format!("event: frame\ndata: {}\n\n", payload.to_string_compact()))
}

/// Standard base64 (RFC 4648, with padding) — std-only, for the PPM
/// payload inside the frame JSON.
pub fn base64(bytes: &[u8]) -> String {
    const ALPHABET: &[u8; 64] =
        b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";
    let mut out = String::with_capacity(bytes.len().div_ceil(3) * 4);
    for chunk in bytes.chunks(3) {
        let b = [
            chunk[0],
            chunk.get(1).copied().unwrap_or(0),
            chunk.get(2).copied().unwrap_or(0),
        ];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(n >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            ALPHABET[n as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Registry;

    fn hub() -> (StreamHub, Arc<Counter>, Arc<Counter>) {
        let reg = Registry::new();
        let frames = reg.counter("f");
        let dropped = reg.counter("d");
        let hub = StreamHub::new(
            Arc::clone(&frames),
            Arc::clone(&dropped),
            reg.gauge("s"),
        );
        (hub, frames, dropped)
    }

    #[test]
    fn base64_matches_known_vectors() {
        assert_eq!(base64(b""), "");
        assert_eq!(base64(b"f"), "Zg==");
        assert_eq!(base64(b"fo"), "Zm8=");
        assert_eq!(base64(b"foo"), "Zm9v");
        assert_eq!(base64(b"foobar"), "Zm9vYmFy");
        assert_eq!(base64(&[0xFF, 0x00, 0xAB]), "/wCr");
    }

    #[test]
    fn slow_subscribers_drop_frames_without_blocking() {
        let (hub, frames, dropped) = hub();
        let (token, rx) = hub.subscribe(7);
        assert_eq!(hub.subscriber_count(), 1);
        // Fill the bounded queue, then keep publishing: the overflow is
        // dropped and counted, the publisher never blocks.
        for i in 0..SUBSCRIBER_QUEUE + 3 {
            hub.publish(7, &format!("event {i}"));
        }
        assert_eq!(frames.get(), SUBSCRIBER_QUEUE as u64);
        assert_eq!(dropped.get(), 3);
        // The frames that did queue arrive in order.
        assert_eq!(rx.recv().unwrap(), "event 0");
        hub.unsubscribe(7, token);
        assert_eq!(hub.subscriber_count(), 0);
        // Publishing to a session with no subscribers is a no-op.
        hub.publish(7, "nobody listens");
        assert_eq!(frames.get(), SUBSCRIBER_QUEUE as u64);
    }

    #[test]
    fn dropped_receivers_are_pruned_on_publish() {
        let (hub, frames, _) = hub();
        let (_token, rx) = hub.subscribe(1);
        drop(rx);
        hub.publish(1, "x");
        assert_eq!(hub.subscriber_count(), 0, "pruned lazily");
        assert_eq!(frames.get(), 0);
    }
}
