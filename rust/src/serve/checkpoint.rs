//! Versioned on-disk checkpoints of serve sessions.
//!
//! One file per session, `<state-dir>/<id as 16 hex digits>.ckpt`,
//! holding everything [`SessionRegistry`](super::SessionRegistry) needs
//! to rebuild the session *exactly*: the [`ProgramSpec`], the session
//! seed (so `reset` still replays the original board), the step
//! counter, and the backend-[`Resident`] payload in its native layout —
//! bit-planes as `u64` words for ECA/Life, kernel-layout `f32` blobs
//! for Lenia/NCA. Floats are stored as raw IEEE-754 bits
//! (`f32::to_bits`), never formatted, so a save/load round trip is a
//! bitwise identity and a rehydrated trajectory cannot drift from a
//! never-evicted one.
//!
//! # The contract
//!
//! - **Bit-identity.** `load(save(session))` rebuilds a session whose
//!   resident payload, seed, and step counter are bitwise equal to the
//!   original's. Stepping the rebuilt session N times must match
//!   stepping the original N times, bit for bit — `tests/serve_props.rs`
//!   asserts this for every program family.
//! - **Activity maps are deliberately not serialized.** A rehydrated
//!   resident comes back with `activity: None`, so its first sparse
//!   launch rebuilds a fresh all-dirty map (dense-in-disguise). Stale
//!   dirty-tile state can therefore never survive an evict/rehydrate
//!   cycle — the same invalidation rule `reset` follows.
//! - **Atomic replace.** Writes go to `<file>.tmp` in the same
//!   directory and are renamed into place, so a crash mid-write leaves
//!   either the old checkpoint or none — never a torn one. A trailing
//!   FNV-1a checksum rejects truncated or corrupted files at load time.
//! - **Versioned.** Every file starts with the [`MAGIC`] tag and a
//!   little-endian [`VERSION`]; a mismatch is a load error naming both
//!   versions, never a silent misparse.
//!
//! # File layout (version 1, all integers little-endian)
//!
//! ```text
//! [0..6)   magic  b"CAXCKP"
//! [6..8)   format version, u16
//! u8       spec tag: 0 eca, 1 life, 2 lenia, 3 lenia-multi, 4 nca
//! u64 * k  spec fields (tag-dependent; see `encode_spec`)
//! u64      session id
//! u64      session seed
//! u64      steps done
//! u8       resident tag: 0 bit-planes, 1 board blob, 2 host tensor
//! u64      shape rank, then u64 * rank dims
//! u64      payload length, then the payload:
//!            tag 0 -> u64 words (LE); tags 1/2 -> f32::to_bits as u32
//! u64      FNV-1a 64 checksum of every preceding byte
//! ```

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::backend::Resident;
use crate::serve::session::{fmt_id, parse_id, ProgramSpec, Session};
use crate::tensor::Tensor;

/// File-format tag every checkpoint starts with.
pub const MAGIC: &[u8; 6] = b"CAXCKP";
/// Current file-format version (bump on any layout change).
pub const VERSION: u16 = 1;
/// On-disk extension of a live checkpoint (`.tmp` while being written).
pub const EXTENSION: &str = "ckpt";

/// Everything a checkpoint restores: the session minus its compiled
/// program (rebuilt pure from the spec) and minus the registry-side
/// bookkeeping (id, LRU recency).
#[derive(Debug)]
pub struct SessionState {
    pub spec: ProgramSpec,
    pub seed: u64,
    pub steps_done: u64,
    pub resident: Resident,
}

/// A directory of per-session checkpoint files (see the module docs for
/// the format contract). All operations are keyed by session id; the
/// file name is the id's wire form (16 hex digits).
#[derive(Debug)]
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    /// Open (creating if needed) a state directory.
    pub fn open(dir: &Path) -> Result<CheckpointStore> {
        fs::create_dir_all(dir).with_context(|| {
            format!("state-dir {}: create failed", dir.display())
        })?;
        Ok(CheckpointStore { dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("{}.{EXTENSION}", fmt_id(id)))
    }

    /// Atomically persist one session (temp file + rename).
    pub fn save(&self, session: &Session) -> Result<()> {
        let bytes = encode(session);
        let path = self.path(session.id);
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, &bytes)
            .with_context(|| format!("checkpoint {}: write", tmp.display()))?;
        fs::rename(&tmp, &path).with_context(|| {
            format!("checkpoint {}: rename into place", path.display())
        })
    }

    /// Load a session's checkpoint; `Ok(None)` when none exists.
    pub fn load(&self, id: u64) -> Result<Option<SessionState>> {
        let path = self.path(id);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("checkpoint {}: read", path.display())
                })
            }
        };
        decode(&bytes)
            .map(Some)
            .with_context(|| format!("checkpoint {}", path.display()))
    }

    /// Whether a checkpoint exists for this id.
    pub fn contains(&self, id: u64) -> bool {
        self.path(id).exists()
    }

    /// Delete a session's checkpoint; `Ok(false)` when none existed.
    pub fn remove(&self, id: u64) -> Result<bool> {
        let path = self.path(id);
        match fs::remove_file(&path) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e).with_context(|| {
                format!("checkpoint {}: remove", path.display())
            }),
        }
    }

    /// Ids of every checkpoint currently on disk.
    pub fn ids(&self) -> Vec<u64> {
        let Ok(entries) = fs::read_dir(&self.dir) else { return vec![] };
        let mut ids: Vec<u64> = entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name();
                let name = name.to_str()?;
                let stem = name.strip_suffix(&format!(".{EXTENSION}"))?;
                parse_id(stem)
            })
            .collect();
        ids.sort_unstable();
        ids
    }
}

// ---------------------------------------------------------------- codec

fn w8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn w16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn encode_spec(out: &mut Vec<u8>, spec: &ProgramSpec) {
    match spec {
        ProgramSpec::Eca { rule, width } => {
            w8(out, 0);
            w64(out, *rule as u64);
            w64(out, *width as u64);
        }
        ProgramSpec::Life { height, width } => {
            w8(out, 1);
            w64(out, *height as u64);
            w64(out, *width as u64);
        }
        ProgramSpec::Lenia { radius, height, width } => {
            w8(out, 2);
            w64(out, *radius as u64);
            w64(out, *height as u64);
            w64(out, *width as u64);
        }
        ProgramSpec::LeniaMulti { kernels, radius, height, width } => {
            w8(out, 3);
            w64(out, *kernels as u64);
            w64(out, *radius as u64);
            w64(out, *height as u64);
            w64(out, *width as u64);
        }
        ProgramSpec::NcaGrowing => w8(out, 4),
    }
}

fn encode_f32s(out: &mut Vec<u8>, shape: &[usize], data: &[f32], tag: u8) {
    w8(out, tag);
    w64(out, shape.len() as u64);
    for &d in shape {
        w64(out, d as u64);
    }
    w64(out, data.len() as u64);
    for &v in data {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Serialize a session to the version-1 byte layout (module docs).
pub fn encode(session: &Session) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    w16(&mut out, VERSION);
    encode_spec(&mut out, &session.spec);
    w64(&mut out, session.id);
    w64(&mut out, session.seed);
    w64(&mut out, session.steps_done);
    match &session.resident {
        Resident::Bits { words, shape, .. } => {
            w8(&mut out, 0);
            w64(&mut out, shape.len() as u64);
            for &d in shape {
                w64(&mut out, d as u64);
            }
            w64(&mut out, words.len() as u64);
            for &w in words {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        Resident::Board { data, shape, .. } => {
            encode_f32s(&mut out, shape, data, 1);
        }
        Resident::Host(t) => encode_f32s(&mut out, t.shape(), t.data(), 2),
    }
    let sum = fnv1a(&out);
    w64(&mut out, sum);
    out
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated at byte {} (wanted {n} more of {})",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn dim(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).context("dimension overflows usize")
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        let rank = self.dim()?;
        if rank > 8 {
            bail!("implausible shape rank {rank}");
        }
        (0..rank).map(|_| self.dim()).collect()
    }
}

fn decode_spec(r: &mut Reader) -> Result<ProgramSpec> {
    Ok(match r.u8()? {
        0 => {
            let rule = r.u64()?;
            if rule > 255 {
                bail!("eca rule {rule} > 255");
            }
            ProgramSpec::Eca { rule: rule as u8, width: r.dim()? }
        }
        1 => ProgramSpec::Life { height: r.dim()?, width: r.dim()? },
        2 => ProgramSpec::Lenia {
            radius: r.dim()?,
            height: r.dim()?,
            width: r.dim()?,
        },
        3 => ProgramSpec::LeniaMulti {
            kernels: r.dim()?,
            radius: r.dim()?,
            height: r.dim()?,
            width: r.dim()?,
        },
        4 => ProgramSpec::NcaGrowing,
        other => bail!("unknown program tag {other}"),
    })
}

/// Parse the version-1 byte layout back into a [`SessionState`]. The
/// stored id is informational (the store keys files by name); the
/// registry re-keys the rebuilt session under the id it looked up.
pub fn decode(bytes: &[u8]) -> Result<SessionState> {
    if bytes.len() < MAGIC.len() + 2 + 8 || &bytes[..MAGIC.len()] != MAGIC {
        bail!("not a cax checkpoint (bad magic)");
    }
    let body = &bytes[..bytes.len() - 8];
    let stored_sum =
        u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let sum = fnv1a(body);
    if sum != stored_sum {
        bail!("checksum mismatch (got {sum:#018x}, file says \
               {stored_sum:#018x}) — truncated or corrupted");
    }
    let mut r = Reader { buf: body, pos: MAGIC.len() };
    let version = r.u16()?;
    if version != VERSION {
        bail!("format version {version} (this build reads {VERSION})");
    }
    let spec = decode_spec(&mut r)?;
    let _id = r.u64()?;
    let seed = r.u64()?;
    let steps_done = r.u64()?;
    let resident = match r.u8()? {
        0 => {
            let shape = r.shape()?;
            let n = r.dim()?;
            let mut words = Vec::with_capacity(n.min(1 << 24));
            for _ in 0..n {
                words.push(r.u64()?);
            }
            Resident::Bits { words, shape, activity: None }
        }
        1 => {
            let shape = r.shape()?;
            let n = r.dim()?;
            let mut data = Vec::with_capacity(n.min(1 << 24));
            for _ in 0..n {
                data.push(f32::from_bits(r.u32()?));
            }
            Resident::Board { data, shape, activity: None }
        }
        2 => {
            let shape = r.shape()?;
            let n = r.dim()?;
            let mut data = Vec::with_capacity(n.min(1 << 24));
            for _ in 0..n {
                data.push(f32::from_bits(r.u32()?));
            }
            Resident::Host(Tensor::new(shape, data)?)
        }
        other => bail!("unknown resident tag {other}"),
    };
    if r.pos != body.len() {
        bail!("{} trailing bytes after the payload", body.len() - r.pos);
    }
    Ok(SessionState { spec, seed, steps_done, resident })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, NativeBackend};

    fn session(spec: ProgramSpec, seed: u64) -> Session {
        let backend = NativeBackend::with_threads(1);
        let prog = spec.program().unwrap();
        let board = spec.initial_board(seed).unwrap();
        let resident = backend.admit(&prog, &board).unwrap();
        Session { id: 0xABCD, spec, prog, resident, seed, steps_done: 7 }
    }

    #[test]
    fn encode_decode_roundtrips_bits_and_boards() {
        for spec in [
            ProgramSpec::Eca { rule: 110, width: 70 },
            ProgramSpec::Life { height: 24, width: 33 },
            ProgramSpec::Lenia { radius: 5, height: 16, width: 16 },
            ProgramSpec::LeniaMulti {
                kernels: 2,
                radius: 4,
                height: 12,
                width: 12,
            },
        ] {
            let s = session(spec.clone(), 0xFEED);
            let state = decode(&encode(&s)).unwrap();
            assert_eq!(state.spec, spec);
            assert_eq!(state.seed, 0xFEED);
            assert_eq!(state.steps_done, 7);
            match (&state.resident, &s.resident) {
                (
                    Resident::Bits { words: a, shape: sa, activity },
                    Resident::Bits { words: b, shape: sb, .. },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(sa, sb);
                    assert!(activity.is_none(), "maps never round-trip");
                }
                (
                    Resident::Board { data: a, shape: sa, activity },
                    Resident::Board { data: b, shape: sb, .. },
                ) => {
                    // Bitwise, not approximate: to_bits on both sides.
                    let bits =
                        |v: &[f32]| -> Vec<u32> {
                            v.iter().map(|x| x.to_bits()).collect()
                        };
                    assert_eq!(bits(a), bits(b));
                    assert_eq!(sa, sb);
                    assert!(activity.is_none(), "maps never round-trip");
                }
                other => panic!("resident kind changed: {other:?}"),
            }
        }
    }

    #[test]
    fn corruption_and_version_skew_are_load_errors() {
        let s = session(ProgramSpec::Life { height: 8, width: 8 }, 1);
        let good = encode(&s);
        assert!(decode(&good).is_ok());
        // Flip one payload byte: checksum must catch it.
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        let err = decode(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        // Truncate: also a checksum (or length) error, never a panic.
        assert!(decode(&good[..good.len() - 3]).is_err());
        assert!(decode(b"CA").is_err());
        // Version bump: named in the error.
        let mut skew = good.clone();
        skew[6] = 0x7F;
        let sum = fnv1a(&skew[..skew.len() - 8]);
        let n = skew.len();
        skew[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&skew).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn store_save_load_remove_and_scan() {
        let dir = std::env::temp_dir()
            .join(format!("cax-ckpt-unit-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let store = CheckpointStore::open(&dir).unwrap();
        assert!(store.ids().is_empty());
        let s = session(ProgramSpec::Eca { rule: 30, width: 40 }, 9);
        assert!(!store.contains(s.id));
        store.save(&s).unwrap();
        assert!(store.contains(s.id));
        assert_eq!(store.ids(), vec![s.id]);
        let state = store.load(s.id).unwrap().unwrap();
        assert_eq!(state.spec, s.spec);
        assert!(store.load(0xDEAD).unwrap().is_none());
        assert!(store.remove(s.id).unwrap());
        assert!(!store.remove(s.id).unwrap(), "second remove is a no-op");
        assert!(store.load(s.id).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }
}
