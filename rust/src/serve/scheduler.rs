//! The coalescing scheduler: many sessions, few kernel launches.
//!
//! Every step request lands in one FIFO queue. Each *tick* the
//! [`Coalescer`] walks the queue in arrival order and packs requests
//! into per-shape-class batches (class = [`ProgramSpec::class_key`] +
//! requested step count — everything that must be uniform for one
//! batched launch), then runs **one** [`Backend::step_resident`] call
//! per batch across the worker pool and scatters the results back to
//! their sessions.
//!
//! # Fairness / deadline policy
//!
//! - Requests are admitted to batches strictly in arrival order; a
//!   request is only deferred to the next tick when (a) its session is
//!   already claimed by an earlier request this tick, (b) its shape
//!   class already holds `max_batch` requests, or (c) an *earlier*
//!   request of the same session was deferred this tick (deferral
//!   blocks the session for the rest of the tick, so a session's
//!   requests are always served in arrival order — never reordered
//!   across classes). Deferred requests keep their queue position, so
//!   a request at position `p` is served within at most `p + 1` ticks
//!   — no starvation, no priority inversion. (These invariants are
//!   property-checked over randomized workloads; see
//!   `tests/serve_props.rs` and the unit tests below.)
//! - Every tick with a non-empty queue serves at least the oldest
//!   request (with a result or an error), so the queue always drains.
//!
//! # Admission control / backpressure
//!
//! The pending queue is bounded (`max_pending`); submissions beyond the
//! bound are rejected immediately (HTTP 503) rather than queued without
//! limit. Session admission itself is bounded by the registry's
//! `max_sessions`.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::backend::{Backend, NativeBackend};
use crate::obs::{trace, Counter, Gauge, Histogram, MetricSnapshot, Registry};
use crate::serve::checkpoint::CheckpointStore;
use crate::serve::session::{fmt_id, SessionRegistry, FAMILIES};
use crate::serve::stream::StreamHub;
use crate::serve::ServeConfig;

/// A pending "step session S by N" request, with its reply channel.
/// Built via [`StepRequest::new`], which stamps the enqueue time the
/// request-wait histogram (`serve_wait_seconds`) measures from.
#[derive(Debug)]
pub struct StepRequest {
    pub session: u64,
    pub steps: usize,
    pub reply: Sender<StepReply>,
    enqueued: Instant,
}

impl StepRequest {
    pub fn new(session: u64, steps: usize, reply: Sender<StepReply>)
               -> StepRequest {
        StepRequest { session, steps, reply, enqueued: Instant::now() }
    }

    /// How long this request has existed (enqueue → now); recorded into
    /// `serve_wait_seconds` at the moment its reply is sent.
    pub fn waited(&self) -> Duration {
        self.enqueued.elapsed()
    }
}

/// What a served request learns. `batch` is the number of sessions that
/// rode the same launch — the coalescing observability hook.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepDone {
    pub session: u64,
    pub steps_done: u64,
    pub batch: usize,
}

/// Reply to a step request; errors cross threads as strings.
pub type StepReply = Result<StepDone, String>;

/// Monotonic counters the `/stats` endpoint and the benches read, plus
/// this coalescer's own metric [`Registry`] of latency histograms,
/// cause counters and queue gauges.
///
/// Each coalescer owns an **isolated** registry so parallel test
/// servers never share percentiles; kernel spans still record into the
/// process-global [`Registry::global`], and `GET /metrics` exposes
/// both.
#[derive(Debug)]
pub struct ServeStats {
    /// Step requests accepted into the queue.
    pub requests: AtomicU64,
    /// Step requests refused by backpressure.
    pub rejected: AtomicU64,
    /// Scheduler ticks that served at least one request.
    pub ticks: AtomicU64,
    /// Batched kernel launches.
    pub batches: AtomicU64,
    /// Total session-steps executed (sum of steps x batch size).
    pub session_steps: AtomicU64,
    /// Largest batch packed so far.
    pub peak_batch: AtomicU64,
    /// Requests pushed to a later tick (busy / claimed / batch full).
    pub deferred: AtomicU64,
    wait: Arc<Histogram>,
    step_latency: Arc<Histogram>,
    tick_duration: Arc<Histogram>,
    batch_size: Arc<Histogram>,
    queue_depth_samples: Arc<Histogram>,
    queue_depth: Arc<Gauge>,
    rejected_draining: Arc<Counter>,
    deferred_busy: Arc<Counter>,
    deferred_claimed: Arc<Counter>,
    deferred_batch_full: Arc<Counter>,
    evictions: Arc<Counter>,
    rehydrations: Arc<Counter>,
    stream_frames: Arc<Counter>,
    stream_dropped: Arc<Counter>,
    stream_subscribers: Arc<Gauge>,
    family: Vec<Arc<Counter>>,
    registry: Registry,
}

impl Default for ServeStats {
    fn default() -> ServeStats {
        let registry = Registry::new();
        ServeStats {
            requests: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            ticks: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            session_steps: AtomicU64::new(0),
            peak_batch: AtomicU64::new(0),
            deferred: AtomicU64::new(0),
            wait: registry.histogram("serve_wait_seconds"),
            step_latency: registry.histogram("serve_step_seconds"),
            tick_duration: registry.histogram("serve_tick_seconds"),
            batch_size: registry.histogram("serve_batch_size"),
            queue_depth_samples: registry
                .histogram("serve_queue_depth_samples"),
            queue_depth: registry.gauge("serve_queue_depth"),
            rejected_draining: registry
                .counter("serve_rejected_draining_total"),
            deferred_busy: registry.counter("serve_deferred_busy_total"),
            deferred_claimed: registry
                .counter("serve_deferred_claimed_total"),
            deferred_batch_full: registry
                .counter("serve_deferred_batch_full_total"),
            evictions: registry.counter("serve_evictions_total"),
            rehydrations: registry.counter("serve_rehydrations_total"),
            stream_frames: registry.counter("serve_stream_frames_total"),
            stream_dropped: registry.counter("serve_stream_dropped_total"),
            stream_subscribers: registry.gauge("serve_stream_subscribers"),
            family: FAMILIES
                .iter()
                .map(|f| registry.counter(&format!(
                    "serve_requests_{f}_total")))
                .collect(),
            registry,
        }
    }
}

impl ServeStats {
    fn bump_peak(&self, batch: u64) {
        self.peak_batch.fetch_max(batch, Ordering::Relaxed);
    }

    /// This coalescer's metric registry; `GET /metrics` exposes it
    /// alongside the process-global one.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Enqueue → reply latency (`serve_wait_seconds`, ns).
    pub fn wait(&self) -> &Histogram {
        &self.wait
    }

    /// Batched `step_resident` launch latency (`serve_step_seconds`).
    pub fn step_latency(&self) -> &Histogram {
        &self.step_latency
    }

    /// Whole-tick duration (`serve_tick_seconds`).
    pub fn tick_duration(&self) -> &Histogram {
        &self.tick_duration
    }

    /// Sessions per batched launch (`serve_batch_size`).
    pub fn batch_size(&self) -> &Histogram {
        &self.batch_size
    }

    /// Current pending-queue depth with its high-water mark.
    pub fn queue_depth(&self) -> &Gauge {
        &self.queue_depth
    }

    /// Queue depth observed at each tick (`serve_queue_depth_samples`).
    pub fn queue_depth_samples(&self) -> &Histogram {
        &self.queue_depth_samples
    }

    /// Sessions checkpointed to disk to make room
    /// (`serve_evictions_total`).
    pub fn evictions(&self) -> &Counter {
        &self.evictions
    }

    /// Evicted sessions lazily restored on touch
    /// (`serve_rehydrations_total`).
    pub fn rehydrations(&self) -> &Counter {
        &self.rehydrations
    }

    /// SSE frames delivered to subscriber queues
    /// (`serve_stream_frames_total`).
    pub fn stream_frames(&self) -> &Counter {
        &self.stream_frames
    }

    /// SSE frames dropped on slow clients whose bounded queue was full
    /// (`serve_stream_dropped_total`).
    pub fn stream_dropped(&self) -> &Counter {
        &self.stream_dropped
    }

    /// Live SSE subscribers, with a high-water mark
    /// (`serve_stream_subscribers`).
    pub fn stream_subscribers(&self) -> &Gauge {
        &self.stream_subscribers
    }

    /// `(family, accepted requests)` per program family, in
    /// [`FAMILIES`] order.
    pub fn family_requests(&self) -> Vec<(&'static str, u64)> {
        FAMILIES
            .iter()
            .copied()
            .zip(self.family.iter().map(|c| c.get()))
            .collect()
    }

    /// Plain-value snapshots of the scheduler's top-level atomics
    /// (counters the histogram [`registry`](Self::registry) doesn't
    /// cover) plus the instantaneous session/pending occupancy gauges
    /// — the shared basis of `GET /metrics` and `GET /metrics.json`,
    /// so both pages expose identical names with fleet-mergeable
    /// semantics (counters add; gauges sum now-values, max
    /// high-waters).
    pub fn core_metrics(&self, sessions: usize, pending: usize)
                        -> Vec<(String, MetricSnapshot)> {
        let counter = |name: &str, v: u64| {
            (name.to_string(), MetricSnapshot::Counter(v))
        };
        let gauge = |name: &str, v: u64| {
            (name.to_string(),
             MetricSnapshot::Gauge { value: v, high_water: v })
        };
        vec![
            counter("serve_requests_total",
                    self.requests.load(Ordering::Relaxed)),
            counter("serve_rejected_total",
                    self.rejected.load(Ordering::Relaxed)),
            counter("serve_deferred_total",
                    self.deferred.load(Ordering::Relaxed)),
            counter("serve_ticks_total",
                    self.ticks.load(Ordering::Relaxed)),
            counter("serve_batches_total",
                    self.batches.load(Ordering::Relaxed)),
            counter("serve_session_steps_total",
                    self.session_steps.load(Ordering::Relaxed)),
            gauge("serve_peak_batch",
                  self.peak_batch.load(Ordering::Relaxed)),
            gauge("serve_sessions", sessions as u64),
            gauge("serve_pending", pending as u64),
        ]
    }
}

struct Queue {
    pending: VecDeque<StepRequest>,
    /// Set on shutdown: no new submissions, the run loop exits once the
    /// queue is drained.
    draining: bool,
}

/// The multi-session scheduler. Shared (`Arc`) between the HTTP
/// handlers (submit + registry access) and the scheduler thread (tick).
pub struct Coalescer {
    backend: NativeBackend,
    registry: Mutex<SessionRegistry>,
    queue: Mutex<Queue>,
    work: Condvar,
    max_batch: usize,
    max_pending: usize,
    max_steps: usize,
    /// How long a woken scheduler waits for a burst to accumulate
    /// before packing (latency it trades for batch size).
    tick_window: Duration,
    stats: ServeStats,
    hub: StreamHub,
    started: Instant,
}

impl Coalescer {
    /// Build a coalescer, panicking on an unusable config (see
    /// [`try_new`](Self::try_new) for the fallible path `cax serve`
    /// uses — only an unopenable `--state-dir` can actually fail).
    pub fn new(cfg: &ServeConfig) -> Coalescer {
        Self::try_new(cfg).expect("serve: invalid config")
    }

    pub fn try_new(cfg: &ServeConfig) -> Result<Coalescer> {
        let stats = ServeStats::default();
        let mut registry = SessionRegistry::new(cfg.seed, cfg.max_sessions);
        if let Some((index, count)) = cfg.shard {
            registry.set_shard(index, count);
        }
        if let Some(dir) = &cfg.state_dir {
            let store = CheckpointStore::open(dir)?;
            registry.set_store(
                store,
                Arc::clone(&stats.evictions),
                Arc::clone(&stats.rehydrations),
            );
        }
        let hub = StreamHub::new(
            Arc::clone(&stats.stream_frames),
            Arc::clone(&stats.stream_dropped),
            Arc::clone(&stats.stream_subscribers),
        );
        Ok(Coalescer {
            backend: NativeBackend::with_threads(cfg.threads),
            registry: Mutex::new(registry),
            queue: Mutex::new(Queue {
                pending: VecDeque::new(),
                draining: false,
            }),
            work: Condvar::new(),
            max_batch: cfg.max_batch.max(1),
            max_pending: cfg.max_pending.max(1),
            max_steps: cfg.max_steps.max(1),
            tick_window: cfg.tick_window,
            stats,
            hub,
            started: Instant::now(),
        })
    }

    pub fn backend(&self) -> &NativeBackend {
        &self.backend
    }

    /// The SSE fan-out hub (`GET /sessions/:id/stream` subscribes
    /// here; every batched launch publishes through it).
    pub fn hub(&self) -> &StreamHub {
        &self.hub
    }

    /// Checkpoint every resident session (the graceful-shutdown path
    /// calls this after the scheduler drains). `0` without a state dir.
    pub fn checkpoint_all(&self) -> Result<usize> {
        super::lock_recover(&self.registry).checkpoint_all()
    }

    /// The session registry (create/read/reset/destroy go straight
    /// through; only *stepping* is coalesced).
    pub fn registry(&self) -> &Mutex<SessionRegistry> {
        &self.registry
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Seconds since this coalescer came up (throughput denominators).
    pub fn uptime_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Number of requests waiting to be packed.
    pub fn pending(&self) -> usize {
        super::lock_recover(&self.queue).pending.len()
    }

    /// Enqueue a step request, honoring backpressure and shutdown.
    pub fn submit(&self, req: StepRequest) -> Result<()> {
        if req.steps == 0 {
            bail!("step: steps must be >= 1");
        }
        // One launch runs under the registry lock; an unbounded step
        // count would wedge every other endpoint behind it.
        if req.steps > self.max_steps {
            bail!(
                "step: steps {} exceeds the per-request limit {}",
                req.steps,
                self.max_steps
            );
        }
        let mut q = super::lock_recover(&self.queue);
        if q.draining {
            self.stats.rejected_draining.inc();
            bail!("server is shutting down");
        }
        if q.pending.len() >= self.max_pending {
            self.stats.rejected.fetch_add(1, Ordering::Relaxed);
            bail!(
                "step queue full ({} pending) — retry later",
                q.pending.len()
            );
        }
        q.pending.push_back(req);
        self.stats.queue_depth.set(q.pending.len() as u64);
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.work.notify_one();
        Ok(())
    }

    /// One scheduling round: drain the queue, pack shape-class batches
    /// in FIFO order, launch each batch once, scatter replies. Returns
    /// the number of requests answered (results + errors). Deferred
    /// requests go back to the queue front with their order intact.
    pub fn tick(&self) -> usize {
        let tick_start = Instant::now();
        let taken: Vec<StepRequest> = {
            let mut q = super::lock_recover(&self.queue);
            let taken: Vec<StepRequest> = q.pending.drain(..).collect();
            self.stats.queue_depth.set(q.pending.len() as u64);
            taken
        };
        if taken.is_empty() {
            return 0;
        }
        self.stats.queue_depth_samples.record(taken.len() as u64);
        trace::counter("serve_queue_depth", taken.len() as f64);

        // ---- plan: FIFO walk, group by (class key, steps) -----------
        struct Group {
            reqs: Vec<StepRequest>,
        }
        let mut groups: Vec<Group> = vec![];
        let mut by_key: BTreeMap<(String, usize), usize> = BTreeMap::new();
        let mut claimed: BTreeSet<u64> = BTreeSet::new();
        // Sessions with a deferred request this tick: every later
        // request of theirs must defer too, or a session's trajectory
        // could be served out of arrival order.
        let mut blocked: BTreeSet<u64> = BTreeSet::new();
        let mut deferred: Vec<StepRequest> = vec![];
        let mut served = 0usize;
        {
            let mut registry = super::lock_recover(&self.registry);
            for req in taken {
                // Defensive: a session detached into a still-running
                // launch (possible if tick() ever runs concurrently)
                // defers rather than erroring as unknown.
                if registry.is_busy(req.session) {
                    self.stats.deferred.fetch_add(1, Ordering::Relaxed);
                    self.stats.deferred_busy.inc();
                    blocked.insert(req.session);
                    deferred.push(req);
                    continue;
                }
                // Lazily rehydrate an evicted session before the lookup
                // (may transiently overflow the working-set cap; the
                // trim at the end of this tick restores it).
                if let Err(e) = registry.ensure_resident(req.session) {
                    self.stats.wait.record_duration(req.waited());
                    let _ = req.reply.send(Err(format!("{e:#}")));
                    served += 1;
                    continue;
                }
                let Some(session) = registry.get(req.session) else {
                    self.stats.wait.record_duration(req.waited());
                    let _ = req.reply.send(Err(format!(
                        "no session {}",
                        fmt_id(req.session)
                    )));
                    served += 1;
                    continue;
                };
                if claimed.contains(&req.session)
                    || blocked.contains(&req.session)
                {
                    self.stats.deferred.fetch_add(1, Ordering::Relaxed);
                    self.stats.deferred_claimed.inc();
                    blocked.insert(req.session);
                    deferred.push(req);
                    continue;
                }
                let key = (session.spec.class_key(), req.steps);
                let slot = *by_key.entry(key).or_insert_with(|| {
                    groups.push(Group { reqs: vec![] });
                    groups.len() - 1
                });
                if groups[slot].reqs.len() >= self.max_batch {
                    self.stats.deferred.fetch_add(1, Ordering::Relaxed);
                    self.stats.deferred_batch_full.inc();
                    blocked.insert(req.session);
                    deferred.push(req);
                    continue;
                }
                claimed.insert(req.session);
                self.stats.family[session.spec.family_index()].inc();
                groups[slot].reqs.push(req);
            }
        }

        // ---- execute: one batched launch per group ------------------
        for group in &groups {
            let steps = group.reqs[0].steps;
            // Detach the group's sessions (they become "busy"), then
            // DROP the registry lock for the kernel launch — other
            // endpoints keep working while the batch runs; touching a
            // busy session fails fast with a retryable error.
            let mut sessions = Vec::with_capacity(group.reqs.len());
            let mut live = Vec::with_capacity(group.reqs.len());
            {
                let mut registry =
                    super::lock_recover(&self.registry);
                // A session may have been destroyed between planning
                // and execution; those requests get an error, the rest
                // still ride the launch.
                for req in &group.reqs {
                    match registry.take_for_step(req.session) {
                        Some(s) => {
                            sessions.push(s);
                            live.push(req);
                        }
                        None => {
                            self.stats.wait.record_duration(req.waited());
                            let _ = req.reply.send(Err(format!(
                                "no session {}",
                                fmt_id(req.session)
                            )));
                            served += 1;
                        }
                    }
                }
            }
            if sessions.is_empty() {
                continue;
            }
            let batch = sessions.len();
            let prog = sessions[0].prog.clone();
            self.stats.batch_size.record(batch as u64);
            let launch_start = Instant::now();
            let outcome = {
                let mut refs: Vec<&mut crate::backend::Resident> =
                    sessions.iter_mut().map(|s| &mut s.resident).collect();
                self.backend.step_resident(&prog, &mut refs, steps)
            };
            let launch_dur = launch_start.elapsed();
            self.stats.step_latency.record_duration(launch_dur);
            trace::record_complete("serve_launch", launch_start,
                                   launch_dur);
            if outcome.is_ok() {
                for s in &mut sessions {
                    s.steps_done += steps as u64;
                }
                // Push a frame to any SSE subscribers while we still
                // own the detached sessions (no registry lock held).
                // Fast no-op when nobody is subscribed.
                self.hub.publish_batch(&self.backend, &sessions, batch);
            }
            let replies: Vec<StepReply> = match &outcome {
                Ok(()) => sessions
                    .iter()
                    .map(|s| {
                        Ok(StepDone {
                            session: s.id,
                            steps_done: s.steps_done,
                            batch,
                        })
                    })
                    .collect(),
                Err(e) => {
                    live.iter().map(|_| Err(format!("{e:#}"))).collect()
                }
            };
            {
                let mut registry =
                    super::lock_recover(&self.registry);
                for s in sessions {
                    registry.restore(s);
                }
            }
            if outcome.is_ok() {
                self.stats.batches.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .session_steps
                    .fetch_add((steps * batch) as u64, Ordering::Relaxed);
                self.stats.bump_peak(batch as u64);
            }
            for (req, reply) in live.iter().zip(replies) {
                self.stats.wait.record_duration(req.waited());
                let _ = req.reply.send(reply);
                served += 1;
            }
        }

        if !deferred.is_empty() {
            let mut q = super::lock_recover(&self.queue);
            for req in deferred.into_iter().rev() {
                q.pending.push_front(req);
            }
            self.stats.queue_depth.set(q.pending.len() as u64);
        }
        // Rehydrations may have overflowed the working-set cap this
        // tick; evict back down to it now that every launch is done.
        {
            let mut registry = super::lock_recover(&self.registry);
            if let Err(e) = registry.trim_to_cap() {
                crate::log_warn!("serve: working-set trim failed: {e:#}");
            }
        }
        if served > 0 {
            self.stats.ticks.fetch_add(1, Ordering::Relaxed);
            let tick_dur = tick_start.elapsed();
            self.stats.tick_duration.record_duration(tick_dur);
            trace::record_complete("serve_tick", tick_start, tick_dur);
        }
        served
    }

    /// Reject new work and let the run loop drain what is queued.
    pub fn shutdown(&self) {
        let mut q = super::lock_recover(&self.queue);
        q.draining = true;
        self.work.notify_all();
    }

    /// Whether shutdown has been requested.
    pub fn draining(&self) -> bool {
        super::lock_recover(&self.queue).draining
    }

    /// The scheduler loop: sleep until work arrives, optionally wait
    /// `tick_window` for a burst to coalesce, tick. Exits once shutdown
    /// is requested AND the queue is fully drained — in-flight requests
    /// always get their reply.
    pub fn run(&self) {
        loop {
            {
                let mut q = super::lock_recover(&self.queue);
                while q.pending.is_empty() && !q.draining {
                    q = super::recover(self.work.wait(q));
                }
                if q.pending.is_empty() && q.draining {
                    return;
                }
            }
            if !self.tick_window.is_zero() && !self.draining() {
                std::thread::sleep(self.tick_window);
            }
            self.tick();
        }
    }

    /// Spawn the scheduler thread over a shared coalescer.
    pub fn spawn(this: &Arc<Coalescer>) -> std::thread::JoinHandle<()> {
        let that = Arc::clone(this);
        std::thread::Builder::new()
            .name("cax-serve-scheduler".into())
            .spawn(move || that.run())
            .expect("spawn scheduler thread")
    }
}

impl std::fmt::Debug for Coalescer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coalescer")
            .field("max_batch", &self.max_batch)
            .field("max_pending", &self.max_pending)
            .field("pending", &self.pending())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::session::ProgramSpec;
    use std::sync::mpsc::channel;

    fn coalescer(max_batch: usize, max_pending: usize) -> Coalescer {
        Coalescer::new(&ServeConfig {
            threads: 2,
            max_batch,
            max_pending,
            tick_window: Duration::ZERO,
            ..ServeConfig::default()
        })
    }

    fn create(c: &Coalescer, spec: ProgramSpec) -> u64 {
        c.registry()
            .lock()
            .unwrap()
            .create(c.backend(), spec, None)
            .unwrap()
    }

    #[test]
    fn one_tick_packs_one_class_into_one_batch() {
        let c = coalescer(64, 64);
        let ids: Vec<u64> = (0..5)
            .map(|_| create(&c, ProgramSpec::Life { height: 16, width: 16 }))
            .collect();
        let (tx, rx) = channel();
        for &id in &ids {
            c.submit(StepRequest::new(id, 2, tx.clone()))
                .unwrap();
        }
        assert_eq!(c.tick(), 5);
        for _ in 0..5 {
            let done = rx.recv().unwrap().unwrap();
            assert_eq!(done.batch, 5, "all five should ride one launch");
            assert_eq!(done.steps_done, 2);
        }
        assert_eq!(c.stats().batches.load(Ordering::Relaxed), 1);
        assert_eq!(c.stats().session_steps.load(Ordering::Relaxed), 10);
        assert_eq!(c.stats().peak_batch.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn distinct_classes_get_distinct_batches() {
        let c = coalescer(64, 64);
        let a = create(&c, ProgramSpec::Life { height: 16, width: 16 });
        let b = create(&c, ProgramSpec::Life { height: 16, width: 32 });
        let e = create(&c, ProgramSpec::Eca { rule: 30, width: 64 });
        let (tx, rx) = channel();
        for id in [a, b, e] {
            c.submit(StepRequest::new(id, 1, tx.clone()))
                .unwrap();
        }
        // A second request for a claimed session defers one tick, so a
        // session's trajectory order is never reordered inside a batch.
        c.submit(StepRequest::new(a, 1, tx.clone()))
            .unwrap();
        let served = c.tick();
        assert_eq!(served, 3, "a's duplicate must defer to the next tick");
        for _ in 0..3 {
            assert_eq!(rx.recv().unwrap().unwrap().batch, 1);
        }
        assert_eq!(c.tick(), 1, "deferred duplicate served next tick");
        assert_eq!(rx.recv().unwrap().unwrap().steps_done, 2);
        assert_eq!(c.stats().batches.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn max_batch_splits_across_ticks_fifo() {
        let c = coalescer(2, 64);
        let ids: Vec<u64> = (0..5)
            .map(|_| create(&c, ProgramSpec::Eca { rule: 90, width: 32 }))
            .collect();
        let (tx, rx) = channel();
        for &id in &ids {
            c.submit(StepRequest::new(id, 1, tx.clone()))
                .unwrap();
        }
        // 5 requests, cap 2: ticks serve 2, 2, 1 — in arrival order.
        assert_eq!(c.tick(), 2);
        let first: Vec<u64> = (0..2)
            .map(|_| rx.recv().unwrap().unwrap().session)
            .collect();
        assert_eq!(first, ids[0..2].to_vec(), "FIFO order preserved");
        assert_eq!(c.tick(), 2);
        assert_eq!(c.tick(), 1);
        assert_eq!(c.tick(), 0, "queue drained");
    }

    #[test]
    fn deferral_blocks_the_session_for_the_rest_of_the_tick() {
        // Regression (found by the randomized planning model): if a
        // session's request is deferred because its class batch is
        // full, a LATER request of the same session — even in another
        // class — must defer too, or the session's trajectory would be
        // served out of arrival order.
        let c = coalescer(1, 64); // max_batch 1
        let filler = create(&c, ProgramSpec::Eca { rule: 30, width: 32 });
        let victim = create(&c, ProgramSpec::Eca { rule: 30, width: 32 });
        let (tx, rx) = channel();
        // 1) filler claims the only eca:r30:w32 slot (1 step).
        c.submit(StepRequest::new(filler, 1, tx.clone()))
            .unwrap();
        // 2) victim, same class -> batch full -> deferred.
        c.submit(StepRequest::new(victim, 1, tx.clone()))
            .unwrap();
        // 3) victim again with steps: 2 — a DIFFERENT class key; must
        //    NOT overtake the deferred request.
        c.submit(StepRequest::new(victim, 2, tx.clone()))
            .unwrap();
        assert_eq!(c.tick(), 1, "only filler served in tick 1");
        assert_eq!(rx.recv().unwrap().unwrap().session, filler);
        assert_eq!(c.tick(), 1, "victim's FIRST request served next");
        assert_eq!(rx.recv().unwrap().unwrap().steps_done, 1);
        assert_eq!(c.tick(), 1);
        assert_eq!(rx.recv().unwrap().unwrap().steps_done, 3,
                   "1-step then 2-step, in arrival order");
    }

    #[test]
    fn backpressure_rejects_beyond_max_pending() {
        let c = coalescer(8, 2);
        let id = create(&c, ProgramSpec::Eca { rule: 30, width: 16 });
        let (tx, _rx) = channel();
        c.submit(StepRequest::new(id, 1, tx.clone()))
            .unwrap();
        c.submit(StepRequest::new(id, 1, tx.clone()))
            .unwrap();
        let err = c
            .submit(StepRequest::new(id, 1, tx.clone()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("queue full"));
        assert_eq!(c.stats().rejected.load(Ordering::Relaxed), 1);
        assert!(c
            .submit(StepRequest::new(id, 0, tx.clone()))
            .is_err());
        // Per-request step counts are bounded too (one launch holds the
        // registry lock for its whole duration).
        let err = c
            .submit(StepRequest::new(id, 10_001, tx))
            .unwrap_err();
        assert!(format!("{err:#}").contains("per-request limit"));
    }

    #[test]
    fn unknown_sessions_get_error_replies() {
        let c = coalescer(8, 8);
        let (tx, rx) = channel();
        c.submit(StepRequest::new(0xDEAD, 1, tx))
            .unwrap();
        assert_eq!(c.tick(), 1);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("no session"));
    }

    #[test]
    fn instrumentation_tracks_waits_batches_and_families() {
        let c = coalescer(2, 64);
        let life: Vec<u64> = (0..3)
            .map(|_| create(&c, ProgramSpec::Life { height: 8, width: 8 }))
            .collect();
        let eca = create(&c, ProgramSpec::Eca { rule: 30, width: 32 });
        let (tx, rx) = channel();
        for &id in &life {
            c.submit(StepRequest::new(id, 1, tx.clone())).unwrap();
        }
        c.submit(StepRequest::new(eca, 1, tx.clone())).unwrap();
        let stats = c.stats();
        assert_eq!(stats.queue_depth().get(), 4);
        assert_eq!(stats.queue_depth().high_water(), 4);
        // Tick 1: life batch of 2 (cap), eca batch of 1; 3rd life defers.
        assert_eq!(c.tick(), 3);
        assert_eq!(stats.deferred.load(Ordering::Relaxed), 1);
        assert_eq!(stats.deferred_batch_full.get(), 1);
        // Tick 2 serves the deferred life request.
        assert_eq!(c.tick(), 1);
        for _ in 0..4 {
            rx.recv().unwrap().unwrap();
        }
        // Every reply recorded a wait; every launch recorded a batch
        // size and a step latency; every served tick a duration.
        assert_eq!(stats.wait().count(), 4);
        assert_eq!(stats.step_latency().count(), 3);
        assert_eq!(stats.tick_duration().count(), 2);
        let sizes = stats.batch_size().snapshot();
        assert_eq!(sizes.count, 3);
        assert_eq!(sizes.max, 2);
        assert_eq!(stats.queue_depth_samples().count(), 2);
        assert_eq!(stats.queue_depth().get(), 0, "queue drained");
        let fams: std::collections::BTreeMap<_, _> =
            stats.family_requests().into_iter().collect();
        assert_eq!(fams["life"], 3);
        assert_eq!(fams["eca"], 1);
        assert_eq!(fams["lenia"], 0);
        // The wait quantiles are well-formed and ordered.
        let wait = stats.wait().snapshot();
        assert!(wait.quantile(0.5) <= wait.quantile(0.99));
        assert!(wait.quantile(0.99) <= wait.max as f64 + 1.0);
    }

    #[test]
    fn shutdown_rejects_submissions_and_run_drains() {
        let c = Arc::new(coalescer(8, 8));
        let id = create(&c, ProgramSpec::Life { height: 8, width: 8 });
        let (tx, rx) = channel();
        c.submit(StepRequest::new(id, 3, tx.clone()))
            .unwrap();
        let handle = Coalescer::spawn(&c);
        c.shutdown();
        handle.join().unwrap();
        // The in-flight request was drained, not dropped.
        assert_eq!(rx.recv().unwrap().unwrap().steps_done, 3);
        let err = c
            .submit(StepRequest::new(id, 1, tx))
            .unwrap_err();
        assert!(format!("{err:#}").contains("shutting down"));
    }
}
