//! Sessions: program specs, per-session resident state, the registry.
//!
//! A *session* is one live CA board owned by one client: a
//! [`ProgramSpec`] (what to run), a [`CaProgram`] built from it, and a
//! backend-[`Resident`] state stepped in place between reads. The
//! [`SessionRegistry`] owns every session, enforces the admission limit
//! (`max_sessions`), and mints **seeded-deterministic ids**: for a fixed
//! service seed, the n-th created session always gets the same id and
//! the same initial board, so a whole multi-session workload replays
//! exactly.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::automata::lenia::{LeniaParams, LeniaWorld};
use crate::automata::WolframRule;
use crate::backend::native::nca::NcaModel;
use crate::backend::native::train::NcaTrainSpec;
use crate::backend::{
    Backend, CaProgram, NativeBackend, NativeTrainBackend, ProgramBackend,
    Resident,
};
use crate::obs::Counter;
use crate::serve::checkpoint::CheckpointStore;
use crate::tensor::Tensor;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// What a session runs — the parseable, comparable description a create
/// request carries. Every variant maps to exactly one [`CaProgram`] and
/// one board geometry, so two sessions with equal specs are guaranteed
/// batchable (same kernels, same shapes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProgramSpec {
    /// Elementary CA: a `[W]` ring under one Wolfram rule.
    Eca { rule: u8, width: usize },
    /// Conway's Game of Life on an `[H, W]` torus.
    Life { height: usize, width: usize },
    /// Single-kernel Lenia on an `[H, W]` torus (paper-default
    /// mu/sigma/dt; the radius picks sparse-tap vs spectral).
    Lenia { radius: usize, height: usize, width: usize },
    /// Multi-kernel spectral Lenia demo world (`LeniaWorld::demo`):
    /// K kernels cross-mixing `max(2, ceil(K/2))` channels.
    LeniaMulti { kernels: usize, radius: usize, height: usize, width: usize },
    /// The growing-NCA forward cell, wired from the native manifest
    /// programs: geometry from [`NcaTrainSpec::growing`], parameters
    /// from the `growing_params` blob, initial board from the
    /// `growing_seed` program.
    NcaGrowing,
}

/// Optional non-negative-integer JSON field: absent is `None`, present
/// with the wrong type is an ERROR — silently defaulting on a typo'd
/// `{"size": "512"}` would hand the client a board they did not ask
/// for. Shared by the create and step request parsers.
pub fn opt_usize(body: &Json, name: &str) -> Result<Option<usize>> {
    match body.get(name) {
        None => Ok(None),
        Some(v) => v.as_usize().map(Some).with_context(|| {
            format!("{name:?} wants a non-negative integer")
        }),
    }
}

/// The program families, in [`ProgramSpec::family_index`] order — the
/// per-family request-counter labels (`serve_requests_{family}_total`).
pub const FAMILIES: [&str; 5] = ["eca", "life", "lenia", "lenia_multi", "nca"];

/// Largest board axis a create request may ask for.
pub const MAX_DIM: usize = 8192;
/// Largest total cell count per session board (bounds the per-session
/// allocation a single unauthenticated request can trigger).
pub const MAX_CELLS: usize = 1 << 22;
/// Largest kernel count for a `lenia-multi` world (each kernel
/// precomputes an `H x W` spectrum per batch launch).
pub const MAX_KERNELS: usize = 16;

impl ProgramSpec {
    /// Parse a create-request JSON body, e.g.
    /// `{"program": "life", "height": 128, "width": 128}`.
    ///
    /// Geometry is bounded here ([`MAX_DIM`] per axis, [`MAX_CELLS`]
    /// total, [`MAX_KERNELS`] kernels) so a single request can never
    /// ask the server to allocate an unbounded board — the check runs
    /// before any allocation or registry lock.
    pub fn from_json(body: &Json) -> Result<ProgramSpec> {
        let kind = body
            .get("program")
            .and_then(Json::as_str)
            .context("create: body wants a \"program\" string \
                      (eca|life|lenia|lenia-multi|nca)")?;
        let dim = |name: &str, default: usize| -> Result<usize> {
            let value = match opt_usize(body, name)? {
                Some(v) => v,
                None => opt_usize(body, "size")?.unwrap_or(default),
            };
            if value > MAX_DIM {
                bail!("create: {name} {value} exceeds the {MAX_DIM} limit");
            }
            Ok(value)
        };
        let spec = Self::parse_kind(body, kind, &dim)?;
        let cells: usize = spec.board_shape().iter().product();
        if cells > MAX_CELLS {
            bail!(
                "create: board of {cells} cells exceeds the {MAX_CELLS} \
                 limit"
            );
        }
        Ok(spec)
    }

    fn parse_kind(body: &Json, kind: &str,
                  dim: &dyn Fn(&str, usize) -> Result<usize>)
                  -> Result<ProgramSpec> {
        Ok(match kind {
            "eca" => ProgramSpec::Eca {
                rule: match opt_usize(body, "rule")? {
                    None => 30,
                    Some(r) if r <= 255 => r as u8,
                    Some(r) => bail!("create: eca rule {r} > 255"),
                },
                width: dim("width", 256)?,
            },
            "life" => ProgramSpec::Life {
                height: dim("height", 64)?,
                width: dim("width", 64)?,
            },
            "lenia" => ProgramSpec::Lenia {
                radius: opt_usize(body, "radius")?
                    .unwrap_or(LeniaParams::default().radius),
                height: dim("height", 64)?,
                width: dim("width", 64)?,
            },
            "lenia-multi" => {
                let kernels = opt_usize(body, "kernels")?.unwrap_or(2);
                if !(1..=MAX_KERNELS).contains(&kernels) {
                    bail!(
                        "create: kernels {kernels} outside 1..={MAX_KERNELS}"
                    );
                }
                ProgramSpec::LeniaMulti {
                    kernels,
                    radius: opt_usize(body, "radius")?.unwrap_or(8),
                    height: dim("height", 64)?,
                    width: dim("width", 64)?,
                }
            }
            "nca" => ProgramSpec::NcaGrowing,
            other => bail!(
                "create: unknown program {other:?} \
                 (eca|life|lenia|lenia-multi|nca)"
            ),
        })
    }

    pub fn kind(&self) -> &'static str {
        match self {
            ProgramSpec::Eca { .. } => "eca",
            ProgramSpec::Life { .. } => "life",
            ProgramSpec::Lenia { .. } => "lenia",
            ProgramSpec::LeniaMulti { .. } => "lenia-multi",
            ProgramSpec::NcaGrowing => "nca",
        }
    }

    /// Index into [`FAMILIES`] — the metric-safe program-family label
    /// (`lenia_multi`, not `lenia-multi`) the serve counters key on.
    pub fn family_index(&self) -> usize {
        match self {
            ProgramSpec::Eca { .. } => 0,
            ProgramSpec::Life { .. } => 1,
            ProgramSpec::Lenia { .. } => 2,
            ProgramSpec::LeniaMulti { .. } => 3,
            ProgramSpec::NcaGrowing => 4,
        }
    }

    /// The shape-class key the coalescer groups by. Equal keys imply
    /// identical programs *and* identical board shapes (every field the
    /// kernels depend on is spelled into the key), so any two sessions
    /// in one class can ride one batched launch.
    pub fn class_key(&self) -> String {
        match self {
            ProgramSpec::Eca { rule, width } => format!("eca:r{rule}:w{width}"),
            ProgramSpec::Life { height, width } => {
                format!("life:{height}x{width}")
            }
            ProgramSpec::Lenia { radius, height, width } => {
                format!("lenia:r{radius}:{height}x{width}")
            }
            ProgramSpec::LeniaMulti { kernels, radius, height, width } => {
                format!("lenia-multi:k{kernels}:r{radius}:{height}x{width}")
            }
            ProgramSpec::NcaGrowing => "nca:growing".to_string(),
        }
    }

    /// Build the [`CaProgram`] this spec runs. Pure in the spec: equal
    /// specs always produce identical programs (the `nca` cell is
    /// rebuilt from the deterministic `growing_params` manifest blob).
    pub fn program(&self) -> Result<CaProgram> {
        Ok(match self {
            ProgramSpec::Eca { rule, .. } => {
                CaProgram::Eca { rule: WolframRule::new(*rule) }
            }
            ProgramSpec::Life { .. } => CaProgram::Life,
            ProgramSpec::Lenia { radius, .. } => CaProgram::Lenia {
                params: LeniaParams { radius: *radius, ..Default::default() },
            },
            ProgramSpec::LeniaMulti { kernels, radius, .. } => {
                CaProgram::LeniaMulti(LeniaWorld::demo(*kernels, *radius))
            }
            ProgramSpec::NcaGrowing => {
                let spec = NcaTrainSpec::growing();
                let tb = NativeTrainBackend::new();
                let params = tb.load_params("growing_params")?;
                CaProgram::Nca(NcaModel::from_flat(
                    spec.channels,
                    spec.hidden,
                    spec.dt,
                    params.data(),
                ))
            }
        })
    }

    /// Un-batched board shape of one session of this spec.
    pub fn board_shape(&self) -> Vec<usize> {
        match self {
            ProgramSpec::Eca { width, .. } => vec![*width],
            ProgramSpec::Life { height, width }
            | ProgramSpec::Lenia { height, width, .. } => {
                vec![*height, *width]
            }
            ProgramSpec::LeniaMulti { kernels, radius, height, width } => {
                let world = LeniaWorld::demo(*kernels, *radius);
                vec![world.channels, *height, *width]
            }
            ProgramSpec::NcaGrowing => {
                let spec = NcaTrainSpec::growing();
                vec![spec.height, spec.width, spec.channels]
            }
        }
    }

    /// Deterministic initial board for a session seed: a density-0.5
    /// binary soup for the classic CAs (the `cax sim` convention), the
    /// single-seed-cell `growing_seed` state for the NCA.
    pub fn initial_board(&self, seed: u64) -> Result<Tensor> {
        if let ProgramSpec::NcaGrowing = self {
            let tb = NativeTrainBackend::new();
            let out = tb.execute("growing_seed", &[])?;
            return first_output(out, "growing_seed");
        }
        let shape = self.board_shape();
        let numel: usize = shape.iter().product();
        let mut rng = Rng::new(seed);
        Tensor::new(shape, rng.binary_vec(numel, 0.5))
    }

    /// JSON description (session status responses).
    pub fn to_json(&self) -> Json {
        let shape = self.board_shape();
        let mut fields = vec![
            ("program", Json::from(self.kind())),
            ("class", Json::from(self.class_key().as_str())),
            ("shape", Json::Arr(shape.into_iter().map(Json::from).collect())),
        ];
        // Surface which native kernel this session's geometry selects
        // (the coordinator's crossover heuristic), so operators can see
        // why two Lenia sessions land in different batches.
        if let ProgramSpec::Lenia { radius, height, width } = self {
            fields.push((
                "kernel",
                Json::from(crate::coordinator::Simulator::lenia_native_path(
                    LeniaParams { radius: *radius, ..Default::default() },
                    *height,
                    *width,
                )),
            ));
        }
        fields.push(("step_path", Json::from(self.step_path())));
        obj(fields)
    }

    /// Which step path (`dense` / `sparse` / `hashlife`) the native
    /// activity cost model picks for one coalesced single-step tick of
    /// this session — the stepping analogue of the Lenia `kernel`
    /// field. Longer rollouts on big power-of-two boards may upgrade to
    /// `hashlife`; this reports the steps=1 decision, which is what the
    /// scheduler's ticks run.
    pub fn step_path(&self) -> &'static str {
        use crate::backend::native::activity;
        let shape = self.board_shape();
        match self {
            ProgramSpec::Eca { rule, .. } => {
                crate::coordinator::Simulator::native_step_path(
                    &CaProgram::Eca { rule: WolframRule::new(*rule) },
                    &shape,
                    1,
                )
            }
            ProgramSpec::Life { .. } => {
                crate::coordinator::Simulator::native_step_path(
                    &CaProgram::Life, &shape, 1)
            }
            ProgramSpec::Lenia { radius, .. } => {
                crate::coordinator::Simulator::native_step_path(
                    &CaProgram::Lenia {
                        params: LeniaParams {
                            radius: *radius,
                            ..Default::default()
                        },
                    },
                    &shape,
                    1,
                )
            }
            // The spectral world plan is global — always dense (the
            // selector says so without needing the built world).
            ProgramSpec::LeniaMulti { .. } => "dense",
            // NCA's selector is the on/off gate; answering from it
            // avoids loading the trained weights just for status.
            ProgramSpec::NcaGrowing => {
                if activity::enabled() {
                    "sparse"
                } else {
                    "dense"
                }
            }
        }
    }
}

/// First tensor of a program call's output batch, as a proper error
/// when the batch comes back empty. A backend handing back zero outputs
/// is an internal invariant violation, not a client mistake — the
/// message carries the `internal:` prefix the HTTP layer maps to a 500
/// (everything else defaults to 400), and the caller gets an `Err`
/// instead of the panic an `unwrap` here once was.
pub fn first_output(out: Vec<Tensor>, program: &str) -> Result<Tensor> {
    let mut it = out.into_iter();
    it.next().with_context(|| {
        format!("internal: program {program:?} returned an empty output batch")
    })
}

/// Refuse a board containing NaN or ±inf *at admission*. The f32
/// substrates are NaN-propagating (a single poisoned cell spreads to
/// its whole neighborhood every step and never washes out), so the only
/// safe place to stop one is before it becomes backend-resident. Both
/// [`SessionRegistry::create`] and [`SessionRegistry::reset`] run every
/// candidate board through this; the serve layer maps the error to a
/// 400.
pub fn ensure_finite(board: &Tensor) -> Result<()> {
    for (i, &v) in board.data().iter().enumerate() {
        if !v.is_finite() {
            bail!(
                "initial board is non-finite at flat index {i} ({v}); \
                 refusing the session"
            );
        }
    }
    Ok(())
}

/// One live session: spec, compiled program, resident state, counters.
#[derive(Clone, Debug)]
pub struct Session {
    pub id: u64,
    pub spec: ProgramSpec,
    pub prog: CaProgram,
    pub resident: Resident,
    /// Seed of the initial board (kept so `reset` replays it exactly).
    pub seed: u64,
    pub steps_done: u64,
}

impl Session {
    /// The wire form of a session id (16 hex digits).
    pub fn id_str(&self) -> String {
        fmt_id(self.id)
    }
}

pub fn fmt_id(id: u64) -> String {
    format!("{id:016x}")
}

pub fn parse_id(text: &str) -> Option<u64> {
    (text.len() == 16).then(|| u64::from_str_radix(text, 16).ok())?
}

/// All live sessions, with admission control and deterministic ids.
///
/// While the coalescer runs a batched launch, the launched sessions are
/// *detached* ([`take_for_step`](Self::take_for_step)) and marked busy,
/// so the registry lock is NOT held across kernel execution — other
/// endpoints keep working, and accesses to a busy session fail fast
/// with a retryable "busy" error instead of blocking.
///
/// With a [`CheckpointStore`] attached ([`set_store`](Self::set_store)),
/// `max_sessions` becomes a *working-set* cap instead of a hard limit:
/// a full registry evicts its least-recently-touched session to disk to
/// admit a new one, and any access to an evicted id lazily rehydrates
/// it ([`ensure_resident`](Self::ensure_resident)). Checkpoints are
/// bitwise round-trips (see [`crate::serve::checkpoint`]), so an
/// evicted-and-rehydrated trajectory is indistinguishable from a
/// never-evicted one.
#[derive(Debug)]
pub struct SessionRegistry {
    seed: u64,
    counter: u64,
    max_sessions: usize,
    sessions: BTreeMap<u64, Session>,
    /// Sessions currently detached into a batched launch.
    busy: BTreeSet<u64>,
    /// LRU clock: bumped on every touch; per-id last-touch stamps.
    clock: u64,
    recency: BTreeMap<u64, u64>,
    /// Durable home of evicted sessions; `None` = hard-cap behavior.
    store: Option<CheckpointStore>,
    /// Worker identity under the shard router: ids are minted so that
    /// `id % count == index`, letting the router route by id alone.
    shard: Option<(u64, u64)>,
    evictions: Option<Arc<Counter>>,
    rehydrations: Option<Arc<Counter>>,
}

impl SessionRegistry {
    pub fn new(seed: u64, max_sessions: usize) -> SessionRegistry {
        SessionRegistry {
            seed,
            counter: 0,
            max_sessions: max_sessions.max(1),
            sessions: BTreeMap::new(),
            busy: BTreeSet::new(),
            clock: 0,
            recency: BTreeMap::new(),
            store: None,
            shard: None,
            evictions: None,
            rehydrations: None,
        }
    }

    /// Attach the durable checkpoint store (and the eviction /
    /// rehydration counters it reports through), turning `max_sessions`
    /// into a working-set cap.
    pub fn set_store(&mut self, store: CheckpointStore,
                     evictions: Arc<Counter>, rehydrations: Arc<Counter>) {
        self.store = Some(store);
        self.evictions = Some(evictions);
        self.rehydrations = Some(rehydrations);
    }

    /// Constrain minted ids to `id % count == index` (shard-router
    /// worker identity).
    pub fn set_shard(&mut self, index: u64, count: u64) {
        assert!(count >= 1 && index < count, "shard {index}/{count}");
        self.shard = Some((index, count));
    }

    fn touch(&mut self, id: u64) {
        self.clock += 1;
        self.recency.insert(id, self.clock);
    }

    /// Live sessions, including ones detached into a running launch.
    pub fn len(&self) -> usize {
        self.sessions.len() + self.busy.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn max_sessions(&self) -> usize {
        self.max_sessions
    }

    /// Admit a new session. When the registry is full: with a store
    /// attached, the LRU resident session is evicted to disk to make
    /// room; without one, the create is refused. The id and (absent an
    /// explicit `seed`) the initial board derive from
    /// `(service seed, creation counter)` only.
    pub fn create(&mut self, backend: &NativeBackend, spec: ProgramSpec,
                  seed: Option<u64>) -> Result<u64> {
        if self.sessions.len() >= self.max_sessions {
            self.evict_lru()?;
        }
        if self.sessions.len() >= self.max_sessions {
            bail!(
                "session limit reached ({} live); destroy a session first",
                self.max_sessions
            );
        }
        let counter = self.counter;
        self.counter += 1;
        let mut id_rng = Rng::new(self.seed).fold_in(counter);
        let mut id = id_rng.next_u64();
        while id == 0
            || self.sessions.contains_key(&id)
            || self.busy.contains(&id)
            || self.shard.is_some_and(|(i, n)| id % n != i)
            || self.store.as_ref().is_some_and(|s| s.contains(id))
        {
            id = id_rng.next_u64();
        }
        let session_seed = seed.unwrap_or_else(|| {
            let mut r = Rng::new(self.seed).fold_in(counter ^ 0x5E55);
            r.next_u64()
        });
        let prog = spec.program()?;
        let board = spec.initial_board(session_seed)?;
        ensure_finite(&board).context("create")?;
        let resident = backend.admit(&prog, &board)?;
        self.sessions.insert(
            id,
            Session {
                id,
                spec,
                prog,
                resident,
                seed: session_seed,
                steps_done: 0,
            },
        );
        self.touch(id);
        Ok(id)
    }

    /// Evict the least-recently-touched resident session to the store.
    /// A no-op `Ok(false)` without a store or with nothing resident;
    /// busy (detached) sessions are not candidates — they are not in
    /// the map while a launch holds them.
    fn evict_lru(&mut self) -> Result<bool> {
        let Some(store) = &self.store else { return Ok(false) };
        let Some(id) = self
            .sessions
            .keys()
            .map(|&id| (self.recency.get(&id).copied().unwrap_or(0), id))
            .min()
            .map(|(_, id)| id)
        else {
            return Ok(false);
        };
        let session = self.sessions.get(&id).expect("victim is resident");
        store.save(session).context("evict")?;
        self.sessions.remove(&id);
        self.recency.remove(&id);
        if let Some(c) = &self.evictions {
            c.inc();
        }
        Ok(true)
    }

    /// Checkpoint-and-drop one session by id (operational/test hook for
    /// the LRU policy `create` and `trim_to_cap` apply automatically).
    pub fn evict(&mut self, id: u64) -> Result<()> {
        self.check_not_busy(id)?;
        let Some(store) = &self.store else {
            bail!("no state-dir configured; cannot evict");
        };
        let session = self
            .sessions
            .get(&id)
            .with_context(|| format!("no session {}", fmt_id(id)))?;
        store.save(session).context("evict")?;
        self.sessions.remove(&id);
        self.recency.remove(&id);
        if let Some(c) = &self.evictions {
            c.inc();
        }
        Ok(())
    }

    /// Bring an evicted session back into RAM (a no-op for resident or
    /// busy ids). `Ok(false)` means the id is unknown everywhere —
    /// callers fall through to their usual "no session" error.
    ///
    /// Rehydration may transiently overflow `max_sessions` (evicting
    /// here could victimize a session another request in the same tick
    /// is about to step); the scheduler trims back to the cap at the
    /// end of every tick via [`trim_to_cap`](Self::trim_to_cap).
    pub fn ensure_resident(&mut self, id: u64) -> Result<bool> {
        if self.sessions.contains_key(&id) || self.busy.contains(&id) {
            self.touch(id);
            return Ok(true);
        }
        let state = match &self.store {
            None => return Ok(false),
            Some(store) => match store.load(id)? {
                None => return Ok(false),
                Some(state) => state,
            },
        };
        let prog = state.spec.program()?;
        self.sessions.insert(
            id,
            Session {
                id,
                spec: state.spec,
                prog,
                // The decoded resident always carries `activity: None`:
                // stale dirty-tile maps never survive rehydration.
                resident: state.resident,
                seed: state.seed,
                steps_done: state.steps_done,
            },
        );
        self.touch(id);
        if let Some(c) = &self.rehydrations {
            c.inc();
        }
        Ok(true)
    }

    /// Evict LRU sessions until the resident count is back within
    /// `max_sessions`. Returns how many were evicted.
    pub fn trim_to_cap(&mut self) -> Result<usize> {
        let mut evicted = 0;
        while self.sessions.len() > self.max_sessions {
            if !self.evict_lru()? {
                break;
            }
            evicted += 1;
        }
        Ok(evicted)
    }

    /// Checkpoint every resident session (graceful-shutdown hook); the
    /// sessions stay resident. Returns how many were written, `0`
    /// without a store.
    pub fn checkpoint_all(&self) -> Result<usize> {
        let Some(store) = &self.store else { return Ok(0) };
        for session in self.sessions.values() {
            store.save(session).context("final checkpoint")?;
        }
        Ok(self.sessions.len())
    }

    /// Whether this id is currently resident in RAM (not evicted, not
    /// busy) — a test/observability hook.
    pub fn in_ram(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// Sessions evicted to disk and not currently resident.
    pub fn evicted(&self) -> usize {
        let Some(store) = &self.store else { return 0 };
        store
            .ids()
            .into_iter()
            .filter(|id| {
                !self.sessions.contains_key(id) && !self.busy.contains(id)
            })
            .count()
    }

    /// Every session this registry answers for: resident + busy +
    /// evicted-to-disk.
    pub fn total_sessions(&self) -> usize {
        self.len() + self.evicted()
    }

    /// Approximate bytes of backend-resident session state in RAM
    /// (payload vectors only). This is what the working-set cap bounds.
    pub fn resident_bytes(&self) -> usize {
        self.sessions
            .values()
            .map(|s| match &s.resident {
                Resident::Bits { words, .. } => words.len() * 8,
                Resident::Board { data, .. } => data.len() * 4,
                Resident::Host(t) => t.data().len() * 4,
            })
            .sum()
    }

    /// Whether a session is detached into a running launch.
    pub fn is_busy(&self, id: u64) -> bool {
        self.busy.contains(&id)
    }

    pub fn get(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    pub fn ids(&self) -> Vec<u64> {
        self.sessions.keys().copied().collect()
    }

    /// Error for accesses that must wait until the current launch
    /// restores the session.
    fn check_not_busy(&self, id: u64) -> Result<()> {
        if self.is_busy(id) {
            bail!("session {} is busy (stepping); retry", fmt_id(id));
        }
        Ok(())
    }

    /// Materialize a session's board as a host tensor (rehydrating an
    /// evicted one first).
    pub fn read_board(&mut self, backend: &NativeBackend, id: u64)
                      -> Result<Tensor> {
        self.check_not_busy(id)?;
        self.ensure_resident(id)?;
        let s = self
            .sessions
            .get(&id)
            .with_context(|| format!("no session {}", fmt_id(id)))?;
        backend.read_resident(&s.prog, &s.resident)
    }

    /// Rewind a session to its (seed-deterministic) initial board. The
    /// fresh `admit` also discards any accumulated activity map — a
    /// reset trajectory must re-observe the whole board, exactly as a
    /// brand-new session would.
    pub fn reset(&mut self, backend: &NativeBackend, id: u64) -> Result<()> {
        self.check_not_busy(id)?;
        self.ensure_resident(id)?;
        let s = self
            .sessions
            .get_mut(&id)
            .with_context(|| format!("no session {}", fmt_id(id)))?;
        let board = s.spec.initial_board(s.seed)?;
        ensure_finite(&board).context("reset")?;
        s.resident = backend.admit(&s.prog, &board)?;
        s.steps_done = 0;
        self.touch(id);
        Ok(())
    }

    /// Remove a session everywhere it lives: RAM, and (when a store is
    /// attached) its on-disk checkpoint — an evicted session can be
    /// destroyed without rehydrating it first.
    pub fn destroy(&mut self, id: u64) -> Result<()> {
        self.check_not_busy(id)?;
        let in_ram = self.sessions.remove(&id).is_some();
        self.recency.remove(&id);
        let on_disk = match &self.store {
            Some(store) => store.remove(id)?,
            None => false,
        };
        if in_ram || on_disk {
            Ok(())
        } else {
            bail!("no session {}", fmt_id(id));
        }
    }

    /// Detach a session for a batched step: it leaves the map and is
    /// marked busy, so the coalescer can drop the registry lock while
    /// the launch runs. [`restore`](Self::restore) brings it back.
    pub fn take_for_step(&mut self, id: u64) -> Option<Session> {
        let session = self.sessions.remove(&id)?;
        self.busy.insert(id);
        Some(session)
    }

    pub fn restore(&mut self, session: Session) {
        self.busy.remove(&session.id);
        let id = session.id;
        self.sessions.insert(id, session);
        self.touch(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_keys() {
        let spec = ProgramSpec::from_json(
            &Json::parse(r#"{"program": "life", "size": 32}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(spec, ProgramSpec::Life { height: 32, width: 32 });
        assert_eq!(spec.class_key(), "life:32x32");
        assert_eq!(spec.board_shape(), vec![32, 32]);

        let eca = ProgramSpec::from_json(
            &Json::parse(r#"{"program": "eca", "rule": 110, "width": 70}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(eca, ProgramSpec::Eca { rule: 110, width: 70 });
        assert_eq!(eca.board_shape(), vec![70]);

        assert!(ProgramSpec::from_json(
            &Json::parse(r#"{"program": "warp"}"#).unwrap()
        )
        .is_err());
        assert!(ProgramSpec::from_json(
            &Json::parse(r#"{"program": "eca", "rule": 300}"#).unwrap()
        )
        .is_err());
        assert!(ProgramSpec::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn create_geometry_is_bounded() {
        let parse = |text: &str| {
            ProgramSpec::from_json(&Json::parse(text).unwrap())
        };
        // Per-axis cap.
        let err = parse(r#"{"program": "eca", "width": 1000000}"#)
            .unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"));
        // Total-cell cap (each axis individually legal).
        let err = parse(r#"{"program": "life", "size": 3000}"#).unwrap_err();
        assert!(format!("{err:#}").contains("cells"));
        // Kernel-count cap.
        let err = parse(r#"{"program": "lenia-multi", "kernels": 100}"#)
            .unwrap_err();
        assert!(format!("{err:#}").contains("kernels"));
        // The biggest legal Life board still parses.
        assert!(parse(r#"{"program": "life", "size": 2048}"#).is_ok());
    }

    #[test]
    fn explicit_height_width_beat_size() {
        let spec = ProgramSpec::from_json(
            &Json::parse(
                r#"{"program": "lenia", "size": 64, "height": 32,
                    "width": 48, "radius": 5}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(
            spec,
            ProgramSpec::Lenia { radius: 5, height: 32, width: 48 }
        );
        // Lenia status JSON surfaces the crossover-selected kernel.
        let j = spec.to_json();
        assert_eq!(j.get("kernel").and_then(Json::as_str),
                   Some("sparse-tap"));
        // ... and the activity cost model's step path, for every family.
        let spath = j.get("step_path").and_then(Json::as_str).unwrap();
        assert!(spath == "sparse" || spath == "dense", "got {spath}");
        for (text, want_any) in [
            (r#"{"program": "eca", "width": 64}"#,
             &["sparse", "dense"][..]),
            (r#"{"program": "life", "size": 32}"#, &["sparse", "dense"]),
            (r#"{"program": "lenia-multi", "kernels": 2, "size": 32}"#,
             &["dense"]),
        ] {
            let spec =
                ProgramSpec::from_json(&Json::parse(text).unwrap()).unwrap();
            let got = spec
                .to_json()
                .get("step_path")
                .and_then(Json::as_str)
                .unwrap()
                .to_string();
            assert!(want_any.contains(&got.as_str()),
                    "{text}: step_path {got}");
        }
    }

    #[test]
    fn registry_ids_are_seed_deterministic() {
        let backend = NativeBackend::with_threads(1);
        let spec = ProgramSpec::Life { height: 8, width: 8 };
        let mut a = SessionRegistry::new(7, 16);
        let mut b = SessionRegistry::new(7, 16);
        for _ in 0..3 {
            let ia = a.create(&backend, spec.clone(), None).unwrap();
            let ib = b.create(&backend, spec.clone(), None).unwrap();
            assert_eq!(ia, ib);
            // Same seed stream => identical initial boards too.
            assert!(a
                .read_board(&backend, ia)
                .unwrap()
                .bit_eq(&b.read_board(&backend, ib).unwrap()));
        }
        let mut c = SessionRegistry::new(8, 16);
        let ic = c.create(&backend, spec, None).unwrap();
        assert_ne!(a.ids()[0], ic);
    }

    #[test]
    fn registry_enforces_admission_and_destroy_frees() {
        let backend = NativeBackend::with_threads(1);
        let spec = ProgramSpec::Eca { rule: 30, width: 16 };
        let mut reg = SessionRegistry::new(0, 2);
        let a = reg.create(&backend, spec.clone(), None).unwrap();
        let _b = reg.create(&backend, spec.clone(), None).unwrap();
        let err = reg.create(&backend, spec.clone(), None).unwrap_err();
        assert!(format!("{err:#}").contains("session limit"));
        reg.destroy(a).unwrap();
        assert!(reg.create(&backend, spec, None).is_ok());
        assert!(reg.destroy(a).is_err(), "double destroy must fail");
    }

    #[test]
    fn reset_replays_the_initial_board() {
        let backend = NativeBackend::with_threads(1);
        let mut reg = SessionRegistry::new(3, 4);
        let id = reg
            .create(&backend, ProgramSpec::Life { height: 12, width: 12 },
                    Some(0xFEED))
            .unwrap();
        let initial = reg.read_board(&backend, id).unwrap();
        // Step it a few times out-of-band, then reset. While detached
        // the session is busy: reads/reset/destroy fail fast.
        let mut s = reg.take_for_step(id).unwrap();
        assert!(reg.is_busy(id));
        assert!(reg.read_board(&backend, id).is_err());
        assert!(reg.destroy(id).is_err());
        let prog = s.prog.clone();
        backend.step_resident(&prog, &mut [&mut s.resident], 5).unwrap();
        reg.restore(s);
        assert!(!reg.is_busy(id));
        assert!(!reg.read_board(&backend, id).unwrap().bit_eq(&initial));
        reg.reset(&backend, id).unwrap();
        assert!(reg.read_board(&backend, id).unwrap().bit_eq(&initial));
    }

    #[test]
    fn admission_rejects_non_finite_boards() {
        let ok = Tensor::new(vec![2, 2], vec![0.0, 1.0, 0.5, 1.0e-40])
            .unwrap();
        assert!(ensure_finite(&ok).is_ok(), "denormals are admissible");
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let t = Tensor::new(vec![2, 2], vec![0.0, bad, 0.5, 1.0])
                .unwrap();
            let err = ensure_finite(&t).unwrap_err();
            assert!(format!("{err:#}").contains("non-finite"),
                    "error names the failure: {err:#}");
        }
    }

    #[test]
    fn empty_output_batch_is_an_internal_error_not_a_panic() {
        // Regression: this used to be `out.into_iter().next().unwrap()`,
        // so a backend returning an empty batch panicked the handler
        // thread instead of producing a response.
        let err = first_output(vec![], "growing_seed").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.starts_with("internal:"), "500-mapped prefix: {msg}");
        assert!(msg.contains("growing_seed"), "names the program: {msg}");
        let t = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        assert!(first_output(vec![t], "x").is_ok());
    }

    fn temp_store(tag: &str) -> (std::path::PathBuf, CheckpointStore) {
        let dir = std::env::temp_dir()
            .join(format!("cax-reg-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        (dir.clone(), CheckpointStore::open(&dir).unwrap())
    }

    fn counters() -> (Arc<Counter>, Arc<Counter>) {
        let reg = crate::obs::Registry::new();
        (reg.counter("ev"), reg.counter("re"))
    }

    #[test]
    fn full_registry_evicts_lru_when_a_store_is_attached() {
        let backend = NativeBackend::with_threads(1);
        let spec = ProgramSpec::Life { height: 8, width: 8 };
        let (dir, store) = temp_store("lru");
        let (ev, re) = counters();
        let mut reg = SessionRegistry::new(11, 2);
        reg.set_store(store, ev.clone(), re.clone());
        let a = reg.create(&backend, spec.clone(), Some(1)).unwrap();
        let b = reg.create(&backend, spec.clone(), Some(2)).unwrap();
        // Touch `a` so `b` is the LRU victim of the third create.
        let board_a = reg.read_board(&backend, a).unwrap();
        let c = reg.create(&backend, spec.clone(), Some(3)).unwrap();
        assert_eq!(ev.get(), 1);
        assert!(reg.in_ram(a) && reg.in_ram(c) && !reg.in_ram(b));
        assert_eq!(reg.evicted(), 1);
        assert_eq!(reg.total_sessions(), 3);
        // Touching the evicted session rehydrates it (and overflows the
        // cap until trim).
        assert!(reg.read_board(&backend, b).is_ok());
        assert_eq!(re.get(), 1);
        assert_eq!(reg.trim_to_cap().unwrap(), 1);
        assert_eq!(reg.len(), 2);
        // Rehydrated state is byte-equal where it matters.
        assert!(reg.read_board(&backend, a).unwrap().bit_eq(&board_a));
        // Destroy reaches evicted sessions on disk without rehydrating.
        for id in [a, b, c] {
            reg.destroy(id).unwrap();
        }
        assert_eq!(reg.total_sessions(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn minted_ids_respect_shard_identity() {
        let backend = NativeBackend::with_threads(1);
        let spec = ProgramSpec::Eca { rule: 30, width: 16 };
        for index in 0..3u64 {
            let mut reg = SessionRegistry::new(5, 16);
            reg.set_shard(index, 3);
            for _ in 0..4 {
                let id = reg.create(&backend, spec.clone(), None).unwrap();
                assert_eq!(id % 3, index, "id {id:#x} off-shard");
            }
        }
    }

    #[test]
    fn id_wire_format_roundtrips() {
        assert_eq!(parse_id(&fmt_id(0xABCDEF)), Some(0xABCDEF));
        assert_eq!(parse_id("zz"), None);
        assert_eq!(parse_id(""), None);
        assert_eq!(parse_id("0123456789abcdef"), Some(0x0123456789abcdef));
        assert_eq!(parse_id("0123456789abcdef0"), None, "too long");
    }
}
