//! Minimal JSON substrate (parser + writer) — serde is not available offline.
//!
//! Full JSON per RFC 8259 minus exotic escapes rarely produced by Python's
//! `json.dump`: supports objects, arrays, strings (with \uXXXX), numbers,
//! bools, null. Used to read `artifacts/manifest.json` and to write metric /
//! benchmark reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 { Some(n as usize) } else { None }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructor for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

/// `u64` counters (histogram counts, step totals) are emitted via
/// `f64`, which is exact up to 2^53 — unlike a `usize` cast, which
/// silently truncates on 32-bit targets.
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..(width * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let code = self.hex4()?;
                            // Surrogate pairs for chars outside the BMP.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let low = self.hex4()?;
                                let c = 0x10000
                                    + ((code - 0xD800) << 10)
                                    + (low - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(code)
                            };
                            s.push(ch.ok_or_else(
                                || self.err("invalid \\u escape"),
                            )?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut code = 0u32;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            code = code * 16 + d;
            self.pos += 1;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let text = r#"{"arr":[1,2.5,true,null,"s"],"o":{"k":-3}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = obj(vec![
            ("name", Json::from("eca")),
            ("shape", Json::Arr(vec![Json::from(4usize), Json::from(256usize)])),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn real_manifest_fragment() {
        let text = r#"{
          "preset": "test",
          "artifacts": [
            {"name": "eca_step", "file": "eca_step.hlo.txt",
             "inputs": [{"name": "state", "dtype": "f32", "shape": [4, 256]}],
             "outputs": [{"dtype": "f32", "shape": [4, 256]}],
             "meta": {"steps": 256, "mu": 0.15}}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        let art = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(art.get("name").unwrap().as_str(), Some("eca_step"));
        let shape = art.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap()[1].as_usize(), Some(256));
        assert_eq!(art.get("meta").unwrap().get("mu").unwrap().as_f64(),
                   Some(0.15));
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }
}
