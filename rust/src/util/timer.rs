//! Timing substrate shared by metrics and the bench harness.

use std::time::Instant;

/// Simple scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }
}

/// Summary statistics over a set of timing samples (seconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
    pub p99: f64,
    pub std_dev: f64,
}

impl Stats {
    /// Compute stats from raw samples. Panics on empty input.
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "Stats::from_samples: empty");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        Stats {
            n,
            mean,
            median: percentile(&sorted, 0.5),
            min: sorted[0],
            max: sorted[n - 1],
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            std_dev: var.sqrt(),
        }
    }
}

/// THE percentile rank convention: for `n` sorted samples and quantile
/// `q` in `[0, 1]`, the (possibly fractional) rank is `q * (n - 1)`.
/// Returns `(lo, hi, frac)` — interpolate `sample[lo] * (1 - frac) +
/// sample[hi] * frac`. Shared between [`Stats`] over raw samples and
/// [`crate::obs::Histogram`] quantile queries, so both report the same
/// statistic.
pub fn percentile_rank(n: usize, q: f64) -> (usize, usize, f64) {
    if n <= 1 {
        return (0, 0, 0.0);
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = (pos.ceil() as usize).min(n - 1);
    (lo, hi, pos - lo as f64)
}

/// Interpolated percentile of pre-sorted samples (0 for empty input).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let (lo, hi, frac) = percentile_rank(sorted.len(), q);
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Human-readable duration.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn stats_single_sample() {
        let s = Stats::from_samples(&[0.5]);
        assert_eq!(s.median, 0.5);
        assert_eq!(s.p95, 0.5);
        assert_eq!(s.std_dev, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.5), 5.0);
        assert_eq!(percentile(&sorted, 0.95), 9.5);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn p99_uses_the_shared_rank_convention() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Stats::from_samples(&samples);
        // rank = 0.99 * 99 = 98.01 -> between 99.0 and 100.0.
        assert!((s.p99 - 99.01).abs() < 1e-9, "p99 {}", s.p99);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
        let (lo, hi, frac) = percentile_rank(100, 0.99);
        assert_eq!((lo, hi), (98, 99));
        assert!((frac - 0.01).abs() < 1e-9);
        assert_eq!(percentile_rank(1, 0.99), (0, 0, 0.0));
    }

    #[test]
    #[should_panic]
    fn empty_samples_panic() {
        Stats::from_samples(&[]);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(2.5e-9).contains("ns"));
        assert!(fmt_duration(2.5e-5).contains("µs"));
        assert!(fmt_duration(2.5e-2).contains("ms"));
        assert!(fmt_duration(2.5).contains(" s"));
    }

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
