//! Deterministic PRNG substrate: SplitMix64 seeding + xoshiro256**.
//!
//! The offline environment has no `rand` crate; this is the project's single
//! randomness source. All coordinator-side stochasticity (datasets, pool
//! sampling, initial states) flows through [`Rng`], so every experiment is
//! reproducible from one `u64` seed recorded in its config.

/// xoshiro256** (Blackman & Vigna) seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Derive an independent stream (the coordinator's `fold_in`).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mut base = Rng::new(self.s[0] ^ data.rotate_left(17));
        base.s[1] ^= self.s[2];
        base.next_u64();
        base
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // Top 24 bits -> [0, 1) with full float precision.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range: empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        // Lemire's multiply-shift rejection-free-enough bound for our sizes.
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as usize)
    }

    /// Bernoulli with probability `p`.
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher-Yates: first k slots.
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of uniform f32 in [0, 1).
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f32()).collect()
    }

    /// Vector of {0.0, 1.0} with density `p`.
    pub fn binary_vec(&mut self, n: usize, p: f32) -> Vec<f32> {
        (0..n).map(|_| if self.bernoulli(p) { 1.0 } else { 0.0 }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.range(0, 10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            let x = r.range(5, 8);
            assert!((5..8).contains(&x));
        }
    }

    #[test]
    #[should_panic]
    fn empty_range_panics() {
        Rng::new(0).range(3, 3);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let mut idx = r.sample_indices(20, 8);
            idx.sort_unstable();
            idx.dedup();
            assert_eq!(idx.len(), 8);
            assert!(idx.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fold_in_streams_independent() {
        let base = Rng::new(5);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
