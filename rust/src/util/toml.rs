//! Minimal TOML subset parser for experiment config files.
//!
//! Supports exactly what `configs/*.toml` uses: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! bool / homogeneous-array values, `#` comments, and blank lines. Nested
//! inline tables and multi-line strings are intentionally out of scope.

use std::collections::BTreeMap;
use std::fmt;

/// A TOML scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: dotted-path section -> key -> value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Value at (`section`, `key`); the root section is "".
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|m| m.get(key))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key).and_then(|v| v.as_str())
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key).and_then(|v| v.as_usize())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key).and_then(|v| v.as_f64())
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key).and_then(|v| v.as_bool())
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TOML error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a config document.
pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    doc.sections.entry(String::new()).or_default();
    let mut section = String::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            section = name.to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        doc.sections
            .get_mut(&section)
            .unwrap()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn err(lineno: usize, msg: &str) -> TomlError {
    TomlError { line: lineno + 1, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue, TomlError> {
    if text.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(lineno, "embedded quote in string"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|part| parse_value(part.trim(), lineno))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Arr(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if text.contains('.') || text.contains('e') || text.contains('E') {
        if let Ok(f) = text.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = text.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    Err(err(lineno, &format!("cannot parse value {text:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = parse(
            r#"
            # experiment config
            name = "growing"
            seed = 42

            [train]
            steps = 500
            lr = 2e-3
            log_every = 10   # inline comment
            resume = false

            [pool]
            size = 64
            shape = [32, 32, 12]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name"), Some("growing"));
        assert_eq!(doc.get_usize("", "seed"), Some(42));
        assert_eq!(doc.get_usize("train", "steps"), Some(500));
        assert_eq!(doc.get_f64("train", "lr"), Some(2e-3));
        assert_eq!(doc.get_bool("train", "resume"), Some(false));
        let shape = doc.get("pool", "shape").unwrap();
        assert_eq!(
            shape,
            &TomlValue::Arr(vec![
                TomlValue::Int(32),
                TomlValue::Int(32),
                TomlValue::Int(12)
            ])
        );
    }

    #[test]
    fn dotted_sections() {
        let doc = parse("[a.b]\nx = 1\n").unwrap();
        assert_eq!(doc.get_usize("a.b", "x"), Some(1));
    }

    #[test]
    fn int_as_f64() {
        let doc = parse("x = 3\n").unwrap();
        assert_eq!(doc.get_f64("", "x"), Some(3.0));
    }

    #[test]
    fn underscored_ints() {
        let doc = parse("n = 1_024\n").unwrap();
        assert_eq!(doc.get_usize("", "n"), Some(1024));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("", "s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("x = \"open\n").is_err());
        assert!(parse("x = [1, 2\n").is_err());
        assert!(parse("x = what\n").is_err());
    }

    #[test]
    fn missing_lookups_are_none() {
        let doc = parse("x = 1\n").unwrap();
        assert_eq!(doc.get("nope", "x"), None);
        assert_eq!(doc.get("", "y"), None);
        assert_eq!(doc.get_bool("", "x"), None);
    }
}
