//! Mini property-testing framework (the proptest role, built in-tree).
//!
//! A property is a closure over a [`Gen`] (a seeded random source with
//! convenience generators). [`check`] runs it across many seeds and, on
//! failure, re-reports the failing seed so the case can be replayed
//! deterministically with [`replay`]. Shrinking is seed-based: the failing
//! seed is printed, and generators are size-parameterized so smaller `size`
//! values produce structurally smaller cases.

use crate::util::rng::Rng;

/// Random-input generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Structural size knob: generators should scale with it.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    /// usize in [lo, hi).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// A vector of f32 in [0,1) with length in [1, max_len].
    pub fn vec_f32(&mut self, max_len: usize) -> Vec<f32> {
        let len = self.usize_in(1, max_len.max(2));
        self.rng.vec_f32(len)
    }

    /// A binary (0.0/1.0) vector of exactly `len`.
    pub fn binary_vec(&mut self, len: usize) -> Vec<f32> {
        self.rng.binary_vec(len, 0.5)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }
}

/// Outcome of a [`check`] run.
#[derive(Debug)]
pub struct CheckFailure {
    pub seed: u64,
    pub case: usize,
    pub message: String,
}

impl std::fmt::Display for CheckFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed on case {} (replay seed {:#x}): {}",
            self.case, self.seed, self.message
        )
    }
}

/// Run `prop` across `cases` generated inputs. Properties return
/// `Err(message)` to fail, `Ok(())` to pass.
///
/// The per-case seed is derived from `base_seed` and the case index;
/// failures report it for deterministic replay.
pub fn check<F>(base_seed: u64, cases: usize, mut prop: F)
                -> Result<(), CheckFailure>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        // Grow structural size with case index: early cases are small
        // (cheap shrink-like behaviour), later ones larger.
        let size = 2 + (case * 30) / cases.max(1);
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut gen = Gen::new(seed, size);
        if let Err(message) = prop(&mut gen) {
            return Err(CheckFailure { seed, case, message });
        }
    }
    Ok(())
}

/// Re-run a property on the exact seed a failure reported.
pub fn replay<F>(seed: u64, size: usize, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut gen = Gen::new(seed, size);
    prop(&mut gen)
}

/// Assert-style helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |g| {
            let v = g.vec_f32(g.size + 2);
            prop_assert!(!v.is_empty(), "empty");
            prop_assert!(
                v.iter().all(|x| (0.0..1.0).contains(x)),
                "out of range"
            );
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn failing_property_reports_seed_and_replays() {
        let fail = check(2, 500, |g| {
            let n = g.usize_in(0, 100);
            prop_assert!(n != 37, "hit 37");
            Ok(())
        });
        let failure = fail.expect_err("should eventually hit 37");
        // Replay must reproduce the same failure.
        let replayed = replay(failure.seed, 0, |g| {
            let n = g.usize_in(0, 100);
            prop_assert!(n != 37, "hit 37");
            Ok(())
        });
        assert!(replayed.is_err());
        assert!(failure.to_string().contains("hit 37"));
    }

    #[test]
    fn sizes_grow_over_cases() {
        let mut max_seen = 0usize;
        let _ = check(3, 100, |g| {
            max_seen = max_seen.max(g.size);
            Ok(())
        });
        assert!(max_seen >= 20, "size should grow, saw max {max_seen}");
    }

    #[test]
    fn binary_vec_is_binary() {
        check(4, 50, |g| {
            let v = g.binary_vec(64);
            prop_assert!(
                v.iter().all(|&x| x == 0.0 || x == 1.0),
                "non-binary value"
            );
            Ok(())
        })
        .unwrap();
    }
}
