//! Hand-rolled substrates: the offline environment provides only the `xla`
//! crate, so the JSON/TOML/RNG/property-test/timing layers live here.
//! See DESIGN.md §4.4.

pub mod check;
pub mod json;
pub mod rng;
pub mod timer;
pub mod toml;
