//! Hand-rolled substrates: the offline environment provides only the `xla`
//! crate, so the JSON/TOML/RNG/property-test/timing layers live here.
//! See rust/README.md.

pub mod check;
pub mod json;
pub mod rng;
pub mod timer;
pub mod toml;
