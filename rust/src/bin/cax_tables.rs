//! `cax-tables` — regenerate every table and figure of the paper's
//! evaluation (rust/README.md experiment index).
//!
//!   cax-tables fig3     Fig. 3 left+right: fused vs stepwise vs naive
//!   cax-tables table1   Table 1: the CA coverage matrix (registry status)
//!   cax-tables table2   Table 2: 1D-ARC accuracy, NCA vs GPT-4 constants
//!   cax-tables fig5     Fig. 5: damage/regeneration, growing vs diffusing
//!   cax-tables fig8     Fig. 8: per-task space-time diagrams (PPM files)
//!   cax-tables all      everything above
//!
//! Flags: --artifacts DIR  --out DIR  --seed N  --quick (smaller sweeps)
//!        --train-steps N  --tasks move-1,fill,...  (table2/fig8 subset)

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use cax::automata::WolframRule;
use cax::coordinator::trainer::TrainCfg;
use cax::coordinator::damage::{self, DamageMode};
use cax::coordinator::{evaluator, experiments, registry};
use cax::coordinator::{Path as SimPath, Simulator};
use cax::datasets::arc1d::Task;
use cax::datasets::targets::Sprite;
use cax::metrics::BenchRow;
use cax::runtime::{Engine, Value};
use cax::util::rng::Rng;
use cax::util::timer::{fmt_duration, Stats, Timer};
use cax::viz::spacetime;

struct Opt {
    artifacts: PathBuf,
    out: PathBuf,
    seed: u64,
    quick: bool,
    train_steps: Option<usize>,
    tasks: Option<Vec<String>>,
    cmd: String,
}

fn parse_opt() -> Result<Opt> {
    let mut opt = Opt {
        artifacts: std::env::var("CAX_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts")),
        out: PathBuf::from("out"),
        seed: 42,
        quick: false,
        train_steps: None,
        tasks: None,
        cmd: String::new(),
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--artifacts" => {
                opt.artifacts =
                    PathBuf::from(it.next().context("--artifacts value")?)
            }
            "--out" => {
                opt.out = PathBuf::from(it.next().context("--out value")?)
            }
            "--seed" => opt.seed = it.next().context("--seed value")?.parse()?,
            "--quick" => opt.quick = true,
            "--train-steps" => {
                opt.train_steps =
                    Some(it.next().context("--train-steps value")?.parse()?)
            }
            "--tasks" => {
                opt.tasks = Some(
                    it.next()
                        .context("--tasks value")?
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .collect(),
                )
            }
            other if opt.cmd.is_empty() => opt.cmd = other.to_string(),
            other => bail!("unexpected argument {other:?}"),
        }
    }
    if opt.cmd.is_empty() {
        bail!("usage: cax-tables <fig3|table1|table2|fig5|fig8|all> \
               [--quick] [--seed N] [--out DIR] [--train-steps N]");
    }
    Ok(opt)
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<()> {
    let opt = parse_opt()?;
    let engine = Engine::load(&opt.artifacts).with_context(|| {
        format!("loading artifacts from {}", opt.artifacts.display())
    })?;
    std::fs::create_dir_all(&opt.out)?;
    match opt.cmd.as_str() {
        "fig3" => fig3(&engine, &opt)?,
        "table1" => table1(&engine)?,
        "table2" => table2(&engine, &opt)?,
        "fig5" => fig5(&engine, &opt)?,
        "fig8" => fig8(&engine, &opt)?,
        "all" => {
            table1(&engine)?;
            fig3(&engine, &opt)?;
            fig5(&engine, &opt)?;
            table2(&engine, &opt)?;
            fig8(&engine, &opt)?;
        }
        other => bail!("unknown report {other:?}"),
    }
    Ok(())
}

// ---------------------------------------------------------------- helpers

/// Measure a closure `iters` times after `warmup` runs; seconds per call.
fn measure<F: FnMut() -> Result<()>>(warmup: usize, iters: usize, mut f: F)
                                     -> Result<Stats> {
    for _ in 0..warmup {
        f()?;
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f()?;
        samples.push(t.elapsed_secs());
    }
    Ok(Stats::from_samples(&samples))
}

// ------------------------------------------------------------------ fig3

/// Fig. 3: simulation-speed comparison across the three execution paths.
fn fig3(engine: &Engine, opt: &Opt) -> Result<()> {
    let sim = Simulator::new(engine);
    let mut rng = Rng::new(opt.seed);
    let (warm, iters) = if opt.quick { (1, 3) } else { (2, 8) };

    println!("\n=== Figure 3 (left): classic-CA simulation speed ===");
    println!("{:<8} {:<16} {:>10} {:>14} {:>10}", "CA", "path", "median s",
             "cell-upd/s", "speedup");
    let mut rows: Vec<BenchRow> = vec![];

    // Prefer the bench-scale artifacts when the manifest carries them
    // (the tiny test-preset grids sit below the vectorization crossover).
    let has = |n: &str| engine.manifest().artifacts.contains_key(n);
    let eca_arts = if has("eca_rollout_bench") {
        ("eca_step_bench", "eca_rollout_bench")
    } else {
        ("eca_step", "eca_rollout")
    };
    let life_arts = if has("life_rollout_bench") {
        ("life_step_bench", "life_rollout_bench")
    } else {
        ("life_step", "life_rollout")
    };

    for (ca, step_art, artifact) in [
        ("eca", eca_arts.0, eca_arts.1),
        ("life", life_arts.0, life_arts.1),
        ("lenia", "lenia_step", "lenia_rollout"),
    ] {
        let steps = engine
            .manifest()
            .artifact(artifact)?
            .meta_usize("steps")
            .unwrap_or(256);
        let state = sim.random_state(artifact, &mut rng)?;
        let updates = sim.cell_updates(artifact, steps)?;
        let rule = WolframRule::new(30);

        let mut path_time = [0.0f64; 4];
        for (pi, path) in [
            SimPath::Fused,
            SimPath::Stepwise,
            SimPath::Naive,
            SimPath::Native,
        ]
        .into_iter()
        .enumerate()
        {
            // Naive Lenia is O(R^2) per cell and the bench-scale stepwise
            // paths pay T dispatches; trim their iteration counts.
            let it = if path == SimPath::Naive && ca == "lenia" {
                iters.min(2)
            } else if path == SimPath::Stepwise {
                iters.min(4)
            } else {
                iters
            };
            let stats = measure(warm.min(1), it, || {
                match ca {
                    "eca" => sim.run_eca_named(step_art, artifact, path,
                                               &state, rule, steps)?,
                    "life" => sim.run_life_named(step_art, artifact, path,
                                                 &state, steps)?,
                    _ => sim.run_lenia(path, &state, steps)?,
                };
                Ok(())
            })?;
            path_time[pi] = stats.median;
            let speedup = path_time[0] / stats.median.max(1e-12);
            println!(
                "{:<8} {:<16} {:>10.4} {:>14.3e} {:>9.1}x",
                ca, path.name(), stats.median, updates / stats.median,
                1.0 / speedup.max(1e-12)
            );
            rows.push(BenchRow {
                label: format!("{ca}/{}", path.name()),
                items_per_iter: updates,
                stats,
            });
        }
        println!(
            "  -> CAX-fused speedup: {:.0}x vs naive, {:.1}x vs stepwise; \
             native-bitpacked: {:.0}x vs naive",
            path_time[2] / path_time[0].max(1e-12),
            path_time[1] / path_time[0].max(1e-12),
            path_time[2] / path_time[3].max(1e-12)
        );
        // The paper's actual comparator is CellPyLib (pure-Python per-cell
        // dispatch), measured at build time by compile/pybaseline.py.
        if let Some(py) = cax::metrics::read_py_baseline(&opt.artifacts) {
            let py_ups = match ca {
                "eca" => Some(py.eca_updates_per_s),
                "life" => Some(py.life_updates_per_s),
                _ => None,
            };
            if let Some(py_ups) = py_ups {
                let fused_ups = updates / path_time[0].max(1e-12);
                println!(
                    "  -> vs pure-Python per-cell baseline (CellPyLib cost \
                     model, {py_ups:.2e} upd/s): {:.0}x",
                    fused_ups / py_ups
                );
            }
        }
    }

    println!("\n=== Figure 3 (right): NCA training speed (MNIST) ===");
    let train_steps = opt.train_steps.unwrap_or(if opt.quick { 4 } else { 12 });
    let fused = measure(1, train_steps, fig3_fused_step(engine, opt.seed)?)?;
    let stepw =
        measure(1, train_steps.min(6), fig3_stepwise_step(engine, opt.seed)?)?;
    println!("{:<24} {:>12} {:>12}", "path", "median s/step", "speedup");
    println!("{:<24} {:>12.4} {:>11.2}x", "cax-fused", fused.median, 1.0);
    println!("{:<24} {:>12.4} {:>11.2}x", "stepwise-dispatch (TF-proxy)",
             stepw.median, stepw.median / fused.median.max(1e-12));
    println!("(paper reports 1.5x over the official TensorFlow impl)");

    rows.push(BenchRow { label: "mnist-train/fused".into(),
                         items_per_iter: 1.0, stats: fused });
    rows.push(BenchRow { label: "mnist-train/stepwise".into(),
                         items_per_iter: 1.0, stats: stepw });
    cax::metrics::write_bench_report("fig3", &rows,
                                     &opt.out.join("fig3.json"))?;
    println!("wrote {}", opt.out.join("fig3.json").display());
    Ok(())
}

/// Closure running one fused MNIST train step (fresh state per call is
/// amortized into the closure's captured buffers).
fn fig3_fused_step(engine: &Engine, seed: u64)
                   -> Result<impl FnMut() -> Result<()> + '_> {
    use cax::coordinator::trainer::TrainState;
    use cax::datasets::mnist::{self, MnistConfig};
    let info = engine.manifest().artifact("mnist_train_step")?;
    let spec = &info.inputs[4];
    let (b, h, w) = (spec.shape[0], spec.shape[1], spec.shape[2]);
    let digits = mnist::dataset(b, &MnistConfig::for_grid(h, w), seed);
    let refs: Vec<&mnist::Digit> = digits.iter().collect();
    let images = mnist::batch_images(&refs);
    let labels = mnist::batch_labels(&refs);
    let mut st = TrainState::from_blob(engine, "mnist_params")?;
    let mut seed_ctr = seed as u32;
    Ok(move || {
        seed_ctr = seed_ctr.wrapping_add(1);
        let out = engine.execute(
            "mnist_train_step",
            &[
                Value::F32(st.params.clone()),
                Value::F32(st.m.clone()),
                Value::F32(st.v.clone()),
                Value::I32(st.step),
                Value::F32(images.clone()),
                Value::F32(labels.clone()),
                Value::U32(seed_ctr),
            ],
        )?;
        let mut it = out.into_iter();
        st.params = it.next().unwrap();
        st.m = it.next().unwrap();
        st.v = it.next().unwrap();
        st.step += 1;
        Ok(())
    })
}

/// Closure running one host-driven BPTT step (the TF-proxy baseline).
fn fig3_stepwise_step(engine: &Engine, seed: u64)
                      -> Result<impl FnMut() -> Result<()> + '_> {
    use cax::coordinator::stepwise::mnist_stepwise_train_step;
    use cax::coordinator::trainer::TrainState;
    use cax::datasets::mnist::{self, MnistConfig};
    let info = engine.manifest().artifact("mnist_step_fwd")?;
    let spec = &info.inputs[1];
    let (b, h, w) = (spec.shape[0], spec.shape[1], spec.shape[2]);
    let digits = mnist::dataset(b, &MnistConfig::for_grid(h, w), seed);
    let refs: Vec<&mnist::Digit> = digits.iter().collect();
    let images = mnist::batch_images(&refs);
    let labels = mnist::batch_labels(&refs);
    let mut st = TrainState::from_blob(engine, "mnist_params")?;
    let mut seed_ctr = seed as u32;
    Ok(move || {
        seed_ctr = seed_ctr.wrapping_add(1);
        mnist_stepwise_train_step(
            engine, &mut st.params, &mut st.m, &mut st.v, st.step, &images,
            &labels, 1e-3, seed_ctr,
        )?;
        st.step += 1;
        Ok(())
    })
}

// ---------------------------------------------------------------- table1

fn table1(engine: &Engine) -> Result<()> {
    println!("\n=== Table 1: implemented cellular automata ===");
    println!("{:<46} {:<11} {:<5} {}", "Cellular Automata", "Type", "Dims",
             "artifacts");
    let missing = registry::missing_artifacts(engine.manifest());
    for e in registry::table1() {
        let ok = !missing.iter().any(|m| m.starts_with(&format!("{}:", e.key)));
        println!("{:<46} {:<11} {:<5} {}", e.label, e.ca_type.name(),
                 e.dimensions, if ok { "ready" } else { "MISSING" });
    }
    if !missing.is_empty() {
        bail!("missing artifacts: {missing:?}");
    }
    Ok(())
}

// ---------------------------------------------------------------- table2

fn selected_tasks(opt: &Opt) -> Vec<Task> {
    match &opt.tasks {
        None => Task::ALL.to_vec(),
        Some(names) => Task::ALL
            .iter()
            .copied()
            .filter(|t| {
                let slug = t.name().to_lowercase().replace(' ', "-");
                names.iter().any(|n| {
                    n.to_lowercase() == slug
                        || t.name().eq_ignore_ascii_case(n)
                })
            })
            .collect(),
    }
}

/// Table 2: per-task 1D-ARC accuracy. One NCA trained per task from the
/// shared initialization, evaluated by exact match on a held-out split.
fn table2(engine: &Engine, opt: &Opt) -> Result<()> {
    let tasks = selected_tasks(opt);
    let (train_n, test_n, steps) = if opt.quick {
        (64, 25, opt.train_steps.unwrap_or(200))
    } else {
        // 1200 steps ~ the knee of the accuracy/time curve on this CPU;
        // the long-range tasks (pattern copy, move-towards) keep improving
        // to the 2000-step lr-schedule horizon (see EXPERIMENTS.md E5).
        (160, 50, opt.train_steps.unwrap_or(1200))
    };
    println!("\n=== Table 2: 1D-ARC accuracy (NCA vs GPT-4) ===");
    println!("({} tasks, {} train / {} test examples, {} train steps)",
             tasks.len(), train_n, test_n, steps);
    println!("{:<28} {:>7} {:>7} {:>7}", "Task", "GPT-4", "NCA",
             "paper-NCA");

    let total_t = Timer::start();
    let mut gpt_sum = 0.0;
    let mut nca_sum = 0.0;
    let mut paper_sum = 0.0;
    let mut csv = String::from("task,gpt4,nca,paper_nca\n");
    for &task in &tasks {
        let cfg = TrainCfg {
            steps,
            seed: opt.seed as u32,
            log_every: 0,
            out_dir: None,
        };
        let (train_set, test_set) =
            experiments::arc_split(engine, task, train_n, test_n, opt.seed)?;
        let run = experiments::train_arc(engine, &cfg, task, &train_set)?;
        let acc =
            evaluator::arc_accuracy(engine, &run.state.params, &test_set)?
                * 100.0;
        println!("{:<28} {:>6.0}% {:>6.1}% {:>6.0}%", task.name(),
                 task.gpt4_accuracy(), acc, task.paper_nca_accuracy());
        gpt_sum += task.gpt4_accuracy();
        nca_sum += acc;
        paper_sum += task.paper_nca_accuracy();
        csv.push_str(&format!("{},{},{:.2},{}\n", task.name(),
                              task.gpt4_accuracy(), acc,
                              task.paper_nca_accuracy()));
    }
    let n = tasks.len() as f64;
    println!("{:<28} {:>6.2}% {:>6.2}% {:>6.2}%", "Total", gpt_sum / n,
             nca_sum / n, paper_sum / n);
    println!("(paper Table 2 totals: GPT-4 41.56%, NCA 60.12%; ran in {})",
             fmt_duration(total_t.elapsed_secs()));
    csv.push_str(&format!("Total,{:.2},{:.2},{:.2}\n", gpt_sum / n,
                          nca_sum / n, paper_sum / n));
    let path = opt.out.join("table2.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {}", path.display());
    Ok(())
}

// ------------------------------------------------------------------ fig5

/// Fig. 5: train growing + diffusing NCAs on the same lizard target, then
/// amputate the tail and compare recovery.
fn fig5(engine: &Engine, opt: &Opt) -> Result<()> {
    let steps = opt.train_steps.unwrap_or(if opt.quick { 150 } else { 2000 });
    println!("\n=== Figure 5: damage / regeneration ===");
    println!("(training both NCAs for {steps} steps first)");
    let cfg = TrainCfg {
        steps,
        seed: opt.seed as u32,
        log_every: 0,
        out_dir: None,
    };

    // Growing NCA: develop from the seed cell.
    let (grow_run, _pool) = experiments::train_growing(engine, &cfg, 64)?;
    let seed_state = experiments::growing_seed(engine)?;
    let grow_info = engine.manifest().artifact("growing_rollout")?;
    let gshape = &grow_info.inputs[1].shape;
    let grow_target = Sprite::Lizard.render(gshape[0], gshape[1]);
    // Growing: several rollouts to develop from the seed cell, then the
    // same horizon to (attempt to) recover.
    let grow_rounds = if opt.quick { 2 } else { 4 };
    let grow_report = damage::run_damage_trial(
        engine, "growing_rollout", &grow_run.state.params, seed_state,
        &grow_target, grow_rounds, grow_rounds, false, DamageMode::Noise,
        opt.seed as u32,
    )?;

    // Diffusing NCA: the Fig.-5 claim is about the attractor around the
    // *developed* pattern. Develop with one denoising pass from a
    // moderately-noised target (level 0.4, inside the training
    // distribution — full from-noise generation needs paper-scale
    // channels/steps), then amputate and run two recovery passes.
    let diff_run = experiments::train_diffusing(engine, &cfg)?;
    let diff_info = engine.manifest().artifact("diffusing_rollout")?;
    let dshape = &diff_info.inputs[1].shape;
    let diff_target = Sprite::Lizard.render(dshape[0], dshape[1]);
    let mixed = experiments::diffusing_mixed_state(engine, &diff_target,
                                                   0.4, opt.seed)?;
    let diff_report = damage::run_damage_trial(
        engine, "diffusing_rollout", &diff_run.state.params, mixed,
        &diff_target, 1, 2, true, DamageMode::Noise, opt.seed as u32,
    )?;

    println!("{:<12} {:>12} {:>12} {:>12} {:>10}", "NCA", "pre-dmg MSE",
             "post-dmg MSE", "recovered", "healed");
    for (name, r) in [("growing", &grow_report), ("diffusing", &diff_report)] {
        println!("{:<12} {:>12.5} {:>12.5} {:>12.5} {:>9.0}%", name,
                 r.pre_damage_mse, r.post_damage_mse, r.recovered_mse,
                 100.0 * r.recovery_fraction());
    }
    println!("(paper claim: diffusing heals, plain growing is unstable)");

    let mut csv = String::from("nca,step,mse\n");
    for (name, r) in [("growing", &grow_report), ("diffusing", &diff_report)] {
        for (i, v) in r.curve.iter().enumerate() {
            csv.push_str(&format!("{name},{i},{v:.6}\n"));
        }
    }
    let path = opt.out.join("fig5_recovery.csv");
    std::fs::write(&path, csv)?;
    println!("wrote {}", path.display());
    Ok(())
}

// ------------------------------------------------------------------ fig8

/// Fig. 8: space-time diagrams of trained ARC NCAs, one PPM per task.
fn fig8(engine: &Engine, opt: &Opt) -> Result<()> {
    let tasks = selected_tasks(opt);
    let steps = opt.train_steps.unwrap_or(if opt.quick { 200 } else { 800 });
    println!("\n=== Figure 8: 1D-ARC space-time diagrams ===");
    let info = engine.manifest().artifact("arc_traj")?;
    let w = info.inputs[1].shape[0];

    for &task in &tasks {
        let cfg = TrainCfg {
            steps,
            seed: opt.seed as u32,
            log_every: 0,
            out_dir: None,
        };
        let (train_set, test_set) =
            experiments::arc_split(engine, task, 96, 4, opt.seed)?;
        let run = experiments::train_arc(engine, &cfg, task, &train_set)?;
        let example = &test_set[0];
        let rows: Vec<&[u8]> = vec![example.input.as_slice()];
        let input1h = cax::datasets::arc1d::one_hot_batch(&rows, w)
            .index_axis0(0);
        let out = engine.execute(
            "arc_traj",
            &[Value::F32(run.state.params.clone()), Value::F32(input1h)],
        )?;
        let img = spacetime::render_spacetime_arc(&out[0])?;
        let slug = task.name().to_lowercase().replace(' ', "-");
        let path = opt.out.join(format!("fig8_{slug}.ppm"));
        img.upscale(6).write_ppm(&path)?;
        println!("  {:<28} -> {}", task.name(), path.display());
    }
    Ok(())
}
