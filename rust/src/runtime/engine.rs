//! PJRT execution engine: load HLO-text artifacts, compile once, execute
//! from the Layer-3 hot path.
//!
//! The `xla` crate wraps the PJRT C API; HLO **text** is the interchange
//! format (see aot.py / DESIGN.md §4.2). Executables are compiled lazily on
//! first use and cached for the process lifetime; every call is validated
//! against the manifest signature before any FFI happens, so shape bugs
//! surface as precise Rust errors rather than XLA aborts.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::backend::Value;
use crate::runtime::manifest::{ArtifactInfo, Dtype, Manifest};
use crate::tensor::Tensor;

/// XLA-literal marshalling for [`Value`] (defined in `backend`; the
/// PJRT-specific conversion lives with the PJRT code).
trait ToLiteral {
    fn to_literal(&self) -> Result<xla::Literal>;
}

impl ToLiteral for Value {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Value::F32(t) => {
                // Single copy host->literal (vec1 + reshape would copy
                // twice — measurable on train-step params buffers).
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        t.data().as_ptr() as *const u8,
                        t.data().len() * 4,
                    )
                };
                Ok(xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    t.shape(),
                    bytes,
                )?)
            }
            Value::I32(v) => Ok(xla::Literal::scalar(*v)),
            Value::U32(v) => Ok(xla::Literal::scalar(*v)),
        }
    }
}

/// Cumulative execution statistics (perf instrumentation, DESIGN.md §7).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
    pub compile_secs: f64,
    pub execute_secs: f64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// The engine. All PJRT state is created and used on the owning thread.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            compiled: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.compiled.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.artifact_path(name)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.borrow_mut();
            stats.compiles += 1;
            stats.compile_secs += dt;
        }
        self.compiled.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Validate inputs against the manifest signature.
    fn validate(&self, info: &ArtifactInfo, inputs: &[Value]) -> Result<()> {
        if inputs.len() != info.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs ({}), got {}",
                info.name,
                info.inputs.len(),
                info.inputs
                    .iter()
                    .map(|s| s.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", "),
                inputs.len()
            );
        }
        for (value, spec) in inputs.iter().zip(&info.inputs) {
            if value.dtype() != spec.dtype {
                bail!(
                    "artifact {} input {:?}: dtype {} != manifest {}",
                    info.name, spec.name,
                    value.dtype().name(), spec.dtype.name()
                );
            }
            if value.shape() != spec.shape {
                bail!(
                    "artifact {} input {:?}: shape {:?} != manifest {:?}",
                    info.name, spec.name, value.shape(), spec.shape
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact; returns one `Tensor` per manifest output.
    pub fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        // Borrow (not clone) the signature: this runs on every dispatch.
        let info = self.manifest.artifact(name)?;
        self.validate(info, inputs)?;
        self.ensure_compiled(name)?;

        let literals = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<Vec<_>>>()?;

        let t0 = std::time::Instant::now();
        let compiled = self.compiled.borrow();
        let exe = compiled.get(name).expect("ensure_compiled filled cache");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {name}"))?;
        // aot.py lowers with return_tuple=True: one tuple literal out.
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        let dt = t0.elapsed().as_secs_f64();

        if parts.len() != info.outputs.len() {
            bail!(
                "artifact {name}: runtime returned {} outputs, manifest says {}",
                parts.len(),
                info.outputs.len()
            );
        }
        let mut outputs = Vec::with_capacity(parts.len());
        let mut bytes_out = 0u64;
        for (part, spec) in parts.into_iter().zip(&info.outputs) {
            let data: Vec<f32> = match spec.dtype {
                Dtype::F32 => part.to_vec::<f32>()?,
                // All current artifacts return f32; keep the door open.
                Dtype::I32 => part
                    .to_vec::<i32>()?
                    .into_iter()
                    .map(|v| v as f32)
                    .collect(),
                Dtype::U32 => part
                    .to_vec::<u32>()?
                    .into_iter()
                    .map(|v| v as f32)
                    .collect(),
            };
            bytes_out += (data.len() * 4) as u64;
            outputs.push(Tensor::new(spec.shape.clone(), data).with_context(
                || format!("artifact {name}: output shape mismatch"),
            )?);
        }

        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.execute_secs += dt;
        stats.bytes_in += inputs
            .iter()
            .map(|v| match v {
                Value::F32(t) => (t.numel() * 4) as u64,
                _ => 4,
            })
            .sum::<u64>();
        stats.bytes_out += bytes_out;
        Ok(outputs)
    }

    /// Load an initial-parameter blob as a rank-1 tensor.
    pub fn load_params(&self, blob: &str) -> Result<Tensor> {
        let data = self.manifest.load_blob(blob)?;
        let n = data.len();
        Tensor::new(vec![n], data)
    }
}
