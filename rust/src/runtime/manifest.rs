//! `artifacts/manifest.json` — the Layer-2 -> Layer-3 contract.
//!
//! The AOT compiler (python/compile/aot.py) records every artifact's file,
//! typed input/output signature and experiment metadata, plus the initial
//! parameter blobs. This module parses it (via the in-tree JSON substrate)
//! into typed structures the engine validates calls against.
//!
//! A [`Manifest`] is not tied to artifact *files*: the native training
//! backend builds one in memory (`file: "<native>"`) describing its own
//! programs, so the coordinator layers introspect native and PJRT
//! backends identically. Program names are the cross-backend currency —
//! `growing_seed`, `growing_train_step`, `mnist_train_step`,
//! `arc_train_step`, `arc_eval`, `arc_traj` carry the same signatures
//! everywhere (see the contract table on
//! [`ProgramBackend`](crate::backend::ProgramBackend)); callers read
//! batch geometry from [`ArtifactInfo::inputs`] and scenario metadata
//! (`"ca"`, `"steps"`, `"channels"`, `"hidden"`, `"batch"`) from
//! [`ArtifactInfo::meta`] rather than hard-coding shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element dtype crossing the artifact boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u32" => Ok(Dtype::U32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
            Dtype::U32 => "u32",
        }
    }
}

/// One typed argument or result slot.
#[derive(Clone, Debug, PartialEq)]
pub struct Spec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl Spec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Spec>,
    pub outputs: Vec<Spec>,
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactInfo {
    /// Metadata integer (steps, batch, channels, ...).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }

    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|v| v.as_f64())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }
}

/// An initial-parameter (or constant) blob.
#[derive(Clone, Debug)]
pub struct BlobInfo {
    pub name: String,
    pub file: String,
    pub shape: Vec<usize>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub blobs: BTreeMap<String, BlobInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        let preset = root
            .get("preset")
            .and_then(|v| v.as_str())
            .unwrap_or("unknown")
            .to_string();

        let mut artifacts = BTreeMap::new();
        for item in required_arr(&root, "artifacts")? {
            let info = parse_artifact(item)?;
            if artifacts.insert(info.name.clone(), info.clone()).is_some() {
                bail!("duplicate artifact {:?} in manifest", info.name);
            }
        }

        let mut blobs = BTreeMap::new();
        for item in root.get("blobs").and_then(|v| v.as_arr()).unwrap_or(&[]) {
            let name = required_str(item, "name")?.to_string();
            let blob = BlobInfo {
                name: name.clone(),
                file: required_str(item, "file")?.to_string(),
                shape: parse_shape(item.get("shape"))?,
            };
            blobs.insert(name, blob);
        }

        Ok(Manifest { preset, dir: dir.to_path_buf(), artifacts, blobs })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Load a parameter blob as a flat f32 vector.
    pub fn load_blob(&self, name: &str) -> Result<Vec<f32>> {
        let blob = self
            .blobs
            .get(name)
            .ok_or_else(|| anyhow!("blob {name:?} not in manifest"))?;
        let path = self.dir.join(&blob.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expected: usize = blob.shape.iter().product::<usize>() * 4;
        if bytes.len() != expected {
            bail!(
                "blob {name:?}: file has {} bytes, manifest shape {:?} wants {}",
                bytes.len(), blob.shape, expected
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn required_arr<'a>(v: &'a Json, key: &str) -> Result<&'a [Json]> {
    v.get(key)
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("manifest missing array {key:?}"))
}

fn required_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow!("manifest missing string {key:?}"))
}

fn parse_shape(v: Option<&Json>) -> Result<Vec<usize>> {
    let arr = v
        .and_then(|x| x.as_arr())
        .ok_or_else(|| anyhow!("missing shape array"))?;
    arr.iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim {d:?}")))
        .collect()
}

fn parse_spec(v: &Json, with_name: bool) -> Result<Spec> {
    Ok(Spec {
        name: if with_name {
            required_str(v, "name")?.to_string()
        } else {
            String::new()
        },
        dtype: Dtype::parse(required_str(v, "dtype")?)?,
        shape: parse_shape(v.get("shape"))?,
    })
}

fn parse_artifact(v: &Json) -> Result<ArtifactInfo> {
    let name = required_str(v, "name")?.to_string();
    let inputs = required_arr(v, "inputs")?
        .iter()
        .map(|s| parse_spec(s, true))
        .collect::<Result<Vec<_>>>()
        .with_context(|| format!("artifact {name}: inputs"))?;
    let outputs = required_arr(v, "outputs")?
        .iter()
        .map(|s| parse_spec(s, false))
        .collect::<Result<Vec<_>>>()
        .with_context(|| format!("artifact {name}: outputs"))?;
    let meta = match v.get("meta") {
        Some(Json::Obj(m)) => m.clone().into_iter().collect(),
        _ => BTreeMap::new(),
    };
    Ok(ArtifactInfo {
        name,
        file: required_str(v, "file")?.to_string(),
        inputs,
        outputs,
        meta,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "test",
      "artifacts": [
        {"name": "eca_step", "file": "eca_step.hlo.txt",
         "inputs": [
            {"name": "state", "dtype": "f32", "shape": [4, 256]},
            {"name": "rule", "dtype": "f32", "shape": [8]}],
         "outputs": [{"dtype": "f32", "shape": [4, 256]}],
         "meta": {"ca": "eca", "steps": 256}},
        {"name": "t", "file": "t.hlo.txt",
         "inputs": [{"name": "seed", "dtype": "u32", "shape": []}],
         "outputs": [{"dtype": "f32", "shape": []}],
         "meta": {}}
      ],
      "blobs": [
        {"name": "p", "file": "p.bin", "dtype": "f32", "shape": [3]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.preset, "test");
        assert_eq!(m.artifacts.len(), 2);
        let eca = m.artifact("eca_step").unwrap();
        assert_eq!(eca.inputs.len(), 2);
        assert_eq!(eca.inputs[0].name, "state");
        assert_eq!(eca.inputs[0].dtype, Dtype::F32);
        assert_eq!(eca.inputs[0].shape, vec![4, 256]);
        assert_eq!(eca.inputs[0].numel(), 1024);
        assert_eq!(eca.outputs[0].shape, vec![4, 256]);
        assert_eq!(eca.meta_usize("steps"), Some(256));
        assert_eq!(eca.meta_str("ca"), Some("eca"));
        let t = m.artifact("t").unwrap();
        assert_eq!(t.inputs[0].dtype, Dtype::U32);
        assert_eq!(t.inputs[0].shape, Vec::<usize>::new());
    }

    #[test]
    fn missing_artifact_lists_names() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let err = m.artifact("nope").unwrap_err().to_string();
        assert!(err.contains("eca_step"), "{err}");
    }

    #[test]
    fn artifact_path_joins_dir() {
        let m = Manifest::parse(SAMPLE, Path::new("/data/artifacts")).unwrap();
        assert_eq!(
            m.artifact_path("eca_step").unwrap(),
            Path::new("/data/artifacts/eca_step.hlo.txt")
        );
    }

    #[test]
    fn blob_roundtrip() {
        let dir = std::env::temp_dir().join("cax_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let floats: [f32; 3] = [1.5, -2.0, 0.25];
        let mut bytes = Vec::new();
        for f in floats {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        std::fs::write(dir.join("p.bin"), &bytes).unwrap();
        let m = Manifest::parse(SAMPLE, &dir).unwrap();
        assert_eq!(m.load_blob("p").unwrap(), floats.to_vec());
        assert!(m.load_blob("missing").is_err());
        // Truncated file is rejected.
        std::fs::write(dir.join("p.bin"), &bytes[..8]).unwrap();
        assert!(m.load_blob("p").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_documents() {
        assert!(Manifest::parse("{}", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("not json", Path::new("/tmp")).is_err());
        let dup = r#"{"preset":"test","artifacts":[
            {"name":"a","file":"a","inputs":[],"outputs":[],"meta":{}},
            {"name":"a","file":"b","inputs":[],"outputs":[],"meta":{}}
        ],"blobs":[]}"#;
        assert!(Manifest::parse(dup, Path::new("/tmp")).is_err());
        let bad_dtype = r#"{"preset":"t","artifacts":[
            {"name":"a","file":"a","inputs":[{"name":"x","dtype":"f64","shape":[]}],
             "outputs":[],"meta":{}}],"blobs":[]}"#;
        assert!(Manifest::parse(bad_dtype, Path::new("/tmp")).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap().name(), "i32");
        assert!(Dtype::parse("f16").is_err());
    }
}
