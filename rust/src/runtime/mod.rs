//! Runtime: the PJRT bridge. Loads `artifacts/*.hlo.txt` (lowered once by
//! `make artifacts`) and executes them on the CPU PJRT client with typed,
//! manifest-validated signatures. Python is never on this path.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineStats, Value};
pub use manifest::{ArtifactInfo, BlobInfo, Dtype, Manifest, Spec};
