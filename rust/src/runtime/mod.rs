//! Runtime: the PJRT bridge. The [`manifest`] contract (artifact
//! signatures, parameter blobs) is always available; the `engine`
//! module that compiles and executes `artifacts/*.hlo.txt` on a PJRT
//! client is gated behind the off-by-default `pjrt` cargo feature so
//! the default build is hermetic (no XLA runtime, no artifacts, no
//! Python). See `rust/README.md` for the backend feature matrix.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, EngineStats};
pub use manifest::{ArtifactInfo, BlobInfo, Dtype, Manifest, Spec};

// `Value` moved to the backend layer with the pluggable-backend split;
// re-exported here so `cax::runtime::Value` keeps working.
pub use crate::backend::Value;
