//! Metrics: loss histories, throughput counters, CSV/JSON reports.
//!
//! Every trainer/simulator run records into a [`History`]; reports land in
//! `out/` as CSV (for plotting) and JSON (for experiment-report extraction).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};
use crate::util::timer::Stats;

/// A named scalar time series (e.g. per-step training loss).
#[derive(Clone, Debug, Default)]
pub struct History {
    pub name: String,
    steps: Vec<u64>,
    values: Vec<f64>,
}

impl History {
    pub fn new(name: &str) -> History {
        History { name: name.to_string(), ..Default::default() }
    }

    pub fn push(&mut self, step: u64, value: f64) {
        self.steps.push(step);
        self.values.push(value);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn last(&self) -> Option<f64> {
        self.values.last().copied()
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Mean of the first `k` and last `k` values — the improvement summary
    /// used by trainer smoke tests.
    pub fn window_means(&self, k: usize) -> (f64, f64) {
        assert!(!self.is_empty());
        let k = k.min(self.values.len());
        let head: f64 = self.values[..k].iter().sum::<f64>() / k as f64;
        let tail: f64 =
            self.values[self.values.len() - k..].iter().sum::<f64>() / k as f64;
        (head, tail)
    }

    /// Exponential moving average of the series (smoothing for reports).
    pub fn ema(&self, alpha: f64) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.values.len());
        let mut acc = None;
        for &v in &self.values {
            let next = match acc {
                None => v,
                Some(prev) => alpha * v + (1.0 - alpha) * prev,
            };
            out.push(next);
            acc = Some(next);
        }
        out
    }

    /// Write `step,value` CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::from("step,value\n");
        for (s, v) in self.steps.iter().zip(&self.values) {
            out.push_str(&format!("{s},{v}\n"));
        }
        std::fs::write(path, out)
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::from(self.name.as_str())),
            ("steps", Json::Arr(
                self.steps.iter().map(|&s| Json::from(s as usize)).collect(),
            )),
            ("values", Json::Arr(
                self.values.iter().map(|&v| Json::Num(v)).collect(),
            )),
        ])
    }
}

/// Items (cells, steps, requests) per second, guarded against an empty
/// or zero denominator — THE throughput formula. Every surface that
/// prints a rate (`cax sim` cells/sec, `cax serve` steps/sec, the bench
/// rows) divides here instead of rolling its own guard.
pub fn per_second(items: f64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        0.0
    } else {
        items / seconds
    }
}

/// Human-readable rate, e.g. `rate_str(6.4e8, 2.0, "cells")` ->
/// `"3.20e8 cells/s"`.
pub fn rate_str(items: f64, seconds: f64, what: &str) -> String {
    format!("{:.2e} {what}/s", per_second(items, seconds))
}

/// Throughput aggregator: items (cells, steps, requests) per second.
#[derive(Clone, Debug, Default)]
pub struct Throughput {
    items: f64,
    seconds: f64,
}

impl Throughput {
    pub fn record(&mut self, items: f64, seconds: f64) {
        self.items += items;
        self.seconds += seconds;
    }

    pub fn per_second(&self) -> f64 {
        per_second(self.items, self.seconds)
    }

    pub fn total_items(&self) -> f64 {
        self.items
    }
}

/// A benchmark row: label + timing stats + derived throughput.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub label: String,
    pub stats: Stats,
    /// Work items (e.g. cell updates) per iteration, for throughput.
    pub items_per_iter: f64,
}

impl BenchRow {
    pub fn throughput(&self) -> f64 {
        per_second(self.items_per_iter, self.stats.mean)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("label", Json::from(self.label.as_str())),
            ("mean_s", Json::Num(self.stats.mean)),
            ("median_s", Json::Num(self.stats.median)),
            ("p95_s", Json::Num(self.stats.p95)),
            ("p99_s", Json::Num(self.stats.p99)),
            ("n", Json::from(self.stats.n)),
            ("items_per_iter", Json::Num(self.items_per_iter)),
            ("throughput_per_s", Json::Num(self.throughput())),
        ])
    }
}

/// `git describe --always --dirty --tags` of the working tree, if git
/// and a repository are reachable — stamps bench reports with the code
/// revision they measured.
pub fn git_describe() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["describe", "--always", "--dirty", "--tags"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let text = String::from_utf8_lossy(&out.stdout).trim().to_string();
    if text.is_empty() {
        None
    } else {
        Some(text)
    }
}

/// Write a named set of bench rows as a JSON report, stamped with
/// schema metadata (`schema.version`, the bench name, the
/// git-describe string when available and the host thread count) so
/// `BENCH_*.json` files are comparable across revisions.
pub fn write_bench_report(name: &str, rows: &[BenchRow], path: &Path)
                          -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let schema = obj(vec![
        ("version", Json::from(2usize)),
        ("name", Json::from(name)),
        (
            "git",
            match git_describe() {
                Some(g) => Json::from(g.as_str()),
                None => Json::Null,
            },
        ),
        ("threads", Json::from(threads)),
    ]);
    let json = obj(vec![
        ("bench", Json::from(name)),
        ("schema", schema),
        ("rows", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
    ]);
    std::fs::write(path, json.to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    // Every written report also lands in the append-only history
    // ledger next to it, so regressions stay diagnosable across runs.
    if let Err(e) = bench_history::append(path, &json) {
        crate::log_warn!("bench history: {e:#}");
    }
    Ok(())
}

/// The bench-history ledger and the `cax bench compare` regression
/// gate.
///
/// Every [`write_bench_report`] call appends its report as one
/// compact JSONL line (stamped `unix_s`) to `BENCH_history.jsonl`
/// next to the report, so a directory of `BENCH_*.json` files carries
/// its own time series. [`compare`] diffs two reports row by row
/// (matched by `label`, gated on the `median_s` ratio) — the engine
/// behind `cax bench compare --current F --baseline F`.
pub mod bench_history {
    use std::path::{Path, PathBuf};
    use std::time::{SystemTime, UNIX_EPOCH};

    use anyhow::{Context, Result};

    use crate::util::json::{obj, Json};

    /// Ledger filename, kept next to the reports it records.
    pub const HISTORY_FILE: &str = "BENCH_history.jsonl";

    /// Default regression threshold: fail when a row's `median_s`
    /// grows beyond `baseline * (1 + 0.25)`.
    pub const DEFAULT_THRESHOLD: f64 = 0.25;

    /// Where the ledger for a report at `report_path` lives.
    pub fn history_path(report_path: &Path) -> PathBuf {
        match report_path.parent() {
            Some(dir) if !dir.as_os_str().is_empty() => {
                dir.join(HISTORY_FILE)
            }
            _ => PathBuf::from(HISTORY_FILE),
        }
    }

    /// Append one report to the ledger next to it as a single compact
    /// JSONL line stamped with the wall-clock second. Returns the
    /// ledger path.
    pub fn append(report_path: &Path, report: &Json) -> Result<PathBuf> {
        let unix_s = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let line = match report {
            Json::Obj(m) => {
                let mut m = m.clone();
                m.insert("unix_s".to_string(), Json::from(unix_s));
                Json::Obj(m)
            }
            other => obj(vec![
                ("unix_s", Json::from(unix_s)),
                ("report", other.clone()),
            ]),
        };
        let path = history_path(report_path);
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        writeln!(f, "{}", line.to_string_compact())
            .with_context(|| format!("appending {}", path.display()))?;
        Ok(path)
    }

    /// One matched row pair in a comparison.
    #[derive(Clone, Debug)]
    pub struct RowDelta {
        pub label: String,
        pub baseline_s: f64,
        pub current_s: f64,
    }

    impl RowDelta {
        /// Fractional slowdown, `current/baseline - 1` (positive =
        /// slower than baseline).
        pub fn slowdown(&self) -> f64 {
            if self.baseline_s <= 0.0 {
                0.0
            } else {
                self.current_s / self.baseline_s - 1.0
            }
        }
    }

    /// The row-by-row diff of two bench reports.
    #[derive(Clone, Debug, Default)]
    pub struct Comparison {
        pub deltas: Vec<RowDelta>,
        /// Baseline labels absent from the current run — a gate
        /// failure (a silently dropped row is how regressions hide).
        pub missing: Vec<String>,
        /// Current labels with no baseline yet; reported, not gated.
        pub added: Vec<String>,
    }

    impl Comparison {
        pub fn regressions(&self, threshold: f64) -> Vec<&RowDelta> {
            self.deltas
                .iter()
                .filter(|d| d.slowdown() > threshold)
                .collect()
        }

        pub fn passed(&self, threshold: f64) -> bool {
            self.regressions(threshold).is_empty()
                && self.missing.is_empty()
        }
    }

    fn rows_of(report: &Json) -> Vec<(String, f64)> {
        report
            .get("rows")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|r| {
                Some((
                    r.get("label")?.as_str()?.to_string(),
                    r.get("median_s")?.as_f64()?,
                ))
            })
            .collect()
    }

    /// Diff two parsed reports; rows match by `label`.
    pub fn compare(current: &Json, baseline: &Json) -> Comparison {
        let cur = rows_of(current);
        let base = rows_of(baseline);
        let mut cmp = Comparison::default();
        for (label, baseline_s) in &base {
            match cur.iter().find(|(l, _)| l == label) {
                Some((_, current_s)) => cmp.deltas.push(RowDelta {
                    label: label.clone(),
                    baseline_s: *baseline_s,
                    current_s: *current_s,
                }),
                None => cmp.missing.push(label.clone()),
            }
        }
        for (label, _) in &cur {
            if !base.iter().any(|(l, _)| l == label) {
                cmp.added.push(label.clone());
            }
        }
        cmp
    }

    /// [`compare`] over two report files on disk.
    pub fn compare_files(current: &Path, baseline: &Path)
                         -> Result<Comparison> {
        let read = |p: &Path| -> Result<Json> {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading {}", p.display()))?;
            Ok(Json::parse(&text)?)
        };
        Ok(compare(&read(current)?, &read(baseline)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_push_and_windows() {
        let mut h = History::new("loss");
        for i in 0..10u64 {
            h.push(i, 10.0 - i as f64);
        }
        assert_eq!(h.len(), 10);
        assert_eq!(h.last(), Some(1.0));
        let (head, tail) = h.window_means(3);
        assert_eq!(head, 9.0);
        assert_eq!(tail, 2.0);
    }

    #[test]
    fn ema_smooths_monotonically_for_constant() {
        let mut h = History::new("x");
        for i in 0..5u64 {
            h.push(i, 2.0);
        }
        assert!(h.ema(0.3).iter().all(|&v| (v - 2.0).abs() < 1e-12));
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("cax_metrics_test");
        let path = dir.join("loss.csv");
        let mut h = History::new("loss");
        h.push(0, 1.5);
        h.push(10, 0.5);
        h.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,value\n0,1.5\n10,0.5\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn throughput_math() {
        let mut t = Throughput::default();
        t.record(100.0, 2.0);
        t.record(300.0, 2.0);
        assert_eq!(t.per_second(), 100.0);
        assert_eq!(t.total_items(), 400.0);
        assert_eq!(Throughput::default().per_second(), 0.0);
    }

    #[test]
    fn per_second_guards_bad_denominators() {
        assert_eq!(per_second(100.0, 4.0), 25.0);
        assert_eq!(per_second(100.0, 0.0), 0.0);
        assert_eq!(per_second(100.0, -1.0), 0.0);
        assert_eq!(rate_str(6.4e8, 2.0, "cells"), "3.20e8 cells/s");
    }

    #[test]
    fn bench_row_json() {
        let row = BenchRow {
            label: "fused".into(),
            stats: Stats::from_samples(&[0.5, 0.5]),
            items_per_iter: 50.0,
        };
        assert_eq!(row.throughput(), 100.0);
        let json = row.to_json();
        assert_eq!(json.get("label").unwrap().as_str(), Some("fused"));
        assert_eq!(json.get("throughput_per_s").unwrap().as_f64(),
                   Some(100.0));
        assert_eq!(json.get("p99_s").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn bench_report_stamps_schema_metadata() {
        let dir = std::env::temp_dir()
            .join(format!("cax_benchreport_{}", std::process::id()));
        let path = dir.join("BENCH_x.json");
        let rows = vec![BenchRow {
            label: "row".into(),
            stats: Stats::from_samples(&[0.25]),
            items_per_iter: 10.0,
        }];
        write_bench_report("unit_bench", &rows, &path).unwrap();
        let json =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(json.get("bench").unwrap().as_str(), Some("unit_bench"));
        let schema = json.get("schema").unwrap();
        assert_eq!(schema.get("version").unwrap().as_usize(), Some(2));
        assert_eq!(schema.get("name").unwrap().as_str(), Some("unit_bench"));
        assert!(schema.get("threads").unwrap().as_usize().unwrap() >= 1);
        // git may be absent in a bare environment; the key must exist.
        assert!(schema.get("git").is_some());
        let row0 = &json.get("rows").unwrap().as_arr().unwrap()[0];
        assert_eq!(row0.get("p99_s").unwrap().as_f64(), Some(0.25));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_history_ledger_and_compare_gate() {
        let dir = std::env::temp_dir()
            .join(format!("cax_benchhist_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base_path = dir.join("BENCH_base.json");
        let cur_path = dir.join("BENCH_cur.json");
        let rows = |median: f64| {
            vec![BenchRow {
                label: "anchor".into(),
                stats: Stats::from_samples(&[median]),
                items_per_iter: 1.0,
            }]
        };
        write_bench_report("gate", &rows(0.100), &base_path).unwrap();
        write_bench_report("gate", &rows(0.120), &cur_path).unwrap();

        // Both writes appended to the shared ledger, stamped unix_s.
        let hist = std::fs::read_to_string(
            bench_history::history_path(&base_path),
        )
        .unwrap();
        let lines: Vec<&str> = hist.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert!(first.get("unix_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(first.get("bench").unwrap().as_str(), Some("gate"));

        // +20% passes the default gate, fails a tight one.
        let cmp =
            bench_history::compare_files(&cur_path, &base_path).unwrap();
        assert_eq!(cmp.deltas.len(), 1);
        assert!((cmp.deltas[0].slowdown() - 0.2).abs() < 1e-9);
        assert!(cmp.passed(bench_history::DEFAULT_THRESHOLD));
        assert!(!cmp.passed(0.1));
        assert_eq!(cmp.regressions(0.1).len(), 1);

        // A dropped row fails the gate regardless of threshold.
        let dropped = bench_history::compare(
            &Json::parse(r#"{"rows": []}"#).unwrap(),
            &Json::parse(
                r#"{"rows": [{"label": "anchor", "median_s": 0.1}]}"#,
            )
            .unwrap(),
        );
        assert_eq!(dropped.missing, vec!["anchor".to_string()]);
        assert!(!dropped.passed(10.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn history_json_shape() {
        let mut h = History::new("loss");
        h.push(1, 0.25);
        let j = h.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("loss"));
        assert_eq!(j.get("values").unwrap().as_arr().unwrap().len(), 1);
    }
}

/// The pure-Python per-cell baseline measured at build time by
/// `python/compile/pybaseline.py` (the CellPyLib cost model of Fig. 3).
#[derive(Clone, Copy, Debug)]
pub struct PyBaseline {
    /// ECA cell updates per second in pure Python.
    pub eca_updates_per_s: f64,
    /// Game-of-Life cell updates per second in pure Python.
    pub life_updates_per_s: f64,
}

/// Load `<artifacts>/py_baseline.json` if the build produced it.
pub fn read_py_baseline(artifacts_dir: &Path) -> Option<PyBaseline> {
    let text =
        std::fs::read_to_string(artifacts_dir.join("py_baseline.json")).ok()?;
    let json = Json::parse(&text).ok()?;
    Some(PyBaseline {
        eca_updates_per_s: json.get("eca_updates_per_s")?.as_f64()?,
        life_updates_per_s: json.get("life_updates_per_s")?.as_f64()?,
    })
}

#[cfg(test)]
mod py_baseline_tests {
    use super::*;

    #[test]
    fn parses_build_output_format() {
        let dir = std::env::temp_dir()
            .join(format!("cax_pybl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("py_baseline.json"),
            r#"{"eca_updates_per_s": 2.1e6, "life_updates_per_s": 1.8e6}"#,
        )
        .unwrap();
        let b = read_py_baseline(&dir).unwrap();
        assert!(b.eca_updates_per_s > 2e6 && b.life_updates_per_s > 1e6);
        std::fs::remove_dir_all(&dir).ok();
        assert!(read_py_baseline(std::path::Path::new("/nonexistent"))
            .is_none());
    }
}
