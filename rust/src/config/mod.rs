//! Experiment configuration: typed configs loadable from TOML files
//! (`configs/*.toml`), with CLI-friendly defaults.
//!
//! The config system covers what the *coordinator* controls (training
//! length, seeds, pool size, output locations, bench iteration counts).
//! Everything baked into the artifacts at lowering time (grid sizes,
//! channels, hyperparameters) is introspected from the manifest instead —
//! one source of truth per layer.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::toml;

/// Top-level runtime configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    /// Where the AOT artifacts live.
    pub artifacts_dir: PathBuf,
    /// Where reports/images/checkpoints are written.
    pub out_dir: PathBuf,
    /// Master seed for all coordinator-side randomness.
    pub seed: u64,
    pub train: TrainSection,
    pub pool: PoolSection,
    pub bench: BenchSection,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainSection {
    pub steps: usize,
    pub log_every: usize,
    /// Checkpoint + loss CSV output on/off.
    pub write_outputs: bool,
}

#[derive(Clone, Debug, PartialEq)]
pub struct PoolSection {
    pub size: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct BenchSection {
    pub warmup_iters: usize,
    pub measure_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("out"),
            seed: 42,
            train: TrainSection { steps: 300, log_every: 25,
                                  write_outputs: true },
            pool: PoolSection { size: 64 },
            bench: BenchSection { warmup_iters: 2, measure_iters: 10 },
        }
    }
}

impl Config {
    /// Load from a TOML file, overlaying the defaults.
    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
            .with_context(|| format!("parsing config {}", path.display()))
    }

    /// Parse from TOML text, overlaying the defaults.
    pub fn from_toml(text: &str) -> Result<Config> {
        let doc = toml::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = Config::default();

        if let Some(v) = doc.get_str("", "artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.get_str("", "out_dir") {
            cfg.out_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.get_usize("", "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_usize("train", "steps") {
            cfg.train.steps = v;
        }
        if let Some(v) = doc.get_usize("train", "log_every") {
            cfg.train.log_every = v;
        }
        if let Some(v) = doc.get_bool("train", "write_outputs") {
            cfg.train.write_outputs = v;
        }
        if let Some(v) = doc.get_usize("pool", "size") {
            cfg.pool.size = v;
        }
        if let Some(v) = doc.get_usize("bench", "warmup_iters") {
            cfg.bench.warmup_iters = v;
        }
        if let Some(v) = doc.get_usize("bench", "measure_iters") {
            cfg.bench.measure_iters = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.train.steps == 0 {
            bail!("train.steps must be positive");
        }
        if self.pool.size == 0 {
            bail!("pool.size must be positive");
        }
        if self.bench.measure_iters == 0 {
            bail!("bench.measure_iters must be positive");
        }
        Ok(())
    }

    /// Resolve the artifacts dir against the environment override
    /// `CAX_ARTIFACTS` (useful for tests and CI).
    pub fn resolved_artifacts_dir(&self) -> PathBuf {
        std::env::var("CAX_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| self.artifacts_dir.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn toml_overlay() {
        let cfg = Config::from_toml(
            r#"
            seed = 7
            out_dir = "results"

            [train]
            steps = 50
            log_every = 5

            [pool]
            size = 16

            [bench]
            measure_iters = 3
            "#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.out_dir, PathBuf::from("results"));
        assert_eq!(cfg.train.steps, 50);
        assert_eq!(cfg.train.log_every, 5);
        assert_eq!(cfg.pool.size, 16);
        assert_eq!(cfg.bench.measure_iters, 3);
        // Unset fields keep defaults.
        assert_eq!(cfg.bench.warmup_iters, 2);
        assert!(cfg.train.write_outputs);
    }

    #[test]
    fn rejects_invalid() {
        assert!(Config::from_toml("[train]\nsteps = 0\n").is_err());
        assert!(Config::from_toml("[pool]\nsize = 0\n").is_err());
        assert!(Config::from_toml("not toml at all").is_err());
    }

    #[test]
    fn empty_toml_is_defaults() {
        assert_eq!(Config::from_toml("").unwrap(), Config::default());
    }
}
