//! Wolfram rule tables for elementary cellular automata.

use anyhow::{bail, Result};

/// An ECA rule: the 8-entry lookup table of a Wolfram rule number.
///
/// `table[i]` is the next state for the neighbourhood pattern with value
/// `i = 4*left + 2*center + right` — the same encoding the Layer-1 Pallas
/// kernel uses, so tables serialize directly into artifact inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WolframRule {
    pub number: u8,
    table: [u8; 8],
}

impl WolframRule {
    pub fn new(number: u8) -> WolframRule {
        let mut table = [0u8; 8];
        for (i, slot) in table.iter_mut().enumerate() {
            *slot = (number >> i) & 1;
        }
        WolframRule { number, table }
    }

    /// Next state for (left, center, right) bits.
    #[inline]
    pub fn apply(&self, left: u8, center: u8, right: u8) -> u8 {
        self.table[(4 * left + 2 * center + right) as usize]
    }

    /// The table as f32s — the artifact input layout.
    pub fn table_f32(&self) -> [f32; 8] {
        let mut out = [0.0f32; 8];
        for (o, &t) in out.iter_mut().zip(&self.table) {
            *o = t as f32;
        }
        out
    }

    /// Parse from a decimal string (CLI surface).
    pub fn parse(text: &str) -> Result<WolframRule> {
        match text.trim().parse::<u16>() {
            Ok(n) if n <= 255 => Ok(WolframRule::new(n as u8)),
            _ => bail!("invalid Wolfram rule number {text:?} (want 0-255)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_110_table() {
        let r = WolframRule::new(110);
        // 110 = 0b01101110
        let expected = [0, 1, 1, 1, 0, 1, 1, 0];
        for (i, &e) in expected.iter().enumerate() {
            let (l, c, rr) = ((i >> 2) as u8 & 1, (i >> 1) as u8 & 1, i as u8 & 1);
            assert_eq!(r.apply(l, c, rr), e, "pattern {i}");
        }
    }

    #[test]
    fn rule_0_and_255() {
        let zero = WolframRule::new(0);
        let all = WolframRule::new(255);
        for i in 0..8u8 {
            let (l, c, r) = (i >> 2 & 1, i >> 1 & 1, i & 1);
            assert_eq!(zero.apply(l, c, r), 0);
            assert_eq!(all.apply(l, c, r), 1);
        }
    }

    #[test]
    fn rule_204_is_identity() {
        let r = WolframRule::new(204);
        for i in 0..8u8 {
            let (l, c, rr) = (i >> 2 & 1, i >> 1 & 1, i & 1);
            assert_eq!(r.apply(l, c, rr), c);
        }
    }

    #[test]
    fn table_f32_matches() {
        let r = WolframRule::new(30);
        let t = r.table_f32();
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], 1.0); // 30 = 0b00011110
        assert_eq!(t[4], 1.0);
        assert_eq!(t[5], 0.0);
    }

    #[test]
    fn parse_validates() {
        assert_eq!(WolframRule::parse("110").unwrap().number, 110);
        assert!(WolframRule::parse("256").is_err());
        assert!(WolframRule::parse("x").is_err());
    }
}
