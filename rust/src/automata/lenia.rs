//! Naive Lenia simulator: direct ring-kernel convolution, per-cell loops.
//!
//! Semantics match the `lenia_*` artifacts (same ring kernel, growth
//! mapping, clip) up to float accumulation order — integration tests allow
//! 1e-4. Quadratic per-step cost in kernel size: exactly the cost profile a
//! non-FFT CPU implementation has, which is the baseline story of Fig. 3
//! extended to continuous CA.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Lenia world parameters (Chan 2019).
#[derive(Clone, Copy, Debug)]
pub struct LeniaParams {
    pub radius: usize,
    pub mu: f32,
    pub sigma: f32,
    pub dt: f32,
}

impl Default for LeniaParams {
    fn default() -> Self {
        LeniaParams { radius: 10, mu: 0.15, sigma: 0.017, dt: 0.1 }
    }
}

/// The standard Lenia ring kernel, normalized to sum 1 — identical to
/// `kernels/lenia.py::ring_kernel`.
pub fn ring_kernel(radius: usize) -> Tensor {
    let size = 2 * radius + 1;
    let mut data = vec![0.0f32; size * size];
    let mut sum = 0.0f64;
    for y in 0..size {
        for x in 0..size {
            let dy = y as f64 - radius as f64;
            let dx = x as f64 - radius as f64;
            let r = (dx * dx + dy * dy).sqrt() / radius as f64;
            if r > 0.0 && r < 1.0 {
                let v = (4.0 - 1.0 / (r * (1.0 - r)).max(1e-9)).exp();
                data[y * size + x] = v as f32;
                sum += v;
            }
        }
    }
    for v in &mut data {
        *v = (*v as f64 / sum) as f32;
    }
    Tensor::new(vec![size, size], data).unwrap()
}

/// Single-board continuous CA in [0,1].
#[derive(Clone, Debug)]
pub struct LeniaSim {
    pub params: LeniaParams,
    kernel: Tensor,
    state: Tensor, // [H, W]
}

impl LeniaSim {
    pub fn new(params: LeniaParams, state: Tensor) -> LeniaSim {
        assert_eq!(state.shape().len(), 2, "LeniaSim wants [H, W]");
        LeniaSim { kernel: ring_kernel(params.radius), params, state }
    }

    /// Random soup in a centred patch (a standard Lenia starting condition).
    pub fn random_patch(params: LeniaParams, size: usize, patch: usize,
                        rng: &mut Rng) -> LeniaSim {
        let mut state = Tensor::zeros(&[size, size]);
        let start = (size - patch) / 2;
        for y in start..start + patch {
            for x in start..start + patch {
                state.set(&[y, x], rng.next_f32());
            }
        }
        LeniaSim::new(params, state)
    }

    pub fn state(&self) -> &Tensor {
        &self.state
    }

    /// One step: direct convolution + growth + clip (naive hot loop).
    pub fn step(&mut self) {
        let (h, w) = (self.state.shape()[0], self.state.shape()[1]);
        let r = self.params.radius;
        let ksz = 2 * r + 1;
        let mut next = Tensor::zeros(&[h, w]);
        for y in 0..h {
            for x in 0..w {
                let mut u = 0.0f32;
                for ky in 0..ksz {
                    for kx in 0..ksz {
                        let sy = (y + h + r - ky) % h;
                        let sx = (x + w + r - kx) % w;
                        u += self.kernel.at(&[ky, kx])
                            * self.state.at(&[sy, sx]);
                    }
                }
                let z = (u - self.params.mu) / self.params.sigma;
                let growth = 2.0 * (-0.5 * z * z).exp() - 1.0;
                let v = self.state.at(&[y, x]) + self.params.dt * growth;
                next.set(&[y, x], v.clamp(0.0, 1.0));
            }
        }
        self.state = next;
    }

    pub fn run(&mut self, steps: usize) {
        for _ in 0..steps {
            self.step();
        }
    }

    /// Total mass (sum of the field) — Lenia's standard health metric.
    pub fn mass(&self) -> f32 {
        self.state.data().iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_kernel_normalized_and_hollow() {
        for r in [3usize, 5, 10] {
            let k = ring_kernel(r);
            let sum: f32 = k.data().iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "sum {sum}");
            assert_eq!(k.at(&[r, r]), 0.0, "centre must be 0");
            assert!(k.data().iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn empty_world_stays_empty_enough() {
        // u = 0 everywhere -> growth = 2*exp(-mu^2/(2 sigma^2)) - 1 ~ -1,
        // so an empty world stays clamped at 0.
        let mut sim = LeniaSim::new(LeniaParams::default(),
                                    Tensor::zeros(&[32, 32]));
        sim.run(3);
        assert_eq!(sim.mass(), 0.0);
    }

    #[test]
    fn state_stays_in_unit_interval() {
        let mut rng = Rng::new(5);
        let mut sim = LeniaSim::random_patch(
            LeniaParams { radius: 4, ..Default::default() }, 24, 12, &mut rng,
        );
        sim.run(5);
        for &v in sim.state().data() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn saturated_world_decays() {
        // u ~ 1 >> mu -> growth ~ -1 -> mass must fall.
        let mut sim = LeniaSim::new(
            LeniaParams { radius: 4, ..Default::default() },
            Tensor::full(&[24, 24], 1.0),
        );
        let m0 = sim.mass();
        sim.step();
        assert!(sim.mass() < m0);
    }

    #[test]
    fn convolution_is_translation_equivariant() {
        let params = LeniaParams { radius: 3, ..Default::default() };
        let mut a = Tensor::zeros(&[16, 16]);
        a.set(&[4, 4], 0.8);
        a.set(&[5, 5], 0.6);
        let mut sim_a = LeniaSim::new(params, a.clone());
        // Shift the input by (2, 3) with wrap.
        let mut b = Tensor::zeros(&[16, 16]);
        for y in 0..16 {
            for x in 0..16 {
                b.set(&[(y + 2) % 16, (x + 3) % 16], a.at(&[y, x]));
            }
        }
        let mut sim_b = LeniaSim::new(params, b);
        sim_a.step();
        sim_b.step();
        for y in 0..16 {
            for x in 0..16 {
                let va = sim_a.state().at(&[y, x]);
                let vb = sim_b.state().at(&[(y + 2) % 16, (x + 3) % 16]);
                assert!((va - vb).abs() < 1e-6);
            }
        }
    }
}
