//! Naive Lenia simulator: direct ring-kernel convolution, per-cell loops.
//!
//! Semantics match the `lenia_*` artifacts (same ring kernel, growth
//! mapping, clip) up to float accumulation order — integration tests allow
//! 1e-4. Quadratic per-step cost in kernel size: exactly the cost profile a
//! non-FFT CPU implementation has, which is the baseline story of Fig. 3
//! extended to continuous CA.
//!
//! Besides the classic single-channel [`LeniaSim`], this module defines
//! the generalized multi-channel / multi-kernel [`LeniaWorld`] (the
//! Flow-Lenia-style parameter space) together with its scalar reference
//! step — the oracle the spectral path in
//! [`crate::backend::native::lenia`] is differentially tested against.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Lenia world parameters (Chan 2019).
#[derive(Clone, Copy, Debug)]
pub struct LeniaParams {
    pub radius: usize,
    pub mu: f32,
    pub sigma: f32,
    pub dt: f32,
}

impl Default for LeniaParams {
    fn default() -> Self {
        LeniaParams { radius: 10, mu: 0.15, sigma: 0.017, dt: 0.1 }
    }
}

/// The Lenia growth mapping: a Gaussian bump over the neighborhood
/// potential `u`, rescaled to `[-1, 1]`. One shared definition keeps the
/// naive oracle, the sparse-tap kernel and the spectral path bit-identical
/// in the growth stage (they may still differ in how they compute `u`).
#[inline(always)]
pub fn growth(u: f32, mu: f32, sigma: f32) -> f32 {
    let z = (u - mu) / sigma;
    2.0 * (-0.5 * z * z).exp() - 1.0
}

/// The standard Lenia ring kernel, normalized to sum 1 — identical to
/// `kernels/lenia.py::ring_kernel`.
pub fn ring_kernel(radius: usize) -> Tensor {
    let size = 2 * radius + 1;
    let mut data = vec![0.0f32; size * size];
    let mut sum = 0.0f64;
    for y in 0..size {
        for x in 0..size {
            let dy = y as f64 - radius as f64;
            let dx = x as f64 - radius as f64;
            let r = (dx * dx + dy * dy).sqrt() / radius as f64;
            if r > 0.0 && r < 1.0 {
                let v = (4.0 - 1.0 / (r * (1.0 - r)).max(1e-9)).exp();
                data[y * size + x] = v as f32;
                sum += v;
            }
        }
    }
    for v in &mut data {
        *v = (*v as f64 / sum) as f32;
    }
    Tensor::new(vec![size, size], data).unwrap()
}

/// Single-board continuous CA in [0,1].
#[derive(Clone, Debug)]
pub struct LeniaSim {
    pub params: LeniaParams,
    kernel: Tensor,
    state: Tensor, // [H, W]
}

impl LeniaSim {
    pub fn new(params: LeniaParams, state: Tensor) -> LeniaSim {
        assert_eq!(state.shape().len(), 2, "LeniaSim wants [H, W]");
        LeniaSim { kernel: ring_kernel(params.radius), params, state }
    }

    /// Random soup in a centred patch (a standard Lenia starting condition).
    pub fn random_patch(params: LeniaParams, size: usize, patch: usize,
                        rng: &mut Rng) -> LeniaSim {
        let mut state = Tensor::zeros(&[size, size]);
        let start = (size - patch) / 2;
        for y in start..start + patch {
            for x in start..start + patch {
                state.set(&[y, x], rng.next_f32());
            }
        }
        LeniaSim::new(params, state)
    }

    pub fn state(&self) -> &Tensor {
        &self.state
    }

    /// One step: direct convolution + growth + clip (naive hot loop).
    pub fn step(&mut self) {
        let (h, w) = (self.state.shape()[0], self.state.shape()[1]);
        let r = self.params.radius;
        let ksz = 2 * r + 1;
        let mut next = Tensor::zeros(&[h, w]);
        for y in 0..h {
            for x in 0..w {
                let mut u = 0.0f32;
                for ky in 0..ksz {
                    for kx in 0..ksz {
                        let sy = (y + h + r - ky) % h;
                        let sx = (x + w + r - kx) % w;
                        u += self.kernel.at(&[ky, kx])
                            * self.state.at(&[sy, sx]);
                    }
                }
                let g = growth(u, self.params.mu, self.params.sigma);
                let v = self.state.at(&[y, x]) + self.params.dt * g;
                next.set(&[y, x], v.clamp(0.0, 1.0));
            }
        }
        self.state = next;
    }

    pub fn run(&mut self, steps: usize) {
        for _ in 0..steps {
            self.step();
        }
    }

    /// Total mass (sum of the field) — Lenia's standard health metric.
    pub fn mass(&self) -> f32 {
        self.state.data().iter().sum()
    }
}

// ------------------------------------------- multi-channel / multi-kernel

/// One convolution kernel of a [`LeniaWorld`]: a ring kernel of its own
/// radius reading one source channel, with a per-kernel growth mapping
/// and a row of the channel-mixing weight matrix.
#[derive(Clone, Debug)]
pub struct KernelSpec {
    /// Channel this kernel convolves (`< world.channels`).
    pub src: usize,
    /// Ring-kernel radius (cells); must be `>= 2` — radius 1 has no
    /// cells strictly inside the ring.
    pub radius: usize,
    /// Growth centre.
    pub mu: f32,
    /// Growth width.
    pub sigma: f32,
    /// Channel-mixing weights: `weights[c]` scales this kernel's growth
    /// in channel `c`'s update (one row of the `K x C` mixing matrix).
    pub weights: Vec<f32>,
}

/// Multi-channel, multi-kernel Lenia (the Flow-Lenia-style parameter
/// space): `C` fields on one torus, `K` ring kernels each reading a
/// source channel, per-kernel growth, and a `K x C` weight matrix mixing
/// the growths into every channel's update:
///
/// ```text
/// u_k      = ring(radius_k) * state[src_k]          (circular conv)
/// g_k      = growth(u_k, mu_k, sigma_k)
/// state[c] = clip(state[c] + dt * sum_k weights[k][c] * g_k, 0, 1)
/// ```
///
/// [`LeniaWorld::single`] embeds the classic [`LeniaParams`] case as
/// `C = 1, K = 1, weights = [1.0]` — every path that accepts a world
/// reproduces the single-kernel behavior exactly on that embedding.
#[derive(Clone, Debug)]
pub struct LeniaWorld {
    /// Number of state channels (fields on the torus).
    pub channels: usize,
    /// Shared integration step.
    pub dt: f32,
    /// The kernels, applied in order (growth accumulation is k-major,
    /// so results are deterministic).
    pub kernels: Vec<KernelSpec>,
}

impl LeniaWorld {
    /// The classic single-channel world for `params` — the `1 x 1`
    /// default every multi-kernel path must reproduce exactly.
    pub fn single(params: LeniaParams) -> LeniaWorld {
        LeniaWorld {
            channels: 1,
            dt: params.dt,
            kernels: vec![KernelSpec {
                src: 0,
                radius: params.radius,
                mu: params.mu,
                sigma: params.sigma,
                weights: vec![1.0],
            }],
        }
    }

    /// A deterministic K-kernel demo world for the CLI: one channel for
    /// `K = 1`, two cross-mixed channels otherwise, growth centres and
    /// widths spread smoothly over the kernels (the smooth-growth regime,
    /// where trajectories are well-conditioned). Per-channel incoming
    /// weight is normalized to 1 so `dt` keeps its single-kernel meaning.
    pub fn demo(kernels: usize, radius: usize) -> LeniaWorld {
        assert!(kernels >= 1, "LeniaWorld::demo: need at least one kernel");
        let channels = if kernels == 1 { 1 } else { 2 };
        let mut specs = Vec::with_capacity(kernels);
        for k in 0..kernels {
            let own = k % channels;
            let mut weights = vec![0.0f32; channels];
            if channels == 1 {
                weights[0] = 1.0;
            } else {
                // Feed mostly the *other* channel so the demo world
                // actually exercises channel mixing.
                weights[own] = 0.3;
                weights[(own + 1) % channels] = 0.7;
            }
            let t = k as f32 / kernels as f32;
            specs.push(KernelSpec {
                src: own,
                radius,
                mu: 0.25 + 0.10 * t,
                sigma: 0.09 + 0.04 * t,
                weights,
            });
        }
        let mut incoming = vec![0.0f32; channels];
        for spec in &specs {
            for (acc, w) in incoming.iter_mut().zip(&spec.weights) {
                *acc += w.abs();
            }
        }
        for spec in &mut specs {
            for (w, &total) in spec.weights.iter_mut().zip(&incoming) {
                if total > 0.0 {
                    *w /= total;
                }
            }
        }
        LeniaWorld { channels, dt: 0.1, kernels: specs }
    }

    /// Largest kernel radius (the board-size lower bound).
    pub fn max_radius(&self) -> usize {
        self.kernels.iter().map(|k| k.radius).max().unwrap_or(0)
    }

    /// Structural validation: non-empty, channels wired consistently,
    /// radii usable. Shape-vs-board checks live in
    /// [`crate::backend::validate_state`].
    pub fn validate(&self) -> Result<()> {
        if self.channels == 0 {
            bail!("LeniaWorld: zero channels");
        }
        if self.kernels.is_empty() {
            bail!("LeniaWorld: no kernels");
        }
        for (k, spec) in self.kernels.iter().enumerate() {
            if spec.src >= self.channels {
                bail!(
                    "LeniaWorld: kernel {k} reads channel {} but the world \
                     has {} channels",
                    spec.src,
                    self.channels
                );
            }
            if spec.weights.len() != self.channels {
                bail!(
                    "LeniaWorld: kernel {k} carries {} mixing weights for \
                     {} channels",
                    spec.weights.len(),
                    self.channels
                );
            }
            if spec.radius < 2 {
                bail!(
                    "LeniaWorld: kernel {k} radius {} < 2 (the ring kernel \
                     is empty below radius 2)",
                    spec.radius
                );
            }
        }
        Ok(())
    }

    /// One scalar-reference step on a `[C, H, W]` board held as a
    /// row-major slice — direct convolution, per-cell loops, f32
    /// accumulation. This is the oracle the spectral path is tested
    /// against; it is deliberately simple, not fast.
    pub fn step_naive(&self, state: &[f32], next: &mut [f32], h: usize,
                      w: usize) {
        let hw = h * w;
        assert_eq!(state.len(), self.channels * hw);
        assert_eq!(next.len(), self.channels * hw);
        // Per-kernel growth fields first (kernels may share channels).
        let mut growths = vec![0.0f32; self.kernels.len() * hw];
        for (k, spec) in self.kernels.iter().enumerate() {
            let kernel = ring_kernel(spec.radius);
            let r = spec.radius;
            let ksz = 2 * r + 1;
            let src = &state[spec.src * hw..(spec.src + 1) * hw];
            let g = &mut growths[k * hw..(k + 1) * hw];
            for y in 0..h {
                for x in 0..w {
                    let mut u = 0.0f32;
                    for ky in 0..ksz {
                        for kx in 0..ksz {
                            let sy = (y + h + r - ky) % h;
                            let sx = (x + w + r - kx) % w;
                            u += kernel.at(&[ky, kx]) * src[sy * w + sx];
                        }
                    }
                    g[y * w + x] = growth(u, spec.mu, spec.sigma);
                }
            }
        }
        for c in 0..self.channels {
            for i in 0..hw {
                let mut acc = 0.0f32;
                for (k, spec) in self.kernels.iter().enumerate() {
                    acc += spec.weights[c] * growths[k * hw + i];
                }
                next[c * hw + i] =
                    (state[c * hw + i] + self.dt * acc).clamp(0.0, 1.0);
            }
        }
    }

    /// Run `steps` scalar-reference updates in place on one `[C, H, W]`
    /// board.
    pub fn rollout_naive(&self, board: &mut [f32], h: usize, w: usize,
                         steps: usize) {
        let mut scratch = vec![0.0f32; board.len()];
        for _ in 0..steps {
            self.step_naive(board, &mut scratch, h, w);
            board.copy_from_slice(&scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_kernel_normalized_and_hollow() {
        for r in [3usize, 5, 10] {
            let k = ring_kernel(r);
            let sum: f32 = k.data().iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "sum {sum}");
            assert_eq!(k.at(&[r, r]), 0.0, "centre must be 0");
            assert!(k.data().iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn empty_world_stays_empty_enough() {
        // u = 0 everywhere -> growth = 2*exp(-mu^2/(2 sigma^2)) - 1 ~ -1,
        // so an empty world stays clamped at 0.
        let mut sim = LeniaSim::new(LeniaParams::default(),
                                    Tensor::zeros(&[32, 32]));
        sim.run(3);
        assert_eq!(sim.mass(), 0.0);
    }

    #[test]
    fn state_stays_in_unit_interval() {
        let mut rng = Rng::new(5);
        let mut sim = LeniaSim::random_patch(
            LeniaParams { radius: 4, ..Default::default() }, 24, 12, &mut rng,
        );
        sim.run(5);
        for &v in sim.state().data() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn saturated_world_decays() {
        // u ~ 1 >> mu -> growth ~ -1 -> mass must fall.
        let mut sim = LeniaSim::new(
            LeniaParams { radius: 4, ..Default::default() },
            Tensor::full(&[24, 24], 1.0),
        );
        let m0 = sim.mass();
        sim.step();
        assert!(sim.mass() < m0);
    }

    #[test]
    fn world_single_step_naive_is_bit_exact_with_lenia_sim() {
        // The 1x1 world's scalar reference walks the same taps in the
        // same order with the same growth/update math as LeniaSim, so
        // it must agree bit for bit.
        let params = LeniaParams { radius: 4, ..Default::default() };
        let mut rng = Rng::new(0x5111);
        let mut sim = LeniaSim::random_patch(params, 24, 12, &mut rng);
        let world = LeniaWorld::single(params);
        let mut board = sim.state().data().to_vec();
        world.rollout_naive(&mut board, 24, 24, 3);
        sim.run(3);
        for (i, (&a, &b)) in
            board.iter().zip(sim.state().data()).enumerate()
        {
            assert!(a.to_bits() == b.to_bits(),
                    "cell {i}: world {a} != sim {b}");
        }
    }

    #[test]
    fn world_validate_rejects_bad_wiring() {
        let params = LeniaParams::default();
        assert!(LeniaWorld::single(params).validate().is_ok());
        let mut world = LeniaWorld::single(params);
        world.kernels[0].src = 3;
        assert!(world.validate().is_err(), "src out of range");
        let mut world = LeniaWorld::single(params);
        world.kernels[0].weights = vec![1.0, 0.5];
        assert!(world.validate().is_err(), "weight row length");
        let mut world = LeniaWorld::single(params);
        world.kernels[0].radius = 1;
        assert!(world.validate().is_err(), "radius 1 ring is empty");
        let mut world = LeniaWorld::single(params);
        world.kernels.clear();
        assert!(world.validate().is_err(), "no kernels");
    }

    #[test]
    fn demo_worlds_are_valid_and_normalized() {
        for k in 1..=4 {
            let world = LeniaWorld::demo(k, 6);
            world.validate().unwrap();
            assert_eq!(world.kernels.len(), k);
            assert_eq!(world.channels, if k == 1 { 1 } else { 2 });
            assert_eq!(world.max_radius(), 6);
            // Every channel's incoming |weight| sums to ~1.
            for c in 0..world.channels {
                let total: f32 = world
                    .kernels
                    .iter()
                    .map(|s| s.weights[c].abs())
                    .sum();
                assert!((total - 1.0).abs() < 1e-6,
                        "k={k} channel {c} incoming {total}");
            }
        }
    }

    #[test]
    fn world_step_keeps_unit_interval_and_mixes_channels() {
        let world = LeniaWorld::demo(2, 3);
        let (h, w) = (16, 16);
        let mut rng = Rng::new(0x2C7);
        let mut board = rng.vec_f32(world.channels * h * w);
        let before = board.clone();
        world.rollout_naive(&mut board, h, w, 2);
        assert!(board.iter().all(|v| (0.0..=1.0).contains(v)));
        assert!(board != before, "world should evolve");
    }

    #[test]
    fn convolution_is_translation_equivariant() {
        let params = LeniaParams { radius: 3, ..Default::default() };
        let mut a = Tensor::zeros(&[16, 16]);
        a.set(&[4, 4], 0.8);
        a.set(&[5, 5], 0.6);
        let mut sim_a = LeniaSim::new(params, a.clone());
        // Shift the input by (2, 3) with wrap.
        let mut b = Tensor::zeros(&[16, 16]);
        for y in 0..16 {
            for x in 0..16 {
                b.set(&[(y + 2) % 16, (x + 3) % 16], a.at(&[y, x]));
            }
        }
        let mut sim_b = LeniaSim::new(params, b);
        sim_a.step();
        sim_b.step();
        for y in 0..16 {
            for x in 0..16 {
                let va = sim_a.state().at(&[y, x]);
                let vb = sim_b.state().at(&[(y + 2) % 16, (x + 3) % 16]);
                assert!((va - vb).abs() < 1e-6);
            }
        }
    }
}
