//! Naive Game-of-Life simulator (Moore neighbourhood, periodic boundary).
//!
//! Same semantics as the `life_*` artifacts; per-cell scalar loops — the
//! Figure-3 baseline and bit-exactness oracle for 2D.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Batched Life over {0,1} boards.
#[derive(Clone, Debug)]
pub struct LifeSim {
    boards: Vec<Vec<u8>>, // row-major H*W per batch element
    pub height: usize,
    pub width: usize,
}

impl LifeSim {
    pub fn from_tensor(state: &Tensor) -> LifeSim {
        assert_eq!(state.shape().len(), 3, "LifeSim wants [B, H, W]");
        let (b, h, w) = (state.shape()[0], state.shape()[1], state.shape()[2]);
        let boards = (0..b)
            .map(|i| {
                let mut board = Vec::with_capacity(h * w);
                for y in 0..h {
                    for x in 0..w {
                        board.push((state.at(&[i, y, x]) > 0.5) as u8);
                    }
                }
                board
            })
            .collect();
        LifeSim { boards, height: h, width: w }
    }

    pub fn random(batch: usize, height: usize, width: usize, density: f32,
                  rng: &mut Rng) -> LifeSim {
        let boards = (0..batch)
            .map(|_| {
                (0..height * width)
                    .map(|_| rng.bernoulli(density) as u8)
                    .collect()
            })
            .collect();
        LifeSim { boards, height, width }
    }

    /// Empty boards with a glider in the top-left of each.
    pub fn gliders(batch: usize, height: usize, width: usize) -> LifeSim {
        assert!(height >= 5 && width >= 5);
        let mut sim = LifeSim {
            boards: vec![vec![0u8; height * width]; batch],
            height,
            width,
        };
        let cells = [(0usize, 1usize), (1, 2), (2, 0), (2, 1), (2, 2)];
        for board in &mut sim.boards {
            for &(y, x) in &cells {
                board[(y + 1) * width + (x + 1)] = 1;
            }
        }
        sim
    }

    pub fn batch(&self) -> usize {
        self.boards.len()
    }

    /// One step: per-cell neighbour count (the naive hot loop).
    pub fn step(&mut self) {
        let (h, w) = (self.height, self.width);
        for board in &mut self.boards {
            let prev = board.clone();
            for y in 0..h {
                for x in 0..w {
                    let mut n = 0u8;
                    for dy in [h - 1, 0, 1] {
                        for dx in [w - 1, 0, 1] {
                            if dy == 0 && dx == 0 {
                                continue;
                            }
                            n += prev[((y + dy) % h) * w + (x + dx) % w];
                        }
                    }
                    let alive = prev[y * w + x] == 1;
                    board[y * w + x] =
                        ((alive && (n == 2 || n == 3)) || (!alive && n == 3))
                            as u8;
                }
            }
        }
    }

    pub fn run(&mut self, steps: usize) {
        for _ in 0..steps {
            self.step();
        }
    }

    pub fn to_tensor(&self) -> Tensor {
        let (b, h, w) = (self.batch(), self.height, self.width);
        let mut data = Vec::with_capacity(b * h * w);
        for board in &self.boards {
            data.extend(board.iter().map(|&bit| bit as f32));
        }
        Tensor::new(vec![b, h, w], data).unwrap()
    }

    pub fn population(&self) -> usize {
        self.boards
            .iter()
            .map(|b| b.iter().map(|&x| x as usize).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board_from(cells: &[(usize, usize)], h: usize, w: usize) -> Tensor {
        let mut t = Tensor::zeros(&[1, h, w]);
        for &(y, x) in cells {
            t.set(&[0, y, x], 1.0);
        }
        t
    }

    #[test]
    fn block_is_still_life() {
        let t = board_from(&[(3, 3), (3, 4), (4, 3), (4, 4)], 8, 8);
        let mut sim = LifeSim::from_tensor(&t);
        sim.run(3);
        assert!(sim.to_tensor().bit_eq(&t));
    }

    #[test]
    fn blinker_period_two() {
        let t = board_from(&[(4, 3), (4, 4), (4, 5)], 9, 9);
        let mut sim = LifeSim::from_tensor(&t);
        sim.step();
        assert!(!sim.to_tensor().bit_eq(&t));
        sim.step();
        assert!(sim.to_tensor().bit_eq(&t));
    }

    #[test]
    fn glider_moves_diagonally() {
        let mut sim = LifeSim::gliders(1, 16, 16);
        let before = sim.to_tensor();
        sim.run(4);
        let after = sim.to_tensor();
        assert_eq!(sim.population(), 5);
        // After 4 steps the glider pattern translates by (1, 1).
        for y in 0..16 {
            for x in 0..16 {
                assert_eq!(
                    after.at(&[0, (y + 1) % 16, (x + 1) % 16]),
                    before.at(&[0, y, x]),
                );
            }
        }
    }

    #[test]
    fn underpopulation_dies() {
        let t = board_from(&[(2, 2)], 6, 6);
        let mut sim = LifeSim::from_tensor(&t);
        sim.step();
        assert_eq!(sim.population(), 0);
    }

    #[test]
    fn wraps_periodically() {
        // Blinker straddling the edge: cells at x = {7, 0, 1} on row 4.
        let t = board_from(&[(4, 7), (4, 0), (4, 1)], 9, 8);
        let mut sim = LifeSim::from_tensor(&t);
        sim.step();
        sim.step();
        assert!(sim.to_tensor().bit_eq(&t));
    }

    #[test]
    fn batch_elements_independent() {
        let mut rng = Rng::new(9);
        let mut sim = LifeSim::random(3, 12, 12, 0.4, &mut rng);
        let solo: Vec<LifeSim> = (0..3)
            .map(|i| {
                LifeSim::from_tensor(
                    &Tensor::stack(&[sim.to_tensor().index_axis0(i)]).unwrap(),
                )
            })
            .collect();
        sim.run(5);
        for (i, mut s) in solo.into_iter().enumerate() {
            s.run(5);
            assert!(
                s.to_tensor().index_axis0(0)
                    .bit_eq(&sim.to_tensor().index_axis0(i)),
                "batch element {i} diverged"
            );
        }
    }
}
