//! Naive per-cell CA simulators — the CellPyLib-role baseline substrate.
//!
//! These implement the *same semantics* as the XLA artifacts (periodic
//! boundaries, identical rule encodings) with deliberately straightforward
//! per-cell scalar loops and per-step dispatch. They serve two purposes:
//!
//! 1. **Figure-3 baseline** (E1/E2): the cost structure of a conventional
//!    CPU CA library, against which the fused XLA rollouts are measured.
//! 2. **Correctness oracle**: integration tests require the XLA ECA/Life
//!    artifacts to match these bit-exactly over random states and rules,
//!    closing the loop across all three layers.

pub mod eca;
pub mod lenia;
pub mod life;
pub mod rule;

pub use eca::EcaSim;
pub use lenia::LeniaSim;
pub use life::LifeSim;
pub use rule::WolframRule;
