//! Naive elementary-CA simulator (periodic boundary).
//!
//! Semantics identical to the `eca_*` artifacts; deliberately per-cell
//! scalar code — this is the Figure-3 baseline and the bit-exactness oracle.

use crate::automata::rule::WolframRule;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Batched 1D CA over {0,1} states stored as f32 for interchange parity.
#[derive(Clone, Debug)]
pub struct EcaSim {
    pub rule: WolframRule,
    /// Current state bits, one row per batch element.
    rows: Vec<Vec<u8>>,
}

impl EcaSim {
    /// Start from an explicit f32 {0,1} batch tensor [B, W].
    pub fn from_tensor(rule: WolframRule, state: &Tensor) -> EcaSim {
        assert_eq!(state.shape().len(), 2, "EcaSim wants [B, W]");
        let (b, w) = (state.shape()[0], state.shape()[1]);
        let rows = (0..b)
            .map(|i| {
                (0..w)
                    .map(|j| if state.at(&[i, j]) > 0.5 { 1u8 } else { 0u8 })
                    .collect()
            })
            .collect();
        EcaSim { rule, rows }
    }

    /// Random initial condition with density 0.5.
    pub fn random(rule: WolframRule, batch: usize, width: usize,
                  rng: &mut Rng) -> EcaSim {
        let rows = (0..batch)
            .map(|_| (0..width).map(|_| rng.bernoulli(0.5) as u8).collect())
            .collect();
        EcaSim { rule, rows }
    }

    /// Single-cell-seed initial condition (the classic rule-30/110 picture).
    pub fn single_seed(rule: WolframRule, batch: usize, width: usize) -> EcaSim {
        let mut rows = vec![vec![0u8; width]; batch];
        for row in &mut rows {
            row[width / 2] = 1;
        }
        EcaSim { rule, rows }
    }

    pub fn batch(&self) -> usize {
        self.rows.len()
    }

    pub fn width(&self) -> usize {
        self.rows.first().map_or(0, |r| r.len())
    }

    /// One global-rule application, per cell (the naive hot loop).
    pub fn step(&mut self) {
        for row in &mut self.rows {
            let w = row.len();
            let prev = row.clone();
            for x in 0..w {
                let left = prev[(x + w - 1) % w];
                let right = prev[(x + 1) % w];
                row[x] = self.rule.apply(left, prev[x], right);
            }
        }
    }

    /// Run `steps` applications.
    pub fn run(&mut self, steps: usize) {
        for _ in 0..steps {
            self.step();
        }
    }

    /// Current state as the artifact-layout tensor [B, W].
    pub fn to_tensor(&self) -> Tensor {
        let (b, w) = (self.batch(), self.width());
        let mut data = Vec::with_capacity(b * w);
        for row in &self.rows {
            data.extend(row.iter().map(|&bit| bit as f32));
        }
        Tensor::new(vec![b, w], data).unwrap()
    }

    /// Space-time diagram of batch element `i`: runs `steps`, returning
    /// [steps+1, W] including the initial row.
    pub fn spacetime(&mut self, i: usize, steps: usize) -> Tensor {
        let w = self.width();
        let mut data = Vec::with_capacity((steps + 1) * w);
        data.extend(self.rows[i].iter().map(|&b| b as f32));
        for _ in 0..steps {
            self.step();
            data.extend(self.rows[i].iter().map(|&b| b as f32));
        }
        Tensor::new(vec![steps + 1, w], data).unwrap()
    }

    /// Population (number of live cells) across the batch.
    pub fn population(&self) -> usize {
        self.rows.iter().map(|r| r.iter().map(|&b| b as usize).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule0_extinguishes() {
        let mut rng = Rng::new(1);
        let mut sim = EcaSim::random(WolframRule::new(0), 2, 32, &mut rng);
        sim.step();
        assert_eq!(sim.population(), 0);
    }

    #[test]
    fn rule204_is_static() {
        let mut rng = Rng::new(2);
        let mut sim = EcaSim::random(WolframRule::new(204), 2, 32, &mut rng);
        let before = sim.to_tensor();
        sim.run(5);
        assert!(before.bit_eq(&sim.to_tensor()));
    }

    #[test]
    fn rule30_single_seed_growth() {
        // After t steps the light cone spans at most 2t+1 cells and rule 30
        // keeps the centre column alive.
        let mut sim = EcaSim::single_seed(WolframRule::new(30), 1, 64);
        sim.run(4);
        let t = sim.to_tensor();
        assert!(sim.population() > 1);
        for x in 0..64usize {
            let dist = (x as i64 - 32).unsigned_abs() as usize;
            if dist > 4 {
                assert_eq!(t.at(&[0, x]), 0.0, "outside light cone at {x}");
            }
        }
        assert_eq!(t.at(&[0, 32]), 1.0);
    }

    #[test]
    fn wraps_periodically() {
        // Rule 2: cell becomes 1 iff pattern 001 (right neighbour alive).
        // A live cell at x=0 must light x=W-1 through the wrap.
        let mut state = Tensor::zeros(&[1, 8]);
        state.set(&[0, 0], 1.0);
        let mut sim = EcaSim::from_tensor(WolframRule::new(2), &state);
        sim.step();
        let t = sim.to_tensor();
        assert_eq!(t.at(&[0, 7]), 1.0);
        assert_eq!(t.at(&[0, 0]), 0.0);
    }

    #[test]
    fn tensor_roundtrip() {
        let mut rng = Rng::new(3);
        let sim = EcaSim::random(WolframRule::new(110), 3, 16, &mut rng);
        let t = sim.to_tensor();
        let sim2 = EcaSim::from_tensor(WolframRule::new(110), &t);
        assert!(t.bit_eq(&sim2.to_tensor()));
    }

    #[test]
    fn spacetime_shape_and_first_row() {
        let mut sim = EcaSim::single_seed(WolframRule::new(90), 1, 16);
        let first = sim.to_tensor();
        let st = sim.spacetime(0, 10);
        assert_eq!(st.shape(), &[11, 16]);
        for x in 0..16 {
            assert_eq!(st.at(&[0, x]), first.at(&[0, x]));
        }
    }
}
