//! Dataset substrates: synthetic digit corpus, 1D-ARC task generators,
//! procedural RGBA target sprites. All deterministic from a `u64` seed.
//! See rust/README.md for the paper-data -> synthetic-data substitutions.

pub mod arc1d;
pub mod mnist;
pub mod targets;
