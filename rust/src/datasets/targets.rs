//! Procedural RGBA target sprites (the emoji-role substitution, DESIGN.md §3).
//!
//! The growing/conditional/diffusing NCAs need RGBA targets with an alpha
//! mask; Fig. 5's damage protocol additionally needs an appendage to
//! amputate. `lizard` is a gecko-like body + tail + legs blob; `heart` and
//! `square` round out the conditional-NCA goal set.

use crate::tensor::Tensor;

/// Available sprites.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sprite {
    Lizard,
    Heart,
    Square,
}

impl Sprite {
    pub const ALL: [Sprite; 3] = [Sprite::Lizard, Sprite::Heart, Sprite::Square];

    pub fn name(&self) -> &'static str {
        match self {
            Sprite::Lizard => "lizard",
            Sprite::Heart => "heart",
            Sprite::Square => "square",
        }
    }

    pub fn parse(name: &str) -> Option<Sprite> {
        Sprite::ALL.iter().copied().find(|s| s.name() == name)
    }

    /// Render as RGBA f32[H, W, 4]; alpha in {0, 1}, premultiplied colors.
    pub fn render(&self, h: usize, w: usize) -> Tensor {
        let mut t = Tensor::zeros(&[h, w, 4]);
        let set_px = |t: &mut Tensor, y: usize, x: usize, rgb: [f32; 3]| {
            if y < h && x < w {
                t.set(&[y, x, 0], rgb[0]);
                t.set(&[y, x, 1], rgb[1]);
                t.set(&[y, x, 2], rgb[2]);
                t.set(&[y, x, 3], 1.0);
            }
        };
        let (cy, cx) = (h as f32 / 2.0, w as f32 / 2.0);
        match self {
            Sprite::Lizard => {
                let green = [0.30, 0.65, 0.25];
                let dark = [0.18, 0.42, 0.16];
                // Body: ellipse in the upper-left 2/3.
                let (by, bx) = (cy - h as f32 * 0.08, cx - w as f32 * 0.08);
                let (ry, rx) = (h as f32 * 0.18, w as f32 * 0.26);
                for y in 0..h {
                    for x in 0..w {
                        let dy = (y as f32 - by) / ry;
                        let dx = (x as f32 - bx) / rx;
                        if dy * dy + dx * dx <= 1.0 {
                            set_px(&mut t, y, x, green);
                        }
                    }
                }
                // Head: smaller disc at the body's left end.
                let (hy, hx) = (by - ry * 0.4, bx - rx * 1.05);
                let hr = h as f32 * 0.10;
                for y in 0..h {
                    for x in 0..w {
                        let dy = y as f32 - hy;
                        let dx = x as f32 - hx;
                        if dy * dy + dx * dx <= hr * hr {
                            set_px(&mut t, y, x, green);
                        }
                    }
                }
                // Tail: tapering diagonal strip to the lower-right corner —
                // the appendage Fig. 5 amputates.
                let steps = (w as f32 * 0.45) as usize;
                for i in 0..steps {
                    let frac = i as f32 / steps as f32;
                    let y = by + ry * 0.5 + frac * (h as f32 * 0.32);
                    let x = bx + rx * 0.8 + frac * (w as f32 * 0.38);
                    let thick = (2.5 * (1.0 - frac) + 0.7) as usize;
                    for dy in 0..=thick {
                        for dx in 0..=thick {
                            set_px(&mut t, y as usize + dy, x as usize + dx,
                                   dark);
                        }
                    }
                }
                // Legs: four short stubs.
                for (sy, sx) in [(-0.9f32, -0.5f32), (-0.9, 0.5),
                                 (0.9, -0.5), (0.9, 0.5)] {
                    let ly = by + sy * ry;
                    let lx = bx + sx * rx;
                    for i in 0..(h / 10).max(2) {
                        set_px(
                            &mut t,
                            (ly + sy.signum() * i as f32) as usize,
                            lx as usize,
                            dark,
                        );
                    }
                }
            }
            Sprite::Heart => {
                let red = [0.85, 0.15, 0.25];
                // Implicit heart curve: (x^2 + y^2 - 1)^3 - x^2 y^3 <= 0.
                for y in 0..h {
                    for x in 0..w {
                        let fx = (x as f32 - cx) / (w as f32 * 0.3);
                        let fy = -(y as f32 - cy) / (h as f32 * 0.3);
                        let a = fx * fx + fy * fy - 1.0;
                        if a * a * a - fx * fx * fy * fy * fy <= 0.0 {
                            set_px(&mut t, y, x, red);
                        }
                    }
                }
            }
            Sprite::Square => {
                let blue = [0.2, 0.35, 0.8];
                let side = (h.min(w) as f32 * 0.5) as usize;
                let y0 = (h - side) / 2;
                let x0 = (w - side) / 2;
                for y in y0..y0 + side {
                    for x in x0..x0 + side {
                        set_px(&mut t, y, x, blue);
                    }
                }
            }
        }
        t
    }
}

/// Cut a rectangular region out of an RGBA or NCA-state tensor [H, W, C]
/// (the Fig. 5 damage protocol): zero all channels inside the rectangle.
pub fn damage_rect(state: &mut Tensor, y0: usize, x0: usize, dy: usize,
                   dx: usize) {
    let shape = state.shape().to_vec();
    assert_eq!(shape.len(), 3, "damage_rect wants [H, W, C]");
    let (h, w, c) = (shape[0], shape[1], shape[2]);
    for y in y0..(y0 + dy).min(h) {
        for x in x0..(x0 + dx).min(w) {
            for ch in 0..c {
                state.set(&[y, x, ch], 0.0);
            }
        }
    }
}

/// Zero everything in the lower-right quadrant beyond the given fractions —
/// "cut the tail of the gecko" (paper Fig. 5).
pub fn amputate_tail(state: &mut Tensor) {
    let shape = state.shape().to_vec();
    let (h, w) = (shape[0], shape[1]);
    damage_rect(state, h * 3 / 5, w * 3 / 5, h, w);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sprites_render_with_alpha() {
        for sprite in Sprite::ALL {
            let t = sprite.render(32, 32);
            assert_eq!(t.shape(), &[32, 32, 4]);
            let alive: usize = (0..32 * 32)
                .filter(|&i| t.data()[i * 4 + 3] > 0.5)
                .count();
            assert!(alive > 40, "{} too small: {alive}", sprite.name());
            assert!(alive < 32 * 32 / 2, "{} fills the grid", sprite.name());
        }
    }

    #[test]
    fn alpha_is_binary_and_rgb_masked() {
        let t = Sprite::Heart.render(24, 24);
        for i in 0..24 * 24 {
            let a = t.data()[i * 4 + 3];
            assert!(a == 0.0 || a == 1.0);
            if a == 0.0 {
                assert_eq!(t.data()[i * 4], 0.0);
            }
        }
    }

    #[test]
    fn lizard_has_tail_in_lower_right() {
        let t = Sprite::Lizard.render(40, 40);
        let mut tail = 0;
        for y in 26..40 {
            for x in 26..40 {
                if t.at(&[y, x, 3]) > 0.5 {
                    tail += 1;
                }
            }
        }
        assert!(tail > 5, "no tail to amputate ({tail} px)");
    }

    #[test]
    fn parse_roundtrip() {
        for s in Sprite::ALL {
            assert_eq!(Sprite::parse(s.name()), Some(s));
        }
        assert_eq!(Sprite::parse("dragon"), None);
    }

    #[test]
    fn damage_zeroes_rectangle() {
        let mut t = Tensor::full(&[8, 8, 3], 1.0);
        damage_rect(&mut t, 2, 3, 2, 2);
        assert_eq!(t.at(&[2, 3, 0]), 0.0);
        assert_eq!(t.at(&[3, 4, 2]), 0.0);
        assert_eq!(t.at(&[1, 3, 0]), 1.0);
        assert_eq!(t.at(&[4, 3, 0]), 1.0);
        // Out-of-range damage clips safely.
        damage_rect(&mut t, 7, 7, 5, 5);
        assert_eq!(t.at(&[7, 7, 0]), 0.0);
    }

    #[test]
    fn amputation_removes_tail_pixels() {
        let mut t = Sprite::Lizard.render(40, 40);
        let before: f32 = t.data().iter().sum();
        amputate_tail(&mut t);
        let after: f32 = t.data().iter().sum();
        assert!(after < before, "amputation removed nothing");
        for y in 24..40 {
            for x in 24..40 {
                assert_eq!(t.at(&[y, x, 3]), 0.0);
            }
        }
    }
}
